"""Tuned-config registry: persistence round-trip, fastest-wins record
semantics, the dispatch consult tier (registry sits between explicit
config and the VMEM heuristic), fail-loud behavior on malformed files,
and the mtime-checked cache refresh."""
import dataclasses
import json

import pytest

from repro.configs import get_dfa_config
from repro.kernels import dispatch
from repro.kernels import tuning

ENV_VAR = "REPRO_TUNING_REGISTRY"


@pytest.fixture
def cfg(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR, raising=False)
    monkeypatch.delenv(dispatch.INGEST_ENV_VAR, raising=False)
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    return get_dfa_config(reduced=True)


def _write(path, reg):
    reg.save(str(path))
    return str(path)


# -- registry object ---------------------------------------------------------

def test_roundtrip(tmp_path):
    reg = tuning.TuningRegistry()
    assert reg.record("ingest_update.variant", "interpret", [4096], "hbm",
                      812.4, source="ingest_scaling")
    assert reg.record("ingest_update.event_tile", "interpret", (4096,),
                      128, 700.0)
    assert reg.record("gather_enrich.variant", "pallas",
                      (131072, 4, 512, 24), "full", 55.0)
    p = _write(tmp_path / "t.json", reg)
    back = tuning.TuningRegistry.load(p)
    assert back.lookup("ingest_update.variant", "interpret",
                       (4096,)) == "hbm"
    assert back.lookup("ingest_update.event_tile", "interpret",
                       [4096]) == 128
    assert back.lookup("gather_enrich.variant", "pallas",
                       (131072, 4, 512, 24)) == "full"
    # exact-match only: other shape / other backend -> None
    assert back.lookup("ingest_update.variant", "interpret",
                       (8192,)) is None
    assert back.lookup("ingest_update.variant", "pallas",
                       (4096,)) is None
    # file is valid JSON with the schema marker
    doc = json.loads(open(p).read())
    assert doc["schema"] == tuning.SCHEMA
    assert len(doc["entries"]) == 3


def test_record_fastest_wins():
    reg = tuning.TuningRegistry()
    assert reg.record("ingest_update.event_tile", "ref", (256,), 64, 100.0)
    # slower measurement for the same key is rejected
    assert not reg.record("ingest_update.event_tile", "ref", (256,),
                          256, 150.0)
    assert reg.lookup("ingest_update.event_tile", "ref", (256,)) == 64
    # faster one replaces
    assert reg.record("ingest_update.event_tile", "ref", (256,), 128, 80.0)
    assert reg.lookup("ingest_update.event_tile", "ref", (256,)) == 128


def test_unknown_knob_and_bad_value_fail_loud(tmp_path):
    reg = tuning.TuningRegistry()
    with pytest.raises(ValueError, match="unknown tuning knob"):
        reg.record("gather_enrich.warp_count", "ref", (1,), 4, 1.0)
    with pytest.raises(ValueError, match="unknown tuning knob"):
        reg.lookup("nope", "ref", (1,))
    with pytest.raises(TypeError, match="str or int"):
        reg.record("ingest_update.variant", "ref", (1,), 1.5, 1.0)
    # schema mismatch refuses to load
    p = tmp_path / "bad_schema.json"
    p.write_text(json.dumps({"schema": "other-v9", "entries": []}))
    with pytest.raises(ValueError, match="schema"):
        tuning.TuningRegistry.load(str(p))
    # a corrupt entry names its index
    p2 = tmp_path / "bad_entry.json"
    p2.write_text(json.dumps({
        "schema": tuning.SCHEMA,
        "entries": [{"knob": "ingest_update.variant", "backend": "ref",
                     "key": [1], "value": "hbm", "us_per_call": 1.0},
                    {"knob": "ingest_update.variant", "backend": "ref",
                     "key": [2]}]}))
    with pytest.raises(ValueError, match="bad tuning entry #1"):
        tuning.TuningRegistry.load(str(p2))


def test_cache_refreshes_on_mtime_change(tmp_path):
    import os
    reg = tuning.TuningRegistry()
    reg.record("ingest_update.event_tile", "ref", (64,), 32, 5.0)
    p = _write(tmp_path / "c.json", reg)
    assert tuning.load_cached(p).lookup(
        "ingest_update.event_tile", "ref", (64,)) == 32
    reg.record("ingest_update.event_tile", "ref", (64,), 16, 1.0)
    reg.save(p)
    os.utime(p, (1, 1))            # force a distinct mtime either way
    assert tuning.load_cached(p).lookup(
        "ingest_update.event_tile", "ref", (64,)) == 16


# -- dispatch consult tier ---------------------------------------------------

def test_resolve_path_precedence(cfg, monkeypatch, tmp_path):
    assert tuning.resolve_path(cfg) is None             # off by default
    c = dataclasses.replace(cfg, tuning_registry="/cfg/path.json")
    assert tuning.resolve_path(c) == "/cfg/path.json"
    monkeypatch.setenv(ENV_VAR, "/env/path.json")
    assert tuning.resolve_path(c) == "/env/path.json"   # env beats cfg
    monkeypatch.setenv(ENV_VAR, "")                     # empty = unset
    assert tuning.resolve_path(c) == "/cfg/path.json"


def test_tiles_consult_registry(cfg, tmp_path):
    # unarmed: static config defaults
    assert dispatch.resolve_event_tile(cfg, 4096) == cfg.event_tile
    assert dispatch.resolve_report_tile(cfg, 1024) == cfg.flow_tile
    reg = tuning.TuningRegistry()
    reg.record("ingest_update.event_tile", "ref", (4096,), 128, 1.0)
    reg.record("gather_enrich.report_tile", "ref", (1024,), 64, 1.0)
    p = _write(tmp_path / "t.json", reg)
    c = dataclasses.replace(cfg, tuning_registry=p)
    # cfg resolves backend "ref" on CPU -> entries match
    assert dispatch.resolve_event_tile(c, 4096) == 128
    assert dispatch.resolve_report_tile(c, 1024) == 64
    # no measurement for this shape -> fall back to the static default
    assert dispatch.resolve_event_tile(c, 8192) == cfg.event_tile
    # a tuned tile measured under a different backend must not apply
    ci = dataclasses.replace(c, kernel_backend="interpret")
    assert dispatch.resolve_event_tile(ci, 4096) == cfg.event_tile


def test_variant_consult_sits_inside_heuristic_tier(cfg, monkeypatch,
                                                    tmp_path):
    F, H, RT, D = 131072, cfg.history, 512, cfg.derived_dim
    base = dispatch.resolve_gather_variant(None, cfg, F, H, RT, D)
    flipped = "hbm" if base == "full" else "full"
    reg = tuning.TuningRegistry()
    reg.record("gather_enrich.variant", "ref", (F, H, RT, D), flipped, 1.0)
    reg.record("ingest_update.variant", "ref", (1 << 20,), "block", 1.0)
    p = _write(tmp_path / "t.json", reg)
    c = dataclasses.replace(cfg, tuning_registry=p)
    # the registry overrides the VMEM heuristic...
    assert dispatch.resolve_gather_variant(None, c, F, H, RT, D) == flipped
    assert dispatch.resolve_ingest_variant(None, c, 1 << 20, 256) == "block"
    # ...but loses to an explicit cfg attr, env var, and argument
    c_attr = dataclasses.replace(c, gather_variant=base)
    assert dispatch.resolve_gather_variant(None, c_attr, F, H, RT, D) == base
    monkeypatch.setenv(dispatch.GATHER_ENV_VAR, base)
    assert dispatch.resolve_gather_variant(None, c, F, H, RT, D) == base
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR)
    assert dispatch.resolve_gather_variant(base, c, F, H, RT, D) == base
    # no measurement for another shape -> heuristic untouched
    assert dispatch.resolve_gather_variant(None, c, F // 2, H, RT, D) == \
        dispatch.resolve_gather_variant(None, cfg, F // 2, H, RT, D)


def test_armed_but_broken_registry_fails_loud(cfg, tmp_path):
    p = tmp_path / "junk.json"
    p.write_text("{not json")
    c = dataclasses.replace(cfg, tuning_registry=str(p))
    with pytest.raises(json.JSONDecodeError):
        dispatch.resolve_event_tile(c, 4096)
    missing = dataclasses.replace(
        cfg, tuning_registry=str(tmp_path / "absent.json"))
    with pytest.raises(FileNotFoundError):
        dispatch.resolve_event_tile(missing, 4096)
    # a tuned tile < 1 is a corrupt file, not a silent fallback
    reg = tuning.TuningRegistry()
    reg.record("ingest_update.event_tile", "ref", (4096,), 0, 1.0)
    bad = _write(tmp_path / "zero.json", reg)
    cz = dataclasses.replace(cfg, tuning_registry=bad)
    with pytest.raises(ValueError, match=">= 1"):
        dispatch.resolve_event_tile(cz, 4096)
    # a tuned variant outside the registered choices is rejected
    doc = {"schema": tuning.SCHEMA,
           "entries": [{"knob": "ingest_update.variant", "backend": "ref",
                        "key": [4096], "value": "warp", "us_per_call": 1.0}]}
    pv = tmp_path / "variant.json"
    pv.write_text(json.dumps(doc))
    cv = dataclasses.replace(cfg, tuning_registry=str(pv))
    with pytest.raises(ValueError):
        dispatch.resolve_ingest_variant(None, cv, 4096, 256)
