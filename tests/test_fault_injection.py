"""Lossy-transport fault injection: exact-accounting differentials.

The injector (``repro.data.faults``) perturbs the collector-facing
payload stream AFTER translation and BEFORE ring ingest — the RDMA
segment of §III-B — and the pipeline's three defense layers must account
for every injected fault exactly, per period, with no silent absorption
and no double counting:

    bad_checksum   == injected_flips                  (Fig 4 checksum)
    seq_anomalies  == injected_dups + injected_replays  (§VI-B window)
    lost_reports   == injected_drops + injected_flips   (seq-gap tracker;
                      a corrupted report is a lost report that arrived)

Beyond the counters, the suite proves the *state* story:

* reversible faults (duplicate / stale replay / bounded reorder) leave
  the merged end state and every period's enriched output BITWISE equal
  to the clean run — the §VI-B rejection really is first-arrival-wins;
* lossy faults (drop / bit-flip) leave the state equal to the clean run
  with exactly the victim ring cells zeroed — reconstructed from the
  injector's per-row fault ledger, nothing else may differ;
* an unarmed spec compiles the whole fault path out (config describe
  says "none", metrics carry no injected_* keys).

``test_fault_smoke_end_to_end`` is the CI fault-smoke anchor (selected
by ``-k fault_smoke``, deselected from tier-1's default run).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import pod_mesh_or_skip
from repro.configs.dfa import REDUCED
from repro.core.pipeline import DFASystem
from repro.data import faults as FAULTS
from repro.data import scenarios as SC
from repro.data.faults import FaultSpec

TOTAL_PORTS = 4
EVENTS_PER_PORT = 48
T = 3
G = 512
REPORTER_SLOTS = 64
PORT_CAPACITY = 16

MIXED = FaultSpec(seed=7, drop_rate=0.15, dup_rate=0.1, flip_rate=0.1,
                  replay_rate=0.05, reorder_rate=0.3, reorder_window=4)
REVERSIBLE = FaultSpec(seed=11, dup_rate=0.2, replay_rate=0.1,
                       reorder_rate=0.5, reorder_window=4)
LOSSY = FaultSpec(seed=13, drop_rate=0.2, flip_rate=0.15)

_systems = {}
_traces = {}


def _mesh_cfg(pods, shards, spec, wire="v1"):
    ndev = pods * shards
    return dataclasses.replace(
        REDUCED,
        flow_home="hash",
        wire_format=wire,
        pods=pods,
        ports_per_pod=TOTAL_PORTS // pods,
        reporter_slots=REPORTER_SLOTS,
        flows_per_shard=G // ndev,
        port_report_capacity=PORT_CAPACITY,
        kernel_backend="ref",
        fault_spec=spec)


def _system(pods, shards, spec, wire="v1"):
    key = (pods, shards, spec, wire)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        sysm = DFASystem(_mesh_cfg(pods, shards, spec, wire), mesh)
        _systems[key] = (sysm, jax.jit(sysm.run_periods),
                         jax.jit(sysm.run_periods_overlapped))
    return _systems[key]


def _trace(name):
    if name not in _traces:
        ev, nows = SC.build(name, TOTAL_PORTS, EVENTS_PER_PORT, T)
        _traces[name] = ({k: jnp.asarray(v) for k, v in ev.items()},
                         jnp.asarray(nows))
    return _traces[name]


def _run(pods, shards, spec, scenario, overlapped=False, wire="v1"):
    sysm, seq, ovl = _system(pods, shards, spec, wire)
    events, nows = _trace(scenario)
    with sysm.mesh:
        out = (ovl if overlapped else seq)(sysm.init_state(), events,
                                           nows)
    return (sysm, _merged_state(sysm, out.state),
            _canon_periods(out.enriched, out.flow_ids, out.mask),
            {k: np.asarray(v) for k, v in out.metrics.items()})


def _merged_state(system, state):
    n = system.n_shards
    out = {f"rep.{k}": np.asarray(a)
           for k, a in state.reporter._asdict().items()}
    out["tr.hist_counter"] = np.asarray(state.translator.hist_counter)
    c = state.collector
    out["coll.memory"] = np.asarray(c.memory)
    out["coll.entry_valid"] = np.asarray(c.entry_valid)
    out["coll.last_seq"] = np.asarray(c.last_seq).reshape(n, -1).max(0)
    for k in ("bad_checksum", "seq_anomalies", "received",
              "lost_reports"):
        out[f"coll.{k}"] = np.asarray(getattr(c, k)).astype(
            np.uint64).sum()
    return out


def _canon_periods(enr, fid, em):
    enr, fid, em = np.asarray(enr), np.asarray(fid), np.asarray(em)
    per = []
    for t in range(enr.shape[0]):
        m = em[t]
        order = np.argsort(fid[t][m], kind="stable")
        per.append({"fid": fid[t][m][order], "enr": enr[t][m][order]})
    return per


def _assert_identities(met):
    """The three per-period exact-accounting identities + non-vacuity."""
    np.testing.assert_array_equal(
        met["bad_checksum"], met["injected_flips"],
        err_msg="checksum detections != injected flips")
    np.testing.assert_array_equal(
        met["seq_anomalies"], met["injected_dups"] + met["injected_replays"],
        err_msg="dup-window rejections != injected dups+replays")
    np.testing.assert_array_equal(
        met["lost_reports"], met["injected_drops"] + met["injected_flips"],
        err_msg="seq-gap losses != injected drops+flips")


# -- injector unit behavior ----------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(drop_rate=1.5)
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(flip_rate=-0.1)
    with pytest.raises(ValueError, match="sum"):
        FaultSpec(drop_rate=0.5, dup_rate=0.4, flip_rate=0.3)
    with pytest.raises(ValueError, match="reorder_window"):
        FaultSpec(reorder_rate=0.5, reorder_window=1)
    assert not FaultSpec().armed
    assert FaultSpec().describe() == "none"
    assert FaultSpec(reorder_rate=0.1).armed
    assert not FaultSpec(reorder_rate=0.1).appends_copies
    assert FaultSpec(dup_rate=0.1).appends_copies
    s = MIXED.describe()
    assert s.startswith("seed=7,") and "drop_rate=0.15" in s


def test_blockwise_permutation_bounded():
    """Rows only ever move within their reorder_window block — the
    displacement bound that makes reorder-only runs bitwise clean."""
    R, W = 64, 4
    perm = np.asarray(FAULTS._blockwise_permutation(
        jax.random.key(3), R, W, 1.0))
    assert sorted(perm.tolist()) == list(range(R))
    np.testing.assert_array_equal(perm // W, np.arange(R) // W)
    assert (perm != np.arange(R)).any(), "rate=1.0 never shuffled"
    ident = np.asarray(FAULTS._blockwise_permutation(
        jax.random.key(3), R, W, 0.0))
    np.testing.assert_array_equal(ident, np.arange(R))


def test_inject_deterministic():
    """Same (spec, period, salt) => identical schedule; different salt
    (device) => independent schedule."""
    from repro.core import wire as WIRE
    wf = WIRE.get("v1")
    rng = np.random.default_rng(5)
    R, W = 32, wf.payload_words
    pay = jnp.asarray(rng.integers(0, 1 << 16, (R, W)), dtype=jnp.uint32)
    mask = jnp.asarray(rng.random(R) < 0.9)
    args = (pay, mask, MIXED, wf)
    now, salt = jnp.uint32(100), jnp.uint32(0)
    a = FAULTS.inject(*args, now, salt)
    b = FAULTS.inject(*args, now, salt)
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    c = FAULTS.inject(*args, now, jnp.uint32(1))
    assert any((np.asarray(xa) != np.asarray(xc)).any()
               for xa, xc in zip(jax.tree.leaves(a), jax.tree.leaves(c)))


def test_unarmed_spec_compiles_out():
    """An all-zero spec must be indistinguishable from no spec: the
    pipeline's fault branch is skipped at trace time and the metrics
    carry no injected_* keys — the zero-cost-when-unconfigured contract."""
    sysm, seq, _ = _system(1, 2, FaultSpec())
    assert sysm.fault_spec is None
    assert sysm.describe()["fault_injection"] == "none"
    events, nows = _trace("port_local")
    with sysm.mesh:
        out = seq(sysm.init_state(), events, nows)
    assert not any(k in out.metrics for k in FAULTS.COUNT_KEYS)
    assert not any(k in out.metrics for k in FAULTS.LEDGER_KEYS)
    for k in ("bad_checksum", "seq_anomalies", "lost_reports"):
        assert int(np.asarray(out.metrics[k]).sum()) == 0, k


# -- end-to-end exact accounting -----------------------------------------

@pytest.mark.parametrize("overlapped", [False, True],
                         ids=["seq", "ovl"])
@pytest.mark.parametrize("wire", ["v1", "v2"])
def test_fault_identities_end_to_end(wire, overlapped):
    """Mixed fault schedule on a (2,2) pod mesh: every defense layer
    accounts for its fault class exactly, per period, on both drivers
    and both wire formats."""
    _, _, _, met = _run(2, 2, MIXED, "cross_pod_mix",
                        overlapped=overlapped, wire=wire)
    assert int(met["injected_drops"].sum()) > 0
    assert int(met["injected_dups"].sum()) > 0
    assert int(met["injected_flips"].sum()) > 0
    assert int(met["injected_replays"].sum()) > 0
    assert int(met["injected_reorders"].sum()) > 0
    _assert_identities(met)


def test_reversible_faults_bitwise_clean():
    """Duplicate + replay + reorder only: the §VI-B window rejects every
    copy before placement, so the merged end state and every period's
    enriched output are BITWISE identical to the clean run — the only
    trace left is the anomaly counter."""
    _, cst, cper, cmet = _run(2, 2, None, "cross_pod_mix")
    _, fst, fper, fmet = _run(2, 2, REVERSIBLE, "cross_pod_mix")
    injected = int((fmet["injected_dups"]
                    + fmet["injected_replays"]).sum())
    assert injected > 0 and int(fmet["injected_reorders"].sum()) > 0
    for k in cst:
        if k == "coll.seq_anomalies":
            assert int(fst[k]) == int(cst[k]) + injected
        else:
            np.testing.assert_array_equal(cst[k], fst[k],
                                          err_msg=f"state {k}")
    for t, (c, f) in enumerate(zip(cper, fper)):
        for k in c:
            np.testing.assert_array_equal(
                c[k], f[k], err_msg=f"period {t} {k}")
    for k in cmet:
        if k != "seq_anomalies":
            np.testing.assert_array_equal(cmet[k], fmet[k],
                                          err_msg=f"metric {k}")


def test_lossy_faults_state_equals_clean_minus_victims():
    """Drop + flip only: the faulted end state must equal the clean run
    with EXACTLY the victim ring cells zeroed — reconstructed from the
    injector's fault ledger. Anything else differing means a fault
    leaked past its defense; anything less means silent absorption."""
    sysm, cst, _, cmet = _run(2, 2, None, "cross_pod_mix")
    _, fst, _, fmet = _run(2, 2, LOSSY, "cross_pod_mix")
    kind = fmet["fault_kind"]
    drops = int(fmet["injected_drops"].sum())
    flips = int(fmet["injected_flips"].sum())
    assert drops > 0 and flips > 0
    _assert_identities(fmet)
    # expected state: clean, with every ledgered victim cell vacated
    exp_mem = cst["coll.memory"].copy()
    exp_val = cst["coll.entry_valid"].copy()
    victims = 0
    for t in range(kind.shape[0]):
        hit = (kind[t] == FAULTS.KIND_DROP) | (kind[t] == FAULTS.KIND_FLIP)
        for f, h in zip(fmet["fault_flow"][t][hit],
                        fmet["fault_hist"][t][hit]):
            exp_mem[int(f), int(h), :] = 0
            exp_val[int(f), int(h)] = False
            victims += 1
    assert victims == drops + flips, "ledger disagrees with counts"
    np.testing.assert_array_equal(fst["coll.memory"], exp_mem)
    np.testing.assert_array_equal(fst["coll.entry_valid"], exp_val)
    # seq continuity survives the losses (victims are never a reporter's
    # batch tail, so the window still advances past them)
    np.testing.assert_array_equal(fst["coll.last_seq"],
                                  cst["coll.last_seq"])
    assert int(fst["coll.received"]) == int(cst["coll.received"]) \
        - drops - flips
    assert int(fst["coll.lost_reports"]) == drops + flips
    assert int(fst["coll.bad_checksum"]) == int(cst["coll.bad_checksum"]) \
        + flips
    # reporter/translator state is upstream of the injection point:
    # bitwise untouched by construction
    for k in cst:
        if k.startswith(("rep.", "tr.")):
            np.testing.assert_array_equal(cst[k], fst[k],
                                          err_msg=f"state {k}")


def test_fault_smoke_end_to_end():
    """CI fault-smoke anchor (``-k fault_smoke``): one mixed-schedule
    run on the smallest pod mesh, identities exact, injection visible in
    describe()."""
    sysm, _, _, met = _run(1, 2, MIXED, "port_local")
    assert sysm.describe()["fault_injection"].startswith("seed=7,")
    assert int(sum(met[k].sum() for k in FAULTS.COUNT_KEYS)) > 0
    _assert_identities(met)


# -- randomized fault schedules (hypothesis; the deterministic sweep
#    below still runs when hypothesis is absent) --------------------------

SWEEP_SPECS = (
    FaultSpec(seed=0, drop_rate=0.3),
    FaultSpec(seed=1, flip_rate=0.25, reorder_rate=0.5),
    FaultSpec(seed=2, dup_rate=0.3, replay_rate=0.2),
    FaultSpec(seed=3, drop_rate=0.1, dup_rate=0.1, flip_rate=0.1,
              replay_rate=0.1, reorder_rate=0.2, reorder_window=8),
)
SWEEP_MESHES = ((1, 2), (2, 2))


def _sweep_case(spec, mesh, scenario):
    _, _, _, met = _run(*mesh, spec, scenario)
    assert int(sum(met[k].sum() for k in FAULTS.COUNT_KEYS)) > 0, \
        "schedule injected nothing — vacuous case"
    _assert_identities(met)


@pytest.mark.parametrize("spec", SWEEP_SPECS,
                         ids=[s.describe() for s in SWEEP_SPECS])
def test_fault_schedule_sweep_deterministic(spec):
    """Every fault-class mix keeps the identities exact on both mesh
    shapes (each FaultSpec is jit-static: the sweep is deliberately a
    small fixed grid — one compile per (spec, mesh))."""
    for mesh in SWEEP_MESHES:
        _sweep_case(spec, mesh, "port_local")


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(
        seed=st.integers(0, 3),
        spec_idx=st.integers(0, len(SWEEP_SPECS) - 1),
        mesh=st.sampled_from(SWEEP_MESHES),
        scenario=st.sampled_from(["port_local", "cross_pod_mix"]),
    )
    def test_fault_schedule_sweep_randomized(seed, spec_idx, mesh,
                                             scenario):
        """Randomized (seed x mix x mesh x scenario) draws of the same
        contract. Seeds stay in a small set on purpose: spec.seed is
        trace-time static, so every new seed is a fresh SPMD compile."""
        spec = dataclasses.replace(SWEEP_SPECS[spec_idx], seed=seed)
        _sweep_case(spec, mesh, scenario)
