import os
import sys

# tests must see ONE device (the dry-run sets its own flag in-process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh():
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
