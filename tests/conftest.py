import os
import sys

# Force 8 host CPU devices BEFORE jax initializes so multi-shard mesh tests
# run on CPU-only hosts; merge with (never clobber) caller-provided
# XLA_FLAGS. The dry-run sets its own 512-device flag in-process, which
# wins because it runs in a fresh interpreter.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + _flags).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro import compat  # noqa: E402


def pytest_collection_modifyitems(config, items):
    """Skip @pytest.mark.multidevice tests when the forced-device trick
    didn't take (e.g. another jax-initializing plugin ran first)."""
    if jax.device_count() >= 8:
        return
    skip = pytest.mark.skip(
        reason=f"needs >= 8 local devices, have {jax.device_count()}")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


def pod_mesh_or_skip(pods: int, shards: int):
    """(pods, shards) 2D mesh on a prefix of the forced host devices.

    The 8 forced devices factor as (1,8)/(2,4)/(4,2)/(8,1) — and any
    smaller product such as (1,2)/(2,2)/(4,1) — WITHOUT interfering with
    other factorizations requested in the same process (each mesh takes
    its own device prefix, so there is no skip cascade between tests
    using different shapes). A request that doesn't fit the available
    device count skips with the arithmetic spelled out instead of letting
    mesh construction raise."""
    need = pods * shards
    have = jax.device_count()
    if have < need:
        pytest.skip(f"mesh ({pods}, {shards}) needs {need} forced host "
                    f"devices, have {have}")
    return compat.make_mesh((pods, shards), ("pod", "shard"),
                            devices=jax.devices()[:need])


@pytest.fixture(scope="session")
def mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
