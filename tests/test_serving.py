"""The continuous serving loop + the structured streaming API.

Covers the ISSUE-6 contracts:

* exact drop accounting — ``offered == processed + dropped`` PER PERIOD
  when there is no carry-over queue, and cumulatively after a graceful
  drain when there is one, under a forced-overrun offered rate;
* latency percentile math against a hand-computed sample set;
* graceful shutdown drains in-flight periods (nothing is lost between
  "stop accepting" and "stop serving");
* a tier-1 smoke run of the real loop (host ring + donated step) for a
  handful of periods on the forced-host-device config;
* ``describe()`` key stability (the serving knobs are part of the
  contract now);
* the ``StepOutputs`` API — named access, ``stream()`` entry point,
  deprecated tuple shims warning exactly once per driver name;
* the ``configs.env`` registry — uniform fail-loud validation for every
  ``REPRO_*`` override.
"""
import dataclasses

import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import env as ENV
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem, StepOutputs
from repro.data import packets as PK
from repro.data.replay import TraceReplaySource
from repro.launch.serving import (ServingLoop, build_source,
                                  latency_summary, serve_trace)


def _trace(n_shards=1, T=3, E=128):
    return PK.period_batches(n_shards, T, E, n_flows=16, flow_seed=1)


def _source(E=64, T=3, **kw):
    events, nows = _trace(T=T, E=E)
    kw.setdefault("batch_events", E)
    kw.setdefault("budget_us", 20_000)
    return TraceReplaySource(events, nows, **kw)


def _capacity_eps(E=64, budget_us=20_000):
    return E / (budget_us / 1e6)


# -- replay source: pacing + exact accounting ---------------------------------

def test_line_rate_offers_full_batches_no_drops():
    src = _source()
    for _ in range(5):
        batch, now, acct = src.next_batch()
        assert acct == (64, 64, 0, 0)
        assert batch["valid"].all()
        assert (np.diff(batch["ts"].astype(np.int64)) >= 0).all()
    assert src.total.offered == src.total.processed == 5 * 64


def test_per_period_accounting_exact_without_queue():
    """queue_events=0 forced overrun: every single period closes its own
    books — offered == processed + dropped, nothing carried."""
    src = _source(offered_eps=2 * _capacity_eps(), queue_events=0)
    for _ in range(6):
        _, _, acct = src.next_batch()
        assert acct.offered == acct.processed + acct.dropped
        assert acct.queued == 0
        assert acct.offered == 128 and acct.processed == 64


def test_cumulative_accounting_with_queue_and_drain():
    src = _source(offered_eps=2 * _capacity_eps(), queue_events=96)
    for _ in range(6):
        src.next_batch()
    t = src.total
    assert t.dropped > 0, "2x capacity must overflow a 96-event queue"
    assert t.offered == t.processed + t.dropped + t.queued
    assert t.queued > 0
    src.begin_drain()
    while src.pending:
        _, _, acct = src.next_batch()
        assert acct.offered == 0          # shutdown accepts nothing new
    t = src.total
    assert t.offered == t.processed + t.dropped
    assert t.offered == 6 * 128


def test_drop_policy_newest_vs_oldest():
    """Tail-drop keeps the head of the arrival stream; head-drop keeps
    the tail — distinguishable by which five-tuples survive."""
    outs = {}
    for policy in ("newest", "oldest"):
        src = _source(offered_eps=2 * _capacity_eps(), queue_events=0,
                      drop_policy=policy)
        batch, _, acct = src.next_batch()
        assert acct.offered == 128 and acct.processed == 64
        assert acct.dropped == 64
        outs[policy] = batch["five_tuple"].copy()
    # tail-drop keeps arrivals 0..63, head-drop keeps 64..127
    assert not (outs["newest"] == outs["oldest"]).all()


def test_replay_validation_fails_loud():
    events, nows = _trace()
    with pytest.raises(ValueError, match="drop_policy"):
        TraceReplaySource(events, nows, batch_events=64,
                          drop_policy="coldest")
    with pytest.raises(ValueError, match="batch_events"):
        TraceReplaySource(events, nows, batch_events=0)
    with pytest.raises(ValueError, match="stacked"):
        TraceReplaySource({k: v[0] for k, v in events.items()}, nows,
                          batch_events=64)


def test_offered_rate_long_run_exact():
    """Fractional arrivals carry: a rate that isn't an integer multiple
    of the period still offers exactly rate*time events in the long run."""
    eps = 3_225.0                        # 64.5 events / 20 ms period
    src = _source(offered_eps=eps, queue_events=1 << 20)
    for _ in range(124):                 # 124 * 64.5 = 7998 exactly
        src.next_batch()
    assert src.total.offered == 7998


# -- latency percentile math --------------------------------------------------

def test_latency_summary_known_samples():
    # 1..100: linear-interp percentiles have closed forms
    s = latency_summary(list(range(1, 101)))
    assert s["p50"] == pytest.approx(50.5)
    assert s["p99"] == pytest.approx(99.01)
    assert s["p999"] == pytest.approx(99.901)
    # 4 samples, hand-computed: p50 midway, p99 interpolates the tail
    s4 = latency_summary([10.0, 20.0, 30.0, 40.0])
    assert s4["p50"] == pytest.approx(25.0)
    assert s4["p99"] == pytest.approx(39.7)
    assert s["count"] == 100 and s4["count"] == 4


def test_latency_summary_empty_is_explicit():
    """Zero samples -> an explicit empty summary: count pins it as "no
    data" and the percentiles are NaN, never a fake 0.0 latency."""
    empty = latency_summary([])
    assert empty["count"] == 0
    assert all(np.isnan(empty[k]) for k in ("p50", "p99", "p999"))
    assert set(empty) == {"p50", "p99", "p999", "count"}


def test_latency_summary_single_sample():
    """One period: every percentile of a single sample IS that sample —
    count=1 is what tells the consumer not to read a tail from it."""
    one = latency_summary([42.0])
    assert one["count"] == 1
    assert one["p50"] == one["p99"] == one["p999"] == 42.0


def test_zero_period_run_reports_explicit_empty():
    """A 0-period run must produce the explicit empty summary and a 0.0
    sustained rate — not a ZeroDivisionError or NaN accounting."""
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(get_dfa_config(reduced=True), mesh)
    events, nows = _trace(system.n_shards, E=system.cfg.event_block)
    report = serve_trace(system, events, nows, periods=0, drain=False)
    assert report.periods == 0 and report.drained_periods == 0
    assert report.offered == report.processed == report.dropped == 0
    assert report.balanced
    assert report.latency["count"] == 0
    assert all(np.isnan(report.latency[k])
               for k in ("p50", "p99", "p999"))
    assert report.sustained_eps == 0.0


def test_one_period_run_collapses_percentiles():
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(get_dfa_config(reduced=True), mesh)
    events, nows = _trace(system.n_shards, E=system.cfg.event_block)
    report = serve_trace(system, events, nows, periods=1)
    assert report.periods == 1
    lat = report.latency
    assert lat["count"] == 1
    assert lat["p50"] == lat["p99"] == lat["p999"] > 0.0


# -- the serving loop ---------------------------------------------------------

def test_serving_loop_smoke_line_rate():
    """Tier-1 smoke: the real loop (ring + donated step) for a handful
    of periods at line rate — full batches, zero drops, percentiles."""
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(get_dfa_config(reduced=True), mesh)
    events, nows = _trace(system.n_shards, E=system.cfg.event_block)
    report = serve_trace(system, events, nows, periods=5)
    assert report.periods == 5 and report.drained_periods == 0
    assert report.offered == report.processed == 5 * (
        system.n_shards * system.cfg.event_block)
    assert report.dropped == 0 and report.balanced
    assert len(report.latency_us) == 5
    assert set(report.latency) == {"p50", "p99", "p999", "count"}
    assert report.latency["count"] == 5
    assert isinstance(report.last, StepOutputs)
    assert report.last.enriched.shape[1] == system.cfg.derived_dim
    assert int(np.asarray(report.last.metrics["reports_recv"])) > 0


def test_serving_loop_snapshots_without_stalling(tmp_path):
    """Elastic satellite: with snapshot_every_periods set, the loop
    checkpoints the DFAState every N completed periods plus the final
    one — async, between block_until_ready and the next donated dispatch
    — and the newest snapshot equals the loop's end state bitwise."""
    import jax
    from repro.checkpoint import checkpoint as CKPT
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              snapshot_every_periods=2)
    system = DFASystem(cfg, mesh)
    events, nows = _trace(system.n_shards, E=system.cfg.event_block)
    source = build_source(system, events, nows)
    report = ServingLoop(system, source,
                         snapshot_dir=str(tmp_path)).run(5)
    assert report.periods == 5 and report.balanced
    # periods 2, 4 and the final 5 snapshot (keep=3 retains all three)
    assert report.snapshots == 3
    assert CKPT.list_steps(str(tmp_path)) == [2, 4, 5]
    restored, step = CKPT.restore(str(tmp_path))
    assert step == 5
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(report.last.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the knob off means zero snapshot side effects (default path)
    off = serve_trace(system, events, nows, periods=2)
    assert off.snapshots == 0


def test_serving_loop_forced_overrun_drains_on_shutdown():
    """Offered 2x the budget's capacity: the queue fills, the policy
    sheds exactly, and graceful shutdown serves the in-flight backlog
    (drained periods) so the books close."""
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    E = cfg.event_block
    cap = E / (cfg.monitoring_period_us / 1e6)
    cfg = dataclasses.replace(cfg, serve_offered_eps=2 * cap,
                              serve_queue_events=2 * E)
    system = DFASystem(cfg, mesh)
    events, nows = _trace(system.n_shards, E=E)
    report = serve_trace(system, events, nows, periods=6)
    assert report.dropped > 0
    assert report.drained_periods > 0, "shutdown must drain the queue"
    assert report.balanced, (report.offered, report.processed,
                             report.dropped)
    assert len(report.latency_us) == 6 + report.drained_periods
    # the drained backlog really went through the pipeline: the loop's
    # source is empty and every period's accounting row is consistent
    assert report.per_period[-1].queued == 0
    for acct in report.per_period:
        assert acct.offered >= 0 and acct.processed <= E


def test_serving_loop_no_drain_leaves_queue_accounted():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    E = cfg.event_block
    cap = E / (cfg.monitoring_period_us / 1e6)
    cfg = dataclasses.replace(cfg, serve_offered_eps=2 * cap,
                              serve_queue_events=2 * E)
    system = DFASystem(cfg, mesh)
    events, nows = _trace(system.n_shards, E=E)
    source = build_source(system, events, nows)
    report = ServingLoop(system, source).run(4, drain=False)
    assert report.drained_periods == 0
    assert source.pending > 0
    assert report.offered == (report.processed + report.dropped
                              + source.pending)


@pytest.mark.multidevice
def test_serving_loop_rejects_indivisible_batch():
    mesh = make_mesh((2, 2), ("data", "model"))
    system = DFASystem(get_dfa_config(reduced=True), mesh)
    events, nows = _trace(T=2, E=63)
    src = TraceReplaySource(events, nows, batch_events=63)
    with pytest.raises(ValueError, match="divide across"):
        ServingLoop(system, src)


# -- describe(): serving knobs + key stability --------------------------------

DESCRIBE_KEYS = sorted([
    "kernel_backend", "gather_variant", "ingest_variant", "event_tile",
    "ingest_vmem_bytes", "ring_region_bytes", "vmem_budget_bytes",
    "gather_vmem_bytes", "n_shards", "flow_home", "pods",
    "shards_per_pod", "total_ports", "ports_per_device",
    "reporter_slots", "port_report_capacity", "overlap_periods",
    "inference_head", "serve_offered_eps", "serve_budget_us",
    "serve_queue_events", "drop_policy", "home_nodes",
    "snapshot_every_periods", "wire_format",
    "fault_injection", "rehome_collision_policy",
    "crosspod_exchange", "crosspod_capacity", "stage2_capacity",
    "tuning_registry",
])


def test_describe_reports_serving_knobs_and_keys_stable():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              serve_offered_eps=1e6,
                              serve_queue_events=512,
                              drop_policy="oldest")
    d = DFASystem(cfg, mesh).describe()
    assert sorted(d) == DESCRIBE_KEYS, \
        "describe() keys are a stable contract — update DESCRIBE_KEYS " \
        "deliberately when adding fields"
    assert d["serve_offered_eps"] == 1e6
    assert d["serve_queue_events"] == 512
    assert d["drop_policy"] == "oldest"
    # budget resolves to the paper's monitoring period when unset
    assert d["serve_budget_us"] == cfg.monitoring_period_us
    d2 = DFASystem(dataclasses.replace(cfg, serve_budget_us=5_000),
                   mesh).describe()
    assert d2["serve_budget_us"] == 5_000


# -- StepOutputs + stream() ---------------------------------------------------

def test_stream_entry_point_matches_run_periods():
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(get_dfa_config(reduced=True), mesh)
    events, nows = _trace(system.n_shards, T=2, E=system.cfg.event_block)
    with system.mesh:
        a = system.stream(system.init_state(), events, nows)
        b = system.stream(system.init_state(), events, nows,
                          overlapped=True)
    assert isinstance(a, StepOutputs) and isinstance(b, StepOutputs)
    assert a.preds is None and b.preds is None
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    np.testing.assert_allclose(np.asarray(a.enriched),
                               np.asarray(b.enriched),
                               rtol=1e-6, atol=1e-6)


def test_step_outputs_arity_is_fixed():
    """The whole point of the redesign: preds presence never changes the
    field count."""
    assert StepOutputs._fields == ("state", "enriched", "flow_ids",
                                   "mask", "metrics", "preds")
    out5 = StepOutputs("s", "e", "f", "m", {})
    assert out5.preds is None
    out6 = StepOutputs("s", "e", "f", "m", {}, preds="p")
    assert out6.preds == "p" and len(out6) == 6


def test_deprecated_tuple_shims_are_gone():
    """The PR 6 deprecation window closed: the `*_tuple` drivers and the
    variadic `as_tuple()` view no longer exist — callers consume
    StepOutputs fields by name."""
    for name in ("dfa_step_tuple", "run_periods_tuple",
                 "run_periods_overlapped_tuple", "_tuple_shim"):
        assert not hasattr(DFASystem, name), \
            f"removed shim {name} reappeared"
    assert not hasattr(StepOutputs, "as_tuple")


# -- configs.env: the one override registry -----------------------------------

def test_env_registry_covers_all_repro_vars():
    names = set(ENV.registered())
    assert names == {"REPRO_KERNEL_BACKEND", "REPRO_GATHER_VARIANT",
                     "REPRO_INGEST_VARIANT", "REPRO_BENCH_TINY",
                     "REPRO_REGEN_GOLDENS", "REPRO_WIRE_FORMAT",
                     "REPRO_TUNING_REGISTRY"}
    table = ENV.env_table()
    for n in names:
        assert n in table


def test_env_choice_fail_loud(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "palas")
    with pytest.raises(ValueError) as e:
        ENV.read_choice("REPRO_KERNEL_BACKEND")
    msg = str(e.value)
    assert "REPRO_KERNEL_BACKEND" in msg and "pallas" in msg
    for ok, expect in (("", None), ("auto", None), ("REF", "ref"),
                       (" pallas ", "pallas")):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", ok)
        assert ENV.read_choice("REPRO_KERNEL_BACKEND") == expect


def test_env_flag_fail_loud(monkeypatch):
    for raw, want in (("", False), ("0", False), ("false", False),
                      ("no", False), ("off", False), ("1", True),
                      ("true", True), ("YES", True), ("on", True)):
        monkeypatch.setenv("REPRO_BENCH_TINY", raw)
        assert ENV.read_flag("REPRO_BENCH_TINY") is want
    monkeypatch.setenv("REPRO_BENCH_TINY", "maybe")
    with pytest.raises(ValueError, match="REPRO_BENCH_TINY|maybe"):
        ENV.read_flag("REPRO_BENCH_TINY")


def test_env_unregistered_name_rejected():
    with pytest.raises(KeyError, match="unregistered"):
        ENV.read_flag("REPRO_NOT_A_THING")
    with pytest.raises(KeyError, match="unregistered"):
        ENV.spec("REPRO_NOT_A_THING")
