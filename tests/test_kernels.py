"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_dfa_config
from repro.kernels.derived_features.kernel import derived_features_pallas
from repro.kernels.derived_features.ref import derived_features_ref
from repro.kernels.flow_moments.kernel import (EVENT_BLOCK,
                                               flow_moments_pallas)
from repro.kernels.flow_moments.ref import flow_moments_ref
from repro.kernels.ring_scatter.kernel import ring_scatter_pallas
from repro.kernels.ring_scatter.ref import ring_scatter_ref

J = jnp.asarray


@pytest.mark.parametrize("F,E,tile", [
    (64, 16, 16), (128, 100, 32), (256, 256, 64), (256, 300, 128),
    (512, 1000, 512),
])
def test_flow_moments_sweep(rng, F, E, tile):
    regs = rng.integers(0, 2**31, size=(F, 7)).astype(np.uint32)
    slots = rng.integers(0, F, size=E).astype(np.int32)
    deltas = rng.integers(0, 2**32, size=(E, 7),
                          dtype=np.uint64).astype(np.uint32)
    valid = rng.random(E) > 0.15
    got = flow_moments_pallas(regs, slots, deltas, valid, flow_tile=tile)
    want = flow_moments_ref(J(regs), J(slots), J(deltas), J(valid))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_flow_moments_wraparound(rng):
    """u16-split matmul accumulation must preserve mod-2^32 wraparound."""
    F = 64
    regs = np.full((F, 7), 0xFFFFFF00, np.uint32)
    E = EVENT_BLOCK
    slots = np.zeros(E, np.int32)
    deltas = np.full((E, 7), 0x10, np.uint32)
    valid = np.ones(E, bool)
    got = flow_moments_pallas(regs, slots, deltas, valid, flow_tile=64)
    want = flow_moments_ref(J(regs), J(slots), J(deltas), J(valid))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_flow_moments_all_invalid(rng):
    regs = rng.integers(0, 100, size=(64, 7)).astype(np.uint32)
    got = flow_moments_pallas(regs, np.zeros(32, np.int32),
                              np.ones((32, 7), np.uint32),
                              np.zeros(32, bool), flow_tile=64)
    np.testing.assert_array_equal(np.asarray(got), regs)


@pytest.mark.parametrize("F,H,R,tile", [
    (32, 10, 16, 32), (128, 10, 64, 32), (64, 4, 128, 64),
])
def test_ring_scatter_sweep(rng, F, H, R, tile):
    mem = rng.integers(0, 2**32, size=(F, H, 16),
                       dtype=np.uint64).astype(np.uint32)
    coords = rng.choice(F * H, size=min(R, F * H), replace=False)
    R = len(coords)
    flow = (coords // H).astype(np.int32)
    hist = (coords % H).astype(np.int32)
    pay = rng.integers(0, 2**32, size=(R, 16),
                       dtype=np.uint64).astype(np.uint32)
    pay[:, 0] = np.maximum(pay[:, 0], 1)
    mask = rng.random(R) > 0.2
    got = ring_scatter_pallas(mem, pay, flow, hist, mask, flow_tile=tile,
                              history=H)
    want = ring_scatter_ref(J(mem), J(pay), J(flow), J(hist), J(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ring_scatter_duplicate_order(rng):
    """RDMA WRITE ordering: later report to the same address wins."""
    F, H = 32, 10
    mem = np.zeros((F, H, 16), np.uint32)
    pay = np.stack([np.full(16, 1, np.uint32), np.full(16, 2, np.uint32),
                    np.full(16, 3, np.uint32)])
    flow = np.asarray([4, 4, 4], np.int32)
    hist = np.asarray([7, 7, 7], np.int32)
    got = np.asarray(ring_scatter_pallas(mem, pay, flow, hist,
                                         np.ones(3, bool), flow_tile=32,
                                         history=H))
    assert (got[4, 7] == 3).all()


@pytest.mark.parametrize("F,tile", [(64, 64), (128, 64), (256, 128)])
def test_derived_features_sweep(rng, F, tile):
    cfg = get_dfa_config(reduced=True)
    entries = rng.integers(0, 2**20, size=(F, cfg.history, 16),
                           dtype=np.uint64).astype(np.uint32)
    valid = rng.random((F, cfg.history)) > 0.3
    got = derived_features_pallas(entries, valid,
                                  derived_dim=cfg.derived_dim,
                                  flow_tile=tile)
    want = derived_features_ref(J(entries), J(valid), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_kernels_plug_into_reporter(rng):
    """flow_moments as the reporter's accumulate_fn (interpret mode)."""
    from repro.core import reporter as R
    from repro.kernels.flow_moments import ops
    cfg = get_dfa_config(reduced=True)
    keys = rng.integers(1, 2**31, size=(6, 5)).astype(np.uint32)
    fidx = rng.integers(0, 6, size=48)
    ev = {"ts": J(np.sort(rng.integers(0, 5000, 48)).astype(np.uint32)
                  + np.arange(48, dtype=np.uint32)),
          "size": J(rng.integers(40, 1500, 48).astype(np.uint32)),
          "five_tuple": J(keys[fidx]),
          "valid": J(np.ones(48, bool))}
    st_ref = R.ingest(R.init_state(cfg), ev, cfg)
    acc = lambda regs, slots, deltas, valid: ops.flow_moments(
        regs, slots, deltas, valid, force="interpret")
    st_k = R.ingest(R.init_state(cfg), ev, cfg, accumulate_fn=acc)
    np.testing.assert_array_equal(np.asarray(st_ref.regs),
                                  np.asarray(st_k.regs))
