"""run_periods_overlapped ≡ run_periods — the software-pipelined stream
must be OUTPUT-IDENTICAL to the sequential per-period chain (the overlap
moves work between scan bodies, it never changes what is computed: period
t's enrich half still reads the ring after period t's placement and before
period t+1's).

Covers: enriched features, flow ids, masks, per-period metrics and the
full final state — bitwise for integers/bools, exact-by-construction
floats compared with a tight allclose; on a (1, 1) mesh, a multi-shard
(2, 2) mesh (fixed seed), the T=1 degenerate case (zero-length scan:
warm-up + drain only), and with the immediate-inference hook armed.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK


def _period_batches(system, T, events_per_shard=128, seed=7):
    return PK.period_batches(system.n_shards, T, events_per_shard,
                             n_flows=12, flow_seed=seed)


def _assert_streams_equal(seq, ovl, with_preds=False):
    np.testing.assert_allclose(np.asarray(seq.enriched),
                               np.asarray(ovl.enriched),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(seq.flow_ids),
                                  np.asarray(ovl.flow_ids))
    np.testing.assert_array_equal(np.asarray(seq.mask),
                                  np.asarray(ovl.mask))
    assert sorted(seq.metrics) == sorted(ovl.metrics)
    for k in seq.metrics:
        np.testing.assert_array_equal(np.asarray(seq.metrics[k]),
                                      np.asarray(ovl.metrics[k]),
                                      err_msg=k)
    for a, b in zip(jax.tree.leaves(seq.state),
                    jax.tree.leaves(ovl.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (seq.preds is None) == (ovl.preds is None) == (not with_preds)
    if with_preds:
        np.testing.assert_allclose(np.asarray(seq.preds),
                                   np.asarray(ovl.preds),
                                   rtol=1e-6, atol=1e-6)


def test_overlapped_equals_sequential_single_shard():
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = _period_batches(system, T=5)
    with system.mesh:
        seq = jax.jit(system.run_periods)(system.init_state(), events,
                                          nows)
        ovl = jax.jit(system.run_periods_overlapped)(system.init_state(),
                                                     events, nows)
    _assert_streams_equal(seq, ovl)


def test_overlapped_t1_degenerate():
    """T=1: the pipelined scan has zero iterations — the stream is just
    the warm-up ingest plus the drain enrich, and still must match."""
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = _period_batches(system, T=1)
    with system.mesh:
        seq = jax.jit(system.run_periods)(system.init_state(), events,
                                          nows)
        ovl = jax.jit(system.run_periods_overlapped)(system.init_state(),
                                                     events, nows)
    assert ovl.enriched.shape[0] == 1
    _assert_streams_equal(seq, ovl)


@pytest.mark.multidevice
def test_overlapped_equals_sequential_multi_shard():
    """(2, 2) mesh: the carried RoutedBatch round-trips through sharded
    scan carries and the all_to_all still lands every report with the
    same owner — equivalence must survive real cross-shard routing."""
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, make_mesh((2, 2), ("data", "model")))
    events, nows = _period_batches(system, T=3, events_per_shard=64,
                                   seed=11)
    with system.mesh:
        seq = jax.jit(system.run_periods)(system.init_state(), events,
                                          nows)
        ovl = jax.jit(system.run_periods_overlapped)(system.init_state(),
                                                     events, nows)
    assert int(np.asarray(seq.metrics["reports_recv"]).sum()) > 0
    _assert_streams_equal(seq, ovl)


def test_overlapped_with_inference_head():
    """The immediate-inference hook rides the enrich half, so its preds
    must be driver-independent too (and masked rows must stay zero)."""
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              inference_head="linear",
                              inference_classes=4)
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    assert system.infer_fn is not None and system.infer_params is not None
    events, nows = _period_batches(system, T=3)
    with system.mesh:
        seq = jax.jit(system.run_periods)(system.init_state(), events,
                                          nows)
        ovl = jax.jit(system.run_periods_overlapped)(system.init_state(),
                                                     events, nows)
    _assert_streams_equal(seq, ovl, with_preds=True)
    preds, em = np.asarray(ovl.preds), np.asarray(ovl.mask)
    assert preds.shape == em.shape + (4,)
    assert (preds[~em] == 0.0).all()
    assert np.abs(preds[em]).sum() > 0


def test_dfa_step_is_half_step_composition():
    """dfa_step must remain exactly ingest_half ∘ enrich_half — the
    half-step split cannot drift from the fused step."""
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = _period_batches(system, T=1)
    ev0 = {k: v[0] for k, v in events.items()}
    with system.mesh:
        out_a = jax.jit(system.dfa_step)(
            system.init_state(), ev0, nows[0])
        st_a, enr_a, fid_a, em_a, met_a = (out_a.state, out_a.enriched,
                                           out_a.flow_ids, out_a.mask,
                                           out_a.metrics)
        assert out_a.preds is None
        st_b, routed, met_b = jax.jit(system.ingest_half)(
            system.init_state(), ev0, nows[0])
        enr_b, fid_b, em_b, preds = jax.jit(system.enrich_half)(st_b,
                                                                routed)
    assert preds is None
    np.testing.assert_allclose(np.asarray(enr_a), np.asarray(enr_b),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(fid_a), np.asarray(fid_b))
    np.testing.assert_array_equal(np.asarray(em_a), np.asarray(em_b))
    for k in met_a:
        assert int(met_a[k]) == int(met_b[k]), k
    for a, b in zip(jax.tree.leaves(st_a), jax.tree.leaves(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the routed coords the carry would hold are well-formed
    lf, em = np.asarray(routed.local_flow), np.asarray(routed.mask)
    assert (lf[em] >= 0).all() and (lf[em] < cfg.flows_per_shard).all()


def test_per_period_metrics_are_deltas():
    """Metric semantics: every key reports what THE PERIOD added — the
    old code psum'd the CUMULATIVE collision/checksum/sequence counters
    every step, so those three were running totals while
    reports_sent/recv were per-period. 200 flows hashed into 256 slots
    guarantee collisions in several periods, which distinguishes the two
    semantics."""
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = PK.period_batches(system.n_shards, T=4,
                                     events_per_shard=256, n_flows=200,
                                     flow_seed=7)
    with system.mesh:
        out = jax.jit(system.run_periods)(
            system.init_state(), events, nows)
        state, met = out.state, out.metrics
    coll = np.asarray(met["collisions"]).astype(np.int64)
    cum = int(np.asarray(state.reporter.collisions).sum())
    assert cum > 0 and (coll > 0).sum() >= 2, \
        "trace must actually exercise the collision counter"
    # per-period deltas sum to the cumulative state counter — a running
    # total would sum to strictly more once two periods are nonzero
    assert coll.sum() == cum
    assert np.asarray(met["bad_checksum"]).sum() == int(
        np.asarray(state.collector.bad_checksum).sum())
    assert np.asarray(met["seq_anomalies"]).sum() == int(
        np.asarray(state.collector.seq_anomalies).sum())
    assert (np.asarray(met["reports_sent"]) > 0).all()
