"""Fault-tolerance monitor pieces that back the multi-pod stream:
pod-aware heartbeat grouping (whole-pod failure vs lone straggler) and
the stage-axis guard of the pod-axis pipeline."""
import time

import pytest

from repro.compat import make_mesh
from repro.distributed.monitor import Heartbeat


def test_dead_peers_grouped_by_pod(tmp_path):
    d = str(tmp_path)
    beats = [Heartbeat(d, process_index=i, stale_after_s=0.05,
                       pod=i // 2) for i in range(4)]
    for hb in beats:
        hb.beat(step=7)
    time.sleep(0.1)
    # pod 1 (procs 2, 3) stays dead; pod 0 refreshes
    beats[0].beat(step=8)
    beats[1].beat(step=8)
    by_pod = beats[0].dead_peers_by_pod()
    assert sorted(by_pod) == [1]
    assert sorted(by_pod[1]) == [2, 3]
    assert all(age > 0.05 for age in by_pod[1].values())
    # the flat view still reports the same peers
    assert sorted(beats[0].dead_peers()) == [2, 3]


def test_heartbeat_pre_pod_files_default_to_pod_zero(tmp_path):
    """Old heartbeat files (no pod field) group under pod 0 instead of
    being dropped."""
    d = str(tmp_path)
    import json
    import os
    with open(os.path.join(d, "hb_5.json"), "w") as f:
        json.dump({"step": 1, "t": time.time() - 999}, f)
    hb = Heartbeat(d, process_index=0, stale_after_s=60.0)
    assert sorted(hb.dead_peers_by_pod()) == [0]
    assert 5 in hb.dead_peers_by_pod()[0]


def test_pipeline_apply_names_missing_axis():
    from repro.distributed.pipeline import pipeline_apply
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="pod"):
        pipeline_apply(lambda p, x, s: x, {}, None, mesh, axis="pod")
