"""Fault-tolerance monitor pieces that back the multi-pod stream:
pod-aware heartbeat grouping (whole-pod failure vs lone straggler) and
the stage-axis guard of the pod-axis pipeline."""
import time

import pytest

from repro.compat import make_mesh
from repro.distributed.monitor import Heartbeat


def test_dead_peers_grouped_by_pod(tmp_path):
    d = str(tmp_path)
    beats = [Heartbeat(d, process_index=i, stale_after_s=0.05,
                       pod=i // 2) for i in range(4)]
    for hb in beats:
        hb.beat(step=7)
    time.sleep(0.1)
    # pod 1 (procs 2, 3) stays dead; pod 0 refreshes
    beats[0].beat(step=8)
    beats[1].beat(step=8)
    by_pod = beats[0].dead_peers_by_pod()
    assert sorted(by_pod) == [1]
    assert sorted(by_pod[1]) == [2, 3]
    assert all(age > 0.05 for age in by_pod[1].values())
    # the flat view still reports the same peers
    assert sorted(beats[0].dead_peers()) == [2, 3]


def test_heartbeat_pre_pod_files_default_to_pod_zero(tmp_path):
    """Old heartbeat files (no pod field) group under pod 0 instead of
    being dropped."""
    d = str(tmp_path)
    import json
    import os
    with open(os.path.join(d, "hb_5.json"), "w") as f:
        json.dump({"step": 1, "t": time.time() - 999}, f)
    hb = Heartbeat(d, process_index=0, stale_after_s=60.0)
    assert sorted(hb.dead_peers_by_pod()) == [0]
    assert 5 in hb.dead_peers_by_pod()[0]


def test_pipeline_apply_names_missing_axis():
    from repro.distributed.pipeline import pipeline_apply
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="pod"):
        pipeline_apply(lambda p, x, s: x, {}, None, mesh, axis="pod")


# -- expected-peers roster (regression: a peer that died BEFORE its first
#    beat left no hb_*.json and was invisible forever) -------------------

def test_never_beaten_registered_peer_reports_age_inf(tmp_path):
    d = str(tmp_path)
    roster = {0: 0, 1: 0, 2: 1, 3: 1}
    hb = Heartbeat(d, process_index=0, stale_after_s=60.0,
                   expected_peers=roster)
    hb.beat(step=1)
    Heartbeat(d, process_index=1, pod=0).beat(step=1)
    # procs 2 and 3 (all of pod 1) never wrote a file
    dead = hb.dead_peers()
    assert sorted(dead) == [2, 3]
    assert all(age == float("inf") for age in dead.values())
    by_pod = hb.dead_peers_by_pod()
    assert sorted(by_pod) == [1] and sorted(by_pod[1]) == [2, 3]


def test_expected_peers_iterable_form(tmp_path):
    """A bare index iterable registers everyone under pod 0."""
    hb = Heartbeat(str(tmp_path), process_index=0, expected_peers=[0, 1])
    hb.beat(step=1)
    assert sorted(hb.dead_peers()) == [1]
    assert hb.dead_peers_by_pod() == {0: {1: float("inf")}}


def test_unparsable_beat_counts_as_never_beaten(tmp_path):
    """A corrupt heartbeat file is a suspect process, not a healthy one."""
    import os
    with open(os.path.join(str(tmp_path), "hb_1.json"), "w") as f:
        f.write("{not json")
    hb = Heartbeat(str(tmp_path), process_index=0, stale_after_s=60.0,
                   expected_peers={1: 2})
    assert hb.dead_peers_by_pod() == {2: {1: float("inf")}}


# -- run_with_restart (regressions: an exception before the first
#    checkpoint escaped as FileNotFoundError, bypassing max_restarts; and
#    a trailing num_steps % checkpoint_every tail was never saved) -------

def _restart_harness(tmp_path, num_steps, checkpoint_every,
                     fail_at=(), max_restarts=3):
    from repro.distributed.monitor import run_with_restart
    saves = []
    failed = set()

    def step_fn(state, step):
        if step in fail_at and step not in failed:
            failed.add(step)
            raise RuntimeError(f"injected crash at {step}")
        return state + 1, {}

    def save_fn(state, step):
        saves.append((int(state), step))

    def restore_fn():
        if not saves:
            raise FileNotFoundError("no checkpoints yet")
        state, step = saves[-1]
        return state, step

    state, step = run_with_restart(
        step_fn, 0, 0, num_steps, save_fn, restore_fn,
        checkpoint_every=checkpoint_every, max_restarts=max_restarts)
    return state, step, saves


def test_restart_before_first_checkpoint_falls_back_to_initial(tmp_path):
    """A crash at step 0 (no checkpoint on disk yet) must restart from
    the caller's initial state — pre-fix this escaped as an uncaught
    FileNotFoundError from restore_fn."""
    state, step, _ = _restart_harness(tmp_path, num_steps=5,
                                      checkpoint_every=10, fail_at={0})
    assert (state, step) == (5, 5)


def test_restart_budget_still_enforced_without_checkpoint(tmp_path):
    """The fallback must not bypass max_restarts accounting."""
    from repro.distributed.monitor import run_with_restart

    def step_fn(state, step):
        raise RuntimeError("always")

    def restore_fn():
        raise FileNotFoundError

    with pytest.raises(RuntimeError, match="always"):
        run_with_restart(step_fn, 0, 0, 5, lambda s, i: None, restore_fn,
                         checkpoint_every=10, max_restarts=2)


def test_final_tail_state_always_saved(tmp_path):
    """num_steps % checkpoint_every != 0: the tail must still be saved on
    loop exit (pre-fix the last 3 steps of progress evaporated)."""
    state, step, saves = _restart_harness(tmp_path, num_steps=13,
                                          checkpoint_every=5)
    assert (state, step) == (13, 13)
    assert saves[-1] == (13, 13)
    assert (5, 5) in saves and (10, 10) in saves


def test_restart_replays_from_last_checkpoint(tmp_path):
    """The pre-existing contract still holds: a mid-run crash resumes
    from the newest checkpoint, exactly."""
    state, step, saves = _restart_harness(tmp_path, num_steps=12,
                                          checkpoint_every=4,
                                          fail_at={6})
    assert (state, step) == (12, 12)
    assert saves[-1] == (12, 12)
