"""The nightly regression gate's comparison logic (benchmarks/compare_bench):
matched-row thresholds, untimed/new row handling, and the vanished-row
policy (a baseline row missing from the current artifact fails the gate
unless --allow-missing downgrades it)."""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.compare_bench import compare, gate_verdict  # noqa: E402


def _rows(**named):
    return {k: {"name": k, "us_per_call": v} for k, v in named.items()}


def test_within_threshold_passes():
    base = _rows(a=100.0, b=50.0)
    cur = _rows(a=110.0, b=45.0)
    reg, imp, skipped, unmatched = compare(base, cur, 0.15)
    assert reg == [] and imp == [] and skipped == [] and unmatched == []


def test_regression_and_improvement_detected():
    base = _rows(a=100.0, b=100.0)
    cur = _rows(a=130.0, b=60.0)
    reg, imp, *_ = compare(base, cur, 0.15)
    assert [r[0] for r in reg] == ["a"]
    assert [r[0] for r in imp] == ["b"]


def test_untimed_and_new_rows_never_gate():
    base = _rows(a=100.0, zero=0.0)
    cur = _rows(a=100.0, fresh=999.0, zero=0.0)
    cur["nan"] = {"name": "nan", "us_per_call": float("nan")}
    reg, _, skipped, unmatched = compare(base, cur, 0.15)
    assert reg == []
    assert {s[0] for s in skipped} == {"fresh", "zero", "nan"}
    assert unmatched == []
    assert gate_verdict(reg, unmatched, allow_missing=False) == []


def test_vanished_baseline_row_fails_the_gate():
    """A renamed/dropped benchmark must not pass silently — that is how
    a regression in it would hide forever."""
    base = _rows(a=100.0, gone=10.0)
    cur = _rows(a=100.0)
    reg, _, _, unmatched = compare(base, cur, 0.15)
    assert reg == [] and unmatched == ["gone"]
    reasons = gate_verdict(reg, unmatched, allow_missing=False)
    assert len(reasons) == 1 and "vanished" in reasons[0]
    # the explicit downgrade restores the old lenient behavior
    assert gate_verdict(reg, unmatched, allow_missing=True) == []


def test_regression_and_vanished_row_both_reported():
    base = _rows(a=100.0, gone=10.0)
    cur = _rows(a=200.0)
    reg, _, _, unmatched = compare(base, cur, 0.15)
    reasons = gate_verdict(reg, unmatched, allow_missing=False)
    assert len(reasons) == 2
    # --allow-missing must NOT mask a genuine regression
    assert len(gate_verdict(reg, unmatched, allow_missing=True)) == 1


def test_exact_threshold_boundary_passes():
    base = _rows(a=100.0)
    cur = _rows(a=115.0)          # exactly +15%: not a regression
    reg, *_ = compare(base, cur, 0.15)
    assert reg == []


def test_multipod_row_is_gated():
    """The pod-sweep rows streaming_periods emits are MATCHED rows: a
    cross-pod routing slowdown must trip the gate while the derived-only
    overhead-ratio row (us=0) stays informational."""
    base = _rows(**{"streaming_multipod_ports4": 100.0,
                    "streaming_crosspod_overhead_pods2": 0.0})
    cur = _rows(**{"streaming_multipod_ports4": 140.0,
                   "streaming_crosspod_overhead_pods2": 0.0})
    reg, _, skipped, _ = compare(base, cur, 0.15)
    assert [r[0] for r in reg] == ["streaming_multipod_ports4"]
    assert {s[0] for s in skipped} == {"streaming_crosspod_overhead_pods2"}
