"""Property-based equivalence suite for the ingest_update family.

Four implementations must agree on every input — BITWISE, on all five
reporter register arrays (regs / last_ts / keys / active / collisions):

* ref          — multipass oracle (the pre-fusion reporter ingest shape)
* fused jnp    — sort-once + per-column cumsum segment reduction
* block kernel — Pallas, sorted stream BlockSpec-tiled (interpret mode)
* hbm kernel   — Pallas, stream HBM-resident, scalar-prefetched run
                 metadata, double-buffered tile DMA (interpret mode)

The math is all-integer (u32 mod 2^32, wrap-safe by construction), so
unlike the gather_enrich float suite there is no tolerance: any
reduction-order or boundary-handling slip shows up as a hard mismatch.

Covers: mid-block u32 timestamp wrap, colliding / duplicate slots,
first-packet runs, all-invalid blocks, non-power-of-two E vs event_tile,
the in-block duplicate-install corner, the power-of-two hash fast path,
variant precedence/heuristic, and a randomized hypothesis sweep.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_dfa_config
from repro.core import reporter as R
from repro.kernels import dispatch
from repro.kernels.ingest_update.kernel import MAX_EVENT_TILE, clamp_tile
from repro.kernels.ingest_update.ops import (ingest_update,
                                             ingest_update_fused)

J = jnp.asarray
OUT_NAMES = ("regs", "last_ts", "keys", "active", "collisions")


def make_state(rng, cfg, occupancy=0.3):
    """ReporterState with ``occupancy`` of slots already holding flows."""
    F = cfg.flows_per_shard
    st = R.init_state(cfg)
    occ = J(rng.random(F) < occupancy)
    return st._replace(
        regs=J(rng.integers(0, 2**32, size=(F, 7),
                            dtype=np.uint64).astype(np.uint32)),
        last_ts=J(rng.integers(0, 2**32, size=F,
                               dtype=np.uint64).astype(np.uint32)),
        keys=J(rng.integers(1, 2**31, size=(F, 5)).astype(np.uint32)),
        active=occ)


def make_events(rng, E, n_keys=8, invalid_frac=0.0, ts_base=0):
    """Time-sorted event block over a pool of ``n_keys`` five-tuples.
    ``ts_base`` near 2^32 produces mid-block u32 clock wraps."""
    keys = rng.integers(1, 2**31, size=(max(1, n_keys), 5)
                        ).astype(np.uint32)
    fidx = rng.integers(0, max(1, n_keys), size=E)
    ts = np.sort(rng.integers(0, 50_000, size=E)) + np.arange(E)
    ts = (np.uint64(ts_base) + ts.astype(np.uint64)) % (1 << 32)
    return {"ts": J(ts.astype(np.uint32)),
            "size": J(rng.integers(40, 1500, size=E).astype(np.uint32)),
            "five_tuple": J(keys[fidx]),
            "valid": J(rng.random(E) >= invalid_frac)}


def run_all_four(st, events, cfg):
    """Run every implementation; assert bitwise equality; return ref."""
    slots = R.hash_slot(events["five_tuple"], cfg.flows_per_shard)
    args = (st.regs, st.last_ts, st.keys, st.active, st.collisions,
            slots, events["ts"], events["size"], events["five_tuple"],
            events["valid"])
    ref = ingest_update(*args, cfg, backend="ref")
    impls = {
        "fused_jnp": ingest_update_fused(*args, cfg),
        "block": ingest_update(*args, cfg, backend="interpret",
                               variant="block"),
        "hbm": ingest_update(*args, cfg, backend="interpret",
                             variant="hbm"),
    }
    for impl, got in impls.items():
        for name, a, b in zip(OUT_NAMES, ref, got):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{impl} diverges from ref on {name}")
    return ref


# -- deterministic edge cases -------------------------------------------------

def test_clamp_tile():
    assert clamp_tile(256, 1024) == 256      # exactness cap holds
    assert clamp_tile(512, 1024) == MAX_EVENT_TILE
    assert clamp_tile(64, 1024) == 64
    assert clamp_tile(256, 100) == 100       # tile never exceeds E
    assert clamp_tile(0, 8) == 1


def test_first_packet_runs(rng):
    """Empty table, many new flows: every run head must install + flag
    first (IAT terms zero), every follower chains off its predecessor."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, 96, n_keys=12)
    ref = run_all_four(R.init_state(cfg), ev, cfg)
    assert int(ref[4]) == 0                  # no residents -> no collisions
    assert int(np.asarray(ref[3]).sum()) > 0


def test_occupied_table_and_collisions(rng):
    """Pre-populated slots with foreign keys: every valid event either
    matches, installs, or counts one collision — identically everywhere."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, 128, n_keys=10)
    ref = run_all_four(make_state(rng, cfg, occupancy=0.6), ev, cfg)
    assert int(ref[4]) > 0                   # foreign keys must collide


def test_mid_block_timestamp_wrap(rng):
    """u32 µs clock wraps INSIDE the block: arrival order (not numeric ts
    order) must drive the IAT chain and the final last_ts register."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, 64, n_keys=5, ts_base=(1 << 32) - 30_000)
    ts = np.asarray(ev["ts"])
    assert ts[0] > ts[-1]                    # really wrapped mid-block
    run_all_four(R.init_state(cfg), ev, cfg)


def test_heavy_slot_collisions(rng):
    """A 16-slot table under 200 events: long duplicate-slot runs, many
    same-block install races, colliding residents — the worst case for
    segment boundary handling."""
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              flows_per_shard=16)
    ev = make_events(rng, 200, n_keys=40)
    ref = run_all_four(make_state(rng, cfg, occupancy=0.5), ev, cfg)
    assert int(ref[4]) > 0


def test_all_invalid_block(rng):
    """valid all-False: every register array must come back bitwise
    untouched (the whole block rides the sentinel bucket)."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, 64, invalid_frac=1.1)
    assert not bool(np.asarray(ev["valid"]).any())
    st = make_state(rng, cfg)
    ref = run_all_four(st, ev, cfg)
    for name, a, b in zip(OUT_NAMES, ref,
                          (st.regs, st.last_ts, st.keys, st.active,
                           st.collisions)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_zero_length_block_noops_on_every_backend(rng):
    """E == 0 must be a no-op on EVERY backend (the ref branch used to
    crash in resolve_iat while the kernel branch returned unchanged)."""
    cfg = get_dfa_config(reduced=True)
    st = make_state(rng, cfg)
    args = (st.regs, st.last_ts, st.keys, st.active, st.collisions,
            jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.uint32),
            jnp.zeros((0,), jnp.uint32), jnp.zeros((0, 5), jnp.uint32),
            jnp.zeros((0,), bool))
    for out in (ingest_update(*args, cfg, backend="ref"),
                ingest_update(*args, cfg, backend="interpret"),
                ingest_update_fused(*args, cfg)):
        for name, a, b in zip(OUT_NAMES, out,
                              (st.regs, st.last_ts, st.keys, st.active,
                               st.collisions)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


@pytest.mark.parametrize("E,event_tile", [(1, 64), (7, 64), (100, 32),
                                          (100, 7), (300, 256),
                                          (256, 256)])
def test_non_pow2_event_counts_vs_tile(rng, E, event_tile):
    """E that doesn't divide event_tile: pad rows ride the sentinel slot
    and must not perturb any register."""
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              event_tile=event_tile)
    ev = make_events(rng, E, n_keys=max(1, E // 4), invalid_frac=0.2)
    run_all_four(make_state(rng, cfg), ev, cfg)


def test_in_block_duplicate_install_corner(rng):
    """Two NEW flows hashing to one empty slot in one block: the fused
    paths must agree with the (fixed) first-come oracle on which key is
    installed and that the loser counts as a collision."""
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              flows_per_shard=8)
    ev = make_events(rng, 48, n_keys=24)
    ref = run_all_four(R.init_state(cfg), ev, cfg)
    assert int(ref[4]) > 0                   # 24 keys over 8 slots race


def test_reporter_ingest_routes_fused_bitwise(rng):
    """reporter.ingest(backend='interpret') — the full state-level entry
    the pipeline uses — must be bitwise-identical to the ref path."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, 96, n_keys=9, invalid_frac=0.1)
    st = make_state(rng, cfg, occupancy=0.4)
    a = R.ingest(st, ev, cfg, backend="ref")
    b = R.ingest(st, ev, cfg, backend="interpret")
    for name in ("regs", "last_ts", "keys", "active", "collisions",
                 "last_report", "seq"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def test_hash_slot_pow2_mask_fast_path(rng):
    """The mask fast path must be bit-identical to the generic modulo
    for power-of-two tables (and the modulo path must still serve
    non-power-of-two sizes)."""
    tuples = J(rng.integers(0, 2**32, size=(512, 5),
                            dtype=np.uint64).astype(np.uint32))

    def hash_mod(five_tuple, n_slots):     # the pre-fast-path definition
        h = jnp.full(five_tuple.shape[:-1], 0x811C9DC5, jnp.uint32)
        for i in range(5):
            h = (h ^ five_tuple[..., i].astype(jnp.uint32)) * jnp.uint32(
                0x01000193)
        return (h % jnp.uint32(n_slots)).astype(jnp.int32)

    for n_slots in (1, 2, 256, 1 << 17):
        np.testing.assert_array_equal(
            np.asarray(R.hash_slot(tuples, n_slots)),
            np.asarray(hash_mod(tuples, n_slots)), err_msg=str(n_slots))
    got = np.asarray(R.hash_slot(tuples, 100))      # non-pow2: % path
    assert got.min() >= 0 and got.max() < 100


def test_streaming_drivers_bitwise_unchanged_under_fused(monkeypatch):
    """Acceptance: run_periods AND run_periods_overlapped produce
    bitwise-identical reporter state and metrics whether ingest runs the
    multipass ref path or the fused kernels (REPRO_KERNEL_BACKEND=
    interpret) — the same fixed-seed trace the T=4 golden pins."""
    import jax

    from repro.compat import make_mesh
    from repro.core.pipeline import DFASystem
    from repro.data import packets as PK

    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = PK.period_batches(system.n_shards, 2, 128, n_flows=10,
                                     flow_seed=3)
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR, raising=False)
    monkeypatch.delenv(dispatch.INGEST_ENV_VAR, raising=False)

    def run(backend, overlapped):
        monkeypatch.setenv(dispatch.ENV_VAR, backend)
        fn = (system.run_periods_overlapped if overlapped
              else system.run_periods)
        with system.mesh:
            out = jax.jit(fn)(system.init_state(), events, nows)
        return (out.state.reporter, out.flow_ids, out.mask,
                out.metrics)

    for overlapped in (False, True):
        rep_r, fid_r, em_r, met_r = run("ref", overlapped)
        rep_i, fid_i, em_i, met_i = run("interpret", overlapped)
        for name in ("regs", "last_ts", "keys", "active", "collisions"):
            np.testing.assert_array_equal(
                np.asarray(getattr(rep_r, name)),
                np.asarray(getattr(rep_i, name)),
                err_msg=f"overlapped={overlapped} {name}")
        np.testing.assert_array_equal(np.asarray(fid_r),
                                      np.asarray(fid_i))
        np.testing.assert_array_equal(np.asarray(em_r), np.asarray(em_i))
        for k in met_r:
            np.testing.assert_array_equal(np.asarray(met_r[k]),
                                          np.asarray(met_i[k]), err_msg=k)


# -- variant resolution -------------------------------------------------------

def test_ingest_variant_precedence(monkeypatch):
    cfg = get_dfa_config(reduced=True)
    monkeypatch.delenv(dispatch.INGEST_ENV_VAR, raising=False)
    # auto on the reduced config: the sorted stream fits VMEM -> block
    assert dispatch.resolve_ingest_variant(None, cfg, 128, 64) == "block"
    # config field beats auto
    cfg_h = dataclasses.replace(cfg, ingest_variant="hbm")
    assert dispatch.resolve_ingest_variant(None, cfg_h, 128, 64) == "hbm"
    # env beats config
    monkeypatch.setenv(dispatch.INGEST_ENV_VAR, "block")
    assert dispatch.resolve_ingest_variant(None, cfg_h, 128, 64) == "block"
    # explicit argument beats env
    assert dispatch.resolve_ingest_variant("hbm", cfg_h, 128, 64) == "hbm"
    # malformed env raises even under an explicit argument
    monkeypatch.setenv(dispatch.INGEST_ENV_VAR, "sram")
    for explicit in (None, "auto", "block", "hbm"):
        with pytest.raises(ValueError) as ei:
            dispatch.resolve_ingest_variant(explicit, cfg, 128, 64)
        assert dispatch.INGEST_ENV_VAR in str(ei.value)
        assert "hbm" in str(ei.value)


def test_ingest_variant_vmem_budget_heuristic(monkeypatch):
    monkeypatch.delenv(dispatch.INGEST_ENV_VAR, raising=False)
    cfg = get_dfa_config(reduced=True)
    # a 2^10-event block fits any sane budget; 2^20 events (the scaling
    # target) exceed 16 MB of staged stream -> hbm
    assert dispatch.resolve_ingest_variant(None, cfg, 1 << 10,
                                           256) == "block"
    assert dispatch.resolve_ingest_variant(None, cfg, 1 << 20,
                                           256) == "hbm"
    # the hbm working set is E-independent
    assert dispatch.ingest_vmem_bytes(
        "hbm", 1 << 20, 256) == dispatch.ingest_vmem_bytes(
        "hbm", 1 << 10, 256)
    tiny = dataclasses.replace(cfg, vmem_budget_mb=0)
    assert dispatch.resolve_ingest_variant(None, tiny, 128, 64) == "hbm"
    with pytest.raises(ValueError):
        dispatch.ingest_vmem_bytes("sram", 128, 64)


# -- randomized sweep (hypothesis; deterministic tests above still run
#    when hypothesis is absent) ----------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        E=st.integers(1, 220),
        F=st.sampled_from([8, 64, 256]),
        event_tile=st.sampled_from([8, 32, 64, 256]),
        n_keys=st.integers(1, 48),
        invalid_frac=st.sampled_from([0.0, 0.3, 1.1]),
        occupancy=st.sampled_from([0.0, 0.4, 1.0]),
        ts_base=st.sampled_from([0, (1 << 32) - 40_000]),
    )
    def test_equivalence_randomized(seed, E, F, event_tile, n_keys,
                                    invalid_frac, occupancy, ts_base):
        cfg = dataclasses.replace(get_dfa_config(reduced=True),
                                  flows_per_shard=F,
                                  event_tile=event_tile)
        rng = np.random.default_rng(seed)
        ev = make_events(rng, E, n_keys=n_keys,
                         invalid_frac=invalid_frac, ts_base=ts_base)
        run_all_four(make_state(rng, cfg, occupancy=occupancy), ev, cfg)
