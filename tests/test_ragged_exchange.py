"""Ragged (compact) cross-pod exchange ≡ padded differential.

The stage-2 pod exchange can ship compact per-destination segments
(``crosspod_exchange="ragged"``: pod-local reports never enter the
exchange, remote reports are pre-merged flow-major at the source) instead
of worst-case padded buckets. At auto capacity the compaction cannot
drop, and because the home translator canonically re-orders arrivals the
packing is invisible downstream — so the ragged run must be BITWISE
identical to the padded run: merged end state, every period's enriched
output, and every shared metric, across mesh factorizations, both
drivers, both wire formats, both routing schemes (hash + rendezvous),
and with the lossy-transport injector armed (where the ragged payload
stream differs row-for-row, the fault LEDGER IDENTITIES must still hold
exactly).

The ragged path additionally emits exchange-volume accounting —
``crosspod_sent`` (rows that actually crossed pods) and
``crosspod_messages`` (distinct (destination, flow) runs = batched
messages a wire transport would send) — which must stay absent on the
padded path so the committed golden fingerprints never see them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import pod_mesh_or_skip
from repro.configs.dfa import REDUCED
from repro.core.pipeline import DFASystem
from repro.data import scenarios as SC
from repro.data.faults import FaultSpec

TOTAL_PORTS = 4
EVENTS_PER_PORT = 48
T = 3
G = 512
REPORTER_SLOTS = 64
PORT_CAPACITY = 16

GRID = ((1, 2), (2, 2), (4, 1))
RAGGED_KEYS = ("crosspod_sent", "crosspod_messages")
MIXED = FaultSpec(seed=7, drop_rate=0.15, dup_rate=0.1, flip_rate=0.1,
                  replay_rate=0.05, reorder_rate=0.3, reorder_window=4)

_systems = {}
_traces = {}


def _cfg(pods, shards, exchange, wire="v1", capacity=0, spec=None,
         flow_home="hash"):
    ndev = pods * shards
    return dataclasses.replace(
        REDUCED,
        flow_home=flow_home,
        wire_format=wire,
        pods=pods,
        ports_per_pod=TOTAL_PORTS // pods,
        reporter_slots=REPORTER_SLOTS,
        flows_per_shard=G // ndev,
        port_report_capacity=PORT_CAPACITY,
        kernel_backend="ref",
        crosspod_exchange=exchange,
        crosspod_capacity=capacity,
        fault_spec=spec)


def _system(pods, shards, exchange, wire="v1", capacity=0, spec=None,
            flow_home="hash"):
    key = (pods, shards, exchange, wire, capacity, spec, flow_home)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        sysm = DFASystem(
            _cfg(pods, shards, exchange, wire, capacity, spec,
                 flow_home), mesh)
        _systems[key] = (sysm, jax.jit(sysm.run_periods),
                         jax.jit(sysm.run_periods_overlapped))
    return _systems[key]


def _trace(name):
    if name not in _traces:
        ev, nows = SC.build(name, TOTAL_PORTS, EVENTS_PER_PORT, T)
        _traces[name] = ({k: jnp.asarray(v) for k, v in ev.items()},
                         jnp.asarray(nows))
    return _traces[name]


def _merged_state(system, state):
    n = system.n_shards
    out = {f"rep.{k}": np.asarray(a)
           for k, a in state.reporter._asdict().items()}
    out["tr.hist_counter"] = np.asarray(state.translator.hist_counter)
    c = state.collector
    out["coll.memory"] = np.asarray(c.memory)
    out["coll.entry_valid"] = np.asarray(c.entry_valid)
    out["coll.last_seq"] = np.asarray(c.last_seq).reshape(n, -1).max(0)
    for k in ("bad_checksum", "seq_anomalies", "received",
              "lost_reports"):
        out[f"coll.{k}"] = np.asarray(getattr(c, k)).astype(
            np.uint64).sum()
    return out


def _canon_periods(enr, fid, em):
    enr, fid, em = np.asarray(enr), np.asarray(fid), np.asarray(em)
    per = []
    for t in range(enr.shape[0]):
        m = em[t]
        order = np.argsort(fid[t][m], kind="stable")
        per.append({"fid": fid[t][m][order], "enr": enr[t][m][order]})
    return per


def _run(pods, shards, exchange, scenario, overlapped=False, wire="v1",
         capacity=0, spec=None, flow_home="hash"):
    sysm, seq, ovl = _system(pods, shards, exchange, wire, capacity,
                             spec, flow_home)
    events, nows = _trace(scenario)
    with sysm.mesh:
        out = (ovl if overlapped else seq)(sysm.init_state(), events,
                                           nows)
    return (sysm, _merged_state(sysm, out.state),
            _canon_periods(out.enriched, out.flow_ids, out.mask),
            {k: np.asarray(v) for k, v in out.metrics.items()})


def _assert_bitwise_equiv(padded, ragged, ctx):
    """padded run == ragged run, except the ragged-only volume keys."""
    _, pst, pper, pmet = padded
    _, rst, rper, rmet = ragged
    for k in pst:
        np.testing.assert_array_equal(pst[k], rst[k],
                                      err_msg=f"{ctx}: state {k}")
    for t, (p, r) in enumerate(zip(pper, rper)):
        for k in p:
            np.testing.assert_array_equal(
                p[k], r[k], err_msg=f"{ctx}: period {t} {k}")
    assert sorted(rmet) == sorted(list(pmet) + list(RAGGED_KEYS)), ctx
    for k in pmet:
        np.testing.assert_array_equal(pmet[k], rmet[k],
                                      err_msg=f"{ctx}: metric {k}")


@pytest.mark.parametrize("scenario", ["cross_pod_mix", "elephants_mice"])
def test_ragged_bitwise_equals_padded(scenario):
    """THE tentpole differential: every mesh in the grid, both drivers —
    the compact exchange changes not one bit of state, output or shared
    metric, while its volume accounting shows only the true cross-pod
    fraction crossing."""
    for pods, shards in GRID:
        for overlapped in (False, True):
            ctx = f"{scenario} ({pods},{shards}) ovl={overlapped}"
            padded = _run(pods, shards, "padded", scenario, overlapped)
            ragged = _run(pods, shards, "ragged", scenario, overlapped)
            _assert_bitwise_equiv(padded, ragged, ctx)
            met = ragged[3]
            recv = int(met["reports_recv"].sum())
            sent_x = int(met["crosspod_sent"].sum())
            assert recv > 0, f"{ctx}: vacuous trace"
            if pods == 1:
                assert sent_x == 0, \
                    f"{ctx}: single-pod mesh claims cross-pod traffic"
            else:
                # compaction is real: some but NOT all delivered reports
                # crossed pods (cross-pod fraction strictly < 1 because
                # every scenario keeps some pod-local flows)
                assert 0 < sent_x < recv, ctx
                assert 0 < int(met["crosspod_messages"].sum()) <= sent_x


def test_ragged_equals_padded_v2_wire():
    """Same contract under the widened u16 wire schema (the compact
    packing and pre-merge sort key come off the schema registry, not
    hard-coded V1 shifts)."""
    padded = _run(2, 2, "padded", "cross_pod_mix", wire="v2")
    ragged = _run(2, 2, "ragged", "cross_pod_mix", wire="v2")
    _assert_bitwise_equiv(padded, ragged, "v2 (2,2)")
    assert int(ragged[3]["crosspod_sent"].sum()) > 0


def test_ragged_equals_padded_rendezvous():
    """Same contract under HRW (elastic) homing — the ragged path
    recomputes home pods through node_position, not the range scheme."""
    padded = _run(2, 2, "padded", "cross_pod_mix",
                  flow_home="rendezvous")
    ragged = _run(2, 2, "ragged", "cross_pod_mix",
                  flow_home="rendezvous")
    _assert_bitwise_equiv(padded, ragged, "rendezvous (2,2)")
    assert int(ragged[3]["crosspod_sent"].sum()) > 0


def test_fault_ledger_identities_hold_on_compact_path():
    """With the injector armed the ragged payload stream is NOT
    row-for-row comparable to the padded one (victim selection keys on
    buffer positions), but every defense layer must still account for
    every injected fault exactly — the identities are packing-invariant.
    """
    _, _, _, met = _run(2, 2, "ragged", "cross_pod_mix", spec=MIXED)
    for k in ("injected_drops", "injected_dups", "injected_flips",
              "injected_replays", "injected_reorders"):
        assert int(met[k].sum()) > 0, f"{k} never fired — vacuous"
    np.testing.assert_array_equal(met["bad_checksum"],
                                  met["injected_flips"])
    np.testing.assert_array_equal(
        met["seq_anomalies"],
        met["injected_dups"] + met["injected_replays"])
    np.testing.assert_array_equal(
        met["lost_reports"],
        met["injected_drops"] + met["injected_flips"])
    assert int(met["crosspod_sent"].sum()) > 0


def test_tiny_capacity_overflow_is_counted():
    """An under-sized compact segment drops the excess — DTA's lossy
    trade on the pod link — and the books must still balance exactly:
    sent == delivered + capacity drops + misroutes, per period."""
    sysm, _, _, met = _run(2, 2, "ragged", "cross_pod_mix", capacity=1)
    assert sysm.crosspod_capacity == 1
    assert int(met["bucket_drops"].sum()) > 0, \
        "capacity=1 never overflowed on cross_pod_mix — vacuous"
    np.testing.assert_array_equal(
        met["reports_sent"],
        met["reports_recv"] + met["bucket_drops"] + met["misroutes"])
    # per period, at most ndev * pods * capacity rows can cross
    assert (met["crosspod_sent"]
            <= sysm.n_shards * sysm.mesh_pods * 1).all()


def test_padded_default_emits_no_crosspod_keys():
    """Golden safety: the default padded path must not grow metric keys
    (the pinned fingerprints compare key sets exactly), and the new
    misroutes counter must be zero on a clean trace."""
    _, _, _, met = _run(2, 2, "padded", "cross_pod_mix")
    assert not any(k in met for k in RAGGED_KEYS)
    assert int(met["misroutes"].sum()) == 0
    assert int(met["bucket_drops"].sum()) == 0


def test_describe_surfaces_exchange_strategy():
    sysm, _, _, _ = _run(2, 2, "ragged", "cross_pod_mix")
    d = sysm.describe()
    assert d["crosspod_exchange"] == "ragged"
    assert d["stage2_capacity"] == sysm.shards_per_pod * max(
        1, sysm.ports_per_device * sysm.port_capacity)
    assert d["crosspod_capacity"] == d["stage2_capacity"]  # auto size
    psys, _, _, _ = _run(2, 2, "padded", "cross_pod_mix")
    pd = psys.describe()
    assert pd["crosspod_exchange"] == "padded"
    assert pd["crosspod_capacity"] == 0


def test_misconfigurations_fail_loud():
    mesh = pod_mesh_or_skip(1, 1)
    with pytest.raises(ValueError, match="ragged"):
        DFASystem(dataclasses.replace(
            REDUCED, crosspod_exchange="ragged"), mesh)
    with pytest.raises(ValueError, match="crosspod_capacity"):
        DFASystem(dataclasses.replace(
            REDUCED, crosspod_capacity=4), mesh)
    with pytest.raises(ValueError, match="padded.*ragged|ragged|unknown"):
        DFASystem(dataclasses.replace(
            REDUCED, crosspod_exchange="compact"), mesh)
    m22 = pod_mesh_or_skip(2, 2)
    big = _cfg(2, 2, "ragged")
    worst = DFASystem(big, m22).stage2_capacity
    with pytest.raises(ValueError, match="exceeds the worst-case"):
        DFASystem(dataclasses.replace(
            big, crosspod_capacity=worst + 1), m22)
    with pytest.raises(ValueError, match="only applies"):
        DFASystem(dataclasses.replace(
            _cfg(2, 2, "padded"), crosspod_capacity=2), m22)
