"""Wire-format round trips (paper Figs 2/4) — bit-level properties.

Plain tests run everywhere; the randomized round-trip/corruption sweeps
additionally run under hypothesis when it is installed (CI always has it).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import protocol as P

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    u32 = st.integers(min_value=0, max_value=2**32 - 1)


def test_sizes_match_paper():
    assert P.PAYLOAD_WORDS * 4 == 64          # RoCEv2 pow-2 payload
    assert P.MARINA_VECTOR_BYTES == 45        # 7*4B stats + 17B five-tuple
    assert P.N_STATS == 7
    assert P.REPORT_WORDS * 4 - 8 > P.MARINA_VECTOR_BYTES  # data fits


def _payload(flow=7, rid=1, seq=0, hist=3, stats=None, tup=None):
    rep = {"flow_id": jnp.uint32(flow), "reporter_id": jnp.uint32(rid),
           "seq": jnp.uint32(seq),
           "stats": jnp.asarray(stats if stats is not None
                                else np.arange(7), jnp.uint32),
           "five_tuple": jnp.asarray(tup if tup is not None
                                     else np.arange(5), jnp.uint32)}
    return P.pack_rocev2_payload(rep, jnp.uint32(hist))


# -- hypothesis round trips ---------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(u32, st.integers(0, 255), st.integers(0, 255),
           st.lists(u32, min_size=7, max_size=7),
           st.lists(u32, min_size=5, max_size=5))
    def test_dta_roundtrip(flow, rid, seq, stats, tup):
        r = P.pack_dta_report(jnp.uint32(flow), jnp.uint32(rid),
                              jnp.uint32(seq),
                              jnp.asarray(stats, jnp.uint32),
                              jnp.asarray(tup, jnp.uint32))
        assert r.shape == (P.REPORT_WORDS,)
        u = P.unpack_dta_report(r)
        assert int(u["flow_id"]) == flow
        assert int(u["reporter_id"]) == rid
        assert int(u["seq"]) == seq
        np.testing.assert_array_equal(np.asarray(u["stats"]), stats)
        np.testing.assert_array_equal(np.asarray(u["five_tuple"]), tup)

    @settings(max_examples=100, deadline=None)
    @given(u32, st.integers(0, 255), st.integers(0, 255),
           st.integers(0, 9),
           st.lists(u32, min_size=7, max_size=7),
           st.lists(u32, min_size=5, max_size=5))
    def test_payload_roundtrip_and_checksum(flow, rid, seq, hist, stats,
                                            tup):
        p = _payload(flow, rid, seq, hist, stats, tup)
        assert p.shape == (P.PAYLOAD_WORDS,)
        assert bool(P.payload_valid(p))
        u = P.unpack_payload(p)
        assert int(u["flow_id"]) == flow
        assert int(u["hist_idx"]) == hist
        assert int(u["seq"]) == seq
        np.testing.assert_array_equal(np.asarray(u["stats"]), stats)

    @settings(max_examples=50, deadline=None)
    @given(u32, st.integers(0, 15), st.integers(1, 2**32 - 1))
    def test_checksum_detects_any_single_word_flip(flow, word, flip):
        """Flipping exactly one word ANYWHERE in the payload — data words
        0..13, the stored checksum (14), or the pad word (15, previously
        outside the fold's coverage) — is always detected."""
        tampered = _payload(flow).at[word].set(
            _payload(flow)[word] ^ jnp.uint32(flip))
        assert not bool(P.payload_valid(tampered))

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 13), st.integers(1, 2**32 - 1))
    def test_xor_checksum_linearity(word, flip):
        """checksum(p with word^mask) == checksum(p) ^ rotl(mask, word) —
        a 1-word corruption flips the fold by its mask rotated to the
        word's position, which is why any nonzero single-word flip is
        caught AND why the same mask on two different words no longer
        cancels."""
        p = _payload()
        body = p[:P.CSUM_WORD]
        tampered = body.at[word].set(body[word] ^ jnp.uint32(flip))
        k = word % 32
        rotated = ((flip << k) | (flip >> ((32 - k) % 32))) & 0xFFFFFFFF
        assert int(P.xor_checksum(tampered)) == (
            int(P.xor_checksum(body)) ^ rotated)

    @settings(max_examples=100, deadline=None)
    @given(u32)
    def test_seq_ids_roundtrip_mod_256(seq):
        """Reporter sequence ids are 8-bit on the wire (sec VI-B): packing
        a raw (unmasked) seq then unpacking yields seq mod 256, and the
        overflow bits never bleed into the adjacent meta fields."""
        p = _payload(rid=0xAB, hist=5, seq=seq)
        u = P.unpack_payload(p)
        assert int(u["seq"]) == seq % 256
        assert int(u["reporter_id"]) == 0xAB
        assert int(u["hist_idx"]) == 5


# -- deterministic checksum algebra / former blind spots ----------------------

def test_checksum_word_flip_smoke():
    p = _payload()
    assert bool(P.payload_valid(p))
    for word in range(16):          # every word, pad included
        tampered = p.at[word].set(p[word] ^ jnp.uint32(0xDEAD))
        assert not bool(P.payload_valid(tampered)), word


def test_checksum_two_word_cancellation_detected():
    """The plain xor-fold's blind spot — the SAME mask applied to two
    covered words cancelled and validated clean — is closed by the
    position-dependent fold: rotl(mask, i) ^ rotl(mask, j) != 0 for
    i != j unless the mask is rotation-invariant under (i - j)."""
    p = _payload()
    mask = jnp.uint32(0xBEEF)       # the historical documented blind spot
    double = p.at[2].set(p[2] ^ mask).at[9].set(p[9] ^ mask)
    assert not bool(P.payload_valid(double))
    # sweep every covered pair with an asymmetric mask
    for i in range(14):
        for j in range(i + 1, 14):
            t = p.at[i].set(p[i] ^ mask).at[j].set(p[j] ^ mask)
            assert not bool(P.payload_valid(t)), (i, j)


def test_checksum_rotation_invariant_mask_residual_blind_spot():
    """Honest residual: a mask invariant under rotation by (i - j) — the
    all-ones word is invariant under EVERY rotation — still cancels
    across two words. The paper's §VI-B sequence-continuity check is the
    backstop for adversarial tampering; the fold targets fat-finger /
    bit-rot corruption."""
    p = _payload()
    ones = jnp.uint32(0xFFFFFFFF)
    double = p.at[2].set(p[2] ^ ones).at[9].set(p[9] ^ ones)
    assert bool(P.payload_valid(double))


def test_checksum_pad_word_flip_detected():
    """Word 15 (pad) used to be outside the fold — flips there were
    invisible. It is now covered (rotated by position 15): any nonzero
    pad is rejected, while unpack_payload still never reads it."""
    p = _payload()
    tampered = p.at[15].set(jnp.uint32(0xFFFFFFFF))
    assert not bool(P.payload_valid(tampered))
    tampered_lsb = p.at[15].set(jnp.uint32(1))
    assert not bool(P.payload_valid(tampered_lsb))
    u_clean, u_bad = P.unpack_payload(p), P.unpack_payload(tampered)
    for k in u_clean:
        np.testing.assert_array_equal(np.asarray(u_clean[k]),
                                      np.asarray(u_bad[k]))


def test_batched_roundtrip_shapes():
    """Packing is shape-polymorphic: (N,)-batched reports round-trip
    identically to scalar packing (the reporter packs whole capacity
    blocks at once)."""
    rng = np.random.default_rng(7)
    N = 33
    flow = rng.integers(0, 2**32, size=N, dtype=np.uint64).astype(np.uint32)
    rid = rng.integers(0, 256, size=N).astype(np.uint32)
    seq = rng.integers(0, 256, size=N).astype(np.uint32)
    stats = rng.integers(0, 2**32, size=(N, 7),
                         dtype=np.uint64).astype(np.uint32)
    tup = rng.integers(0, 2**32, size=(N, 5),
                       dtype=np.uint64).astype(np.uint32)
    hist = rng.integers(0, 10, size=N).astype(np.uint32)

    r = P.pack_dta_report(jnp.asarray(flow), jnp.asarray(rid),
                          jnp.asarray(seq), jnp.asarray(stats),
                          jnp.asarray(tup))
    assert r.shape == (N, P.REPORT_WORDS)
    u = P.unpack_dta_report(r)
    np.testing.assert_array_equal(np.asarray(u["flow_id"]), flow)
    np.testing.assert_array_equal(np.asarray(u["reporter_id"]), rid)
    np.testing.assert_array_equal(np.asarray(u["seq"]), seq)
    np.testing.assert_array_equal(np.asarray(u["stats"]), stats)
    np.testing.assert_array_equal(np.asarray(u["five_tuple"]), tup)

    p = P.pack_rocev2_payload(u, jnp.asarray(hist))
    assert p.shape == (N, P.PAYLOAD_WORDS)
    assert bool(P.payload_valid(p).all())
    up = P.unpack_payload(p)
    np.testing.assert_array_equal(np.asarray(up["flow_id"]), flow)
    np.testing.assert_array_equal(np.asarray(up["hist_idx"]), hist)
    np.testing.assert_array_equal(np.asarray(up["stats"]), stats)
    # row k of the batch == packing row k alone (no cross-row coupling)
    k = 5
    solo = P.pack_rocev2_payload(
        {kk: jnp.asarray(vv[k]) for kk, vv in u.items()},
        jnp.uint32(hist[k]))
    np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(solo))


def test_five_tuple_pack():
    t = P.pack_five_tuple(jnp.uint32(0x0A000001), jnp.uint32(0xC0A80001),
                          jnp.uint32(443), jnp.uint32(51000),
                          jnp.uint32(6))
    assert t.shape == (5,)
    assert int(t[2]) == (443 << 16) | 51000
    assert int(t[3]) == 6
