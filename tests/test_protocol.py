"""Wire-format round trips (paper Figs 2/4) — bit-level properties."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import protocol as P

u32 = st.integers(min_value=0, max_value=2**32 - 1)


def test_sizes_match_paper():
    assert P.PAYLOAD_WORDS * 4 == 64          # RoCEv2 pow-2 payload
    assert P.MARINA_VECTOR_BYTES == 45        # 7*4B stats + 17B five-tuple
    assert P.N_STATS == 7
    assert P.REPORT_WORDS * 4 - 8 > P.MARINA_VECTOR_BYTES  # data fits


@settings(max_examples=100, deadline=None)
@given(u32, st.integers(0, 255), st.integers(0, 255),
       st.lists(u32, min_size=7, max_size=7),
       st.lists(u32, min_size=5, max_size=5))
def test_dta_roundtrip(flow, rid, seq, stats, tup):
    r = P.pack_dta_report(jnp.uint32(flow), jnp.uint32(rid),
                          jnp.uint32(seq), jnp.asarray(stats, jnp.uint32),
                          jnp.asarray(tup, jnp.uint32))
    assert r.shape == (P.REPORT_WORDS,)
    u = P.unpack_dta_report(r)
    assert int(u["flow_id"]) == flow
    assert int(u["reporter_id"]) == rid
    assert int(u["seq"]) == seq
    np.testing.assert_array_equal(np.asarray(u["stats"]), stats)
    np.testing.assert_array_equal(np.asarray(u["five_tuple"]), tup)


@settings(max_examples=100, deadline=None)
@given(u32, st.integers(0, 255), st.integers(0, 255), st.integers(0, 9),
       st.lists(u32, min_size=7, max_size=7),
       st.lists(u32, min_size=5, max_size=5))
def test_payload_roundtrip_and_checksum(flow, rid, seq, hist, stats, tup):
    rep = {"flow_id": jnp.uint32(flow), "reporter_id": jnp.uint32(rid),
           "seq": jnp.uint32(seq), "stats": jnp.asarray(stats, jnp.uint32),
           "five_tuple": jnp.asarray(tup, jnp.uint32)}
    p = P.pack_rocev2_payload(rep, jnp.uint32(hist))
    assert p.shape == (P.PAYLOAD_WORDS,)
    assert bool(P.payload_valid(p))
    u = P.unpack_payload(p)
    assert int(u["flow_id"]) == flow
    assert int(u["hist_idx"]) == hist
    assert int(u["seq"]) == seq
    np.testing.assert_array_equal(np.asarray(u["stats"]), stats)


@settings(max_examples=50, deadline=None)
@given(u32, st.integers(0, 13), st.integers(1, 2**32 - 1))
def test_checksum_detects_tampering(flow, word, flip):
    rep = {"flow_id": jnp.uint32(flow), "reporter_id": jnp.uint32(1),
           "seq": jnp.uint32(0),
           "stats": jnp.arange(7, dtype=jnp.uint32),
           "five_tuple": jnp.arange(5, dtype=jnp.uint32)}
    p = P.pack_rocev2_payload(rep, jnp.uint32(3))
    tampered = p.at[word].set(p[word] ^ jnp.uint32(flip))
    assert not bool(P.payload_valid(tampered))


def test_five_tuple_pack():
    t = P.pack_five_tuple(jnp.uint32(0x0A000001), jnp.uint32(0xC0A80001),
                          jnp.uint32(443), jnp.uint32(51000),
                          jnp.uint32(6))
    assert t.shape == (5,)
    assert int(t[2]) == (443 << 16) | 51000
    assert int(t[3]) == 6
