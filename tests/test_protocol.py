"""Wire-format round trips (paper Figs 2/4) — bit-level properties.

Plain tests run everywhere; the randomized round-trip/corruption sweeps
additionally run under hypothesis when it is installed (CI always has it).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import protocol as P

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    u32 = st.integers(min_value=0, max_value=2**32 - 1)


def test_sizes_match_paper():
    assert P.PAYLOAD_WORDS * 4 == 64          # RoCEv2 pow-2 payload
    assert P.MARINA_VECTOR_BYTES == 45        # 7*4B stats + 17B five-tuple
    assert P.N_STATS == 7
    assert P.REPORT_WORDS * 4 - 8 > P.MARINA_VECTOR_BYTES  # data fits


def _payload(flow=7, rid=1, seq=0, hist=3, stats=None, tup=None):
    rep = {"flow_id": jnp.uint32(flow), "reporter_id": jnp.uint32(rid),
           "seq": jnp.uint32(seq),
           "stats": jnp.asarray(stats if stats is not None
                                else np.arange(7), jnp.uint32),
           "five_tuple": jnp.asarray(tup if tup is not None
                                     else np.arange(5), jnp.uint32)}
    return P.pack_rocev2_payload(rep, jnp.uint32(hist))


# -- hypothesis round trips ---------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(u32, st.integers(0, 255), st.integers(0, 255),
           st.lists(u32, min_size=7, max_size=7),
           st.lists(u32, min_size=5, max_size=5))
    def test_dta_roundtrip(flow, rid, seq, stats, tup):
        r = P.pack_dta_report(jnp.uint32(flow), jnp.uint32(rid),
                              jnp.uint32(seq),
                              jnp.asarray(stats, jnp.uint32),
                              jnp.asarray(tup, jnp.uint32))
        assert r.shape == (P.REPORT_WORDS,)
        u = P.unpack_dta_report(r)
        assert int(u["flow_id"]) == flow
        assert int(u["reporter_id"]) == rid
        assert int(u["seq"]) == seq
        np.testing.assert_array_equal(np.asarray(u["stats"]), stats)
        np.testing.assert_array_equal(np.asarray(u["five_tuple"]), tup)

    @settings(max_examples=100, deadline=None)
    @given(u32, st.integers(0, 255), st.integers(0, 255),
           st.integers(0, 9),
           st.lists(u32, min_size=7, max_size=7),
           st.lists(u32, min_size=5, max_size=5))
    def test_payload_roundtrip_and_checksum(flow, rid, seq, hist, stats,
                                            tup):
        p = _payload(flow, rid, seq, hist, stats, tup)
        assert p.shape == (P.PAYLOAD_WORDS,)
        assert bool(P.payload_valid(p))
        u = P.unpack_payload(p)
        assert int(u["flow_id"]) == flow
        assert int(u["hist_idx"]) == hist
        assert int(u["seq"]) == seq
        np.testing.assert_array_equal(np.asarray(u["stats"]), stats)

    @settings(max_examples=50, deadline=None)
    @given(u32, st.integers(0, 14), st.integers(1, 2**32 - 1))
    def test_checksum_detects_any_single_word_flip(flow, word, flip):
        """Flipping exactly one covered word (0..13 data or the stored
        checksum itself, word 14) is always detected."""
        tampered = _payload(flow).at[word].set(
            _payload(flow)[word] ^ jnp.uint32(flip))
        assert not bool(P.payload_valid(tampered))

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 13), st.integers(1, 2**32 - 1))
    def test_xor_checksum_linearity(word, flip):
        """checksum(p with word^mask) == checksum(p) ^ mask — a 1-word
        corruption flips the fold by exactly its mask, which is why any
        nonzero single-word flip is caught."""
        p = _payload()
        body = p[:P.CSUM_WORD]
        tampered = body.at[word].set(body[word] ^ jnp.uint32(flip))
        assert int(P.xor_checksum(tampered)) == (
            int(P.xor_checksum(body)) ^ flip)

    @settings(max_examples=100, deadline=None)
    @given(u32)
    def test_seq_ids_roundtrip_mod_256(seq):
        """Reporter sequence ids are 8-bit on the wire (sec VI-B): packing
        a raw (unmasked) seq then unpacking yields seq mod 256, and the
        overflow bits never bleed into the adjacent meta fields."""
        p = _payload(rid=0xAB, hist=5, seq=seq)
        u = P.unpack_payload(p)
        assert int(u["seq"]) == seq % 256
        assert int(u["reporter_id"]) == 0xAB
        assert int(u["hist_idx"]) == 5


# -- deterministic checksum algebra / blind spots -----------------------------

def test_checksum_word_flip_smoke():
    p = _payload()
    assert bool(P.payload_valid(p))
    for word in range(15):
        tampered = p.at[word].set(p[word] ^ jnp.uint32(0xDEAD))
        assert not bool(P.payload_valid(tampered)), word


def test_checksum_two_word_cancellation_blind_spot():
    """xor-fold limitation, documented on purpose: the SAME mask applied
    to two covered words cancels and validates clean. The paper's §VI-B
    answer is the per-reporter sequence continuity check, not a stronger
    checksum."""
    p = _payload()
    mask = jnp.uint32(0xBEEF)
    double = p.at[2].set(p[2] ^ mask).at[9].set(p[9] ^ mask)
    assert bool(P.payload_valid(double))


def test_checksum_pad_word_blind_spot():
    """Word 15 (pad) is outside the fold: flips there are invisible to
    payload_valid — unpack_payload must never read it."""
    p = _payload()
    tampered = p.at[15].set(jnp.uint32(0xFFFFFFFF))
    assert bool(P.payload_valid(tampered))
    u_clean, u_bad = P.unpack_payload(p), P.unpack_payload(tampered)
    for k in u_clean:
        np.testing.assert_array_equal(np.asarray(u_clean[k]),
                                      np.asarray(u_bad[k]))


def test_batched_roundtrip_shapes():
    """Packing is shape-polymorphic: (N,)-batched reports round-trip
    identically to scalar packing (the reporter packs whole capacity
    blocks at once)."""
    rng = np.random.default_rng(7)
    N = 33
    flow = rng.integers(0, 2**32, size=N, dtype=np.uint64).astype(np.uint32)
    rid = rng.integers(0, 256, size=N).astype(np.uint32)
    seq = rng.integers(0, 256, size=N).astype(np.uint32)
    stats = rng.integers(0, 2**32, size=(N, 7),
                         dtype=np.uint64).astype(np.uint32)
    tup = rng.integers(0, 2**32, size=(N, 5),
                       dtype=np.uint64).astype(np.uint32)
    hist = rng.integers(0, 10, size=N).astype(np.uint32)

    r = P.pack_dta_report(jnp.asarray(flow), jnp.asarray(rid),
                          jnp.asarray(seq), jnp.asarray(stats),
                          jnp.asarray(tup))
    assert r.shape == (N, P.REPORT_WORDS)
    u = P.unpack_dta_report(r)
    np.testing.assert_array_equal(np.asarray(u["flow_id"]), flow)
    np.testing.assert_array_equal(np.asarray(u["reporter_id"]), rid)
    np.testing.assert_array_equal(np.asarray(u["seq"]), seq)
    np.testing.assert_array_equal(np.asarray(u["stats"]), stats)
    np.testing.assert_array_equal(np.asarray(u["five_tuple"]), tup)

    p = P.pack_rocev2_payload(u, jnp.asarray(hist))
    assert p.shape == (N, P.PAYLOAD_WORDS)
    assert bool(P.payload_valid(p).all())
    up = P.unpack_payload(p)
    np.testing.assert_array_equal(np.asarray(up["flow_id"]), flow)
    np.testing.assert_array_equal(np.asarray(up["hist_idx"]), hist)
    np.testing.assert_array_equal(np.asarray(up["stats"]), stats)
    # row k of the batch == packing row k alone (no cross-row coupling)
    k = 5
    solo = P.pack_rocev2_payload(
        {kk: jnp.asarray(vv[k]) for kk, vv in u.items()},
        jnp.uint32(hist[k]))
    np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(solo))


def test_five_tuple_pack():
    t = P.pack_five_tuple(jnp.uint32(0x0A000001), jnp.uint32(0xC0A80001),
                          jnp.uint32(443), jnp.uint32(51000),
                          jnp.uint32(6))
    assert t.shape == (5,)
    assert int(t[2]) == (443 << 16) | 51000
    assert int(t[3]) == 6
