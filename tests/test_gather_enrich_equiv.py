"""Property-based equivalence suite for the gather_enrich family.

Three implementations must agree on every input:

* ref                — jnp oracle (explicit gather + derive_ref)
* full-block kernel  — ring region pinned in VMEM (interpret mode)
* HBM-tiled kernel   — ring stays in HBM, double-buffered per-tile DMA
                       (interpret mode)

Comparison contract: the two Pallas kernels are BITWISE equal (same
derive_block math on identically gathered rows), and each matches the ref
oracle to <= 1e-5 relative to the row's feature scale. Elementwise rtol is
the wrong yardstick here: the delta columns are newest-minus-window-mean
differences of ~1e6-magnitude operands, so a single-ulp reduction-order
difference in the mean legitimately lands at ~1e-5 of the *delta* while
being 1e-7 of the quantities actually summed.

Covers: randomized F/H/report_tile/derived_dim (hypothesis), non-power-
of-two R padding, duplicate flow ids inside one tile, all-invalid ring
entries, and the paper-scale F = 2^17, H = 8 acceptance shape.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_dfa_config
from repro.configs.dfa import REDUCED_HBM
from repro.core import collector as COLL
from repro.kernels.gather_enrich.ops import _tile_and_pad, gather_enrich

J = jnp.asarray
STAT_MAX = 1 << 20     # Table-I sums are log*-approximated; bound the
                       # magnitude so float32 feature math stays meaningful


def make_case(rng, F, H, R, invalid_frac=0.3):
    mem = J(rng.integers(0, STAT_MAX, size=(F, H, 16),
                         dtype=np.uint64).astype(np.uint32))
    ev = J(rng.random((F, H)) > invalid_frac)
    lf = J(rng.integers(0, F, size=R).astype(np.int32))
    return mem, ev, lf


def assert_feature_close(got, ref, tol=1e-5):
    """max |got - ref| per row <= tol * that row's feature scale."""
    got, ref = np.asarray(got), np.asarray(ref)
    assert got.shape == ref.shape
    scale = np.maximum(1.0, np.abs(ref).max(axis=-1, keepdims=True))
    err = np.abs(got - ref) / scale
    assert err.max() <= tol, f"scaled err {err.max():.3e} > {tol:g}"


def run_all_three(mem, ev, lf, cfg):
    ref = gather_enrich(mem, ev, lf, cfg, backend="ref")
    full = gather_enrich(mem, ev, lf, cfg, backend="interpret",
                         variant="full")
    hbm = gather_enrich(mem, ev, lf, cfg, backend="interpret",
                        variant="hbm")
    np.testing.assert_array_equal(np.asarray(hbm), np.asarray(full))
    assert_feature_close(full, ref)
    assert_feature_close(hbm, ref)
    return ref


# -- deterministic edge cases -------------------------------------------------

def test_tile_and_pad():
    assert _tile_and_pad(128, 64) == (64, 128)    # exact tiling
    assert _tile_and_pad(100, 64) == (64, 128)    # pad, keep the tile
    assert _tile_and_pad(7, 64) == (7, 7)         # single short tile
    assert _tile_and_pad(300, 128) == (128, 384)
    assert _tile_and_pad(1, 512) == (1, 1)


@pytest.mark.parametrize("R", [1, 7, 100, 128, 300])
def test_non_power_of_two_report_counts(rng, R):
    cfg = get_dfa_config(reduced=True)
    mem, ev, lf = make_case(rng, cfg.flows_per_shard, cfg.history, R)
    ref = run_all_three(mem, ev, lf, cfg)
    assert ref.shape == (R, cfg.derived_dim)


def test_duplicate_flow_ids_in_one_tile(rng):
    """Several reports for the same flow inside one report tile: every
    copy of the row must enrich identically (DMA reads, no writes)."""
    cfg = get_dfa_config(reduced=True)
    F, H = cfg.flows_per_shard, cfg.history
    mem, ev, _ = make_case(rng, F, H, 1)
    lf = J(np.asarray([3, 3, 3, 17, 3, 17, 250, 3] * 8, np.int32))  # R=64=tile
    ref = run_all_three(mem, ev, lf, cfg)
    got = np.asarray(ref)
    rows3 = got[np.asarray(lf) == 3]
    np.testing.assert_array_equal(rows3, np.broadcast_to(rows3[0],
                                                         rows3.shape))


def test_all_invalid_ring_entries(rng):
    """Flows whose entire history ring is invalid: no nan/inf, both
    kernels agree with the oracle's masked-to-zero semantics."""
    cfg = get_dfa_config(reduced=True)
    F, H = cfg.flows_per_shard, cfg.history
    mem, _, lf = make_case(rng, F, H, 64)
    ev = J(np.zeros((F, H), bool))
    ref = run_all_three(mem, ev, lf, cfg)
    assert np.isfinite(np.asarray(ref)).all()


def test_mixed_validity_and_clamped_out_of_range_flows(rng):
    cfg = get_dfa_config(reduced=True)
    F, H = cfg.flows_per_shard, cfg.history
    mem, ev, _ = make_case(rng, F, H, 1)
    lf = J(np.asarray([-5, 0, F - 1, F + 100, 42] * 13, np.int32))  # R=65
    run_all_three(mem, ev, lf, cfg)


def test_paper_scale_f17_h8_hbm_interpret(rng):
    """Acceptance shape: F = 2^17 flows/shard, H = 8 — the ring region
    (~71 MB) can't be a VMEM block; the HBM-tiled kernel must match the
    oracle, and auto-selection must pick it."""
    from repro.kernels import dispatch
    cfg = dataclasses.replace(get_dfa_config(), history=8, flow_tile=128)
    F, H, R = 1 << 17, 8, 256
    assert dispatch.resolve_gather_variant(
        None, cfg, F, H, 128, cfg.derived_dim) == "hbm"
    mem, ev, lf = make_case(rng, F, H, R)
    ref = gather_enrich(mem, ev, lf, cfg, backend="ref")
    hbm = gather_enrich(mem, ev, lf, cfg, backend="interpret")  # auto->hbm
    assert hbm.shape == (R, cfg.derived_dim)
    assert_feature_close(hbm, ref)


def test_collector_enrich_flow_history_routes_fused(rng):
    """collector.enrich_flow_history == gather_flow_history + derive_ref."""
    from repro.core import enrich as ENR
    cfg = REDUCED_HBM
    F, H = cfg.flows_per_shard, cfg.history
    mem, ev, lf = make_case(rng, F, H, 48)
    st = COLL.init_state(cfg)._replace(memory=mem, entry_valid=ev)
    entries, evq = COLL.gather_flow_history(st, lf)
    want = ENR.derive_ref(entries, evq, cfg)
    got = COLL.enrich_flow_history(st, lf, cfg, backend="interpret")
    assert_feature_close(got, want)


# -- randomized sweep (hypothesis; deterministic tests above still run
#    when hypothesis is absent) ----------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        F=st.sampled_from([4, 32, 256, 500]),
        H=st.sampled_from([1, 2, 8, 10]),
        R=st.integers(1, 96),
        report_tile=st.sampled_from([1, 16, 32, 64]),
        derived_dim=st.sampled_from([8, 74, 96, 128]),
        invalid_frac=st.sampled_from([0.0, 0.3, 1.0]),
    )
    def test_equivalence_randomized(seed, F, H, R, report_tile,
                                    derived_dim, invalid_frac):
        cfg = dataclasses.replace(get_dfa_config(reduced=True),
                                  flow_tile=report_tile,
                                  derived_dim=derived_dim)
        rng = np.random.default_rng(seed)
        mem, ev, lf = make_case(rng, F, H, R, invalid_frac)
        ref = run_all_three(mem, ev, lf, cfg)
        assert ref.shape == (R, derived_dim)
