"""Training-loop level fault tolerance: loss goes down, resume is exact,
straggler watchdog fires, heartbeat protocol works."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint as C
from repro.distributed.monitor import Heartbeat, StepMonitor
from repro.launch import train as TR


def test_loss_decreases_and_deterministic(tmp_path):
    losses = TR.main(["--arch", "granite-3-2b", "--reduced",
                      "--steps", "30", "--batch", "4", "--seq", "64",
                      "--lr", "3e-3",
                      "--ckpt-dir", str(tmp_path / "a"),
                      "--ckpt-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_crash_resume_matches_uninterrupted(tmp_path):
    """10 steps + resume for 10 more == 20 straight (step-keyed data)."""
    d1 = str(tmp_path / "run1")
    l_first = TR.main(["--arch", "granite-3-2b", "--reduced",
                       "--steps", "10", "--batch", "4", "--seq", "64",
                       "--schedule-steps", "20", "--warmup", "2",
                       "--ckpt-dir", d1, "--ckpt-every", "10"])
    l_resumed = TR.main(["--arch", "granite-3-2b", "--reduced",
                         "--steps", "20", "--batch", "4", "--seq", "64",
                         "--schedule-steps", "20", "--warmup", "2",
                         "--ckpt-dir", d1, "--ckpt-every", "100",
                         "--resume"])
    d2 = str(tmp_path / "run2")
    l_straight = TR.main(["--arch", "granite-3-2b", "--reduced",
                          "--steps", "20", "--batch", "4", "--seq", "64",
                          "--schedule-steps", "20", "--warmup", "2",
                          "--ckpt-dir", d2, "--ckpt-every", "100"])
    np.testing.assert_allclose(l_resumed, l_straight[10:], rtol=2e-4,
                               atol=2e-4)


def test_straggler_watchdog():
    m = StepMonitor(slow_factor=1.5, max_consecutive_slow=2)
    import time
    for _ in range(3):
        m.start()
        time.sleep(0.01)
        m.stop()
    with pytest.raises(RuntimeError):
        for _ in range(3):
            m.start()
            time.sleep(0.06)
            m.stop()


def test_heartbeat_protocol(tmp_path):
    hb = Heartbeat(str(tmp_path), process_index=0, stale_after_s=1000)
    hb.beat(5)
    assert hb.dead_peers() == {}
    hb2 = Heartbeat(str(tmp_path), process_index=1, stale_after_s=-1)
    hb2.beat(5)
    dead = hb2.dead_peers()
    assert 0 in dead and 1 in dead
