"""Live in-loop recovery: the serving loop absorbs a dead pod mid-serve.

PR 7 proved offline elasticity: stop the world, ``recover_from_snapshot``,
re-feed the lost periods from the trace. The serving loop cannot stop the
world and does not HAVE the trace — it has a paced source that hands out
each batch exactly once. This suite proves the in-loop path closes that
gap with a host-side period journal:

    ServingLoop, (2,2) mesh, snapshots every 2 periods, journal of the
    last snapshot-window's batches
        │  chaos/heartbeat declares pod 0 dead after period t
        ▼
    in-loop ``_recover``: restore newest snapshot, rebuild on the (1,2)
    survivor mesh, re-home the dead pod's flows, re-feed the journaled
    periods since the snapshot, re-stage the pending batch — and keep
    serving, never leaving ``run()``
        │
        ▼
    final state BITWISE ≡ offline ``recover_from_snapshot`` + replaying
    the same captured batches through the survivor ``jit_step``

The recovery wall-clock stall is reported as its own SLO bucket
(``recovery_stall_us``), never mixed into the per-period verdict
latencies. A second death declaration for an already-removed pod (a
heartbeat that keeps seeing the stale roster entry, or a chaos replay)
must be a *counted no-op* — ``duplicate_recovery_skips`` — not a second
rehome; after a heartbeat-triggered recovery the dead processes are
retired from the roster so the trigger disarms itself.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import pod_mesh_or_skip
from repro.checkpoint import checkpoint as CKPT
from repro.configs.dfa import REDUCED
from repro.core.pipeline import DFASystem
from repro.data import scenarios as SC
from repro.distributed.monitor import Heartbeat
from repro.launch import elastic as EL
from repro.launch.serving import HostIngestRing, ServingLoop, build_source

TOTAL_PORTS = 4
EVENTS_PER_PORT = 48
T = 6
SNAP_EVERY = 2
FPS = 512
REPORTER_SLOTS = 64
PORT_CAPACITY = 16

_systems = {}
_trace_cache = {}


def _cfg(pods, shards, nodes=(), snap_every=SNAP_EVERY):
    return dataclasses.replace(
        REDUCED,
        flow_home="rendezvous",
        pods=pods,
        ports_per_pod=TOTAL_PORTS // pods,
        reporter_slots=REPORTER_SLOTS,
        flows_per_shard=FPS,
        port_report_capacity=PORT_CAPACITY,
        home_nodes=nodes,
        snapshot_every_periods=snap_every,
        kernel_backend="ref")


def _system(pods, shards, nodes=(), snap_every=SNAP_EVERY):
    key = (pods, shards, nodes, snap_every)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        _systems[key] = DFASystem(
            _cfg(pods, shards, nodes, snap_every), mesh)
    return _systems[key]


def _trace():
    if "t" not in _trace_cache:
        _trace_cache["t"] = SC.build("cross_pod_mix", TOTAL_PORTS,
                                     EVENTS_PER_PORT, T)
    return _trace_cache["t"]


def _source(system):
    ev, nows = _trace()
    return build_source(system, ev, nows)


def _captured_batches(system, n):
    """The first ``n`` (batch, now) pairs an identically-built source
    yields — the replay source is deterministic, so these are exactly
    what a live loop consumed."""
    src = _source(system)
    return [src.next_batch()[:2] for _ in range(n)]


def _survivor_devices(full):
    return full.mesh.devices.reshape(-1)[:2].tolist()


def _offline_oracle(full, dead_pod, kill_at, snap_dir):
    """What live recovery must reproduce, computed the PR 7 way: run the
    full mesh to ``kill_at``, snapshot at the last multiple of
    SNAP_EVERY, offline-recover, then replay the remaining captured
    batches through the survivor ``jit_step``."""
    batches = _captured_batches(full, T)
    snap_at = (kill_at // SNAP_EVERY) * SNAP_EVERY
    ring = HostIngestRing(full, len(batches[0][0]["ts"]) // full.n_shards)
    step = full.jit_step(donate=True)
    state = full.init_sharded_state()
    for t in range(1, snap_at + 1):
        b, now = batches[t - 1]
        state = step(state, *ring.stage(b, now)).state
    jax.block_until_ready(state)
    CKPT.save(state, snap_dir, step=snap_at,
              keep=full.cfg.snapshot_keep, async_=False)
    new_sys, state, period = EL.recover_from_snapshot(
        full, snap_dir, dead_pod, devices=_survivor_devices(full))
    assert period == snap_at
    new_ring = HostIngestRing(
        new_sys, len(batches[0][0]["ts"]) // new_sys.n_shards)
    new_step = new_sys.jit_step(donate=True)
    for t in range(snap_at + 1, T + 1):
        b, now = batches[t - 1]
        state = new_step(state, *new_ring.stage(b, now)).state
    jax.block_until_ready(state)
    return new_sys, state


@pytest.mark.parametrize("kill_at,expect_replay",
                         [(SNAP_EVERY * 2, 0), (SNAP_EVERY * 2 + 1, 1)],
                         ids=["at-snapshot", "mid-window"])
def test_live_recovery_matches_offline(kill_at, expect_replay, tmp_path):
    """THE differential: kill pod 0 after period ``kill_at`` mid-serve;
    the loop recovers in place (journal replay, no trace access) and the
    final state is bitwise what offline recover-and-replay produces.
    ``mid-window`` kills one period past a snapshot, so exactly one
    journaled period must be re-fed."""
    full = _system(2, 2)
    loop = ServingLoop(
        full, _source(full), snapshot_dir=str(tmp_path / "live"),
        chaos=lambda t: [0] if t == kill_at else [],
        recovery_devices=_survivor_devices(full))
    report = loop.run(T)
    assert report.recoveries == 1
    assert report.journal_replayed == expect_replay
    assert report.duplicate_recovery_skips == 0
    assert len(report.recovery_stall_us) == 1
    assert report.recovery_stall_us[0] > 0
    # the stall is its own bucket: one latency sample per period, none
    # of them the recovery wall
    assert len(report.latency_us) == T
    assert report.balanced
    # the loop really moved to the survivor mesh and kept serving
    assert loop.system.mesh_pods == 1
    assert loop.system.home_nodes == (2, 3)
    assert loop._live_pods == [1] and loop._removed_pods == {0}
    ref_sys, ref_state = _offline_oracle(full, 0, kill_at,
                                         str(tmp_path / "off"))
    assert loop.system.home_nodes == ref_sys.home_nodes
    for a, b in zip(jax.tree.leaves(ref_state),
                    jax.tree.leaves(report.last.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_duplicate_death_declaration_is_counted_noop(tmp_path):
    """Chaos declares pod 0 dead TWICE (a re-trip after removal): one
    recovery happens, the second declaration is skipped and counted, and
    the end state matches the single-kill offline oracle."""
    full = _system(2, 2)
    kill_at = SNAP_EVERY * 2
    loop = ServingLoop(
        full, _source(full), snapshot_dir=str(tmp_path / "live"),
        chaos=lambda t: [0] if t in (kill_at, kill_at + 1) else [],
        recovery_devices=_survivor_devices(full))
    report = loop.run(T)
    assert report.recoveries == 1
    assert report.duplicate_recovery_skips == 1
    assert len(report.recovery_stall_us) == 1
    _, ref_state = _offline_oracle(full, 0, kill_at,
                                   str(tmp_path / "off"))
    for a, b in zip(jax.tree.leaves(ref_state),
                    jax.tree.leaves(report.last.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_heartbeat_trip_recovers_then_disarms(tmp_path):
    """A whole-pod heartbeat trip drives recovery from inside the loop,
    and the recovered-from processes are retired from the roster so the
    trigger fires exactly once — no duplicate declarations on the
    following periods even though the dead processes never beat again."""
    hb_dir = str(tmp_path / "hb")
    roster = {0: 0, 1: 0, 2: 1, 3: 1}
    hb = Heartbeat(hb_dir, process_index=0, stale_after_s=60.0,
                   expected_peers=roster)
    hb.beat(step=0)
    Heartbeat(hb_dir, process_index=1, pod=0).beat(step=0)
    # procs 2, 3 (pod 1) never beat -> whole-pod trip on the first scan
    full = _system(2, 2, snap_every=1)   # snapshot exists by t=1
    loop = ServingLoop(
        full, _source(full), snapshot_dir=str(tmp_path / "snap"),
        heartbeat=hb, recovery_devices=_survivor_devices(full))
    report = loop.run(T)
    assert report.recoveries == 1
    assert report.duplicate_recovery_skips == 0, \
        "retirement did not disarm the heartbeat trigger"
    assert hb.retired == {2, 3}
    assert EL.whole_dead_pods(hb) == []
    assert loop.system.home_nodes == (0, 1)   # pod 1's nodes are gone
    assert report.balanced


def test_recovery_without_snapshots_refused(tmp_path):
    """No snapshot_dir => recovery cannot work; the loop must say so
    instead of crashing into recover_from_snapshot."""
    full = _system(2, 2)
    loop = ServingLoop(full, _source(full), snapshot_dir=None,
                       chaos=lambda t: [0] if t == 1 else [])
    with pytest.raises(RuntimeError, match="needs snapshots"):
        loop.run(T)


def test_journal_window_too_shallow_refused(tmp_path):
    """A restore point older than the journal's reach must fail loudly:
    silently skipping unreplayable periods would serve a state missing
    their updates. Seeded with a period-0 snapshot and snapshotting
    disabled, the journal (depth 2) cannot bridge back to period 0."""
    full = _system(2, 2, snap_every=0)
    snap = str(tmp_path / "snap")
    CKPT.save(full.init_sharded_state(), snap, step=0, keep=1,
              async_=False)
    loop = ServingLoop(full, _source(full), snapshot_dir=snap,
                       chaos=lambda t: [0] if t == 3 else [],
                       recovery_devices=_survivor_devices(full))
    with pytest.raises(RuntimeError, match="journal window"):
        loop.run(T)


def test_journal_bookkeeping(tmp_path):
    """The journal keeps exactly the last snapshot-window's batches with
    1-indexed period tags — the replay invariant every recovery depends
    on."""
    full = _system(2, 2)
    loop = ServingLoop(full, _source(full),
                       snapshot_dir=str(tmp_path))
    assert loop._journal.maxlen == SNAP_EVERY + 1
    report = loop.run(T)
    assert report.recoveries == 0 and report.journal_replayed == 0
    tags = [idx for idx, _, _ in loop._journal]
    assert tags == list(range(T - SNAP_EVERY, T + 1))


def test_maybe_recover_ignores_listed_pods(tmp_path):
    """The offline trigger's double-recovery guard: pods already
    recovered from are excluded from the dead scan."""
    hb_dir = str(tmp_path / "hb")
    hb = Heartbeat(hb_dir, process_index=0,
                   expected_peers={0: 0, 1: 0, 2: 1, 3: 1})
    hb.beat(step=0)
    Heartbeat(hb_dir, process_index=1, pod=0).beat(step=0)
    assert EL.whole_dead_pods(hb) == [1]
    full = _system(2, 2)
    assert EL.maybe_recover(hb, full, str(tmp_path / "nosnap"),
                            ignore_pods=[1]) is None
    # retirement achieves the same standing disarm
    hb.retire_pod(1)
    assert EL.whole_dead_pods(hb) == []
    assert hb.dead_peers() == {}
