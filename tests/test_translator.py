"""Translator semantics: history addressing + routing partition."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs import get_dfa_config
from repro.core import protocol as P
from repro.core import translator as T


def test_history_counter_mod_history():
    cfg = get_dfa_config(reduced=True)
    ts = T.init_state(cfg)
    flow = jnp.zeros((1,), jnp.int32)
    mask = jnp.ones((1,), bool)
    seen = []
    for i in range(2 * cfg.history + 3):
        ts, hist = T.compute_addresses(ts, flow, mask, cfg)
        seen.append(int(hist[0]))
    assert seen == [i % cfg.history for i in range(len(seen))]


def test_same_flow_in_batch_gets_consecutive_history():
    cfg = get_dfa_config(reduced=True)
    ts = T.init_state(cfg)
    flows = jnp.asarray([3, 3, 3, 5], jnp.int32)
    mask = jnp.ones((4,), bool)
    ts, hist = T.compute_addresses(ts, flows, mask, cfg)
    h = np.asarray(hist)
    assert sorted(h[:3].tolist()) == [0, 1, 2]
    assert h[3] == 0
    assert int(ts.hist_counter[3]) == 3 % cfg.history
    assert int(ts.hist_counter[5]) == 1


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 1023), min_size=1, max_size=40),
       st.integers(2, 8))
def test_routing_is_a_partition(flow_ids, n_shards):
    """Every masked report lands exactly once, in its owner's bucket (or is
    dropped by capacity, counted)."""
    fps = 128
    R = len(flow_ids)
    reports = np.zeros((R, P.REPORT_WORDS), np.uint32)
    reports[:, 0] = flow_ids
    reports[:, 2] = np.arange(R) + 1              # payload marker
    mask = np.ones(R, bool)
    cap = 8
    buckets, bmask = T.route_reports(jnp.asarray(reports),
                                     jnp.asarray(mask), n_shards, fps, cap)
    buckets, bmask = np.asarray(buckets), np.asarray(bmask)
    placed = buckets[bmask]
    # each placed report is in the right shard
    for s in range(n_shards):
        for r in buckets[s][bmask[s]]:
            assert min(int(r[0]) // fps, n_shards - 1) == s
    # no duplicates, no inventions
    markers = sorted(placed[:, 2].tolist())
    assert len(set(markers)) == len(markers)
    assert set(markers) <= set(range(1, R + 1))
    # conservation: placed + dropped == total
    assert bmask.sum() <= R
    per_dest = {}
    for f in flow_ids:
        d = min(f // fps, n_shards - 1)
        per_dest[d] = per_dest.get(d, 0) + 1
    expected_placed = sum(min(v, cap) for v in per_dest.values())
    assert bmask.sum() == expected_placed


def test_translate_produces_valid_payloads():
    cfg = get_dfa_config(reduced=True)
    ts = T.init_state(cfg)
    R = 6
    reports = np.zeros((R, P.REPORT_WORDS), np.uint32)
    reports[:, 0] = np.arange(R)                   # local flows 0..5
    reports[:, 2:9] = np.arange(R * 7).reshape(R, 7)
    mask = np.ones(R, bool)
    mask[4] = False
    ts, payloads, coords = T.translate(ts, jnp.asarray(reports),
                                       jnp.asarray(mask), 0, cfg)
    ok = np.asarray(P.payload_valid(payloads))
    assert ok[np.asarray(mask)].all()
    assert (np.asarray(payloads)[~np.asarray(mask)] == 0).all()


def test_batching_beyond_paper():
    cfg = get_dfa_config(reduced=True)
    payloads = jnp.arange(8 * 16, dtype=jnp.uint32).reshape(8, 16)
    mask = jnp.asarray([1, 1, 0, 0, 1, 0, 0, 0], bool)
    msgs, mmask = T.batch_payloads(payloads, mask, batch=4)
    assert msgs.shape == (2, 64)
    assert np.asarray(mmask).tolist() == [True, True]
