"""Translator semantics: history addressing, routing partition, and the
two-stage (pod, shard) exchange invariants.

Plain + deterministic-sweep tests run everywhere; the randomized
property versions additionally run under hypothesis when it is
installed (CI always has it)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

from repro.configs import get_dfa_config
from repro.core import protocol as P
from repro.core import translator as T


def test_history_counter_mod_history():
    cfg = get_dfa_config(reduced=True)
    ts = T.init_state(cfg)
    flow = jnp.zeros((1,), jnp.int32)
    mask = jnp.ones((1,), bool)
    seen = []
    for i in range(2 * cfg.history + 3):
        ts, hist = T.compute_addresses(ts, flow, mask, cfg)
        seen.append(int(hist[0]))
    assert seen == [i % cfg.history for i in range(len(seen))]


def test_same_flow_in_batch_gets_consecutive_history():
    cfg = get_dfa_config(reduced=True)
    ts = T.init_state(cfg)
    flows = jnp.asarray([3, 3, 3, 5], jnp.int32)
    mask = jnp.ones((4,), bool)
    ts, hist = T.compute_addresses(ts, flows, mask, cfg)
    h = np.asarray(hist)
    assert sorted(h[:3].tolist()) == [0, 1, 2]
    assert h[3] == 0
    assert int(ts.hist_counter[3]) == 3 % cfg.history
    assert int(ts.hist_counter[5]) == 1


def _check_routing_partition(flow_ids, n_shards):
    """Every masked IN-RANGE report lands exactly once, in its owner's
    bucket (or is dropped by capacity, counted); an out-of-range flow id
    is a misroute — never placed anywhere, tallied exactly."""
    fps = 128
    R = len(flow_ids)
    reports = np.zeros((R, P.REPORT_WORDS), np.uint32)
    reports[:, 0] = flow_ids
    reports[:, 2] = np.arange(R) + 1              # payload marker
    mask = np.ones(R, bool)
    cap = 8
    buckets, bmask, mis = T.route_reports(
        jnp.asarray(reports), jnp.asarray(mask), n_shards, fps, cap)
    buckets, bmask = np.asarray(buckets), np.asarray(bmask)
    placed = buckets[bmask]
    # each placed report is in the right shard — its OWN shard, not a
    # clipped one
    for s in range(n_shards):
        for r in buckets[s][bmask[s]]:
            assert int(r[0]) // fps == s
    # no duplicates, no inventions, and no out-of-range id ever placed
    markers = sorted(placed[:, 2].tolist())
    oor = {i + 1 for i, f in enumerate(flow_ids)
           if f // fps >= n_shards}
    assert len(set(markers)) == len(markers)
    assert set(markers) <= set(range(1, R + 1)) - oor
    # conservation: placed + capacity drops + misroutes == total
    assert int(mis) == len(oor)
    per_dest = {}
    for f in flow_ids:
        d = f // fps
        if d < n_shards:
            per_dest[d] = per_dest.get(d, 0) + 1
    expected_placed = sum(min(v, cap) for v in per_dest.values())
    assert bmask.sum() == expected_placed


@pytest.mark.parametrize("seed", range(6))
def test_routing_is_a_partition(seed):
    rng = np.random.default_rng(seed)
    _check_routing_partition(
        rng.integers(0, 1024, rng.integers(1, 41)).tolist(),
        int(rng.integers(2, 9)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 1023), min_size=1, max_size=40),
           st.integers(2, 8))
    def test_routing_is_a_partition_hypothesis(flow_ids, n_shards):
        _check_routing_partition(flow_ids, n_shards)


def test_out_of_range_flow_id_never_lands_in_a_ring():
    """Regression: a corrupt/hostile flow id beyond the sharded keyspace
    used to be CLIPPED onto the last real shard (silently misrouting it
    into someone else's ring); now it is dropped at the routing stage
    and counted in the misroutes tally."""
    fps, n_shards, cap = 128, 4, 8
    reports = np.zeros((3, P.REPORT_WORDS), np.uint32)
    reports[0, 0] = 5                        # in range -> shard 0
    reports[1, 0] = n_shards * fps + 7       # one shard past the keyspace
    reports[2, 0] = 0xFFFFFFFF               # hostile id (negative in i32)
    reports[:, 2] = [1, 2, 3]                # payload markers
    mask = np.ones(3, bool)
    buckets, bmask, mis = T.route_reports(
        jnp.asarray(reports), jnp.asarray(mask), n_shards, fps, cap)
    buckets, bmask = np.asarray(buckets), np.asarray(bmask)
    assert int(mis) == 2
    placed = buckets[bmask]
    assert placed.shape[0] == 1 and placed[0, 2] == 1
    # the last shard in particular holds nothing — that is where the old
    # clip used to land both corrupt rows
    assert not bmask[n_shards - 1].any()
    # two-stage path: the shard coordinate of a corrupt id is still in
    # range (floor mod), so it survives stage 1 — the POD coordinate is
    # what carries the out-of-range signal into stage 2's misroute count
    pods, S = 2, 2
    hpod, hshard, _ = (np.asarray(x) for x in T.home_coords(
        jnp.asarray(reports[:, 0]), fps, S, pods * S))
    assert 0 <= hshard[1] < S and 0 <= hshard[2] < S
    assert not (0 <= hpod[1] < pods) and not (0 <= hpod[2] < pods)
    corrupt = reports[1:]
    empty = np.zeros((2, P.REPORT_WORDS), np.uint32)
    out, om = _emulate_two_stage(
        [corrupt] + [empty.copy()] * (pods * S - 1),
        [np.ones(2, bool)] + [np.zeros(2, bool)] * (pods * S - 1),
        pods, S, fps)
    assert not om.any(), "corrupt flow id was delivered to a ring"


def test_translate_produces_valid_payloads():
    cfg = get_dfa_config(reduced=True)
    ts = T.init_state(cfg)
    R = 6
    reports = np.zeros((R, P.REPORT_WORDS), np.uint32)
    reports[:, 0] = np.arange(R)                   # local flows 0..5
    reports[:, 2:9] = np.arange(R * 7).reshape(R, 7)
    mask = np.ones(R, bool)
    mask[4] = False
    ts, payloads, coords = T.translate(ts, jnp.asarray(reports),
                                       jnp.asarray(mask), 0, cfg)
    ok = np.asarray(P.payload_valid(payloads))
    assert ok[np.asarray(mask)].all()
    assert (np.asarray(payloads)[~np.asarray(mask)] == 0).all()


def test_batching_beyond_paper():
    cfg = get_dfa_config(reduced=True)
    payloads = jnp.arange(8 * 16, dtype=jnp.uint32).reshape(8, 16)
    mask = jnp.asarray([1, 1, 0, 0, 1, 0, 0, 0], bool)
    msgs, mmask = T.batch_payloads(payloads, mask, batch=4)
    assert msgs.shape == (2, 64)
    assert np.asarray(mmask).tolist() == [True, True]


# -- two-stage (pod, shard) routing invariants in isolation ---------------
#
# The exchanges themselves (all_to_all) are emulated with numpy
# transposes — `tiled` all_to_all over an axis is exactly "device i's
# bucket j becomes device j's chunk i" — so these properties pin the pure
# routing functions (home_flow_ids / home_coords / route_by_dest /
# canonical_order) without paying an SPMD compile per example. The full
# mesh path is covered end to end by tests/test_multipod_equiv.py.


def _emulate_two_stage(reports_by_dev, masks_by_dev, pods, S, fps):
    """[ingest dev] -> (reports, mask) after both exchange stages, at
    each (pod, shard) home device. Capacities sized no-drop."""
    ndev = pods * S
    W = reports_by_dev[0].shape[1]
    cap1 = max(1, max(r.shape[0] for r in reports_by_dev))
    # stage 1: per-device bucket by home shard, exchange within each pod
    b1 = np.zeros((ndev, S, cap1, W), np.uint32)
    m1 = np.zeros((ndev, S, cap1), bool)
    for d in range(ndev):
        rep, msk = reports_by_dev[d], masks_by_dev[d]
        _, hshard, _ = T.home_coords(jnp.asarray(rep[:, 0]), fps, S, ndev)
        bb, bm, _ = T.route_by_dest(jnp.asarray(rep), jnp.asarray(msk),
                                    hshard, S, cap1)
        b1[d], m1[d] = np.asarray(bb), np.asarray(bm)
    b1 = b1.reshape(pods, S, S, cap1, W).transpose(0, 2, 1, 3, 4)
    m1 = m1.reshape(pods, S, S, cap1).transpose(0, 2, 1, 3)
    r1 = b1.reshape(ndev, S * cap1, W)
    m1 = m1.reshape(ndev, S * cap1)
    # stage 2: bucket by home pod, exchange across pods at fixed shard
    cap2 = S * cap1
    b2 = np.zeros((ndev, pods, cap2, W), np.uint32)
    m2 = np.zeros((ndev, pods, cap2), bool)
    for d in range(ndev):
        hpod, _, _ = T.home_coords(jnp.asarray(r1[d][:, 0]), fps, S, ndev)
        bb, bm, _ = T.route_by_dest(jnp.asarray(r1[d]), jnp.asarray(m1[d]),
                                    hpod, pods, cap2)
        b2[d], m2[d] = np.asarray(bb), np.asarray(bm)
    b2 = b2.reshape(pods, S, pods, cap2, W).transpose(2, 1, 0, 3, 4)
    m2 = m2.reshape(pods, S, pods, cap2).transpose(2, 1, 0, 3)
    return (b2.reshape(ndev, pods * cap2, W),
            m2.reshape(ndev, pods * cap2))


def _check_two_stage_exactly_once(key_seeds, mesh_shape, spread):
    """Every valid report is delivered exactly once, to its home
    (pod, shard); padding rows never cross either exchange stage."""
    pods, S = mesh_shape
    ndev, fps = pods * S, 16
    G = ndev * fps
    rng = np.random.default_rng(spread)
    keys = np.stack([rng.integers(1, 2**31, 5, dtype=np.int64)
                     .astype(np.uint32) * np.uint32(k % 977 + 1)
                     for k in key_seeds])
    homes = np.asarray(T.home_flow_ids(jnp.asarray(keys), G))
    R = len(keys)
    # scatter the reports across ingest devices, with padding rows mixed
    # in (marker word 2 identifies each real report)
    reports_by_dev, masks_by_dev = [], []
    ingest_dev = rng.integers(0, ndev, R)
    for d in range(ndev):
        rows = np.where(ingest_dev == d)[0]
        rep = np.zeros((max(len(rows), 1) + 2, P.REPORT_WORDS), np.uint32)
        msk = np.zeros(rep.shape[0], bool)
        for j, r in enumerate(rows):
            rep[j, 0] = homes[r]
            rep[j, 2] = r + 1                  # unique marker
            msk[j] = True
        # padding rows carry poison that must never be delivered
        rep[len(rows):, 2] = 0xDEAD
        reports_by_dev.append(rep)
        masks_by_dev.append(msk)
    out, om = _emulate_two_stage(reports_by_dev, masks_by_dev, pods, S,
                                 fps)
    delivered = {}
    for d in range(ndev):
        for row in out[d][om[d]]:
            assert row[2] != 0xDEAD, "padding row leaked a mask"
            marker = int(row[2])
            assert marker not in delivered, "duplicate delivery"
            delivered[marker] = d
    assert set(delivered) == set(range(1, R + 1)), "lost reports"
    for r in range(R):
        home_dev = int(homes[r]) // fps
        assert delivered[r + 1] == home_dev, (
            f"report {r} landed on device {delivered[r + 1]}, "
            f"home is {home_dev}")


_SHAPES = ((1, 2), (2, 2), (2, 4), (4, 2), (4, 1))


@pytest.mark.parametrize("shape", _SHAPES)
@pytest.mark.parametrize("seed", range(3))
def test_two_stage_delivers_exactly_once(shape, seed):
    rng = np.random.default_rng(seed + 101)
    key_seeds = rng.integers(1, 2**31, rng.integers(1, 25)).tolist()
    _check_two_stage_exactly_once(key_seeds, shape, seed)


def _check_dup_keys_converge(seed_a, mesh_shape):
    """The same five-tuple observed on ports of two DIFFERENT pods names
    one home ring: identical flow id, identical (pod, shard) coords."""
    pods, S = mesh_shape
    ndev, fps = pods * S, 32
    G = ndev * fps
    rng = np.random.default_rng(seed_a % (2**31))
    key = rng.integers(1, 2**31, (1, 5)).astype(np.uint32)
    fid = np.asarray(T.home_flow_ids(jnp.asarray(key), G))
    assert fid.shape == (1,) and 0 <= int(fid[0]) < G
    hp, hs, hd = (np.asarray(x) for x in T.home_coords(
        jnp.asarray(fid), fps, S, ndev))
    assert int(hd[0]) == int(hp[0]) * S + int(hs[0])
    # observation pod is irrelevant by construction: the id is a pure
    # function of the key — route a report from each pod and check both
    # land on the same device
    rep = np.zeros((1, P.REPORT_WORDS), np.uint32)
    rep[0, 0] = fid[0]
    rep[0, 2] = 1
    empty = np.zeros((1, P.REPORT_WORDS), np.uint32)
    reports = [rep.copy() if d in (0, ndev - 1) else empty.copy()
               for d in range(ndev)]
    masks = [np.asarray([d in (0, ndev - 1)]) for d in range(ndev)]
    out, om = _emulate_two_stage(reports, masks, pods, S, fps)
    landed = [d for d in range(ndev) if om[d].any()]
    assert landed == [int(hd[0])]
    assert int(om[int(hd[0])].sum()) == 2     # both copies, one ring


@pytest.mark.parametrize("shape", ((2, 2), (4, 2), (2, 4)))
@pytest.mark.parametrize("seed", (0, 7, 123456))
def test_dup_keys_from_two_pods_converge(shape, seed):
    _check_dup_keys_converge(seed, shape)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(1, 2**31), min_size=1, max_size=24),
           st.sampled_from(list(_SHAPES)), st.integers(0, 3))
    def test_two_stage_exactly_once_hypothesis(key_seeds, mesh_shape,
                                               spread):
        _check_two_stage_exactly_once(key_seeds, mesh_shape, spread)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from([(2, 2), (4, 2), (2, 4)]))
    def test_dup_keys_converge_hypothesis(seed_a, mesh_shape):
        _check_dup_keys_converge(seed_a, mesh_shape)


def test_canonical_order_is_permutation_invariant():
    """Home-side re-ordering erases the exchange interleaving: any
    permutation of the same batch canonicalizes to the same array, valid
    rows sorted by (flow, reporter, seq), padding rows last."""
    rng = np.random.default_rng(0)
    R = 40
    reports = np.zeros((R, P.REPORT_WORDS), np.uint32)
    mask = rng.random(R) < 0.7
    reports[:, 0] = rng.integers(0, 64, R)
    rid = rng.integers(0, 8, R).astype(np.uint32)
    seq = rng.integers(0, 256, R).astype(np.uint32)
    reports[:, 1] = (rid << 24) | (seq << 16)
    reports[~mask] = 0
    ref_r, ref_m = (np.asarray(x) for x in T.canonical_order(
        jnp.asarray(reports), jnp.asarray(mask)))
    n_valid = int(mask.sum())
    assert ref_m[:n_valid].all() and not ref_m[n_valid:].any()
    keys = [(int(r[0]), int(r[1]) >> 24, (int(r[1]) >> 16) & 0xFF)
            for r in ref_r[:n_valid]]
    assert keys == sorted(keys)
    for _ in range(5):
        perm = rng.permutation(R)
        got_r, got_m = (np.asarray(x) for x in T.canonical_order(
            jnp.asarray(reports[perm]), jnp.asarray(mask[perm])))
        np.testing.assert_array_equal(got_r[got_m], ref_r[ref_m])
        np.testing.assert_array_equal(got_m, ref_m)
