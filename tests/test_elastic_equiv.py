"""Elastic pod failure recovery: kill a pod mid-trace, prove nothing lost.

The PR 5 harness proved the merged DFA state is mesh-factorization
independent; this suite proves it is *roster*-independent under HRW
homing plus snapshot/restore — the property that makes pod loss
survivable:

    (2,2) mesh, roster {0,1,2,3}, snapshots every 2 periods
        │  pod 0 dies after period 4
        ▼
    recover_from_snapshot: restore period-4 snapshot, rebuild on a
    (1,2) mesh with roster {2,3}, re-home ONLY the dead pod's flows
        │  replay periods 5..T (the documented replay window)
        ▼
    merged end state + per-period outputs ≡ a clean run of the whole
    trace on the (1,2)/{2,3} mesh — BITWISE.

Why bitwise is achievable: HRW's restriction property (removing a node
never changes surviving keys' winners), node-id-encoded flow ids
(survivor ring blocks move without rewrites), port-major reporter state
(the same total port set replays the same report streams), and the
stored five-tuple in every ring entry (dead flows re-home from the entry
itself). The replay window is exact here because the harness re-feeds
the lost periods; live deployments lose at most
``snapshot_every_periods`` periods of updates.

Merged-state canonicalization follows test_multipod_equiv: reporter
arrays are port-major global; translator/collector rows are compared on
the shared node blocks; ``last_seq`` merges by elementwise max and the
scalar telemetry counters by sum (their per-device placement is a
topology artifact — recovery folds the dead pod's values into survivor
device 0).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import pod_mesh_or_skip
from repro.checkpoint import checkpoint as CKPT
from repro.configs.dfa import REDUCED
from repro.core import reporter as REP
from repro.core import translator as TRANS
from repro.core.pipeline import DFASystem
from repro.data import scenarios as SC
from repro.launch import elastic as EL

TOTAL_PORTS = 4
EVENTS_PER_PORT = 48
T = 6                    # trace periods; snapshots land at 2, 4, (6)
KILL_AT = 4              # pod dies after this period's snapshot
SNAP_EVERY = 2
FPS = 512                # ring rows per device — FIXED across rosters
REPORTER_SLOTS = 64      # per-PORT Marina table, fixed across rosters
PORT_CAPACITY = 16

_systems = {}
_traces = {}


def _cfg(pods, shards, nodes=()):
    return dataclasses.replace(
        REDUCED,
        flow_home="rendezvous",
        pods=pods,
        ports_per_pod=TOTAL_PORTS // pods,
        reporter_slots=REPORTER_SLOTS,
        flows_per_shard=FPS,
        port_report_capacity=PORT_CAPACITY,
        home_nodes=nodes,
        snapshot_every_periods=SNAP_EVERY,
        kernel_backend="ref")


def _system(pods, shards, nodes=()):
    key = (pods, shards, nodes)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        _systems[key] = DFASystem(_cfg(pods, shards, nodes), mesh)
    return _systems[key]


def _trace(name):
    if name not in _traces:
        ev, nows = SC.build(name, TOTAL_PORTS, EVENTS_PER_PORT, T)
        _traces[name] = ({k: jnp.asarray(v) for k, v in ev.items()},
                         jnp.asarray(nows))
    return _traces[name]


def _merged_state(system, state):
    """Roster-canonical view of DFAState (see module docstring)."""
    n = system.n_shards
    out = {f"rep.{k}": np.asarray(a)
           for k, a in state.reporter._asdict().items()}
    out["tr.hist_counter"] = np.asarray(state.translator.hist_counter)
    c = state.collector
    out["coll.memory"] = np.asarray(c.memory)
    out["coll.entry_valid"] = np.asarray(c.entry_valid)
    out["coll.last_seq"] = np.asarray(c.last_seq).reshape(n, -1).max(0)
    for k in ("bad_checksum", "seq_anomalies", "received",
              "lost_reports"):
        out[f"coll.{k}"] = np.asarray(getattr(c, k)).astype(
            np.uint64).sum()
    return out


def _canon_periods(out):
    """Per period: flow-id-sorted (fid, enriched) — row order inside a
    period is an exchange artifact; the VALUES must match bitwise."""
    enr, fid, em = (np.asarray(out.enriched), np.asarray(out.flow_ids),
                    np.asarray(out.mask))
    per = []
    for t in range(enr.shape[0]):
        m = em[t]
        order = np.argsort(fid[t][m], kind="stable")
        per.append({"fid": fid[t][m][order], "enr": enr[t][m][order]})
    return per


def _assert_state_eq(ref, got, ctx):
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k],
                                      err_msg=f"{ctx}: state {k}")


# -- HRW properties (pure translator, no mesh) ---------------------------

def test_hrw_restriction_property(rng):
    """Removing a node never changes a surviving key's winner — THE
    property recovery correctness rests on."""
    kh = jnp.asarray(rng.integers(0, 2**32, size=4096, dtype=np.uint32))
    full = jnp.asarray(range(8), jnp.uint32)
    pos_full = np.asarray(TRANS.rendezvous_position(kh, full))
    for dead in (0, 3, 7):
        survivors = np.asarray([n for n in range(8) if n != dead],
                               np.uint32)
        pos_sub = np.asarray(TRANS.rendezvous_position(
            kh, jnp.asarray(survivors)))
        stay = np.asarray(full)[pos_full] != dead
        # survivors keep their winner...
        np.testing.assert_array_equal(
            survivors[pos_sub[stay]], np.asarray(full)[pos_full[stay]],
            err_msg=f"dead={dead}: a surviving key changed home")
        # ...and only ~1/8 of keys move at all (binomial 3-sigma bounds)
        moved = float((~stay).mean())
        assert 0.06 < moved < 0.20, \
            f"dead={dead}: {moved:.3f} of keys moved, expected ~1/8"


def test_rendezvous_flow_ids_movement_bound(rng):
    """Flow ids over the survivor roster: unchanged for surviving homes
    (node id AND slot), re-homed only for the dead node's flows."""
    keys = jnp.asarray(rng.integers(0, 2**32, size=(512, 5),
                                    dtype=np.uint32))
    full = jnp.asarray(range(4), jnp.uint32)
    sub = jnp.asarray([0, 1, 3], jnp.uint32)      # node 2 died
    fid_full = np.asarray(TRANS.rendezvous_flow_ids(keys, full, FPS))
    fid_sub = np.asarray(TRANS.rendezvous_flow_ids(keys, sub, FPS))
    stay = (fid_full // FPS) != 2
    np.testing.assert_array_equal(fid_full[stay], fid_sub[stay])
    # dead-node flows land on survivors, same slot (roster-free hash)
    assert (fid_sub[~stay] // FPS != 2).all()
    np.testing.assert_array_equal(fid_full[~stay] % FPS,
                                  fid_sub[~stay] % FPS)
    assert (~stay).any(), "trace never homed a flow on the dead node"


def test_home_nodes_validation():
    mesh = pod_mesh_or_skip(1, 2)
    with pytest.raises(ValueError, match="entries"):
        DFASystem(_cfg(1, 2, nodes=(0, 1, 2)), mesh)
    with pytest.raises(ValueError, match="strictly increasing"):
        DFASystem(_cfg(1, 2, nodes=(3, 1)), mesh)


# -- factorization invariance of the rendezvous scheme -------------------

@pytest.mark.parametrize("scenario", ["cross_pod_mix", "elephants_mice"])
def test_rendezvous_factorization_invariance(scenario):
    """Same 2-device roster {0,1} as (1,2) and (2,1): merged state and
    per-period outputs bitwise equal — rendezvous inherits the PR 5
    pod-count-invariance contract."""
    events, nows = _trace(scenario)
    ref_sys, alt_sys = _system(1, 2), _system(2, 1)
    with ref_sys.mesh:
        ref = ref_sys.stream(ref_sys.init_state(), events, nows)
    with alt_sys.mesh:
        alt = alt_sys.stream(alt_sys.init_state(), events, nows)
    assert int(np.asarray(ref.metrics["reports_recv"]).sum()) > 0
    _assert_state_eq(_merged_state(ref_sys, ref.state),
                     _merged_state(alt_sys, alt.state), scenario)
    for t, (r, g) in enumerate(zip(_canon_periods(ref),
                                   _canon_periods(alt))):
        for k in r:
            np.testing.assert_array_equal(
                r[k], g[k], err_msg=f"{scenario}: period {t} {k}")


# -- snapshotting --------------------------------------------------------

def test_snapshot_stream_bitwise_identical(tmp_path):
    """The chunk-and-checkpoint stream path is pure observation: outputs
    and end state bitwise equal to the unchunked stream, snapshots land
    at every period boundary multiple of SNAP_EVERY plus the final
    period, and the newest snapshot restores to exactly the end state."""
    events, nows = _trace("cross_pod_mix")
    sysm = _system(1, 2)
    with sysm.mesh:
        plain = sysm.stream(sysm.init_state(), events, nows)
        snap = sysm.stream(sysm.init_state(), events, nows,
                           snapshot_dir=str(tmp_path))
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(snap)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert CKPT.list_steps(str(tmp_path)) == [2, 4, 6]
    restored, step = CKPT.restore(str(tmp_path))
    assert step == T
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(snap.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- the tentpole differential: kill a pod mid-trace ---------------------

def _kill_and_recover(scenario, dead_pod, snap_dir):
    """(2,2) streams KILL_AT periods with snapshots; ``dead_pod`` dies;
    recovery replays the rest on the (1,2) survivor mesh."""
    events, nows = _trace(scenario)
    full = _system(2, 2)
    with full.mesh:
        full.stream(full.init_state(),
                    {k: v[:KILL_AT] for k, v in events.items()},
                    nows[:KILL_AT], snapshot_dir=snap_dir)
    devices = full.mesh.devices.reshape(-1)[:2].tolist()
    new_sys, new_state, period = EL.recover_from_snapshot(
        full, snap_dir, dead_pod, devices=devices)
    assert period == KILL_AT
    with new_sys.mesh:
        out = new_sys.stream(new_state,
                             {k: v[period:] for k, v in events.items()},
                             nows[period:])
    return new_sys, out


@pytest.mark.parametrize("scenario", ["cross_pod_mix", "elephants_mice",
                                      "flow_churn"])
def test_kill_a_pod_matches_clean_small_mesh(scenario, tmp_path):
    """THE correctness anchor: survivor-mesh end state after recovery +
    replay ≡ a clean full-trace run on the small mesh — bitwise, for
    state, replayed per-period outputs AND per-period metric deltas."""
    events, nows = _trace(scenario)
    new_sys, out = _kill_and_recover(scenario, 0, str(tmp_path))
    assert new_sys.home_nodes == (2, 3)
    clean_sys = _system(1, 2, nodes=(2, 3))
    with clean_sys.mesh:
        clean = clean_sys.stream(clean_sys.init_state(), events, nows)
    assert int(np.asarray(clean.metrics["reports_recv"]).sum()) > 0
    _assert_state_eq(_merged_state(clean_sys, clean.state),
                     _merged_state(new_sys, out.state), scenario)
    # the replayed window's outputs match the clean run's same periods
    ref = _canon_periods(clean)[KILL_AT:]
    got = _canon_periods(out)
    assert len(ref) == len(got) == T - KILL_AT
    for t, (r, g) in enumerate(zip(ref, got)):
        for k in r:
            np.testing.assert_array_equal(
                r[k], g[k],
                err_msg=f"{scenario}: replayed period {KILL_AT + t} {k}")
    for k, v in out.metrics.items():
        np.testing.assert_array_equal(
            np.asarray(clean.metrics[k])[KILL_AT:], np.asarray(v),
            err_msg=f"{scenario}: replayed metric {k}")


def test_kill_pod_one(tmp_path):
    """Killing the OTHER pod exercises the non-contiguous survivor slice
    (positions 0,1 survive, 2,3 die)."""
    new_sys, out = _kill_and_recover("cross_pod_mix", 1, str(tmp_path))
    assert new_sys.home_nodes == (0, 1)
    events, nows = _trace("cross_pod_mix")
    clean_sys = _system(1, 2, nodes=(0, 1))
    with clean_sys.mesh:
        clean = clean_sys.stream(clean_sys.init_state(), events, nows)
    _assert_state_eq(_merged_state(clean_sys, clean.state),
                     _merged_state(new_sys, out.state), "dead_pod=1")


def test_elastic_recovery_smoke(tmp_path):
    """CI anchor (tier-1-deselected, dedicated smoke step): one
    kill-recover-replay cycle end to end, plus the heartbeat trigger
    wiring — a registered pod that never beats fires whole_dead_pods and
    maybe_recover returns the survivor system."""
    from repro.distributed.monitor import Heartbeat
    snap = str(tmp_path / "snap")
    new_sys, out = _kill_and_recover("cross_pod_mix", 0, snap)
    assert int(np.asarray(out.metrics["reports_recv"]).sum()) > 0
    assert new_sys.mesh_pods == 1 and new_sys.total_ports == TOTAL_PORTS
    d = new_sys.describe()
    assert d["flow_home"] == "rendezvous"
    assert d["home_nodes"] == (2, 3)
    assert d["snapshot_every_periods"] == SNAP_EVERY
    # trigger wiring: procs 0,1 = pod 0 beat; procs 2,3 = pod 1 never do
    hb_dir = str(tmp_path / "hb")
    roster = {0: 0, 1: 0, 2: 1, 3: 1}
    hb = Heartbeat(hb_dir, process_index=0, stale_after_s=60.0,
                   expected_peers=roster)
    hb.beat(step=1)
    Heartbeat(hb_dir, process_index=1, pod=0).beat(step=1)
    assert EL.whole_dead_pods(hb) == [1]
    full = _system(2, 2)
    devices = full.mesh.devices.reshape(-1)[:2].tolist()
    got = EL.maybe_recover(hb, full, snap, devices=devices)
    assert got is not None
    rec_sys, _, period = got
    assert period == KILL_AT
    assert rec_sys.home_nodes == (0, 1)   # pod 1 dead -> nodes 2,3 gone


# -- the V2 wide format survives pod loss past the V1 port wall ----------

V2_PORTS = 264           # > the V1 8-bit reporter-id space
V2_EVENTS_PER_PORT = 4


def _cfg_v2(pods, shards, nodes=()):
    """The elastic config under wire_format='v2' with 264 ports.

    elephants_mice shares the SAME 24 flow keys the V1 suite streams;
    the ring grows to 1024 rows/device because at FPS=512 two of those
    keys alias to one (node, slot) pair on node 0 under the full roster
    (the documented unsplittable-collision case — recovery cannot split
    a shared ring row). At 1024 all 24 keys map to distinct flow ids on
    both rosters, so only the reporter-id population changes (264 ports
    instead of 4) — exactly the field the wide format widens."""
    return dataclasses.replace(
        _cfg(pods, shards, nodes),
        wire_format="v2",
        ports_per_pod=V2_PORTS // pods,
        flows_per_shard=1024,
        reporter_slots=32,
        port_report_capacity=32)


def test_v2_kill_a_pod_past_256_ports(tmp_path):
    """Kill-recover-replay with 264 virtual ports under V2: recovery's
    checksum refold and seq merge run against the wide schema, and the
    survivor end state still matches a clean small-mesh run bitwise."""
    ev, nows_np = SC.build("elephants_mice", V2_PORTS,
                           V2_EVENTS_PER_PORT, T)
    events = {k: jnp.asarray(v) for k, v in ev.items()}
    nows = jnp.asarray(nows_np)
    full = DFASystem(_cfg_v2(2, 2), pod_mesh_or_skip(2, 2))
    assert full.wire.name == "v2" and full.total_ports == V2_PORTS
    with full.mesh:
        full.stream(full.init_state(),
                    {k: v[:KILL_AT] for k, v in events.items()},
                    nows[:KILL_AT], snapshot_dir=str(tmp_path))
    devices = full.mesh.devices.reshape(-1)[:2].tolist()
    new_sys, new_state, period = EL.recover_from_snapshot(
        full, str(tmp_path), 0, devices=devices)
    assert period == KILL_AT and new_sys.home_nodes == (2, 3)
    with new_sys.mesh:
        out = new_sys.stream(new_state,
                             {k: v[period:] for k, v in events.items()},
                             nows[period:])
    clean_sys = DFASystem(_cfg_v2(1, 2, nodes=(2, 3)),
                          pod_mesh_or_skip(1, 2))
    with clean_sys.mesh:
        clean = clean_sys.stream(clean_sys.init_state(), events, nows)
    assert int(np.asarray(clean.metrics["reports_recv"]).sum()) > 0
    got = _merged_state(new_sys, out.state)
    # ports past the V1 wall really reported before AND after the kill
    assert (got["rep.seq"][256:] > 0).any(), \
        "no port beyond the 8-bit space reported — the wide field was " \
        "never exercised"
    _assert_state_eq(_merged_state(clean_sys, clean.state), got,
                     "v2 elephants_mice")
    ref = _canon_periods(clean)[KILL_AT:]
    for t, (r, g) in enumerate(zip(ref, _canon_periods(out))):
        for k in r:
            np.testing.assert_array_equal(
                r[k], g[k],
                err_msg=f"v2: replayed period {KILL_AT + t} {k}")


# -- guard rails ---------------------------------------------------------

def test_recovery_refuses_range_hash_home():
    """flow_home='hash' renumbers the whole keyspace on a roster change —
    recovery must refuse instead of silently scrambling flow identity."""
    mesh = pod_mesh_or_skip(2, 2)
    cfg = dataclasses.replace(_cfg(2, 2), flow_home="hash", home_nodes=())
    sysm = DFASystem(cfg, mesh)
    with pytest.raises(ValueError, match="rendezvous"):
        EL.survivor_config(sysm, 0)


def test_survivor_config_validation():
    sysm = _system(2, 2)
    with pytest.raises(ValueError, match="not in"):
        EL.survivor_config(sysm, 5)
    single = _system(1, 2)
    with pytest.raises(ValueError, match="single-pod"):
        EL.survivor_config(single, 0)
