"""Kernel dispatch layer: registry contents, backend-selection precedence,
ref vs interpret equivalence for every family, the env-override contract on
the full dfa_step, and run_periods streaming equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.kernels import dispatch
from repro.kernels.derived_features.ops import derived_features
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flow_moments.ops import flow_moments
from repro.kernels.gather_enrich.ops import gather_enrich
from repro.kernels.ring_scatter.ops import ring_scatter

J = jnp.asarray
FAMILIES = ("flow_moments", "ring_scatter", "derived_features",
            "gather_enrich", "gather_enrich_hbm", "ingest_update",
            "ingest_update_hbm", "flash_attention")


# -- registry & selection -----------------------------------------------------

def test_registry_carries_all_backends_for_all_families():
    assert set(FAMILIES) <= set(dispatch.families())
    for fam in FAMILIES:
        assert set(dispatch.implementations(fam)) == set(dispatch.BACKENDS)


def test_negotiate_tile():
    assert dispatch.negotiate_tile(256, 512) == 256   # clamp to size
    assert dispatch.negotiate_tile(512, 512) == 512
    assert dispatch.negotiate_tile(300, 128) == 100   # largest divisor
    assert dispatch.negotiate_tile(7, 4) == 1         # prime -> 1
    assert dispatch.negotiate_tile(128, 64) == 64


def test_backend_precedence(monkeypatch):
    cfg = get_dfa_config(reduced=True)
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    # auto on CPU -> ref
    assert dispatch.resolve_backend(None, cfg) == "ref"
    assert dispatch.resolve_backend("auto", cfg) == "ref"
    # config field beats auto
    cfg_i = dataclasses.replace(cfg, kernel_backend="interpret")
    assert dispatch.resolve_backend(None, cfg_i) == "interpret"
    # env beats config
    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    assert dispatch.resolve_backend(None, cfg_i) == "ref"
    # explicit argument beats env
    assert dispatch.resolve_backend("interpret", cfg_i) == "interpret"
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda", cfg)


def test_unknown_family_raises():
    with pytest.raises(KeyError):
        dispatch.lookup("no_such_kernel")


def test_unknown_env_backend_always_raises(monkeypatch):
    """Regression: a typo'd REPRO_KERNEL_BACKEND used to be silently
    ignored whenever the call site passed an explicit backend= (explicit
    wins the precedence fight, so the env value was never validated).
    A malformed env var must raise with the registered backends listed,
    no matter what else is set."""
    cfg = get_dfa_config(reduced=True)
    monkeypatch.setenv(dispatch.ENV_VAR, "cuda")
    for explicit in (None, "auto", "ref", "interpret"):
        with pytest.raises(ValueError) as ei:
            dispatch.resolve_backend(explicit, cfg)
        msg = str(ei.value)
        assert dispatch.ENV_VAR in msg
        for b in dispatch.BACKENDS:
            assert b in msg
    with pytest.raises(ValueError):
        dispatch.lookup("gather_enrich", "ref", cfg)


def test_unknown_cfg_backend_raises(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              kernel_backend="vulkan")
    with pytest.raises(ValueError) as ei:
        dispatch.resolve_backend(None, cfg)
    assert "kernel_backend" in str(ei.value)
    # explicit argument still beats a malformed config field (only the
    # env var is validated unconditionally: config is code, env is ops)
    assert dispatch.resolve_backend("ref", cfg) == "ref"


# -- gather_enrich memory-strategy variant ------------------------------------

def test_gather_variant_precedence(monkeypatch):
    cfg = get_dfa_config(reduced=True)
    F, H = cfg.flows_per_shard, cfg.history
    args = (F, H, 64, cfg.derived_dim)
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR, raising=False)
    # auto on the reduced config: ring region fits VMEM -> full
    assert dispatch.resolve_gather_variant(None, cfg, *args) == "full"
    # config field beats auto
    cfg_h = dataclasses.replace(cfg, gather_variant="hbm")
    assert dispatch.resolve_gather_variant(None, cfg_h, *args) == "hbm"
    # env beats config
    monkeypatch.setenv(dispatch.GATHER_ENV_VAR, "full")
    assert dispatch.resolve_gather_variant(None, cfg_h, *args) == "full"
    # explicit argument beats env
    assert dispatch.resolve_gather_variant("hbm", cfg_h, *args) == "hbm"
    # malformed env raises even under an explicit argument
    monkeypatch.setenv(dispatch.GATHER_ENV_VAR, "sram")
    for explicit in (None, "auto", "full", "hbm"):
        with pytest.raises(ValueError) as ei:
            dispatch.resolve_gather_variant(explicit, cfg, *args)
        assert dispatch.GATHER_ENV_VAR in str(ei.value)
        assert "hbm" in str(ei.value)


def test_gather_variant_vmem_budget_heuristic(monkeypatch):
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR, raising=False)
    reduced = get_dfa_config(reduced=True)
    paper = get_dfa_config()
    # reduced ring (~170 KB) fits a 16 MB budget; paper ring (~84 MB)
    # cannot -> the Tofino-scale config auto-selects the HBM-tiled path
    assert dispatch.resolve_gather_variant(
        None, reduced, reduced.flows_per_shard, reduced.history, 64,
        reduced.derived_dim) == "full"
    assert dispatch.resolve_gather_variant(
        None, paper, paper.flows_per_shard, paper.history, 512,
        paper.derived_dim) == "hbm"
    # shrinking the budget flips the reduced config to hbm too
    tiny = dataclasses.replace(reduced, vmem_budget_mb=0)
    assert dispatch.resolve_gather_variant(
        None, tiny, tiny.flows_per_shard, tiny.history, 64,
        tiny.derived_dim) == "hbm"
    # the hbm working set is F-independent and under any sane budget
    assert dispatch.gather_vmem_bytes(
        "hbm", 1 << 17, 10, 512, 96) == dispatch.gather_vmem_bytes(
        "hbm", 256, 10, 512, 96)
    assert dispatch.ring_vmem_bytes(1 << 17, 10) > 16 * 2**20


# -- per-family ref vs interpret equivalence ---------------------------------

def test_flow_moments_ref_vs_interpret(rng):
    cfg = get_dfa_config(reduced=True)
    F, E = cfg.flows_per_shard, 200
    regs = rng.integers(0, 2**31, size=(F, 7)).astype(np.uint32)
    slots = rng.integers(0, F, size=E).astype(np.int32)
    deltas = rng.integers(0, 2**32, size=(E, 7),
                          dtype=np.uint64).astype(np.uint32)
    valid = rng.random(E) > 0.2
    ref = flow_moments(J(regs), J(slots), J(deltas), J(valid),
                       backend="ref", cfg=cfg)
    got = flow_moments(J(regs), J(slots), J(deltas), J(valid),
                       backend="interpret", cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_ring_scatter_ref_vs_interpret(rng):
    cfg = get_dfa_config(reduced=True)
    F, H = cfg.flows_per_shard, cfg.history
    mem = rng.integers(0, 2**32, size=(F, H, 16),
                       dtype=np.uint64).astype(np.uint32)
    coords = rng.choice(F * H, size=96, replace=False)
    flow = (coords // H).astype(np.int32)
    hist = (coords % H).astype(np.int32)
    pays = rng.integers(0, 2**32, size=(96, 16),
                        dtype=np.uint64).astype(np.uint32)
    mask = rng.random(96) > 0.25
    ref = ring_scatter(J(mem), J(pays), J(flow), J(hist), J(mask),
                       backend="ref", cfg=cfg)
    got = ring_scatter(J(mem), J(pays), J(flow), J(hist), J(mask),
                       backend="interpret", cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_derived_features_ref_vs_interpret(rng):
    cfg = get_dfa_config(reduced=True)
    F, H = 128, cfg.history
    entries = rng.integers(0, 2**20, size=(F, H, 16),
                           dtype=np.uint64).astype(np.uint32)
    valid = rng.random((F, H)) > 0.3
    ref = derived_features(J(entries), J(valid), cfg, backend="ref")
    got = derived_features(J(entries), J(valid), cfg, backend="interpret")
    # tile-shaped reduction order shifts a few ulp, amplified by the
    # newest-minus-window-mean cancellation: same 1e-3 bound as the
    # kernel sweep in test_kernels
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_gather_enrich_ref_vs_interpret(rng):
    cfg = get_dfa_config(reduced=True)
    F, H, R = cfg.flows_per_shard, cfg.history, 128
    mem = rng.integers(0, 2**20, size=(F, H, 16),
                       dtype=np.uint64).astype(np.uint32)
    ev = rng.random((F, H)) > 0.3
    lf = rng.integers(0, F, size=R).astype(np.int32)
    ref = gather_enrich(J(mem), J(ev), J(lf), cfg, backend="ref")
    got = gather_enrich(J(mem), J(ev), J(lf), cfg, backend="interpret")
    assert got.shape == (R, cfg.derived_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_gather_enrich_fused_matches_unfused_composition(rng):
    """The fused op == gather_flow_history + derive_ref (the old path)."""
    from repro.core import collector as COLL
    from repro.core import enrich as ENR
    cfg = get_dfa_config(reduced=True)
    F, H, R = cfg.flows_per_shard, cfg.history, 64
    st = COLL.init_state(cfg)
    mem = rng.integers(0, 2**20, size=(F, H, 16),
                       dtype=np.uint64).astype(np.uint32)
    ev = rng.random((F, H)) > 0.5
    st = st._replace(memory=J(mem), entry_valid=J(ev))
    lf = J(rng.integers(0, F, size=R).astype(np.int32))
    entries, evq = COLL.gather_flow_history(st, lf)
    want = ENR.derive_ref(entries, evq, cfg)
    got = gather_enrich(st.memory, st.entry_valid, lf, cfg,
                        backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_ref_vs_interpret(rng):
    q = J(rng.standard_normal((4, 32, 16)), jnp.float32)
    k = J(rng.standard_normal((2, 32, 16)), jnp.float32)
    v = J(rng.standard_normal((2, 32, 16)), jnp.float32)
    ref = flash_attention(q, k, v, group=2, causal=True, backend="ref")
    got = flash_attention(q, k, v, group=2, causal=True,
                          backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# -- whole-pipeline backend contract -----------------------------------------

def _one_step(system, env_backend, monkeypatch):
    if env_backend is None:
        monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    else:
        monkeypatch.setenv(dispatch.ENV_VAR, env_backend)
    flows = PK.gen_flows(12, seed=7)
    ev = PK.events_for_shards(flows, 0, system.n_shards, 128)
    state = system.init_state()
    with system.mesh:
        # fresh jit per backend: resolution happens at trace time
        out = jax.jit(system.dfa_step)(
            state, {k: jnp.asarray(v) for k, v in ev.items()},
            jnp.uint32(90_000))
    return out.state, out.enriched, out.mask, out.metrics


def test_env_override_interpret_matches_ref_end_to_end(monkeypatch):
    """Acceptance contract: REPRO_KERNEL_BACKEND=interpret produces
    bitwise-equal collector memory and <= 1e-5 enrichment deltas vs ref."""
    cfg = get_dfa_config(reduced=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(cfg, mesh)
    st_ref, en_ref, em_ref, m_ref = _one_step(system, "ref", monkeypatch)
    st_int, en_int, em_int, m_int = _one_step(system, "interpret",
                                              monkeypatch)
    np.testing.assert_array_equal(np.asarray(st_int.collector.memory),
                                  np.asarray(st_ref.collector.memory))
    np.testing.assert_array_equal(np.asarray(st_int.collector.entry_valid),
                                  np.asarray(st_ref.collector.entry_valid))
    np.testing.assert_array_equal(np.asarray(st_int.reporter.regs),
                                  np.asarray(st_ref.reporter.regs))
    np.testing.assert_array_equal(np.asarray(em_int), np.asarray(em_ref))
    np.testing.assert_allclose(np.asarray(en_int), np.asarray(en_ref),
                               rtol=1e-5, atol=1e-5)
    for k in m_ref:
        assert int(m_int[k]) == int(m_ref[k]), k


# -- multi-period streaming ---------------------------------------------------

def _period_batches(system, T, events_per_shard=128):
    return PK.period_batches(system.n_shards, T, events_per_shard,
                             n_flows=10, flow_seed=3)


def test_run_periods_matches_sequential_steps():
    """Acceptance contract: run_periods over T=4 periods == 4 sequential
    dfa_step calls (state bitwise, outputs stacked)."""
    cfg = get_dfa_config(reduced=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(cfg, mesh)
    T = 4
    events, nows = _period_batches(system, T)
    with system.mesh:
        st_seq = system.init_state()
        step = jax.jit(system.dfa_step)
        outs = []
        for t in range(T):
            ev_t = {k: v[t] for k, v in events.items()}
            o = step(st_seq, ev_t, nows[t])
            st_seq = o.state
            outs.append((o.enriched, o.flow_ids, o.mask, o.metrics))
        streamed = jax.jit(system.run_periods)(
            system.init_state(), events, nows)
        st_str, enr_s, fid_s, em_s, met_s = (
            streamed.state, streamed.enriched, streamed.flow_ids,
            streamed.mask, streamed.metrics)
    for a, b in zip(jax.tree.leaves(st_seq), jax.tree.leaves(st_str)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for t in range(T):
        enr, fid, em, met = outs[t]
        np.testing.assert_allclose(np.asarray(enr_s[t]), np.asarray(enr),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(fid_s[t]), np.asarray(fid))
        np.testing.assert_array_equal(np.asarray(em_s[t]), np.asarray(em))
        for k in met:
            assert int(met_s[k][t]) == int(met[k]), (t, k)


def test_run_periods_donated_stream():
    """jit_stream runs with donated state and fixed event_specs shapes."""
    cfg = get_dfa_config(reduced=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(cfg, mesh)
    T = 3
    events, nows = _period_batches(system, T)
    sds, _ = system.event_specs(128, periods=T)
    for k, v in events.items():
        assert v.shape == sds[k].shape, k
    with system.mesh:
        stream = system.jit_stream(donate=True)
        state = system.init_state()
        out = stream(state, events, nows)
        enr = out.enriched
        # carry is reusable across invocations (streaming loop shape)
        state = stream(out.state, events, nows).state
    assert enr.shape[0] == T
    assert np.isfinite(np.asarray(enr)).all()


@pytest.mark.multidevice
def test_run_periods_multi_shard():
    """Streaming scan over a (2, 2) mesh: routing + scan compose."""
    cfg = get_dfa_config(reduced=True)
    mesh = make_mesh((2, 2), ("data", "model"))
    system = DFASystem(cfg, mesh)
    T = 2
    events, nows = _period_batches(system, T, events_per_shard=64)
    with system.mesh:
        out = jax.jit(system.run_periods)(
            system.init_state(), events, nows)
        fid, em, met = out.flow_ids, out.mask, out.metrics
    sent = int(np.asarray(met["reports_sent"]).sum())
    recv = int(np.asarray(met["reports_recv"]).sum())
    drop = int(np.asarray(met["bucket_drops"]).sum())
    assert sent == recv + drop
    assert recv > 0
    # every received flow id lives in its owner shard's range
    F = cfg.flows_per_shard
    fid_np, em_np = np.asarray(fid), np.asarray(em)
    rows_per_shard = fid_np.shape[1] // system.n_shards
    for t in range(T):
        for shard in range(system.n_shards):
            rows = slice(shard * rows_per_shard,
                         (shard + 1) * rows_per_shard)
            owners = fid_np[t, rows][em_np[t, rows]] // F
            assert (owners == shard).all(), (t, shard)
