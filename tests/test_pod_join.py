"""Elastic pod JOIN: grow the mesh mid-stream, prove it matches clean.

The shrink direction (tests/test_elastic_equiv.py) rests on HRW's
restriction property: removing nodes never changes a survivor's winner.
This suite pins the other direction — ADDING a pod only moves the flows
whose winner over the grown roster is a new node (~1/(pods+1) of live
rows), and ``expand_state`` moves exactly those:

    (1,2) mesh, roster (0,1), 4 ports (4 per pod)
        │  stream periods 0..JOIN_AT
        ▼
    join_config/join_system: pods+1, roster (0,1,2,3), 2 ports per pod
    expand_state: scan live rows, move new-node winners, clear sources
        │  stream periods JOIN_AT..T on the (2,2) mesh
        ▼
    merged end state + post-join per-period outputs ≡ a clean full-trace
    run on the (2,2)/(0,1,2,3) mesh — BITWISE (no replay window: the
    state moves live, nothing is restored from a stale snapshot)

Also pinned here: the movement bound (0 < moved ≤ 3/4 of scanned live
rows — the expectation is 1/2 when 2 nodes join 2), join_config's
roster discipline (new ids strictly above the old maximum, one per
shard, port divisibility), and the unsplittable ring-slot collision
surface in BOTH directions: two flows sharing a ring slot whose HRW
homes disagree cannot be split — ``rehome_collision_policy`` "fail"
(default) raises with the count, "warn" moves by the first entry's key
and warns (satellite of the fault-injection PR).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import pod_mesh_or_skip
from repro.configs.dfa import REDUCED
from repro.core import reporter as REP
from repro.core import translator as TRANS
from repro.core.pipeline import DFASystem
from repro.data import scenarios as SC
from repro.launch import elastic as EL

TOTAL_PORTS = 4
EVENTS_PER_PORT = 48
T = 6
JOIN_AT = 3
FPS = 1024               # ring rows per device — FIXED across rosters
REPORTER_SLOTS = 64
PORT_CAPACITY = 16

_systems = {}
_trace_cache = {}


def _cfg(pods, shards, nodes=(), policy="fail"):
    return dataclasses.replace(
        REDUCED,
        flow_home="rendezvous",
        pods=pods,
        ports_per_pod=TOTAL_PORTS // pods,
        reporter_slots=REPORTER_SLOTS,
        flows_per_shard=FPS,
        port_report_capacity=PORT_CAPACITY,
        home_nodes=nodes,
        rehome_collision_policy=policy,
        kernel_backend="ref")


def _system(pods, shards, nodes=(), policy="fail"):
    key = (pods, shards, nodes, policy)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        _systems[key] = DFASystem(_cfg(pods, shards, nodes, policy), mesh)
    return _systems[key]


def _trace(name):
    if name not in _trace_cache:
        ev, nows = SC.build(name, TOTAL_PORTS, EVENTS_PER_PORT, T)
        _trace_cache[name] = ({k: jnp.asarray(v) for k, v in ev.items()},
                              jnp.asarray(nows))
    return _trace_cache[name]


def _merged_state(system, state):
    n = system.n_shards
    out = {f"rep.{k}": np.asarray(a)
           for k, a in state.reporter._asdict().items()}
    out["tr.hist_counter"] = np.asarray(state.translator.hist_counter)
    c = state.collector
    out["coll.memory"] = np.asarray(c.memory)
    out["coll.entry_valid"] = np.asarray(c.entry_valid)
    out["coll.last_seq"] = np.asarray(c.last_seq).reshape(n, -1).max(0)
    for k in ("bad_checksum", "seq_anomalies", "received",
              "lost_reports"):
        out[f"coll.{k}"] = np.asarray(getattr(c, k)).astype(
            np.uint64).sum()
    return out


def _canon_periods(out):
    enr, fid, em = (np.asarray(out.enriched), np.asarray(out.flow_ids),
                    np.asarray(out.mask))
    per = []
    for t in range(enr.shape[0]):
        m = em[t]
        order = np.argsort(fid[t][m], kind="stable")
        per.append({"fid": fid[t][m][order], "enr": enr[t][m][order]})
    return per


def _place(system, state):
    return jax.tree.map(lambda a, s: jax.device_put(jnp.asarray(a), s),
                        state, system.state_shardings())


def _grow_mid_stream(scenario):
    """Stream JOIN_AT periods on (1,2)/(0,1), join pod (2,3), stream the
    rest on (2,2) — returns (big system, stream out, RehomeStats)."""
    events, nows = _trace(scenario)
    small = _system(1, 2, nodes=(0, 1))
    with small.mesh:
        pre = small.stream(small.init_state(),
                           {k: v[:JOIN_AT] for k, v in events.items()},
                           nows[:JOIN_AT])
    big = EL.join_system(small, (2, 3))
    assert big.mesh_pods == 2 and big.home_nodes == (0, 1, 2, 3)
    assert big.total_ports == TOTAL_PORTS
    grown, stats = EL.expand_state(pre.state, small, big)
    with big.mesh:
        out = big.stream(_place(big, grown),
                         {k: v[JOIN_AT:] for k, v in events.items()},
                         nows[JOIN_AT:])
    return big, out, stats


@pytest.mark.parametrize("scenario", ["cross_pod_mix", "elephants_mice"])
def test_grow_matches_clean_large_mesh(scenario):
    """THE grow differential: mid-stream join ≡ a clean full-trace run
    on the larger mesh — merged state AND post-join per-period outputs,
    bitwise."""
    events, nows = _trace(scenario)
    big, out, stats = _grow_mid_stream(scenario)
    assert stats.moved_rows > 0, "no flow re-homed to the new pod"
    assert stats.unsplittable_collisions == 0
    clean_sys = _system(2, 2, nodes=(0, 1, 2, 3))
    with clean_sys.mesh:
        clean = clean_sys.stream(clean_sys.init_state(), events, nows)
    assert int(np.asarray(clean.metrics["reports_recv"]).sum()) > 0
    ref, got = (_merged_state(clean_sys, clean.state),
                _merged_state(big, out.state))
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k],
                                      err_msg=f"{scenario}: state {k}")
    refp = _canon_periods(clean)[JOIN_AT:]
    gotp = _canon_periods(out)
    assert len(refp) == len(gotp) == T - JOIN_AT
    for t, (r, g) in enumerate(zip(refp, gotp)):
        for k in r:
            np.testing.assert_array_equal(
                r[k], g[k],
                err_msg=f"{scenario}: post-join period {JOIN_AT + t} {k}")
    for k, v in out.metrics.items():
        np.testing.assert_array_equal(
            np.asarray(clean.metrics[k])[JOIN_AT:], np.asarray(v),
            err_msg=f"{scenario}: post-join metric {k}")


def test_grow_movement_bound():
    """HRW movement bound: strictly some rows move, but no more than 3/4
    of the scanned live rows (the expectation is 1/2 for 2 nodes joining
    2; 3/4 is a deterministic-trace safety margin, and a full-scan move
    would mean the restriction property broke)."""
    _, _, stats = _grow_mid_stream("cross_pod_mix")
    assert stats.scanned_rows > 0
    assert 0 < stats.moved_rows <= 0.75 * stats.scanned_rows, \
        (f"moved {stats.moved_rows} of {stats.scanned_rows} live rows — "
         "outside the HRW ~1/(pods+1) movement bound")


def test_join_config_validation():
    small = _system(1, 2, nodes=(0, 1))
    with pytest.raises(ValueError, match="one node id per shard"):
        EL.join_config(small, (2,))
    with pytest.raises(ValueError, match="strictly increasing"):
        EL.join_config(small, (3, 2))
    with pytest.raises(ValueError, match="strictly increasing"):
        EL.join_config(small, (2, 2))
    with pytest.raises(ValueError, match="exceed the current roster"):
        EL.join_config(small, (1, 2))
    # 4 ports cannot spread over 3 pods
    two = _system(2, 2, nodes=(0, 1, 2, 3))
    with pytest.raises(ValueError, match="do not spread"):
        EL.join_config(two, (4, 5))
    # range-hash homes renumber the keyspace on every roster change
    mesh = pod_mesh_or_skip(1, 2)
    hash_sys = DFASystem(dataclasses.replace(
        _cfg(1, 2), flow_home="hash", home_nodes=()), mesh)
    with pytest.raises(ValueError, match="rendezvous"):
        EL.join_config(hash_sys, (2, 3))


# -- unsplittable ring-slot collisions (both directions) ------------------

COLLISION_SLOT = 5       # any ring row: the keys are planted by hand


def _disagreeing_keys(nodes):
    """Two five-tuple keys whose HRW winners over ``nodes`` differ —
    brute-forced, deterministic. (Which ring row they share is the
    test's choice: the collision surface only depends on two flows
    occupying one row while disagreeing on a home.)"""
    nodes_arr = jnp.asarray(nodes, jnp.uint32)
    first = None
    for i in range(1, 4096):
        key = np.asarray([i, i + 1, 7, 9, 11], np.uint32)
        pos = int(np.asarray(TRANS.rendezvous_position(
            REP.hash_u32(jnp.asarray(key[None, :])), nodes_arr))[0])
        if first is None:
            first = (key, pos)
        elif pos != first[1]:
            return first[0], key
    raise AssertionError("no disagreeing key pair found")


def _state_with_shared_slot(system, keys, slot, device_pos):
    """A host DFAState whose ring row ``slot`` on device ``device_pos``
    interleaves entries from two different flows (the collision case)."""
    st = jax.tree.map(np.asarray, jax.device_get(system.init_state()))
    wf = system.wire
    row = device_pos * system.cfg.flows_per_shard + slot
    mem = st.collector.memory.copy()
    ev = st.collector.entry_valid.copy()
    for h, key in enumerate(keys):
        mem[row, h, wf.payload_tuple_slice] = key
        ev[row, h] = True
    return st._replace(collector=st.collector._replace(
        memory=mem, entry_valid=ev))


def test_rehome_collision_fails_loud_by_default():
    """Shrink direction: a dead-pod ring row shared by two flows whose
    survivor homes disagree must raise (default policy) — moving it
    silently would interleave one flow's history into the other's."""
    full = _system(2, 2, nodes=(0, 1, 2, 3))
    surv = _system(1, 2, nodes=(2, 3))
    k1, k2 = _disagreeing_keys((2, 3))
    state = _state_with_shared_slot(full, (k1, k2), COLLISION_SLOT,
                                    device_pos=0)
    with pytest.raises(RuntimeError, match="cannot be split"):
        EL.rehome_state(state, full, surv, dead_pod=0)


def test_rehome_collision_warn_policy_counts():
    """policy='warn': the move proceeds by the first entry's key, warns,
    and the count lands in RehomeStats."""
    full = _system(2, 2, nodes=(0, 1, 2, 3), policy="warn")
    surv = _system(1, 2, nodes=(2, 3), policy="warn")
    k1, k2 = _disagreeing_keys((2, 3))
    state = _state_with_shared_slot(full, (k1, k2), COLLISION_SLOT,
                                    device_pos=0)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _, stats = EL.rehome_state(state, full, surv, dead_pod=0)
    assert stats.unsplittable_collisions == 1
    assert any("cannot be split" in str(w.message) for w in caught)


def test_expand_collision_fails_loud_by_default():
    """Grow direction: same surface — a live row whose entries disagree
    on a home over the GROWN roster is unsplittable."""
    small = _system(1, 2, nodes=(0, 1))
    big = _system(2, 2, nodes=(0, 1, 2, 3))
    k1, k2 = _disagreeing_keys((0, 1, 2, 3))
    state = _state_with_shared_slot(small, (k1, k2), COLLISION_SLOT,
                                    device_pos=0)
    with pytest.raises(RuntimeError, match="cannot be split"):
        EL.expand_state(state, small, big)


def test_expand_collision_warn_policy_counts():
    small = _system(1, 2, nodes=(0, 1), policy="warn")
    big = _system(2, 2, nodes=(0, 1, 2, 3), policy="warn")
    k1, k2 = _disagreeing_keys((0, 1, 2, 3))
    state = _state_with_shared_slot(small, (k1, k2), COLLISION_SLOT,
                                    device_pos=0)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _, stats = EL.expand_state(state, small, big)
    assert stats.unsplittable_collisions == 1
    assert any("cannot be split" in str(w.message) for w in caught)


def test_unknown_collision_policy_refused():
    small = _system(1, 2, nodes=(0, 1))
    big_cfg_sys = _system(2, 2, nodes=(0, 1, 2, 3), policy="explode")
    k1, k2 = _disagreeing_keys((0, 1, 2, 3))
    state = _state_with_shared_slot(small, (k1, k2), COLLISION_SLOT,
                                    device_pos=0)
    with pytest.raises(ValueError, match="rehome_collision_policy"):
        EL.expand_state(state, small, big_cfg_sys)
