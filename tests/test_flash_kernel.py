"""flash_attention Pallas kernel vs oracle (interpret mode): shape/dtype
sweep incl. GQA group index-mapping and non-causal mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("BH,Sq,Sk,D,Dv,group,bq,bk,dtype", [
    (4, 64, 64, 16, 16, 1, 16, 16, jnp.float32),     # MHA
    (8, 64, 64, 16, 16, 4, 32, 16, jnp.float32),     # GQA group=4
    (6, 48, 96, 8, 12, 3, 16, 32, jnp.float32),      # Dv != D, Sq != Sk
    (4, 64, 64, 16, 16, 2, 16, 16, jnp.bfloat16),    # bf16 io
])
def test_flash_kernel_sweep(rng, BH, Sq, Sk, D, Dv, group, bq, bk, dtype):
    q = jnp.asarray(rng.standard_normal((BH, Sq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((BH // group, Sk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((BH // group, Sk, Dv)), dtype)
    got = flash_attention_pallas(q, k, v, group=group, bq=bq, bk=bk)
    want = flash_attention_ref(q, k, v, group=group)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_noncausal(rng):
    q = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, bq=16, bk=16)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_matches_model_attention(rng):
    """The kernel must agree with the model-side pure-JAX flash path."""
    from repro.models.attention import chunked_attention
    B, S, H, KH, D = 2, 64, 8, 2, 16
    G = H // KH
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    want = chunked_attention(q, k, v, q_chunk=16, kv_chunk=32)
    # flatten to kernel layout: (B*KH*G, S, D) with kv (B*KH, S, D)
    qf = q.reshape(B, S, KH, G, D).transpose(0, 2, 3, 1, 4).reshape(
        B * KH * G, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
    got = flash_attention_pallas(qf, kf, vf, group=G, bq=16, bk=16)
    got = got.reshape(B, KH, G, S, D).transpose(0, 3, 1, 2, 4).reshape(
        B, S, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
