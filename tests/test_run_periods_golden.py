"""Golden regression for the streaming driver: a fixed-seed T=4
``run_periods`` run is checked against a committed JSON fingerprint, so
streaming/kernel refactors can't silently change enrichment output.

The fingerprint holds the integer metrics bit-exactly and float summaries
of the enriched features to 1e-4 (ref backend — pure jnp — so the values
are platform-stable on CPU CI).

Regenerate after an INTENTIONAL semantics change with:

    REPRO_REGEN_GOLDENS=1 python -m pytest -q tests/test_run_periods_golden.py

and include the refreshed tests/goldens/run_periods_t4.json in the same
commit as the change that moved it.
"""
import dataclasses
import json
import os

import jax
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.kernels import dispatch

GOLDEN = os.path.join(os.path.dirname(__file__), "goldens",
                      "run_periods_t4.json")
T = 4
EVENTS_PER_SHARD = 128


def _run(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR, raising=False)
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              kernel_backend="ref")
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = PK.period_batches(system.n_shards, T,
                                     EVENTS_PER_SHARD, n_flows=10,
                                     flow_seed=3)
    with system.mesh:
        state, enr, fid, em, met = jax.jit(system.run_periods)(
            system.init_state(), events, nows)
    return state, np.asarray(enr), np.asarray(fid), np.asarray(em), met


def _fingerprint(state, enr, fid, em, met):
    periods = []
    for t in range(T):
        rows = em[t]
        e = enr[t][rows].astype(np.float64)
        periods.append({
            "received": int(rows.sum()),
            "flow_ids": sorted(int(x) for x in fid[t][rows]),
            "enriched_sum": float(e.sum()),
            "enriched_abs_mean": float(np.abs(e).mean()) if e.size else 0.0,
            "first_row_head": [float(x) for x in
                               np.sort(e, axis=0)[0][:8]] if e.size else [],
            "metrics": {k: int(np.asarray(met[k])[t]) for k in sorted(met)},
        })
    return {
        "schema": "run-periods-golden-v1",
        "T": T,
        "events_per_shard": EVENTS_PER_SHARD,
        "collector_received": int(np.asarray(state.collector.received)[0]),
        "entry_valid_count": int(np.asarray(
            state.collector.entry_valid).sum()),
        "regs_checksum": int(np.bitwise_xor.reduce(
            np.asarray(state.reporter.regs).reshape(-1).view(np.uint32))),
        "periods": periods,
    }


def _assert_matches(got, want):
    assert got["schema"] == want["schema"]
    for k in ("T", "events_per_shard", "collector_received",
              "entry_valid_count", "regs_checksum"):
        assert got[k] == want[k], (k, got[k], want[k])
    for t, (g, w) in enumerate(zip(got["periods"], want["periods"])):
        assert g["received"] == w["received"], t
        assert g["flow_ids"] == w["flow_ids"], t
        assert g["metrics"] == w["metrics"], t
        np.testing.assert_allclose(g["enriched_sum"], w["enriched_sum"],
                                   rtol=1e-4, err_msg=f"period {t}")
        np.testing.assert_allclose(g["enriched_abs_mean"],
                                   w["enriched_abs_mean"], rtol=1e-4,
                                   err_msg=f"period {t}")
        np.testing.assert_allclose(g["first_row_head"],
                                   w["first_row_head"], rtol=1e-4,
                                   atol=1e-6, err_msg=f"period {t}")


def test_run_periods_matches_golden(monkeypatch):
    got = _fingerprint(*_run(monkeypatch))
    if os.environ.get("REPRO_REGEN_GOLDENS"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        return
    assert os.path.exists(GOLDEN), (
        f"missing {GOLDEN}; run REPRO_REGEN_GOLDENS=1 pytest "
        "tests/test_run_periods_golden.py")
    with open(GOLDEN) as f:
        want = json.load(f)
    _assert_matches(got, want)
