"""Golden regressions for the streaming drivers: fixed-seed runs are
checked against committed JSON fingerprints, so streaming/kernel/routing
refactors can't silently change enrichment output.

Three goldens are pinned:

* ``run_periods_t4``          — the original single-shard (1,1) T=4 run
                                (legacy flow_home="ingest" path);
* ``run_periods_multipod_t4`` — a (2,2)-pod mesh T=4 run of the
                                REDUCED_MULTIPOD config over the
                                cross_pod_mix scenario (hash homes,
                                two-stage exchange; needs 4 forced host
                                devices, skipped otherwise);
* ``run_periods_multipod_v2_t4`` — the same mesh/scenario shape under
                                wire_format="v2" (u16 reporter_id/seq),
                                with an extra ``ring_checksum`` xor fold
                                over the raw collector ring bytes that
                                pins the widened payload layout bitwise.

Fingerprints hold the integer metrics bit-exactly and float summaries of
the enriched features to 1e-4 (ref backend — pure jnp — so the values
are platform-stable on CPU CI).

Regenerate after an INTENTIONAL semantics change with:

    REPRO_REGEN_GOLDENS=1 python -m pytest -q tests/test_run_periods_golden.py

The regen path refreshes ALL registered golden files in one run —
whichever golden test executes first rewrites every file, so a refactor
can't ship with one fingerprint refreshed and its sibling stale.
Include the refreshed tests/goldens/*.json in the same commit as the
change that moved them.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from conftest import pod_mesh_or_skip
from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.configs.dfa import REDUCED_MULTIPOD, REDUCED_MULTIPOD_V2
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.data import scenarios as SC
from repro.kernels import dispatch

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
T = 4
EVENTS_PER_SHARD = 128


def _clear_env(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    monkeypatch.delenv(dispatch.GATHER_ENV_VAR, raising=False)


def _fingerprint(state, enr, fid, em, met, extra=None):
    periods = []
    for t in range(T):
        rows = em[t]
        e = enr[t][rows].astype(np.float64)
        periods.append({
            "received": int(rows.sum()),
            "flow_ids": sorted(int(x) for x in fid[t][rows]),
            "enriched_sum": float(e.sum()),
            "enriched_abs_mean": float(np.abs(e).mean()) if e.size else 0.0,
            "first_row_head": [float(x) for x in
                               np.sort(e, axis=0)[0][:8]] if e.size else [],
            "metrics": {k: int(np.asarray(met[k])[t]) for k in sorted(met)},
        })
    fp = {
        "schema": "run-periods-golden-v1",
        "T": T,
        "events_per_shard": EVENTS_PER_SHARD,
        "collector_received": int(np.asarray(
            state.collector.received).astype(np.uint64).sum()),
        "entry_valid_count": int(np.asarray(
            state.collector.entry_valid).sum()),
        "regs_checksum": int(np.bitwise_xor.reduce(
            np.asarray(state.reporter.regs).reshape(-1).view(np.uint32))),
        "periods": periods,
    }
    fp.update(extra or {})
    return fp


def _build_single_shard():
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              kernel_backend="ref")
    system = DFASystem(cfg, make_mesh((1, 1), ("data", "model")))
    events, nows = PK.period_batches(system.n_shards, T,
                                     EVENTS_PER_SHARD, n_flows=10,
                                     flow_seed=3)
    with system.mesh:
        out = jax.jit(system.run_periods)(
            system.init_state(), events, nows)
    return _fingerprint(out.state, np.asarray(out.enriched),
                        np.asarray(out.flow_ids), np.asarray(out.mask),
                        out.metrics)


def _build_multipod():
    mesh = pod_mesh_or_skip(2, 2)
    cfg = dataclasses.replace(REDUCED_MULTIPOD, kernel_backend="ref")
    system = DFASystem(cfg, mesh)
    ev, nows = SC.build("cross_pod_mix", system.total_ports,
                        EVENTS_PER_SHARD // system.total_ports, T,
                        seed=3)
    events = {k: jnp.asarray(v) for k, v in ev.items()}
    with system.mesh:
        out = jax.jit(system.run_periods)(
            system.init_state(), events, jnp.asarray(nows))
    return _fingerprint(
        out.state, np.asarray(out.enriched), np.asarray(out.flow_ids),
        np.asarray(out.mask), out.metrics,
        extra={"mesh": [2, 2], "total_ports": system.total_ports,
               "flow_home": "hash"})


def _build_multipod_v2():
    """The same (2,2) cross_pod_mix run under wire_format='v2'. The
    enrichment fingerprint must MATCH the flow-level content of a V1 run
    (the schema changes bit positions, not features); the extra
    ``ring_checksum`` pins the widened byte layout itself — an xor fold
    over every collector ring word, so any drift in where reporter_id /
    seq / hist_idx land inside the 64 B payload trips the golden."""
    mesh = pod_mesh_or_skip(2, 2)
    cfg = dataclasses.replace(REDUCED_MULTIPOD_V2, kernel_backend="ref",
                              port_report_capacity=32)
    system = DFASystem(cfg, mesh)
    assert system.wire.name == "v2"
    ev, nows = SC.build("cross_pod_mix", system.total_ports,
                        EVENTS_PER_SHARD // system.total_ports, T,
                        seed=3)
    events = {k: jnp.asarray(v) for k, v in ev.items()}
    with system.mesh:
        out = jax.jit(system.run_periods)(
            system.init_state(), events, jnp.asarray(nows))
    ring = np.asarray(out.state.collector.memory).reshape(-1)
    return _fingerprint(
        out.state, np.asarray(out.enriched), np.asarray(out.flow_ids),
        np.asarray(out.mask), out.metrics,
        extra={"mesh": [2, 2], "total_ports": system.total_ports,
               "flow_home": "hash", "wire_format": "v2",
               "ring_checksum": int(np.bitwise_xor.reduce(
                   ring.view(np.uint32)))})


# name -> builder; the file is tests/goldens/<name>.json
GOLDENS = {
    "run_periods_t4": _build_single_shard,
    "run_periods_multipod_t4": _build_multipod,
    "run_periods_multipod_v2_t4": _build_multipod_v2,
}

_regenerated = False


def _regen_all():
    """Refresh EVERY registered golden in one pass (regen mode)."""
    import pytest
    global _regenerated
    if _regenerated:
        return
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, builder in GOLDENS.items():
        try:
            fp = builder()
        except pytest.skip.Exception as e:
            # e.g. the multipod golden on a <4-device host: regenerate
            # what we can, surface what we couldn't
            print(f"[goldens] NOT regenerated {name}: {e}")
            continue
        with open(os.path.join(GOLDEN_DIR, f"{name}.json"), "w") as f:
            json.dump(fp, f, indent=1, sort_keys=True)
        print(f"[goldens] regenerated {name}")
    _regenerated = True


def _assert_matches(got, want):
    assert got["schema"] == want["schema"]
    for k in ("T", "events_per_shard", "collector_received",
              "entry_valid_count", "regs_checksum"):
        assert got[k] == want[k], (k, got[k], want[k])
    if "ring_checksum" in want:       # V2 golden pins the raw byte layout
        assert got["ring_checksum"] == want["ring_checksum"], \
            "collector ring bytes moved — a wire-layout change must be " \
            "deliberate (bump/regen the golden with the schema change)"
    for t, (g, w) in enumerate(zip(got["periods"], want["periods"])):
        assert g["received"] == w["received"], t
        assert g["flow_ids"] == w["flow_ids"], t
        # compare the golden's pinned metric keys exactly; metric keys
        # ADDED since a golden was cut (e.g. lost_reports) must be zero
        # on a clean run — the golden files stay byte-identical across
        # purely-additive accounting
        for k, v in w["metrics"].items():
            assert g["metrics"][k] == v, (t, k)
        for k in set(g["metrics"]) - set(w["metrics"]):
            assert g["metrics"][k] == 0, (t, k, g["metrics"][k])
        np.testing.assert_allclose(g["enriched_sum"], w["enriched_sum"],
                                   rtol=1e-4, err_msg=f"period {t}")
        np.testing.assert_allclose(g["enriched_abs_mean"],
                                   w["enriched_abs_mean"], rtol=1e-4,
                                   err_msg=f"period {t}")
        np.testing.assert_allclose(g["first_row_head"],
                                   w["first_row_head"], rtol=1e-4,
                                   atol=1e-6, err_msg=f"period {t}")


def _check(name, monkeypatch):
    _clear_env(monkeypatch)
    if os.environ.get("REPRO_REGEN_GOLDENS"):
        _regen_all()
        return
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    assert os.path.exists(path), (
        f"missing {path}; run REPRO_REGEN_GOLDENS=1 pytest "
        "tests/test_run_periods_golden.py")
    with open(path) as f:
        want = json.load(f)
    _assert_matches(GOLDENS[name](), want)


def test_run_periods_matches_golden(monkeypatch):
    _check("run_periods_t4", monkeypatch)


def test_multipod_run_periods_matches_golden(monkeypatch):
    _check("run_periods_multipod_t4", monkeypatch)


def test_multipod_v2_run_periods_matches_golden(monkeypatch):
    _check("run_periods_multipod_v2_t4", monkeypatch)
