"""Collector: Fig-4 ring semantics, integrity checks, staged-copy path."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_dfa_config
from repro.core import collector as C
from repro.core import protocol as P


def mk_payload(flow, hist, seq=0, rid=1, marker=7):
    rep = {"flow_id": jnp.uint32(flow), "reporter_id": jnp.uint32(rid),
           "seq": jnp.uint32(seq),
           "stats": jnp.full((7,), marker, jnp.uint32),
           "five_tuple": jnp.arange(5, dtype=jnp.uint32)}
    return P.pack_rocev2_payload(rep, jnp.uint32(hist))


def test_ring_placement_and_history():
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    pays = jnp.stack([mk_payload(2, h, seq=h, marker=h + 1)
                      for h in range(cfg.history)])
    st = C.ingest(st, pays, jnp.ones(cfg.history, bool), 0, cfg)
    mem = np.asarray(st.memory)
    for h in range(cfg.history):
        assert mem[2, h, 1] == h + 1          # stats word 0 = marker
    assert int(st.received) == cfg.history
    assert np.asarray(st.entry_valid)[2].all()


def test_last_write_wins():
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    pays = jnp.stack([mk_payload(1, 0, seq=0, marker=11),
                      mk_payload(1, 0, seq=1, marker=22)])
    st = C.ingest(st, pays, jnp.ones(2, bool), 0, cfg)
    assert int(np.asarray(st.memory)[1, 0, 1]) == 22


def test_checksum_rejected():
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    p = mk_payload(0, 0).at[3].set(jnp.uint32(0xDEAD))
    st = C.ingest(st, p[None], jnp.ones(1, bool), 0, cfg)
    assert int(st.bad_checksum) == 1
    assert int(st.received) == 0
    assert not bool(np.asarray(st.entry_valid)[0, 0])


def test_out_of_range_flow_dropped():
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    p = mk_payload(cfg.flows_per_shard + 5, 0)
    st = C.ingest(st, p[None], jnp.ones(1, bool), 0, cfg)
    assert int(st.received) == 0


def test_seq_replay_detected():
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    p1 = mk_payload(0, 0, seq=5)
    st = C.ingest(st, p1[None], jnp.ones(1, bool), 0, cfg)
    st = C.ingest(st, p1[None], jnp.ones(1, bool), 0, cfg)  # replayed
    assert int(st.seq_anomalies) >= 1


def test_staged_equals_direct():
    """The DTA-style staged copy path must be functionally identical —
    only the memory traffic differs (fig9 benchmark)."""
    cfg = get_dfa_config(reduced=True)
    pays = jnp.stack([mk_payload(i, i % cfg.history, seq=i, marker=i + 1)
                      for i in range(6)])
    mask = jnp.ones(6, bool)
    a = C.ingest(C.init_state(cfg), pays, mask, 0, cfg)
    b = C.staged_ingest(C.init_state(cfg), pays, mask, 0, cfg)
    np.testing.assert_array_equal(np.asarray(a.memory),
                                  np.asarray(b.memory))


def test_seq_gap_counts_lost_reports():
    """A hole in a reporter's seq stream is a lost report (§VI-B gap)."""
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    pays = jnp.stack([mk_payload(f, 0, seq=s, rid=1)
                      for f, s in [(0, 0), (1, 1), (3, 3), (4, 4)]])
    st = C.ingest(st, pays, jnp.ones(4, bool), 0, cfg)  # seq 2 missing
    assert int(st.lost_reports) == 1
    assert int(st.received) == 4


def test_tail_drop_detected_next_period():
    """Losing a reporter's LAST report of a period leaves no same-period
    gap evidence; the next period's reports expose it."""
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    p1 = mk_payload(0, 0, seq=0, rid=1)
    st = C.ingest(st, p1[None], jnp.ones(1, bool), 0, cfg)
    assert int(st.lost_reports) == 0        # seq 1 loss not yet visible
    p2 = jnp.stack([mk_payload(2, 1, seq=2, rid=1),
                    mk_payload(3, 1, seq=3, rid=1)])
    st = C.ingest(st, p2, jnp.ones(2, bool), 0, cfg)
    assert int(st.lost_reports) == 1        # the period-1 tail, one late
    assert int(st.received) == 3


def test_within_batch_dup_first_arrival_wins():
    """Two payloads with one (reporter, seq) identity in one ingest: the
    first is placed, the second is rejected as a seq anomaly — a valid
    checksum must not let a replay overwrite ring state."""
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    pays = jnp.stack([mk_payload(1, 0, seq=0, marker=11),
                      mk_payload(1, 0, seq=0, marker=99)])
    st = C.ingest(st, pays, jnp.ones(2, bool), 0, cfg)
    assert int(np.asarray(st.memory)[1, 0, 1]) == 11
    assert int(st.seq_anomalies) == 1
    assert int(st.received) == 1
    assert int(st.lost_reports) == 0


def test_cross_batch_replay_rejected():
    """A replayed (reporter, seq) arriving a batch later is rejected by
    the §VI-B window, leaving the ring bitwise untouched."""
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    p1 = mk_payload(0, 0, seq=5, marker=11)
    st = C.ingest(st, p1[None], jnp.ones(1, bool), 0, cfg)
    mem0 = np.asarray(st.memory).copy()
    replay = mk_payload(0, 0, seq=5, marker=99)
    st = C.ingest(st, replay[None], jnp.ones(1, bool), 0, cfg)
    np.testing.assert_array_equal(np.asarray(st.memory), mem0)
    assert int(st.seq_anomalies) == 1
    assert int(st.received) == 1


def test_gather_flow_history():
    cfg = get_dfa_config(reduced=True)
    st = C.init_state(cfg)
    # distinct seqs: same-(reporter, seq) rows would be dup-rejected
    pays = jnp.stack([mk_payload(3, h, seq=h, marker=h) for h in range(4)])
    st = C.ingest(st, pays, jnp.ones(4, bool), 0, cfg)
    entries, valid = C.gather_flow_history(st, jnp.asarray([3, 0]))
    assert entries.shape == (2, cfg.history, P.PAYLOAD_WORDS)
    assert int(valid[0].sum()) == 4 and int(valid[1].sum()) == 0
