"""Derived-feature math: moment identities on exact inputs."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_dfa_config
from repro.core import enrich as E


def test_entry_features_moment_identities():
    # synthetic exact sums for x = [2, 4, 6]: n=3, S1=12, S2=56, S3=288
    xs = np.array([2.0, 4.0, 6.0])
    ps = np.array([100.0, 200.0, 300.0])
    stats = jnp.asarray([[3, xs.sum(), (xs**2).sum(), (xs**3).sum(),
                          ps.sum(), (ps**2).sum(), (ps**3).sum()]],
                        jnp.uint32)
    f = np.asarray(E.entry_features(stats))[0]
    assert f[0] == 3
    np.testing.assert_allclose(f[1], xs.mean(), rtol=1e-6)        # iat mean
    np.testing.assert_allclose(f[2], xs.var(), rtol=1e-5)         # iat var
    np.testing.assert_allclose(f[3], xs.std(), rtol=1e-5)
    np.testing.assert_allclose(f[4], xs.std() / xs.mean(), rtol=1e-5)
    np.testing.assert_allclose(f[6], ps.mean(), rtol=1e-6)        # ps mean
    np.testing.assert_allclose(f[11], ps.sum(), rtol=1e-6)        # volume
    # skewness of a symmetric sample is ~0
    m3 = ((xs - xs.mean()) ** 3).mean()
    np.testing.assert_allclose(f[5], m3 / xs.std() ** 3, atol=1e-4)


def test_derive_ref_dims_and_masking():
    cfg = get_dfa_config(reduced=True)
    F, H = 8, cfg.history
    mem = np.zeros((F, H, 16), np.uint32)
    mem[0, 0, 1:8] = [5, 50, 600, 8000, 500, 60000, 7000000]
    valid = np.zeros((F, H), bool)
    valid[0, 0] = True
    out = np.asarray(E.derive_ref(jnp.asarray(mem), jnp.asarray(valid),
                                  cfg))
    assert out.shape == (F, cfg.derived_dim)
    assert np.isfinite(out).all()
    # invalid flows contribute nothing (nvalid column is clamped to >= 1)
    nvalid_col = 4 * E.PER_ENTRY
    masked = np.delete(out[1:], nvalid_col, axis=1)
    assert (masked == 0).all()
    assert out[0, 0] == 5                # count survives the window mean
