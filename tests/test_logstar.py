"""log* LUT properties (paper Table I approximation)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import logstar as LS

BITS = 7


def test_log_exact_powers_of_two():
    x = jnp.asarray([1, 2, 4, 1024, 1 << 20, 1 << 31], jnp.uint32)
    got = np.asarray(LS.log2_star(x, BITS), np.int64)
    want = (np.log2(np.asarray(x, np.float64)) * (1 << LS.Q)).round()
    np.testing.assert_allclose(got, want, atol=1.0)


def test_zero_maps_to_zero():
    assert int(LS.log2_star(jnp.uint32(0), BITS)) == 0
    assert int(LS.approx_pow(jnp.uint32(0), 2, BITS)) == 0


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=2**31 - 1))
def test_log_relative_error_bounded(x):
    got = int(LS.log2_star(jnp.uint32(x), BITS))
    want = np.log2(x) * (1 << LS.Q)
    # LUT truncation: one mantissa step, slope 1/ln2 in log2 space
    assert abs(got - want) <= (1 << LS.Q) * 2.0 ** (-BITS) / np.log(2) + 2


@settings(max_examples=150, deadline=None)
@given(st.integers(min_value=0, max_value=2**30))
def test_log_monotone(x):
    a = int(LS.log2_star(jnp.uint32(x), BITS))
    b = int(LS.log2_star(jnp.uint32(x + 1), BITS))
    assert b >= a


@settings(max_examples=150, deadline=None)
@given(st.integers(min_value=1, max_value=65535), st.sampled_from([2, 3]))
def test_approx_pow_relative_error(x, n):
    got = float(int(LS.approx_pow(jnp.uint32(x), n, BITS)))
    want = float(x) ** n
    if want >= 2**32:
        assert got == 2**32 - 1          # saturation (P4 semantics)
    else:
        rel = abs(got - want) / want
        assert rel < 0.05, (x, n, got, want)   # ~n*2^-7 quantization


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=1, max_value=2**31 - 1))
def test_exp_inverts_log(x):
    l = LS.log2_star(jnp.uint32(x), BITS)
    back = float(int(LS.exp2_star(l, BITS)))
    rel = abs(back - x) / x
    assert rel < 0.02, (x, back)


def test_vectorized_matches_scalar():
    xs = np.asarray([1, 3, 7, 100, 1500, 65535, 2**20], np.uint32)
    vec = np.asarray(LS.log2_star(jnp.asarray(xs), BITS))
    for i, x in enumerate(xs):
        assert vec[i] == int(LS.log2_star(jnp.uint32(x), BITS))
