"""Reporter semantics vs a sequential numpy simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_dfa_config
from repro.core import logstar as LS
from repro.core import reporter as R


def np_simulate(cfg, events):
    """Sequential per-packet reference (what the switch actually does)."""
    F = cfg.flows_per_shard
    regs = np.zeros((F, R.N_REG), np.uint64)
    last = np.zeros(F, np.uint64)
    keys = np.zeros((F, 5), np.uint64)
    active = np.zeros(F, bool)
    slots = np.asarray(R.hash_slot(jnp.asarray(events["five_tuple"]), F))
    for i in range(len(slots)):
        if not events["valid"][i]:
            continue
        s = slots[i]
        key = events["five_tuple"][i]
        if active[s] and not (keys[s] == key).all():
            pass                              # collision: resident flow owns
        if not active[s]:
            keys[s] = key
            active[s] = True
            first = True
        else:
            first = False
        ts, ps = int(events["ts"][i]), int(events["size"][i])
        iat = 0 if first else ts - int(last[s])
        d = [1, iat,
             int(LS.approx_pow(jnp.uint32(iat), 2, cfg.logstar_bits)),
             int(LS.approx_pow(jnp.uint32(iat), 3, cfg.logstar_bits)),
             ps,
             int(LS.approx_pow(jnp.uint32(ps), 2, cfg.logstar_bits)),
             int(LS.approx_pow(jnp.uint32(ps), 3, cfg.logstar_bits))]
        regs[s] = (regs[s] + np.asarray(d, np.uint64)) % (1 << 32)
        last[s] = max(last[s], ts)
    return regs.astype(np.uint32), last.astype(np.uint32)


def make_events(rng, cfg, n_flows=8, E=96):
    keys = rng.integers(1, 2**31, size=(n_flows, 5)).astype(np.uint32)
    fidx = rng.integers(0, n_flows, size=E)
    ts = np.sort(rng.integers(0, 10_000, size=E)).astype(np.uint32)
    # strictly increasing to avoid ties (switch sees a total order)
    ts = ts + np.arange(E, dtype=np.uint32)
    return {"ts": ts,
            "size": rng.integers(40, 1500, size=E).astype(np.uint32),
            "five_tuple": keys[fidx],
            "valid": np.ones(E, bool)}


def test_ingest_matches_sequential_simulator(rng):
    cfg = get_dfa_config(reduced=True)
    events = make_events(rng, cfg)
    st = R.init_state(cfg)
    st = R.ingest(st, {k: jnp.asarray(v) for k, v in events.items()}, cfg)
    regs_np, last_np = np_simulate(cfg, events)
    np.testing.assert_array_equal(np.asarray(st.regs), regs_np)
    np.testing.assert_array_equal(np.asarray(st.last_ts), last_np)


def test_two_block_ingest_equals_one(rng):
    """Splitting the stream into blocks must not change the registers."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, cfg, E=64)
    stA = R.init_state(cfg)
    stA = R.ingest(stA, {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    stB = R.init_state(cfg)
    for sl in (slice(0, 32), slice(32, 64)):
        part = {k: jnp.asarray(v[sl]) for k, v in ev.items()}
        stB = R.ingest(stB, part, cfg)
    np.testing.assert_array_equal(np.asarray(stA.regs),
                                  np.asarray(stB.regs))


def test_invalid_events_ignored(rng):
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, cfg, E=32)
    ev["valid"][10:] = False
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    ev2 = {k: v[:10] for k, v in ev.items()}
    st2 = R.ingest(R.init_state(cfg),
                   {k: jnp.asarray(v) for k, v in ev2.items()}, cfg)
    np.testing.assert_array_equal(np.asarray(st.regs),
                                  np.asarray(st2.regs))


def test_due_flows_and_reports(rng):
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, cfg, n_flows=5, E=64)
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    now = jnp.uint32(cfg.monitoring_period_us + 20_000)
    slots, mask = R.due_flows(st, now, cfg, capacity=16)
    n_active = int(np.asarray(st.active).sum())
    assert int(mask.sum()) == n_active          # all active flows due
    st2, reports = R.make_reports(st, slots, mask, now, 3, 0, cfg)
    reports = np.asarray(reports)
    assert (reports[np.asarray(mask), 0] ==
            np.asarray(slots)[np.asarray(mask)]).all()
    assert int(st2.seq) == n_active             # sequence ids consumed
    # immediately after reporting, nothing is due
    _, mask2 = R.due_flows(st2, now, cfg, capacity=16)
    assert int(mask2.sum()) == 0


def test_register_wraparound(rng):
    """P4 32-bit registers wrap mod 2^32 — so do ours."""
    cfg = get_dfa_config(reduced=True)
    st = R.init_state(cfg)
    regs = st.regs.at[0, 1].set(jnp.uint32(0xFFFFFFF0))
    st = st._replace(regs=regs,
                     active=st.active.at[0].set(True),
                     keys=st.keys.at[0].set(jnp.arange(5, dtype=jnp.uint32)))
    deltas = jnp.zeros((1, 7), jnp.uint32).at[0, 1].set(0x20)
    out = R.accumulate_ref(st.regs, jnp.asarray([0]), deltas,
                           jnp.asarray([True]))
    assert int(out[0, 1]) == 0x10               # wrapped


def test_collision_counting(rng):
    cfg = get_dfa_config(reduced=True)
    # two different keys forced into the same slot via crafted search
    keys = rng.integers(1, 2**31, size=(64, 5)).astype(np.uint32)
    slots = np.asarray(R.hash_slot(jnp.asarray(keys),
                                   cfg.flows_per_shard))
    dup = None
    for i in range(len(slots)):
        for j in range(i + 1, len(slots)):
            if slots[i] == slots[j]:
                dup = (i, j)
                break
        if dup:
            break
    if not dup:
        pytest.skip("no hash collision in sample")
    i, j = dup
    ev = {"ts": np.asarray([10, 20], np.uint32),
          "size": np.asarray([100, 200], np.uint32),
          "five_tuple": np.stack([keys[i], keys[j]]),
          "valid": np.ones(2, bool)}
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    st = R.ingest(st, {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    assert int(st.collisions) >= 1
