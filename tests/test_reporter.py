"""Reporter semantics vs a sequential numpy simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_dfa_config
from repro.core import logstar as LS
from repro.core import reporter as R


def np_simulate(cfg, events):
    """Sequential per-packet reference (what the switch actually does)."""
    F = cfg.flows_per_shard
    regs = np.zeros((F, R.N_REG), np.uint64)
    last = np.zeros(F, np.uint64)
    keys = np.zeros((F, 5), np.uint64)
    active = np.zeros(F, bool)
    slots = np.asarray(R.hash_slot(jnp.asarray(events["five_tuple"]), F))
    for i in range(len(slots)):
        if not events["valid"][i]:
            continue
        s = slots[i]
        key = events["five_tuple"][i]
        if active[s] and not (keys[s] == key).all():
            pass                              # collision: resident flow owns
        if not active[s]:
            keys[s] = key
            active[s] = True
            first = True
        else:
            first = False
        ts, ps = int(events["ts"][i]), int(events["size"][i])
        iat = 0 if first else ts - int(last[s])
        d = [1, iat,
             int(LS.approx_pow(jnp.uint32(iat), 2, cfg.logstar_bits)),
             int(LS.approx_pow(jnp.uint32(iat), 3, cfg.logstar_bits)),
             ps,
             int(LS.approx_pow(jnp.uint32(ps), 2, cfg.logstar_bits)),
             int(LS.approx_pow(jnp.uint32(ps), 3, cfg.logstar_bits))]
        regs[s] = (regs[s] + np.asarray(d, np.uint64)) % (1 << 32)
        last[s] = max(last[s], ts)
    return regs.astype(np.uint32), last.astype(np.uint32)


def make_events(rng, cfg, n_flows=8, E=96):
    keys = rng.integers(1, 2**31, size=(n_flows, 5)).astype(np.uint32)
    fidx = rng.integers(0, n_flows, size=E)
    ts = np.sort(rng.integers(0, 10_000, size=E)).astype(np.uint32)
    # strictly increasing to avoid ties (switch sees a total order)
    ts = ts + np.arange(E, dtype=np.uint32)
    return {"ts": ts,
            "size": rng.integers(40, 1500, size=E).astype(np.uint32),
            "five_tuple": keys[fidx],
            "valid": np.ones(E, bool)}


def test_ingest_matches_sequential_simulator(rng):
    cfg = get_dfa_config(reduced=True)
    events = make_events(rng, cfg)
    st = R.init_state(cfg)
    st = R.ingest(st, {k: jnp.asarray(v) for k, v in events.items()}, cfg)
    regs_np, last_np = np_simulate(cfg, events)
    np.testing.assert_array_equal(np.asarray(st.regs), regs_np)
    np.testing.assert_array_equal(np.asarray(st.last_ts), last_np)


def test_two_block_ingest_equals_one(rng):
    """Splitting the stream into blocks must not change the registers."""
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, cfg, E=64)
    stA = R.init_state(cfg)
    stA = R.ingest(stA, {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    stB = R.init_state(cfg)
    for sl in (slice(0, 32), slice(32, 64)):
        part = {k: jnp.asarray(v[sl]) for k, v in ev.items()}
        stB = R.ingest(stB, part, cfg)
    np.testing.assert_array_equal(np.asarray(stA.regs),
                                  np.asarray(stB.regs))


def test_invalid_events_ignored(rng):
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, cfg, E=32)
    ev["valid"][10:] = False
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    ev2 = {k: v[:10] for k, v in ev.items()}
    st2 = R.ingest(R.init_state(cfg),
                   {k: jnp.asarray(v) for k, v in ev2.items()}, cfg)
    np.testing.assert_array_equal(np.asarray(st.regs),
                                  np.asarray(st2.regs))


def test_due_flows_and_reports(rng):
    cfg = get_dfa_config(reduced=True)
    ev = make_events(rng, cfg, n_flows=5, E=64)
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    now = jnp.uint32(cfg.monitoring_period_us + 20_000)
    slots, mask = R.due_flows(st, now, cfg, capacity=16)
    n_active = int(np.asarray(st.active).sum())
    assert int(mask.sum()) == n_active          # all active flows due
    st2, reports = R.make_reports(st, slots, mask, now, 3, 0, cfg)
    reports = np.asarray(reports)
    assert (reports[np.asarray(mask), 0] ==
            np.asarray(slots)[np.asarray(mask)]).all()
    assert int(st2.seq) == n_active             # sequence ids consumed
    # immediately after reporting, nothing is due
    _, mask2 = R.due_flows(st2, now, cfg, capacity=16)
    assert int(mask2.sum()) == 0


def test_register_wraparound(rng):
    """P4 32-bit registers wrap mod 2^32 — so do ours."""
    cfg = get_dfa_config(reduced=True)
    st = R.init_state(cfg)
    regs = st.regs.at[0, 1].set(jnp.uint32(0xFFFFFFF0))
    st = st._replace(regs=regs,
                     active=st.active.at[0].set(True),
                     keys=st.keys.at[0].set(jnp.arange(5, dtype=jnp.uint32)))
    deltas = jnp.zeros((1, 7), jnp.uint32).at[0, 1].set(0x20)
    out = R.accumulate_ref(st.regs, jnp.asarray([0]), deltas,
                           jnp.asarray([True]))
    assert int(out[0, 1]) == 0x10               # wrapped


def test_timestamp_wrap_iat(rng):
    """u32 µs clock wrap (~71.6 min): IATs must stay correct across the
    wrap and last_ts must track the LATEST event, not the numeric max —
    the old ``.max(ts)`` update pinned the stale pre-wrap value forever,
    corrupting every subsequent IAT for the flow."""
    cfg = get_dfa_config(reduced=True)    # COL_IAT sums are exact (no log*)
    key = np.arange(1, 6, dtype=np.uint32)
    slot = int(np.asarray(R.hash_slot(jnp.asarray(key),
                                      cfg.flows_per_shard)))

    def block(ts_list):
        n = len(ts_list)
        return {"ts": jnp.asarray(ts_list, jnp.uint32),
                "size": jnp.full((n,), 100, jnp.uint32),
                "five_tuple": jnp.tile(jnp.asarray(key), (n, 1)),
                "valid": jnp.ones((n,), bool)}

    st = R.init_state(cfg)
    # pre-wrap block: two packets just below 2^32
    st = R.ingest(st, block([0xFFFFFF00, 0xFFFFFFF0]), cfg)
    assert int(st.last_ts[slot]) == 0xFFFFFFF0
    assert int(st.regs[slot, R.COL_IAT]) == 0xF0     # second - first
    # post-wrap block: one packet at 0x10 — true IAT 0x20 via mod 2^32
    st = R.ingest(st, block([0x00000010]), cfg)
    assert int(st.last_ts[slot]) == 0x10, \
        "last_ts must take the post-wrap (numerically smaller) value"
    assert int(st.regs[slot, R.COL_IAT]) == 0xF0 + 0x20
    # next packet's IAT is measured from the post-wrap register
    st = R.ingest(st, block([0x00000030]), cfg)
    assert int(st.regs[slot, R.COL_IAT]) == 0xF0 + 0x20 + 0x20


def test_wrap_crossing_mid_block(rng):
    """A wrap INSIDE one block: arrival order (not numeric ts order) must
    drive both the in-block IAT chain and the final last_ts register."""
    cfg = get_dfa_config(reduced=True)    # COL_IAT sums are exact (no log*)
    key = np.arange(11, 16, dtype=np.uint32)
    slot = int(np.asarray(R.hash_slot(jnp.asarray(key),
                                      cfg.flows_per_shard)))
    ev = {"ts": jnp.asarray([0xFFFFFFE0, 0x00000008], jnp.uint32),
          "size": jnp.full((2,), 64, jnp.uint32),
          "five_tuple": jnp.tile(jnp.asarray(key), (2, 1)),
          "valid": jnp.ones((2,), bool)}
    st = R.ingest(R.init_state(cfg), ev, cfg)
    assert int(st.last_ts[slot]) == 0x8
    assert int(st.regs[slot, R.COL_IAT]) == 0x28     # 0x8 - 0xFFFFFFE0


def test_due_flows_wrap_crossing():
    """last_report just below the wrap, now just after it: the u32
    subtraction yields the true elapsed interval, so the flow goes due
    exactly one period later — and reporting at a post-wrap ``now`` must
    STORE that smaller value (the old .max update stalled the tracker)."""
    cfg = get_dfa_config(reduced=True)
    st = R.init_state(cfg)
    st = st._replace(active=st.active.at[0].set(True),
                     last_report=st.last_report.at[0].set(
                         jnp.uint32(0xFFFFF000)))
    period = jnp.uint32(cfg.monitoring_period_us)
    now_due = jnp.uint32(0xFFFFF000) + period        # wraps past 2^32
    assert int(now_due) < 0xFFFFF000                 # really wrapped
    _, mask_early = R.due_flows(st, now_due - jnp.uint32(1), cfg, 8)
    assert int(mask_early.sum()) == 0
    slots, mask = R.due_flows(st, now_due, cfg, 8)
    assert int(mask.sum()) == 1
    st2, _ = R.make_reports(st, slots, mask, now_due, 0, 0, cfg)
    assert int(st2.last_report[0]) == int(now_due), \
        "post-wrap report time must replace the pre-wrap register"
    _, mask_after = R.due_flows(st2, now_due, cfg, 8)
    assert int(mask_after.sum()) == 0


def test_due_flows_zero_period_edge(rng):
    """monitoring_period_us == 0 means report every period — but the old
    ``top > 0`` proxy scored just-reported flows 0 and silently dropped
    them. The due flags gathered at the top-k indices keep them."""
    import dataclasses
    cfg = dataclasses.replace(get_dfa_config(reduced=True),
                              monitoring_period_us=0)
    ev = make_events(rng, cfg, n_flows=5, E=32)
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    n_active = int(np.asarray(st.active).sum())
    now = jnp.uint32(50_000)
    slots, mask = R.due_flows(st, now, cfg, capacity=16)
    assert int(mask.sum()) == n_active
    st, _ = R.make_reports(st, slots, mask, now, 0, 0, cfg)
    # same instant, zero elapsed: still due (elapsed 0 >= period 0)
    slots2, mask2 = R.due_flows(st, now, cfg, capacity=16)
    assert int(mask2.sum()) == n_active
    got = {int(s) for s, m in zip(np.asarray(slots2), np.asarray(mask2))
           if m}
    assert got == {int(s) for s in np.nonzero(np.asarray(st.active))[0]}


def _colliding_keys(rng, cfg, want=2):
    """Search random five-tuples for ``want`` distinct keys sharing one
    hash slot (birthday-certain over a few hundred samples at F=256)."""
    keys = rng.integers(1, 2**31, size=(2048, 5)).astype(np.uint32)
    slots = np.asarray(R.hash_slot(jnp.asarray(keys),
                                   cfg.flows_per_shard))
    for s in np.unique(slots):
        hit = np.nonzero(slots == s)[0]
        if len(hit) >= want:
            return int(s), [keys[i] for i in hit[:want]]
    pytest.skip("no hash collision in sample")


def test_in_block_duplicate_install_first_come_wins(rng):
    """Regression (documented 'first-come key install'): two NEW flows
    hashing to the same empty slot in one block used to race through a
    duplicate-index ``.at[].set`` (last-write-wins, nondeterministic).
    The first event in arrival order must install its key; the loser is
    a collision and its stats are attributed to the resident flow."""
    cfg = get_dfa_config(reduced=True)
    slot, (key_a, key_b) = _colliding_keys(rng, cfg)

    def block(first_key, second_key):
        return {"ts": jnp.asarray([10, 20], jnp.uint32),
                "size": jnp.asarray([100, 200], jnp.uint32),
                "five_tuple": jnp.stack([jnp.asarray(first_key),
                                         jnp.asarray(second_key)]),
                "valid": jnp.ones(2, bool)}

    st = R.ingest(R.init_state(cfg), block(key_a, key_b), cfg)
    np.testing.assert_array_equal(np.asarray(st.keys[slot]), key_a)
    assert int(st.collisions) == 1          # the loser, counted
    assert bool(st.active[slot])
    # both events still accumulate into the resident slot (count = 2)
    assert int(st.regs[slot, R.COL_COUNT]) == 2
    assert int(st.last_ts[slot]) == 20
    # arrival order decides, not key value: reversed block installs B
    st2 = R.ingest(R.init_state(cfg), block(key_b, key_a), cfg)
    np.testing.assert_array_equal(np.asarray(st2.keys[slot]), key_b)
    assert int(st2.collisions) == 1
    # same key twice is a plain duplicate, never a collision
    st3 = R.ingest(R.init_state(cfg), block(key_a, key_a), cfg)
    assert int(st3.collisions) == 0
    assert int(st3.regs[slot, R.COL_COUNT]) == 2


def test_due_flows_capacity_at_and_beyond_table_size(rng):
    """Regression: ``capacity > F`` used to crash (top_k over a smaller
    axis). The clamp keeps the fixed-size (capacity,) SPMD contract with
    pad rows masked out; ``capacity == F`` selects the whole table."""
    cfg = get_dfa_config(reduced=True)
    F = cfg.flows_per_shard
    ev = make_events(rng, cfg, n_flows=6, E=48)
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    n_active = int(np.asarray(st.active).sum())
    now = jnp.uint32(cfg.monitoring_period_us + 10_000)
    for capacity in (F, F + 1, F + 177):
        slots, mask = R.due_flows(st, now, cfg, capacity=capacity)
        assert slots.shape == (capacity,) and mask.shape == (capacity,)
        assert int(mask.sum()) == n_active
        got = {int(s) for s, m in zip(np.asarray(slots),
                                      np.asarray(mask)) if m}
        assert got == {int(s) for s
                       in np.nonzero(np.asarray(st.active))[0]}


def test_collision_counting(rng):
    cfg = get_dfa_config(reduced=True)
    # two different keys forced into the same slot via crafted search
    keys = rng.integers(1, 2**31, size=(64, 5)).astype(np.uint32)
    slots = np.asarray(R.hash_slot(jnp.asarray(keys),
                                   cfg.flows_per_shard))
    dup = None
    for i in range(len(slots)):
        for j in range(i + 1, len(slots)):
            if slots[i] == slots[j]:
                dup = (i, j)
                break
        if dup:
            break
    if not dup:
        pytest.skip("no hash collision in sample")
    i, j = dup
    ev = {"ts": np.asarray([10, 20], np.uint32),
          "size": np.asarray([100, 200], np.uint32),
          "five_tuple": np.stack([keys[i], keys[j]]),
          "valid": np.ones(2, bool)}
    st = R.ingest(R.init_state(cfg),
                  {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    st = R.ingest(st, {k: jnp.asarray(v) for k, v in ev.items()}, cfg)
    assert int(st.collisions) >= 1
