"""The versioned wire schema (repro.core.wire): both registered formats
pack/unpack losslessly, V1 is bit-faithful to the paper's hand-coded
layout, the V2 checksum detects every single-bit flip, and the registry /
resolution order fails loud.

Property style: randomized field values drawn from each field's declared
capacity (fixed seed, a few hundred samples per format) rather than
hand-picked corners — the roundtrip must hold for ANY representable
(reporter_id, seq, hist_idx) triple, which is exactly what the
schema-driven refactor is supposed to guarantee by construction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import env as ENV
from repro.configs.dfa import REDUCED
from repro.core import protocol as PROTO
from repro.core import wire as WIRE

N_SAMPLES = 256

BOTH = [WIRE.V1, WIRE.V2]
IDS = [w.name for w in BOTH]


def _random_fields(wire, rng, n=N_SAMPLES):
    """Uniform draws over each field's full declared capacity."""
    return {
        "flow_id": rng.integers(0, 2**32, size=n, dtype=np.uint32),
        "reporter_id": rng.integers(0, wire.report_reporter.capacity,
                                    size=n, dtype=np.uint32),
        "seq": rng.integers(0, wire.report_seq.capacity, size=n,
                            dtype=np.uint32),
        "hist_idx": rng.integers(0, wire.payload_hist.capacity, size=n,
                                 dtype=np.uint32),
        "stats": rng.integers(0, 2**32, size=(n, PROTO.N_STATS),
                              dtype=np.uint32),
        "five_tuple": rng.integers(0, 2**32, size=(n, 5),
                                   dtype=np.uint32),
    }


# -- property: pack -> unpack roundtrip, both formats ---------------------

@pytest.mark.parametrize("wire", BOTH, ids=IDS)
def test_report_pack_unpack_roundtrip(wire, rng):
    f = _random_fields(wire, rng)
    rep = PROTO.pack_dta_report(
        jnp.asarray(f["flow_id"]), jnp.asarray(f["reporter_id"]),
        jnp.asarray(f["seq"]), jnp.asarray(f["stats"]),
        jnp.asarray(f["five_tuple"]), wire=wire)
    assert rep.shape == (N_SAMPLES, wire.report_words)
    got = PROTO.unpack_dta_report(rep, wire=wire)
    for k in ("flow_id", "reporter_id", "seq", "stats", "five_tuple"):
        np.testing.assert_array_equal(np.asarray(got[k]), f[k],
                                      err_msg=f"{wire.name}: {k}")


@pytest.mark.parametrize("wire", BOTH, ids=IDS)
def test_payload_pack_unpack_roundtrip_and_valid(wire, rng):
    f = _random_fields(wire, rng)
    rep = {k: jnp.asarray(f[k]) for k in
           ("flow_id", "reporter_id", "seq", "stats", "five_tuple")}
    pay = PROTO.pack_rocev2_payload(rep, jnp.asarray(f["hist_idx"]),
                                    wire=wire)
    assert pay.shape == (N_SAMPLES, wire.payload_words)
    got = PROTO.unpack_payload(pay, wire=wire)
    for k in ("flow_id", "reporter_id", "seq", "hist_idx", "stats",
              "five_tuple"):
        np.testing.assert_array_equal(np.asarray(got[k]), f[k],
                                      err_msg=f"{wire.name}: {k}")
    assert bool(np.asarray(PROTO.payload_valid(pay, wire=wire)).all())


@pytest.mark.parametrize("wire", BOTH, ids=IDS)
def test_field_place_set_get_roundtrip(wire, rng):
    """Field-level algebra: place/get invert, set_in only touches its own
    bits — on random pre-existing word values."""
    for fld in (wire.report_reporter, wire.report_seq,
                wire.payload_reporter, wire.payload_seq,
                wire.payload_hist):
        vals = jnp.asarray(rng.integers(0, fld.capacity, size=64,
                                        dtype=np.uint32))
        words = jnp.asarray(rng.integers(0, 2**32, size=64,
                                         dtype=np.uint32))
        np.testing.assert_array_equal(np.asarray(fld.get(fld.place(vals))),
                                      np.asarray(vals))
        packed = fld.set_in(words, vals)
        np.testing.assert_array_equal(np.asarray(fld.get(packed)),
                                      np.asarray(vals))
        # bits outside the field are untouched
        keep = np.uint32(~(fld.mask << fld.shift) & 0xFFFFFFFF)
        np.testing.assert_array_equal(np.asarray(packed) & keep,
                                      np.asarray(words) & keep)


# -- V1 bit-identity with the paper's hand-coded layout -------------------

def test_v1_meta_words_bit_identical_to_hand_packing(rng):
    wf = WIRE.V1
    rid = rng.integers(0, 256, size=128, dtype=np.uint32)
    seq = rng.integers(0, 256, size=128, dtype=np.uint32)
    hist = rng.integers(0, 256, size=128, dtype=np.uint32)
    meta = np.asarray(wf.pack_report_meta(jnp.asarray(rid),
                                          jnp.asarray(seq)))
    np.testing.assert_array_equal(meta, (rid << 24) | (seq << 16))
    w = rng.integers(0, 2**32, size=128, dtype=np.uint32)
    np.testing.assert_array_equal(
        np.asarray(wf.set_report_reporter(jnp.asarray(w),
                                          jnp.asarray(rid))),
        (w & np.uint32(0x00FFFFFF)) | (rid << 24))
    pm = wf.payload_meta_words(jnp.asarray(rid), jnp.asarray(seq),
                               jnp.asarray(hist))
    np.testing.assert_array_equal(np.asarray(pm[13]),
                                  (rid << 24) | (seq << 16) | hist)
    assert (np.asarray(pm[15]) == 0).all(), "V1 word 15 is the zero pad"


def test_v1_checksum_equals_legacy_body_fold(rng):
    """The explicit-position fold over (0..13, 15) with a zero pad word
    equals the historical arange(14) fold over the body — rotl(0,15)=0,
    so committed V1 payloads verify unchanged."""
    body = jnp.asarray(rng.integers(0, 2**32, size=(64, 14),
                                    dtype=np.uint32))
    legacy = PROTO.xor_checksum(body)                  # positions default
    pad = jnp.zeros((64, 1), jnp.uint32)
    covered = jnp.concatenate([body, pad], axis=-1)
    new = PROTO.xor_checksum(covered, jnp.asarray(WIRE.V1.csum_covered,
                                                  jnp.uint32))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


def test_derived_geometry_pins():
    """The numbers the rest of the codebase keys off — a layout change
    here is a wire-protocol break and must be deliberate."""
    assert WIRE.V1.n_reporters == 256 and WIRE.V2.n_reporters == 65536
    assert WIRE.V1.seq_mask == 0xFF and WIRE.V2.seq_mask == 0xFFFF
    assert WIRE.V1.seq_dup_window == 8          # the paper's §VI-B window
    assert WIRE.V2.seq_dup_window == 2048       # same 1/32 of seq space
    assert WIRE.V1.hist_counter_mask == 0xFF
    assert WIRE.V2.hist_counter_mask == 0xFF    # history depth unchanged
    for wf in BOTH:
        assert wf.report_words == 14 and wf.payload_words == 16
        assert wf.csum_word == 14
        assert wf.csum_covered == tuple(range(14)) + (15,)
        assert wf.report_meta_word == 1 and wf.payload_meta_word == 13


# -- V2 checksum: every single-bit flip of every word is detected ---------

def test_v2_single_bit_flip_detected_in_every_word(rng):
    f = _random_fields(WIRE.V2, rng, n=8)
    rep = {k: jnp.asarray(f[k]) for k in
           ("flow_id", "reporter_id", "seq", "stats", "five_tuple")}
    pay = np.asarray(PROTO.pack_rocev2_payload(
        rep, jnp.asarray(f["hist_idx"]), wire=WIRE.V2))
    W = WIRE.V2.payload_words
    # (n, W*32, W): every payload copied once per (word, bit) flip
    flips = np.repeat(pay[:, None, :], W * 32, axis=1)
    idx = np.arange(W * 32)
    flips[:, idx, idx // 32] ^= np.uint32(1) << (idx % 32).astype(
        np.uint32)
    ok = np.asarray(PROTO.payload_valid(jnp.asarray(flips),
                                        wire=WIRE.V2))
    assert not ok.any(), (
        "a single-bit flip went undetected at (payload, word, bit) "
        f"{np.argwhere(ok)[:4].tolist()} — V2's hist_idx word must be "
        "inside the fold like every other word")


def test_v1_single_bit_flip_detected_in_every_word(rng):
    """Same sweep for V1 — including the pad word 15, whose coverage is
    what makes the V1/V2 fold definitions coincide on V1 payloads."""
    f = _random_fields(WIRE.V1, rng, n=4)
    rep = {k: jnp.asarray(f[k]) for k in
           ("flow_id", "reporter_id", "seq", "stats", "five_tuple")}
    pay = np.asarray(PROTO.pack_rocev2_payload(
        rep, jnp.asarray(f["hist_idx"]), wire=WIRE.V1))
    W = WIRE.V1.payload_words
    flips = np.repeat(pay[:, None, :], W * 32, axis=1)
    idx = np.arange(W * 32)
    flips[:, idx, idx // 32] ^= np.uint32(1) << (idx % 32).astype(
        np.uint32)
    ok = np.asarray(PROTO.payload_valid(jnp.asarray(flips),
                                        wire=WIRE.V1))
    assert not ok.any()


# -- registry, resolution order, jit-compatibility ------------------------

def test_registry_and_fail_loud():
    assert WIRE.get("v1") is WIRE.V1 and WIRE.get("v2") is WIRE.V2
    with pytest.raises(ValueError, match="unknown wire format"):
        WIRE.get("v3")
    with pytest.raises(ValueError, match="repro.core.wire"):
        WIRE.get("")


def test_resolution_order(monkeypatch):
    monkeypatch.delenv("REPRO_WIRE_FORMAT", raising=False)
    assert WIRE.resolve(None) is WIRE.V1
    assert WIRE.resolve(REDUCED) is WIRE.V1
    cfg2 = dataclasses.replace(REDUCED, wire_format="v2")
    assert WIRE.resolve(cfg2) is WIRE.V2
    # env beats cfg
    monkeypatch.setenv("REPRO_WIRE_FORMAT", "v1")
    assert WIRE.resolve(cfg2) is WIRE.V1
    monkeypatch.setenv("REPRO_WIRE_FORMAT", "v2")
    assert WIRE.resolve(REDUCED) is WIRE.V2
    # junk fails loud at the env layer (typo -> error, not silent V1)
    monkeypatch.setenv("REPRO_WIRE_FORMAT", "v2 wide")
    with pytest.raises(ValueError, match="REPRO_WIRE_FORMAT"):
        WIRE.resolve(REDUCED)
    # ...and at the cfg layer
    monkeypatch.delenv("REPRO_WIRE_FORMAT", raising=False)
    with pytest.raises(ValueError, match="unknown wire format"):
        WIRE.resolve(dataclasses.replace(REDUCED, wire_format="wide"))


def test_env_choice_registered():
    assert "REPRO_WIRE_FORMAT" in ENV.registered()


def test_wire_format_is_hashable_jit_static():
    """WireFormat rides through jit as a static argument (how the Pallas
    wrappers and protocol packers receive it)."""
    assert hash(WIRE.V1) != hash(WIRE.V2)

    @jax.jit
    def unpack_v2(p):
        return PROTO.unpack_payload(p, wire=WIRE.V2)["seq"]

    p = jnp.zeros((3, 16), jnp.uint32).at[:, 13].set(
        jnp.asarray([1, 2, 70000 & 0xFFFFFFFF], jnp.uint32))
    np.testing.assert_array_equal(np.asarray(unpack_v2(p)),
                                  [1, 2, 70000 & 0xFFFF])


def test_wire_lint_clean_and_catches(tmp_path):
    """tools/lint_wire.py (the CI lint-tier step): the source tree has no
    raw layout bit-twiddling outside core/wire.py, and a planted
    violation is caught (while docstrings/comments are not)."""
    import os
    import subprocess
    import sys
    root = os.path.join(os.path.dirname(__file__), "..")
    tool = os.path.join(root, "tools", "lint_wire.py")
    r = subprocess.run([sys.executable, tool], cwd=root,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    bad = tmp_path / "bad.py"
    bad.write_text('"""docstring may say << 24."""\n'
                   "# comment may say >> 24\n"
                   "meta = (rid << 24) | (seq << 16)\n"
                   "keep = w & 0x00FFFFFF\n")
    r2 = subprocess.run([sys.executable, tool, str(bad)], cwd=root,
                        capture_output=True, text=True)
    assert r2.returncode == 1
    assert "bad.py:3" in r2.stderr and "bad.py:4" in r2.stderr
    assert "bad.py:1" not in r2.stderr and "bad.py:2" not in r2.stderr


def test_field_validation():
    with pytest.raises(ValueError, match="does not fit"):
        WIRE.Field(word=0, shift=24, width=16)
    with pytest.raises(ValueError, match="width differs"):
        dataclasses.replace(WIRE.V1,
                            report_reporter=WIRE.Field(1, 16, 16))
    with pytest.raises(ValueError, match="cover itself"):
        dataclasses.replace(WIRE.V1,
                            csum_covered=tuple(range(15)))
