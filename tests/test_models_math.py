"""Numerical correctness of the model-side algorithms against naive
references: flash attention (fwd+vjp), SSD chunked scan, RWKV6 chunked WKV,
MLA absorbed decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import rwkv as RW
from repro.models import ssm as SS


def naive_attention(q, k, v, causal=True, scale=None):
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    Dv = v.shape[-1]
    scale = scale or D ** -0.5
    qr = q.reshape(B, S, KH, G, D)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qr, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bcke->bqkge", p.astype(v.dtype), v)
    return o.reshape(B, S, H, Dv)


@pytest.mark.parametrize("S,H,KH,D,Dv,qc,kc", [
    (32, 4, 4, 8, 8, 8, 16),      # MHA
    (64, 8, 2, 16, 16, 16, 32),   # GQA
    (48, 6, 1, 8, 4, 12, 24),     # MQA + Dv != D (MLA-style)
])
def test_flash_forward_matches_naive(rng, S, H, KH, D, Dv, qc, kc):
    B = 2
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, Dv)), jnp.float32)
    got = A.chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_vjp_matches_naive(rng):
    B, S, H, KH, D = 2, 40, 6, 3, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)

    def loss(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a) * 0.3))

    g1 = jax.grad(loss(lambda q, k, v: A.chunked_attention(
        q, k, v, q_chunk=8, kv_chunk=8)), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(naive_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def naive_ssd(x, dt, Aa, B_, C_, D_):
    """Sequential SSM recurrence (fp64 for reference)."""
    b, s, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bh = np.repeat(np.asarray(B_, np.float64), rep, 2)
    Ch = np.repeat(np.asarray(C_, np.float64), rep, 2)
    xn = np.asarray(x, np.float64)
    dtn = np.asarray(dt, np.float64)
    An = np.asarray(Aa, np.float64)
    S = np.zeros((b, H, Pd, N))
    y = np.zeros((b, s, H, Pd))
    for t in range(s):
        dA = np.exp(dtn[:, t] * An[None, :])               # (b,H)
        S = S * dA[:, :, None, None] + np.einsum(
            "bhp,bhn->bhpn", xn[:, t] * dtn[:, t][..., None], Bh[:, t])
        y[:, t] = np.einsum("bhpn,bhn->bhp", S, Ch[:, t]) + \
            xn[:, t] * np.asarray(D_)[None, :, None]
    return y, S


def test_ssd_chunked_matches_recurrence(rng):
    b, s, H, Pd, G, N, K = 2, 40, 4, 8, 1, 8, 8
    x = jnp.asarray(rng.standard_normal((b, s, H, Pd)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, H)) * 0.5 + 0.1, jnp.float32)
    Aa = -jnp.asarray(rng.random(H) + 0.3, jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((b, s, G, N)), jnp.float32)
    C_ = jnp.asarray(rng.standard_normal((b, s, G, N)), jnp.float32)
    D_ = jnp.asarray(rng.random(H), jnp.float32)
    y, S = SS.ssd_chunked(x, dt, Aa, B_, C_, D_, K)
    y2, S2 = naive_ssd(x, dt, Aa, B_, C_, D_)
    np.testing.assert_allclose(np.asarray(y), y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S2, rtol=2e-4, atol=2e-4)


def naive_wkv6(r, k, v, lw, u):
    B, S, H, D = r.shape
    rn, kn, vn, lwn = [np.asarray(t, np.float64) for t in (r, k, v, lw)]
    un = np.asarray(u, np.float64)
    St = np.zeros((B, H, D, D))
    y = np.zeros((B, S, H, D))
    for t in range(S):
        kv = np.einsum("bhd,bhe->bhde", kn[:, t], vn[:, t])
        y[:, t] = np.einsum("bhd,bhde->bhe", rn[:, t],
                            St + un[None, :, :, None] * kv)
        St = St * np.exp(lwn[:, t])[..., None] + kv
    return y, St


def test_wkv6_chunked_matches_recurrence(rng):
    B, S, H, D, K = 2, 48, 3, 8, 8
    r = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    lw = -jnp.asarray(rng.random((B, S, H, D)) * 2 + 0.05, jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, D)), jnp.float32)
    y, St = RW.wkv6_chunked(r, k, v, lw, u, K)
    y2, St2 = naive_wkv6(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y), y2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(St), St2, rtol=3e-4, atol=3e-4)


def test_wkv6_state_carries_across_chunks(rng):
    """Processing [0:S] must equal [0:S/2] then [S/2:S] with state0."""
    B, S, H, D, K = 1, 32, 2, 8, 8
    r = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    lw = -jnp.asarray(rng.random((B, S, H, D)) + 0.05, jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, D)), jnp.float32)
    y_full, S_full = RW.wkv6_chunked(r, k, v, lw, u, K)
    h = S // 2
    y1, S1 = RW.wkv6_chunked(r[:, :h], k[:, :h], v[:, :h], lw[:, :h], u, K)
    y2, S2 = RW.wkv6_chunked(r[:, h:], k[:, h:], v[:, h:], lw[:, h:], u, K,
                             state0=S1)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_full), np.asarray(S2),
                               rtol=1e-4, atol=1e-4)
