"""Multi-device semantics (8 fake CPU devices via subprocess isolation):
flash-decode partial-softmax combine, MoE EP vs dense reference, DFA
routing across shards, pipeline parallelism, compressed psum."""
import os
import subprocess
import sys
import textwrap

import pytest

# truly-multi-device semantics: skipped when the 8 forced host devices are
# unavailable (see conftest.pytest_collection_modifyitems). Each subprocess
# pays a multi-minute 8-device XLA CPU partitioning compile, so the module
# is opt-in (pytest -m slow); tier-1 covers multi-shard routing in-process
# via test_dispatch.py::test_run_periods_multi_shard on a (2, 2) mesh.
pytestmark = [pytest.mark.multidevice, pytest.mark.slow]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh, shard_map
mesh = make_mesh((2,2,2), ("pod","data","model"))
rng = np.random.default_rng(0)
"""


def test_flash_decode_matches_full_attention():
    run_sub(PRELUDE + """
from repro.models.attention import flash_decode
B, S, KH, G, D = 4, 64, 2, 3, 8
H = KH * G
q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
kc = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
vc = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
kn = jnp.asarray(rng.standard_normal((B, KH, D)), jnp.float32)
vn = jnp.asarray(rng.standard_normal((B, KH, D)), jnp.float32)
pos = jnp.asarray([5, 17, 33, 63], jnp.int32)
with mesh:
    out, kc2, vc2 = jax.jit(lambda *a: flash_decode(
        *a, mesh=mesh, seq_axes=("model",), batch_axes=("pod","data")))(
        q, kc, vc, kn, vn, pos)
out, kc2, vc2 = map(np.asarray, (out, kc2, vc2))
# reference: write kv at pos, full softmax over <= pos
for b in range(B):
    kref = np.asarray(kc).copy(); vref = np.asarray(vc).copy()
    kref[b, pos[b]] = np.asarray(kn)[b]; vref[b, pos[b]] = np.asarray(vn)[b]
    np.testing.assert_allclose(kc2[b], kref[b], rtol=1e-6)
    qr = np.asarray(q)[b].reshape(KH, G, D)
    s = np.einsum("kgd,skd->kgs", qr, kref[b]) / np.sqrt(D)
    s[:, :, pos[b]+1:] = -1e30
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("kgs,skd->kgd", p, vref[b]).reshape(H, D)
    np.testing.assert_allclose(out[b], o, rtol=2e-4, atol=2e-4)
print("flash_decode OK")
""")


def test_moe_ep_matches_dense_reference():
    run_sub(PRELUDE + """
from repro.configs import get_config
from repro.models import moe as M
from repro.models.param import materialize
cfg = get_config("deepseek-v3-671b", reduced=True)
m = cfg.moe
params = materialize(M.moe_descs(cfg), jax.random.key(0))
B, S = 4, 8
x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1,
                jnp.float32)
with mesh:
    y = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, mesh,
                                       ("pod", "data")))(params, x)
# dense reference: full routing, no capacity
xf = np.asarray(x, np.float32).reshape(-1, cfg.d_model)
w, idx = map(np.asarray, M.route(
    {k: np.asarray(v, np.float32) for k, v in params.items()
     if k in ("router", "bias")}, jnp.asarray(xf), cfg))
gate = np.asarray(params["gate"], np.float32)
up = np.asarray(params["up"], np.float32)
down = np.asarray(params["down"], np.float32)
def silu(a): return a / (1 + np.exp(-a))
ref = np.zeros_like(xf)
for t in range(xf.shape[0]):
    for j in range(m.top_k):
        e = idx[t, j]
        h = silu(xf[t] @ gate[e]) * (xf[t] @ up[e])
        ref[t] += w[t, j] * (h @ down[e])
shared = params["shared"]
hs = silu(xf @ np.asarray(shared["gate"]["w"], np.float32)) * (
    xf @ np.asarray(shared["up"]["w"], np.float32))
ref += hs @ np.asarray(shared["down"]["w"], np.float32)
np.testing.assert_allclose(np.asarray(y, np.float32).reshape(-1,
    cfg.d_model), ref, rtol=3e-2, atol=3e-2)
print("moe EP OK")
""")


def test_dfa_pipeline_multi_shard_routing():
    run_sub(PRELUDE + """
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
cfg = get_dfa_config(reduced=True)
sysm = DFASystem(cfg, mesh)
flows = PK.gen_flows(16, seed=1)
ev = PK.events_for_shards(flows, 0, sysm.n_shards, 128)
state = sysm.init_state()
with mesh:
    step = jax.jit(sysm.dfa_step)
    out = step(
        state, {k: jnp.asarray(v) for k, v in ev.items()},
        jnp.uint32(60_000))
flow_ids, emask, metrics = out.flow_ids, out.mask, out.metrics
sent = int(np.asarray(metrics["reports_sent"]).flat[0])
recv = int(np.asarray(metrics["reports_recv"]).flat[0])
drop = int(np.asarray(metrics["bucket_drops"]).flat[0])
assert sent == recv + drop, (sent, recv, drop)
# every received flow id must live in the right shard's range
fid = np.asarray(flow_ids); em = np.asarray(emask)
F = cfg.flows_per_shard
rows_per_shard = len(fid) // sysm.n_shards
for shard in range(sysm.n_shards):
    rows = slice(shard * rows_per_shard, (shard + 1) * rows_per_shard)
    owners = fid[rows][em[rows]] // F
    owners = np.minimum(owners, sysm.n_shards - 1)
    assert (owners == shard).all(), (shard, owners)
print("dfa routing OK")
""")


def test_pipeline_parallel_equivalence():
    run_sub(PRELUDE + """
from repro.distributed.pipeline import pipeline_apply
S_stage = 2  # pod axis size
d = 16
Ws = jnp.asarray(rng.standard_normal((S_stage, d, d)) * 0.3, jnp.float32)
def stage_fn(w, x, sid):
    return jnp.tanh(x @ w["w"])
x = jnp.asarray(rng.standard_normal((8, 4, d)), jnp.float32)
with mesh:
    y = jax.jit(lambda w, x: pipeline_apply(
        stage_fn, w, x, mesh, axis="pod", num_micro=2))({"w": Ws}, x)
ref = np.asarray(x)
for s in range(S_stage):
    ref = np.tanh(ref @ np.asarray(Ws[s]))
np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)
print("pipeline parallel OK")
""")


def test_compressed_psum_close_to_exact():
    run_sub(PRELUDE + """
from repro.optim import compression
g = jnp.asarray(rng.standard_normal((8, 64)) * 0.01, jnp.float32)
err = jnp.zeros((8, 64))
def f(g, e):
    out, e2 = compression.compressed_psum({"g": g}, {"g": e},
                                          ("pod", "data"))
    return out["g"], e2["g"]
fn = shard_map(f, mesh=mesh,
               in_specs=(P(("pod","data"), None), P(("pod","data"), None)),
               out_specs=(P(("pod","data"), None), P(("pod","data"), None)),
               check=False)
with mesh:
    got, _ = jax.jit(fn)(g, err)
# exact mean over the 4 (pod,data) ranks, per model-replica
gm = np.asarray(g).reshape(4, 2, 64).mean(0)  # 4 dp ranks x (2 rows each)
got = np.asarray(got).reshape(4, 2, 64)
for r in range(4):
    np.testing.assert_allclose(got[r], gm, rtol=0.05, atol=1e-4)
print("compressed psum OK")
""")
