"""Pod-count invariance of the 2D (pod, shard) mesh stream.

This container is CPU-only, so the correctness of the multi-pod routing
layer (per-port reporter tables, hash-home flow ids, two-stage intra-pod/
cross-pod exchange, home-side canonical re-ordering) is carried entirely
by this differential harness: for every scenario in
``repro.data.scenarios`` the SAME port-major traffic trace is streamed
through a ``(1, S)``, ``(2, S)`` and ``(4, S//2)`` mesh holding the
global ring keyspace fixed (``flows_per_shard = G / n_devices``), and the
merged end state plus every per-period metric delta must be BITWISE
identical — for both drivers (``run_periods`` /
``run_periods_overlapped``) and with the inference head on and off.

Canonical re-gather: reporter state is already port-major-global (one
table per port, identical layout on every mesh); translator counters and
the collector ring concatenate pod-major into the (G, ...) keyspace;
``last_seq`` merges by elementwise max (a monotone tracker — a port's
reports spread over devices differently per mesh); the scalar telemetry
counters merge by sum. Per-period enriched features / flow ids / preds
are compared as flow-id-sorted sets (row order inside a period is a
mesh-dependent exchange artifact; the VALUES must match bitwise).

Compile cost dominates: systems and jitted drivers are cached per
(mesh, head) and shared across all scenarios (same shapes), so the whole
grid pays 12 small SPMD compiles. The 8-device (1,4)/(2,4)/(4,2) family
re-runs two scenarios and is marked slow for the nightly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import pod_mesh_or_skip
from repro.configs.dfa import (REDUCED, REDUCED_MULTIPOD,
                               REDUCED_MULTIPOD_V2)
from repro.core import translator as TRANS
from repro.core.pipeline import DFASystem
from repro.data import scenarios as SC

TOTAL_PORTS = 4
EVENTS_PER_PORT = 48
T = 3
G = 512                  # global ring keyspace, fixed across meshes
REPORTER_SLOTS = 64      # per-PORT Marina table, fixed across meshes
PORT_CAPACITY = 16       # per-port due-report capacity

GRID = ((1, 2), (2, 2), (4, 1))          # S=2 family (<= 4 devices)
GRID_WIDE = ((1, 4), (2, 4), (4, 2))     # S=4 family (8 devices, slow)

SCENARIOS = sorted(SC.SCENARIOS)

_systems = {}
_traces = {}


def _mesh_cfg(pods, shards, head, total_ports):
    ndev = pods * shards
    return dataclasses.replace(
        REDUCED,
        flow_home="hash",
        pods=pods,
        ports_per_pod=total_ports // pods,
        reporter_slots=REPORTER_SLOTS,
        flows_per_shard=G // ndev,
        port_report_capacity=PORT_CAPACITY,
        kernel_backend="ref",
        inference_head=head)


def _system(pods, shards, head, total_ports=TOTAL_PORTS):
    key = (pods, shards, head, total_ports)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        sysm = DFASystem(_mesh_cfg(pods, shards, head, total_ports),
                         mesh)
        _systems[key] = (sysm, jax.jit(sysm.run_periods),
                         jax.jit(sysm.run_periods_overlapped))
    return _systems[key]


def _trace(name, total_ports=TOTAL_PORTS):
    key = (name, total_ports)
    if key not in _traces:
        ev, nows = SC.build(name, total_ports, EVENTS_PER_PORT, T)
        _traces[key] = ({k: jnp.asarray(v) for k, v in ev.items()},
                        jnp.asarray(nows))
    return _traces[key]


def _merged_state(system, state):
    """Canonical re-gather: mesh-shape-independent view of DFAState."""
    n = system.n_shards
    out = {f"rep.{k}": np.asarray(a)
           for k, a in state.reporter._asdict().items()}
    out["tr.hist_counter"] = np.asarray(state.translator.hist_counter)
    c = state.collector
    out["coll.memory"] = np.asarray(c.memory)
    out["coll.entry_valid"] = np.asarray(c.entry_valid)
    out["coll.last_seq"] = np.asarray(c.last_seq).reshape(n, -1).max(0)
    for k in ("bad_checksum", "seq_anomalies", "received",
              "lost_reports"):
        out[f"coll.{k}"] = np.asarray(getattr(c, k)).astype(
            np.uint64).sum()
    return out


def _canon_periods(enr, fid, em, preds=None):
    """Per period: (sorted flow ids, enriched rows in that order[, preds])
    — the mesh-invariant content of the period's output batch."""
    enr, fid, em = np.asarray(enr), np.asarray(fid), np.asarray(em)
    preds = None if preds is None else np.asarray(preds)
    per = []
    for t in range(enr.shape[0]):
        m = em[t]
        order = np.argsort(fid[t][m], kind="stable")
        row = {"fid": fid[t][m][order], "enr": enr[t][m][order]}
        if preds is not None:
            row["preds"] = preds[t][m][order]
        per.append(row)
    return per


def _run(pods, shards, head, overlapped, scenario,
         total_ports=TOTAL_PORTS):
    sysm, seq, ovl = _system(pods, shards, head, total_ports)
    events, nows = _trace(scenario, total_ports)
    with sysm.mesh:
        out = (ovl if overlapped else seq)(sysm.init_state(), events,
                                           nows)
    assert (out.preds is None) == (head == "none")
    return (_merged_state(sysm, out.state),
            _canon_periods(out.enriched, out.flow_ids, out.mask,
                           out.preds),
            {k: np.asarray(v) for k, v in out.metrics.items()})


def _assert_same(ref, got, ctx):
    rst, rout, rmet = ref
    gst, gout, gmet = got
    for k in rst:
        np.testing.assert_array_equal(rst[k], gst[k],
                                      err_msg=f"{ctx}: state {k}")
    assert sorted(rmet) == sorted(gmet)
    for k in rmet:
        np.testing.assert_array_equal(rmet[k], gmet[k],
                                      err_msg=f"{ctx}: metric {k}")
    for t, (r, g) in enumerate(zip(rout, gout)):
        for k in r:
            np.testing.assert_array_equal(
                r[k], g[k], err_msg=f"{ctx}: period {t} {k}")


def _check_grid(grid, scenario, head, total_ports=TOTAL_PORTS):
    for overlapped in (False, True):
        ref = _run(*grid[0], head, overlapped, scenario, total_ports)
        assert int(ref[2]["reports_recv"].sum()) > 0, \
            f"{scenario}: trace produced no routed reports"
        assert int(ref[2]["bucket_drops"].sum()) == 0
        # validity bound of the invariance contract: once a port's
        # lifetime report count passes the wire format's seq space, the
        # collector's per-DEVICE §VI-B dup window can fire differently
        # per mesh factorization (each device sees a mesh-dependent
        # subset of a reporter's seq stream). Scenarios must stay under
        # the wrap — assert it so a future longer trace fails here, not
        # as an inscrutable seq_anomalies mismatch. The bound comes off
        # the schema, not a hard-coded 256: V2 traces get the u16 space.
        wf = _system(*grid[0], head, total_ports)[0].wire
        assert (ref[0]["rep.seq"] <= wf.seq_mask).all(), \
            f"{scenario}: a port wrapped its {wf.seq_width}-bit seq; " \
            "invariance of seq_anomalies is not guaranteed past the wrap"
        for pods, shards in grid[1:]:
            got = _run(pods, shards, head, overlapped, scenario,
                       total_ports)
            _assert_same(ref, got,
                         f"{scenario} head={head} "
                         f"ovl={overlapped} ({pods},{shards}) vs "
                         f"{grid[0]}")


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_pod_count_invariance(scenario):
    """(1,2) == (2,2) == (4,1), both drivers, no inference head."""
    _check_grid(GRID, scenario, "none")


@pytest.mark.parametrize("scenario", ["elephants_mice", "cross_pod_mix",
                                      "flow_churn", "collision_storm",
                                      "u32_wrap"])
def test_pod_count_invariance_with_inference(scenario):
    """Same grid with the linear verdict head armed: preds ride the
    enrich half, so they must be pod-count invariant too."""
    _check_grid(GRID, scenario, "linear")


@pytest.mark.multidevice
@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["elephants_mice", "cross_pod_mix"])
def test_pod_count_invariance_wide(scenario):
    """The 8-device S=4 family (1,4)/(2,4)/(4,2) — nightly-sized.

    8 ports (one per device on the widest meshes, 2/device on (1,4))
    instead of tier-1's 4: total_ports must be a device-count multiple
    on every mesh in the family."""
    _check_grid(GRID_WIDE, scenario, "none", total_ports=8)


def test_pod22_stream_smoke():
    """In-process (2,2)-pod streaming check (the tier-1 CI anchor):
    REDUCED_MULTIPOD on a real (2,2) mesh streams both drivers
    output-identically, reports actually cross pods, and describe()
    surfaces the topology."""
    mesh = pod_mesh_or_skip(2, 2)
    sysm = DFASystem(dataclasses.replace(REDUCED_MULTIPOD,
                                         kernel_backend="ref"), mesh)
    ev, nows = SC.build("cross_pod_mix", sysm.total_ports, 32, T)
    events = {k: jnp.asarray(v) for k, v in ev.items()}
    nows = jnp.asarray(nows)
    with sysm.mesh:
        seq = jax.jit(sysm.run_periods)(sysm.init_state(), events, nows)
        ovl = jax.jit(sysm.run_periods_overlapped)(sysm.init_state(),
                                                   events, nows)
    fid, em, met = seq.flow_ids, seq.mask, seq.metrics
    assert int(np.asarray(met["reports_recv"]).sum()) > 0
    # cross-pod delivery really happened: some flow ingested by a pod-0
    # port is homed on pod 1 (or vice versa) — with hash homes over a
    # shared flow set this is overwhelmingly likely, and deterministic
    # for the fixed seed
    fps = sysm.cfg.flows_per_shard
    homes = np.asarray(fid)[np.asarray(em)].astype(np.int64) // fps
    home_pods = homes // sysm.shards_per_pod
    assert set(home_pods.tolist()) == {0, 1}, \
        "trace never exercised the cross-pod exchange"
    # overlapped driver is output-identical on the pod mesh too
    for a, b in zip(jax.tree.leaves(seq), jax.tree.leaves(ovl)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    d = sysm.describe()
    assert d["flow_home"] == "hash" and d["pods"] == 2
    assert d["total_ports"] == 4 and d["ports_per_device"] == 1


def test_single_device_multiport_mesh():
    """Degenerate (1,1) pod mesh hosting all ports: the two-stage fabric
    collapses to identity exchanges but the per-port tables, hash homes
    and canonical ordering still run — this is the shape the bench-smoke
    pod rows use on 1-device CI runners, so pin it here."""
    mesh = pod_mesh_or_skip(1, 1)
    cfg = dataclasses.replace(
        REDUCED, flow_home="hash", ports_per_pod=4, reporter_slots=64,
        flows_per_shard=256, port_report_capacity=16,
        kernel_backend="ref")
    sysm = DFASystem(cfg, mesh)
    assert sysm.ports_per_device == 4
    ev, nows = SC.build("elephants_mice", 4, 32, T)
    with sysm.mesh:
        out = jax.jit(sysm.run_periods)(
            sysm.init_state(), {k: jnp.asarray(v) for k, v in ev.items()},
            jnp.asarray(nows))
    fid, em, met = out.flow_ids, out.mask, out.metrics
    assert int(np.asarray(met["reports_recv"]).sum()) > 0
    assert int(np.asarray(met["bucket_drops"]).sum()) == 0
    # every routed flow id is a hash home inside the global keyspace
    fids = np.asarray(fid)[np.asarray(em)]
    assert (fids < sysm.total_flows).all()


def test_port_count_beyond_reporter_id_space_refused():
    """Under V1, >256 ports would alias two ports onto one 8-bit reporter
    id and silently break canonical ordering — the constructor must
    refuse (and point at the wide format)."""
    mesh = pod_mesh_or_skip(1, 1)
    cfg = dataclasses.replace(
        REDUCED, flow_home="hash", ports_per_pod=512,
        reporter_slots=64, port_report_capacity=1)
    with pytest.raises(ValueError, match="8-bit reporter id") as e:
        DFASystem(cfg, mesh)
    assert "v2" in str(e.value), \
        "the refusal should tell the operator about wire_format='v2'"


# -- the V2 wide format: the 256-port cap is a schema property ------------
#
# Same differential contract as the V1 grid, run past the V1 wall: 264
# virtual ports (> the 8-bit reporter-id space) stream the vectorized
# wide_port_sweep trace through three mesh factorizations under
# wire_format="v2", and the merged state / per-period outputs / metrics
# must stay bitwise identical. Trace is short (T=2, 2 events/port) —
# the point is reporter ids above 255 surviving the whole
# pack->route->unpack->canonical-sort path, not traffic volume.

V2_PORTS = 264
V2_EVENTS_PER_PORT = 2
V2_T = 2
V2_G = 8192              # global ring keyspace, fixed across meshes
V2_GRID = ((1, 2), (2, 2), (4, 1))


def _mesh_cfg_v2(pods, shards):
    ndev = pods * shards
    return dataclasses.replace(
        REDUCED_MULTIPOD_V2,
        pods=pods,
        ports_per_pod=V2_PORTS // pods,
        flows_per_shard=V2_G // ndev,
        port_report_capacity=4,
        kernel_backend="ref")


def _run_v2(pods, shards, overlapped, scenario):
    key = ("v2", pods, shards)
    if key not in _systems:
        mesh = pod_mesh_or_skip(pods, shards)
        sysm = DFASystem(_mesh_cfg_v2(pods, shards), mesh)
        _systems[key] = (sysm, jax.jit(sysm.run_periods),
                         jax.jit(sysm.run_periods_overlapped))
    sysm, seq, ovl = _systems[key]
    tkey = ("v2", scenario)
    if tkey not in _traces:
        ev, nows = SC.build(scenario, V2_PORTS, V2_EVENTS_PER_PORT, V2_T)
        _traces[tkey] = ({k: jnp.asarray(v) for k, v in ev.items()},
                         jnp.asarray(nows))
    events, nows = _traces[tkey]
    with sysm.mesh:
        out = (ovl if overlapped else seq)(sysm.init_state(), events,
                                           nows)
    return (sysm,
            (_merged_state(sysm, out.state),
             _canon_periods(out.enriched, out.flow_ids, out.mask),
             {k: np.asarray(v) for k, v in out.metrics.items()}))


def test_v2_accepts_port_counts_past_v1_wall():
    """The config-level 256-port refusal is gone under V2: the same 512
    ports V1 rejects construct cleanly, and describe() says why."""
    mesh = pod_mesh_or_skip(1, 1)
    cfg = dataclasses.replace(
        REDUCED, flow_home="hash", wire_format="v2", ports_per_pod=512,
        reporter_slots=8, port_report_capacity=1)
    sysm = DFASystem(cfg, mesh)
    assert sysm.total_ports == 512 and sysm.wire.name == "v2"
    assert sysm.describe()["wire_format"] == "v2"


def test_v2_pod_count_invariance_past_256_ports():
    """THE V2 acceptance differential: 264 ports (> V1's 8-bit space) are
    pod-count invariant under wire_format='v2', both drivers."""
    for overlapped in (False, True):
        ref_sys, ref = _run_v2(*V2_GRID[0], overlapped,
                               "wide_port_sweep")
        assert ref_sys.wire.seq_mask == 0xFFFF
        assert int(ref[2]["reports_recv"].sum()) > 0
        assert int(ref[2]["bucket_drops"].sum()) == 0
        # ports past the V1 wall really reported: per-port seq counters
        # above index 255 advanced, so reporter ids >255 crossed the wire
        assert (np.asarray(ref[0]["rep.seq"])[256:] > 0).any(), \
            "no port beyond the 8-bit space ever reported — the trace " \
            "does not exercise the widened field"
        assert (ref[0]["rep.seq"] <= ref_sys.wire.seq_mask).all()
        for pods, shards in V2_GRID[1:]:
            _, got = _run_v2(pods, shards, overlapped, "wide_port_sweep")
            _assert_same(ref, got,
                         f"v2 wide_port_sweep ovl={overlapped} "
                         f"({pods},{shards}) vs {V2_GRID[0]}")


def test_config_mesh_pod_mismatch_refused():
    """cfg.pods must agree with the mesh's pod axis — a silent mismatch
    would resize the port set out from under the config."""
    mesh = pod_mesh_or_skip(2, 2)
    with pytest.raises(ValueError, match="pod axis"):
        DFASystem(dataclasses.replace(REDUCED_MULTIPOD, pods=4), mesh)


def test_indivisible_event_split_refused():
    """An event batch that doesn't divide across a device's hosted ports
    must fail at trace time, not silently drop trailing events."""
    mesh = pod_mesh_or_skip(1, 1)
    cfg = dataclasses.replace(
        REDUCED, flow_home="hash", ports_per_pod=4, reporter_slots=64,
        flows_per_shard=256, port_report_capacity=8,
        kernel_backend="ref")
    sysm = DFASystem(cfg, mesh)
    ev, nows = SC.build("port_local", 4, 32, 1)
    events = {k: jnp.asarray(v[0][:-2] if v[0].ndim == 1
                             else v[0][:-2, :]) for k, v in ev.items()}
    with pytest.raises(ValueError, match="divide across"):
        with sysm.mesh:
            jax.jit(sysm.dfa_step)(sysm.init_state(), events,
                                   jnp.asarray(nows)[0])


def test_home_assignment_matches_translator():
    """The flow ids the stream emits agree with translator.home_flow_ids
    of the flows' five-tuples (home = hash of key, not of ingest port)."""
    mesh = pod_mesh_or_skip(2, 2)
    sysm, seq, _ = _system(2, 2, "none")
    events, nows = _trace("port_local")
    with sysm.mesh:
        out = seq(sysm.init_state(), events, nows)
    state, fid, em = out.state, out.flow_ids, out.mask
    # reconstruct home ids for every ACTIVE reporter key, then check all
    # routed flow ids are in that set
    keys = np.asarray(state.reporter.keys)[np.asarray(
        state.reporter.active)]
    expect = set(np.asarray(TRANS.home_flow_ids(
        jnp.asarray(keys), sysm.total_flows)).tolist())
    got = set(np.asarray(fid)[np.asarray(em)].tolist())
    assert got <= expect
    assert got, "no flows routed"
