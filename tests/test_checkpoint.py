"""Checkpointing: roundtrip, atomicity, keep-k GC, async, elastic reshard."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as C


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture()
def tree(rng):
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4)),
                                        jnp.float32),
                       "stack": [jnp.arange(6, dtype=jnp.int32),
                                 jnp.ones((2, 3), jnp.bfloat16)]},
            "opt": (jnp.zeros(()), {"mu": jnp.full((4,), 2.0)}),
            "none_leaf": None}


def test_roundtrip(tmp_path, tree):
    C.save(tree, str(tmp_path), step=7)
    got, step = C.restore(str(tmp_path))
    assert step == 7
    tree_eq(tree, got)


def test_latest_and_keep_k(tmp_path, tree):
    for s in (1, 2, 3, 4, 5):
        C.save(tree, str(tmp_path), step=s, keep=3)
    assert C.list_steps(str(tmp_path)) == [3, 4, 5]
    assert C.latest_step(str(tmp_path)) == 5


def test_async_save(tmp_path, tree):
    t = C.save(tree, str(tmp_path), step=1, async_=True)
    assert isinstance(t, threading.Thread)
    t.join()
    got, _ = C.restore(str(tmp_path))
    tree_eq(tree, got)


def test_no_partial_checkpoint_visible(tmp_path, tree):
    """tmp dirs must never be listed as restorable steps."""
    os.makedirs(tmp_path / "step_9.tmp")
    assert C.list_steps(str(tmp_path)) == []


def test_elastic_restore_resharding(tmp_path, tree):
    """Restore with explicit shardings (mesh migration path)."""
    C.save(tree, str(tmp_path), step=1)
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    shardings = jax.tree.map(lambda _: sh, tree)
    got, _ = C.restore(str(tmp_path), shardings=shardings)
    tree_eq(tree, got)
    for leaf in jax.tree.leaves(got):
        assert leaf.sharding == sh
