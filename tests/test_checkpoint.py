"""Checkpointing: roundtrip, atomicity, keep-k GC, async, elastic reshard,
NamedTuple class fidelity, extension-dtype round-trips."""
import os
import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as C


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture()
def tree(rng):
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4)),
                                        jnp.float32),
                       "stack": [jnp.arange(6, dtype=jnp.int32),
                                 jnp.ones((2, 3), jnp.bfloat16)]},
            "opt": (jnp.zeros(()), {"mu": jnp.full((4,), 2.0)}),
            "none_leaf": None}


def test_roundtrip(tmp_path, tree):
    C.save(tree, str(tmp_path), step=7)
    got, step = C.restore(str(tmp_path))
    assert step == 7
    tree_eq(tree, got)


def test_latest_and_keep_k(tmp_path, tree):
    for s in (1, 2, 3, 4, 5):
        C.save(tree, str(tmp_path), step=s, keep=3)
    assert C.list_steps(str(tmp_path)) == [3, 4, 5]
    assert C.latest_step(str(tmp_path)) == 5


def test_async_save(tmp_path, tree):
    t = C.save(tree, str(tmp_path), step=1, async_=True)
    assert isinstance(t, threading.Thread)
    t.join()
    got, _ = C.restore(str(tmp_path))
    tree_eq(tree, got)


def test_no_partial_checkpoint_visible(tmp_path, tree):
    """tmp dirs must never be listed as restorable steps."""
    os.makedirs(tmp_path / "step_9.tmp")
    assert C.list_steps(str(tmp_path)) == []


def test_elastic_restore_resharding(tmp_path, tree):
    """Restore with explicit shardings (mesh migration path)."""
    C.save(tree, str(tmp_path), step=1)
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    shardings = jax.tree.map(lambda _: sh, tree)
    got, _ = C.restore(str(tmp_path), shardings=shardings)
    tree_eq(tree, got)
    for leaf in jax.tree.leaves(got):
        assert leaf.sharding == sh


# -- NamedTuple class fidelity (regression: _rebuild used to return a
#    plain tuple, so state.reporter.regs crashed after every restore) ----

class Inner(NamedTuple):
    counts: jax.Array
    gone: Optional[jax.Array] = None


class Outer(NamedTuple):
    inner: Inner
    tag: jax.Array


def test_namedtuple_roundtrip_preserves_class(tmp_path):
    """Nested NamedTuples with u32/bf16 leaves and None fields — the DFA
    state tree shape — restore as the REAL classes with attribute
    access, not anonymous tuples."""
    C.register_namedtuple(Inner)
    C.register_namedtuple(Outer)
    t = Outer(Inner(counts=jnp.arange(5, dtype=jnp.uint32)),
              tag=jnp.ones((3,), jnp.bfloat16))
    C.save(t, str(tmp_path), step=1)
    got, _ = C.restore(str(tmp_path))
    assert type(got) is Outer and type(got.inner) is Inner
    assert got.inner.gone is None
    np.testing.assert_array_equal(np.asarray(got.inner.counts),
                                  np.asarray(t.inner.counts))
    assert got.tag.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got.tag, np.float32),
                                  np.asarray(t.tag, np.float32))


def test_unregistered_namedtuple_keeps_attribute_access(tmp_path):
    """An unknown class still restores with its field names (dynamic
    namedtuple) rather than silently degrading to a plain tuple."""
    class Private(NamedTuple):
        a: jax.Array
        b: jax.Array

    C.save(Private(jnp.zeros(2), jnp.ones(3)), str(tmp_path), step=1)
    # simulate restoring in a process that never saw the class
    C._NT_REGISTRY.pop("Private", None)
    got, _ = C.restore(str(tmp_path))
    assert got._fields == ("a", "b")
    np.testing.assert_array_equal(np.asarray(got.b), np.ones(3))


def test_dfa_state_roundtrip_bitwise_step(tmp_path):
    """THE satellite anchor: save→restore a LIVE DFAState and run one
    dfa_step on it — bitwise identical to stepping the unsaved state.
    Fails pre-fix with AttributeError on the first state.reporter."""
    from repro.compat import make_mesh
    from repro.configs import get_dfa_config
    from repro.core.pipeline import DFAState, DFASystem
    from repro.data import packets as PK
    mesh = make_mesh((1, 1), ("data", "model"))
    system = DFASystem(get_dfa_config(reduced=True), mesh)
    flows = PK.gen_flows(8, seed=3)
    ev = {k: jnp.asarray(v) for k, v in PK.events_for_shards(
        flows, 0, system.n_shards, 128).items()}
    with system.mesh:
        step = jax.jit(system.dfa_step)
        live = step(system.init_state(), ev, jnp.uint32(50_000)).state
        C.save(live, str(tmp_path), step=1)
        restored, _ = C.restore(str(tmp_path))
        assert type(restored) is DFAState
        out_a = step(live, ev, jnp.uint32(150_000))
        out_b = step(restored, ev, jnp.uint32(150_000))
    tree_eq(out_a, out_b)


# -- extension dtypes (regression: the dead-code dtype path broke
#    float8_e5m2 saves — np.load rejects its '<f1' descriptor) -----------

@pytest.mark.parametrize("name", ["bfloat16", "float8_e4m3fn",
                                  "float8_e5m2"])
def test_extension_dtype_roundtrip(tmp_path, name):
    import ml_dtypes
    dt = getattr(ml_dtypes, name)
    arr = jnp.asarray(np.arange(16).astype(np.float32)).astype(dt)
    C.save({"x": arr}, str(tmp_path), step=1)
    got, _ = C.restore(str(tmp_path))
    assert str(got["x"].dtype) == name
    np.testing.assert_array_equal(
        np.asarray(got["x"]).view(np.uint8),
        np.asarray(arr).view(np.uint8))


# -- GC + async races (regression: keep=0 sliced steps[:-0] == nothing,
#    and overlapping async writers raced rename + GC) --------------------

def test_gc_keep_zero_deletes_everything(tmp_path, tree):
    for s in (1, 2):
        C.save(tree, str(tmp_path), step=s)
    assert C.list_steps(str(tmp_path)) == [1, 2]
    with C._IO_LOCK:
        C._gc(str(tmp_path), keep=0)
    assert C.list_steps(str(tmp_path)) == []
    with C._IO_LOCK:
        C._gc(str(tmp_path), keep=-1)   # any keep<=0 means keep nothing
    assert C.list_steps(str(tmp_path)) == []


def test_interleaved_async_saves_keep_last_k(tmp_path, tree):
    """A burst of overlapping async saves must converge to exactly the
    newest ``keep`` steps, every one of them restorable."""
    threads = [C.save(tree, str(tmp_path), step=s, keep=3, async_=True)
               for s in range(1, 9)]
    for t in threads:
        t.join()
    assert C.list_steps(str(tmp_path)) == [6, 7, 8]
    for s in (6, 7, 8):
        got, step = C.restore(str(tmp_path), step=s)
        assert step == s
        tree_eq(tree, got)
