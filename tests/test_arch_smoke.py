"""Per-architecture smoke tests (reduced configs): one train step + one
forward on CPU, asserting output shapes and finiteness; serve-path
prefill/decode consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import TrainConfig
from repro.data import tokens as DATA
from repro.launch import steps as ST
from repro.launch.serve import build_cache
from repro.models.registry import get_model

ARCHS = list_archs()


def _batch(cfg, B=2, S=32, seed=0):
    b = DATA.batch_at(0, cfg, B, S, seed)
    return DATA.add_modality_stub(b, cfg, 0, seed)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, mesh):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    tcfg = TrainConfig(total_steps=10, warmup_steps=0)
    step = ST.make_train_step(model, tcfg)
    state = {"params": params,
             "opt": __import__("repro.optim.adamw",
                               fromlist=["init"]).init(params, tcfg)}
    before = np.asarray(jax.tree.leaves(params)[0], np.float32).copy()
    with mesh:
        loss0 = float(jax.jit(model.loss)(params, batch))
        state, metrics = jax.jit(step, donate_argnums=(0,))(state, batch)
    assert np.isfinite(loss0)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["gnorm"]) > 0
    # params actually changed
    after = np.asarray(jax.tree.leaves(state["params"])[0], np.float32)
    assert not np.allclose(before, after)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, mesh):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(0))
    B, S_P, S_C = 2, 16, 32
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S_P), 0,
                                          cfg.vocab_size, jnp.int32)}
    batch = DATA.add_modality_stub(batch, cfg, 0, 0)
    with mesh:
        logits, pcache = jax.jit(model.prefill)(params, batch)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        cache = build_cache(model, pcache, B, S_C)
        n_prefix = cfg.vision.num_patches if cfg.family == "vlm" else 0
        pos = jnp.full((B,), S_P + n_prefix, jnp.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        logits2, cache2 = jax.jit(
            lambda p, t, po, c: model.decode(p, t, po, c, S_C))(
                params, tok, pos, cache)
        assert logits2.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b"])
def test_decode_consistent_with_forward(arch, mesh):
    """Greedy decode after prefill(t0..tn) must equal the argmax of a full
    forward over the same prefix — the serving path is the training path."""
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0,
                              cfg.vocab_size, jnp.int32)
    with mesh:
        # full forward logits at position S-1 predict token S
        batch = {"tokens": toks[:, :S]}
        logits_full, _ = None, None
        lp, pcache = jax.jit(model.prefill)(params, batch)
        # forward over S+1 and read logits at position S-1:
        from repro.models import registry as REG
        if cfg.family == "ssm":
            from repro.models.rwkv_lm import rwkv_hidden
            h = rwkv_hidden(params, {"tokens": toks[:, :S]}, cfg)
        else:
            from repro.models.lm import lm_hidden
            h, _ = lm_hidden(params, {"tokens": toks[:, :S]}, cfg, mesh,
                             ())
        from repro.models import layers as L
        logits_fwd = L.logits_fn(params["embed"], h[:, -1:, :],
                                 cfg.tie_embeddings)[:, 0]
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(logits_fwd, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_exact_configs_match_assignment():
    """Full (non-reduced) configs carry the exact published dimensions."""
    spec = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L_, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L_, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.num_shared_experts == 1 and ds.mla is not None
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.moe.num_experts == 16 and l4.moe.top_k == 1
    z = get_config("zamba2-2.7b")
    assert z.ssm.state_dim == 64 and z.hybrid is not None
