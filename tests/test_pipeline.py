"""End-to-end DFA pipeline: packets -> registers -> reports -> routing ->
ring memory -> enriched features, validated against ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core import protocol as P
from repro.core.pipeline import DFASystem
from repro.data import packets as PK


@pytest.fixture(scope="module")
def system():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    return DFASystem(cfg, mesh)


def test_end_to_end_counts(system, rng):
    cfg = system.cfg
    flows = PK.gen_flows(10, seed=1)
    ev = PK.events_for_shards(flows, 0, system.n_shards, 256)
    state = system.init_state()
    with system.mesh:
        step = jax.jit(system.dfa_step)
        out = step(
            state, {k: jnp.asarray(v) for k, v in ev.items()},
            jnp.uint32(100_000))
        enriched, flow_ids, emask, metrics = (out.enriched, out.flow_ids,
                                              out.mask, out.metrics)
    # ground truth: per-flow packet counts
    slots = np.asarray(__import__("repro.core.reporter",
                                  fromlist=["hash_slot"]).hash_slot(
        jnp.asarray(flows["five_tuple"]), cfg.flows_per_shard))
    emask = np.asarray(emask)
    en = np.asarray(enriched)
    fid = np.asarray(flow_ids)
    got_counts = {int(fid[i]): en[i, 0] for i in range(len(fid))
                  if emask[i]}
    truth = {}
    for i, s in enumerate(np.asarray(ev["five_tuple"])):
        sl = int(np.asarray(__import__("repro.core.reporter",
                                       fromlist=["hash_slot"]).hash_slot(
            jnp.asarray(s), cfg.flows_per_shard)))
        truth[sl] = truth.get(sl, 0) + 1
    for f, c in got_counts.items():
        assert truth.get(f % cfg.flows_per_shard, -1) == c, f
    assert int(metrics["reports_recv"]) == len(got_counts)
    assert int(metrics["bad_checksum"]) == 0


def test_memory_entries_verbatim_payloads(system, rng):
    """Fig-4 property: collector memory rows ARE valid RoCEv2 payloads."""
    flows = PK.gen_flows(6, seed=2)
    ev = PK.events_for_shards(flows, 0, system.n_shards, 128)
    state = system.init_state()
    with system.mesh:
        state = jax.jit(system.dfa_step)(
            state, {k: jnp.asarray(v) for k, v in ev.items()},
            jnp.uint32(50_000)).state
    mem = np.asarray(state.collector.memory)
    ev_valid = np.asarray(state.collector.entry_valid)
    rows = mem[ev_valid]
    assert len(rows) > 0
    # independent recomputation of the rotate-then-xor fold (words 0-13 +
    # pad word 15, each rotated left by its payload position)
    acc = np.zeros(len(rows), np.uint64)
    for w in P.CSUM_COVERED:
        x = rows[:, w].astype(np.uint64)
        k = w % 32
        acc ^= ((x << k) | (x >> ((32 - k) % 32))) & 0xFFFFFFFF
    assert (acc.astype(np.uint32) == rows[:, P.CSUM_WORD]).all()
    assert np.asarray(P.payload_valid(jnp.asarray(rows))).all()


def test_history_accumulates_over_periods(system):
    flows = PK.gen_flows(4, seed=3)
    state = system.init_state()
    with system.mesh:
        step = jax.jit(system.dfa_step)
        for i in range(3):
            ev = PK.events_for_shards(flows, i, system.n_shards, 128)
            out = step(
                state, {k: jnp.asarray(v) for k, v in ev.items()},
                jnp.uint32((i + 1) * 100_000))
            state, metrics = out.state, out.metrics
    ev_valid = np.asarray(state.collector.entry_valid)
    per_flow = ev_valid.sum(axis=1)
    assert per_flow.max() == 3        # 3 monitoring periods -> 3 entries


def test_metrics_are_conserved(system):
    flows = PK.gen_flows(12, seed=4)
    ev = PK.events_for_shards(flows, 0, system.n_shards, 256)
    state = system.init_state()
    with system.mesh:
        out = jax.jit(system.dfa_step)(
            state, {k: jnp.asarray(v) for k, v in ev.items()},
            jnp.uint32(60_000))
        emask, metrics = out.mask, out.metrics
    sent = int(metrics["reports_sent"])
    recv = int(metrics["reports_recv"])
    drop = int(metrics["bucket_drops"])
    assert sent == recv + drop
    assert recv == int(np.asarray(emask).sum())
