"""End-to-end behaviour: DFA telemetry feeding immediate ML inference —
the paper's headline loop (extract -> deliver -> enrich -> infer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1, 1), ("data", "model"))


def test_telemetry_to_inference(mesh1):
    """Packets in -> enriched feature vectors -> the features separate two
    synthetic traffic classes (mice vs elephants)."""
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh1)
    rng = np.random.default_rng(0)
    state = system.init_state()
    feats, labels = [], []
    with mesh1:
        step = jax.jit(system.dfa_step)
        for period in range(4):
            n = 24
            keys = rng.integers(1, 2**31, (n, 5)).astype(np.uint32)
            lab = rng.integers(0, 2, n)
            evs = []
            for i in range(n):
                cnt = 20 if lab[i] else 4
                ts = np.sort(rng.integers(0, 20_000, cnt)) + \
                    period * 100_000
                size = (rng.integers(900, 1500, cnt) if lab[i]
                        else rng.integers(40, 120, cnt))
                evs.append((ts, size, np.tile(keys[i], (cnt, 1))))
            ts = np.concatenate([e[0] for e in evs]).astype(np.uint32)
            order = np.argsort(ts, kind="stable")
            ev = {"ts": jnp.asarray(ts[order]),
                  "size": jnp.asarray(np.concatenate(
                      [e[1] for e in evs]).astype(np.uint32)[order]),
                  "five_tuple": jnp.asarray(np.concatenate(
                      [e[2] for e in evs]).astype(np.uint32)[order]),
                  "valid": jnp.ones(len(ts), bool)}
            out = step(state, ev, jnp.uint32((period + 1) * 100_000))
            state = out.state
            em = np.asarray(out.mask)
            en = np.asarray(out.enriched)[em]
            fid = np.asarray(out.flow_ids)[em]
            from repro.core.reporter import hash_slot
            slot_of = {int(np.asarray(hash_slot(
                jnp.asarray(keys[i]), cfg.flows_per_shard))): lab[i]
                for i in range(n)}
            for j in range(len(fid)):
                sl = int(fid[j]) % cfg.flows_per_shard
                if sl in slot_of:
                    feats.append(en[j])
                    labels.append(slot_of[sl])
    X = np.nan_to_num(np.asarray(feats, np.float64))
    y = np.asarray(labels)
    assert len(X) > 20
    ps_mean = X[:, 6]                       # mean packet size feature
    thresh = np.median(ps_mean)
    acc = ((ps_mean > thresh) == y).mean()
    acc = max(acc, 1 - acc)
    assert acc > 0.9, f"derived features do not separate classes: {acc}"


def test_monitoring_period_enforced(mesh1):
    """No flow reports twice within one monitoring period (paper §III-A)."""
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh1)
    flows = PK.gen_flows(6, seed=5)
    state = system.init_state()
    with mesh1:
        step = jax.jit(system.dfa_step)
        ev = PK.events_for_shards(flows, 0, 1, 128)
        out1 = step(state, {k: jnp.asarray(v) for k, v
                            in ev.items()},
                    jnp.uint32(50_000))
        first = int(out1.metrics["reports_recv"])
        ev2 = PK.events_for_shards(flows, 1, 1, 64, window_us=1000)
        ev2["ts"] = (ev2["ts"] * 0 + 50_500).astype(np.uint32)
        out2 = step(out1.state, {k: jnp.asarray(v) for k, v
                                 in ev2.items()},
                    jnp.uint32(51_000))
        assert int(out2.metrics["reports_recv"]) == 0
        assert first > 0
