"""Optimizer + schedule + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.optim import adamw, compression
from repro.optim.schedule import lr_at


def test_adamw_converges_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0, grad_clip=1e9)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params, tcfg)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.apply(params, g, opt, tcfg,
                                     lr_at(opt.step, tcfg))
    np.testing.assert_allclose(np.asarray(params["w"]), target, atol=0.05)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = np.sqrt(np.sum(np.asarray(clipped["a"]) ** 2))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_weight_decay_decoupled():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                       weight_decay=0.5)
    params = {"w": jnp.ones(2)}
    opt = adamw.init(params, tcfg)
    zero_g = {"w": jnp.zeros(2)}
    p2, _, _ = adamw.apply(params, zero_g, opt, tcfg, jnp.asarray(0.1))
    assert float(p2["w"][0]) < 1.0          # decay applies without grads


def test_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                       total_steps=100)
    lrs = [float(lr_at(s, tcfg)) for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[10]                       # warmup
    assert lrs[10] == pytest.approx(1e-3, rel=1e-5)        # peak
    assert lrs[99] < lrs[50] < lrs[11]                     # decay
    assert lrs[99] >= 1e-4 * 0.99                          # floor 0.1x


def test_compression_quantize_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal(256) * 3, jnp.float32)
    err = jnp.zeros(256)
    q, s, resid = compression.quantize(x, err)
    back = compression.dequantize(q, s)
    np.testing.assert_allclose(np.asarray(back + resid), np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    assert q.dtype == jnp.int8


def test_error_feedback_unbiased_over_steps(rng):
    """With EF, the accumulated applied update converges to the true sum."""
    true = jnp.asarray(rng.standard_normal(64), jnp.float32) * 0.01
    err = jnp.zeros(64)
    applied = jnp.zeros(64)
    for _ in range(50):
        q, s, err = compression.quantize(true, err)
        applied = applied + compression.dequantize(q, s)
    np.testing.assert_allclose(np.asarray(applied), np.asarray(true * 50),
                               rtol=0.02, atol=1e-4)


def test_opt_state_dtype_bf16():
    tcfg = TrainConfig()
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    opt = adamw.init(params, tcfg, state_dtype="bfloat16")
    assert opt.mu["w"].dtype == jnp.bfloat16
