#!/usr/bin/env python
"""Wire-layout lint: no raw meta bit-twiddling outside repro/core/wire.py.

The versioned wire schema (repro.core.wire) is the ONE source of truth
for where reporter_id / seq / hist_idx live inside the report and payload
words. This lint keeps it that way: any *code* (strings and comments are
tokenized away, so docstrings may still illustrate the layout) that
re-derives the packing by hand — the V1 ``rid << 24`` shift, the
``>> 24`` extract, the ``0x00FFFFFF`` keep-mask of the old repack, or the
``(>> 16) & 0xFF`` seq read — fails the lint with a pointer at the
schema helpers.

Usage: ``python tools/lint_wire.py [root ...]`` (default ``src/repro``);
exits non-zero listing every violation. Wired into the CI lint tier next
to ruff.
"""
from __future__ import annotations

import re
import sys
import tokenize
from pathlib import Path

PATTERNS = (
    (re.compile(r"<<\s*24\b"), "reporter-id pack '<< 24'"),
    (re.compile(r">>\s*24\b"), "reporter-id extract '>> 24'"),
    (re.compile(r"0x00FF_?FFFF\b", re.IGNORECASE),
     "meta repack keep-mask 0x00FFFFFF"),
    (re.compile(r">>\s*16\s*\)?\s*&\s*0xFF\b"),
     "seq extract '(>> 16) & 0xFF'"),
)

# the schema itself is the one place allowed to spell out bit positions
ALLOWED = ("core/wire.py",)

HINT = ("wire-layout bit twiddling belongs in repro/core/wire.py — use "
        "Field.get/extract/place/set_in or the WireFormat pack helpers")


def code_lines(path: Path) -> dict[int, str]:
    """line number -> that line's CODE tokens joined by spaces (string
    literals and comments dropped, so prose can't trip the patterns)."""
    out: dict[int, list[str]] = {}
    with open(path, "rb") as f:
        try:
            tokens = list(tokenize.tokenize(f.readline))
        except (tokenize.TokenError, SyntaxError):
            return {}
    skip = {tokenize.STRING, tokenize.COMMENT, tokenize.ENCODING,
            tokenize.NL, tokenize.NEWLINE, tokenize.INDENT,
            tokenize.DEDENT}
    # FSTRING_* only exist on 3.12+; treat their pieces as strings too
    for name in ("FSTRING_START", "FSTRING_MIDDLE", "FSTRING_END"):
        if hasattr(tokenize, name):
            skip.add(getattr(tokenize, name))
    for t in tokens:
        if t.type in skip or not t.string:
            continue
        out.setdefault(t.start[0], []).append(t.string)
    return {n: " ".join(parts) for n, parts in out.items()}


def lint(roots: list[str]) -> list[str]:
    violations = []
    for root in roots:
        base = Path(root)
        files = sorted(base.rglob("*.py")) if base.is_dir() else [base]
        for path in files:
            posix = path.as_posix()
            if any(posix.endswith(a) for a in ALLOWED):
                continue
            for lineno, code in sorted(code_lines(path).items()):
                for pat, what in PATTERNS:
                    if pat.search(code):
                        violations.append(
                            f"{posix}:{lineno}: {what}: {code.strip()}")
    return violations


def main(argv: list[str]) -> int:
    roots = argv or ["src/repro"]
    violations = lint(roots)
    for v in violations:
        print(v, file=sys.stderr)
    if violations:
        print(f"\nlint_wire: {len(violations)} violation(s). {HINT}",
              file=sys.stderr)
        return 1
    print(f"lint_wire: clean ({', '.join(roots)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
