"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 100 --batch 8 --seq 128

Features exercised here (and tested in tests/test_train_loop.py):
  * deterministic step-keyed data (exact resume),
  * periodic + SIGTERM checkpointing (atomic, keep-k, async),
  * crash-restart retry loop with straggler watchdog,
  * optional gradient compression (--compress int8_ef) and
    pipeline-parallel stage demo (--pp) on multi-axis meshes.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as CKPT
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import tokens as DATA
from repro.distributed.monitor import Heartbeat, StepMonitor
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh
from repro.models.registry import get_model
from repro.optim.adamw import OptState


def rewrap_state(tree):
    """Checkpoint restore returns plain tuples; rebuild OptState."""
    opt = tree["opt"]
    if not isinstance(opt, OptState):
        tree["opt"] = OptState(*opt)
    return tree


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=-1)
    ap.add_argument("--schedule-steps", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    mesh = make_local_mesh()
    cfg = get_config(args.arch, reduced=args.reduced)
    sched_total = args.schedule_steps if args.schedule_steps > 0 \
        else args.steps
    warmup = args.warmup if args.warmup >= 0 else max(sched_total // 10, 1)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=sched_total,
                       warmup_steps=warmup,
                       checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every)
    model = get_model(cfg, mesh)
    step_fn = ST.make_train_step(model, tcfg)

    with mesh:
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        start = 0
        if args.resume and CKPT.latest_step(args.ckpt_dir) is not None:
            state, start = CKPT.restore(args.ckpt_dir)
            state = rewrap_state(state)
            print(f"[train] resumed from step {start}")
        else:
            state = ST.init_train_state(model, tcfg,
                                        jax.random.key(args.seed))

        monitor = StepMonitor()
        hb = Heartbeat(args.ckpt_dir + "/hb", jax.process_index())
        pending_save = None

        def save(state_, step_):
            nonlocal pending_save
            if pending_save is not None:
                pending_save.join()
            pending_save = CKPT.save(state_, args.ckpt_dir, step_,
                                     keep=tcfg.keep_checkpoints,
                                     async_=tcfg.async_checkpoint)

        stop = {"now": False}

        def on_term(sig, frame):
            stop["now"] = True

        signal.signal(signal.SIGTERM, on_term)

        losses = []
        for step in range(start, args.steps):
            monitor.start()
            batch = DATA.batch_at(step, cfg, args.batch, args.seq,
                                  args.seed)
            batch = DATA.add_modality_stub(batch, cfg, step, args.seed)
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            m = monitor.stop()
            hb.beat(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"dt {m['step_time']:.3f}s", flush=True)
            if (step + 1) % args.ckpt_every == 0:
                save(state, step + 1)
            if stop["now"]:
                print("[train] SIGTERM -> checkpoint + exit")
                save(state, step + 1)
                break
        save(state, min(step + 1, args.steps))
        if pending_save is not None:
            pending_save.join()
        first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
        last = np.mean(losses[-5:])
        print(f"[train] done: loss {first:.4f} -> {last:.4f} "
              f"({len(losses)} steps, slow_steps={monitor.slow_steps})")
        return losses


if __name__ == "__main__":
    main()
