"""Elastic pod failure recovery: snapshot → survivor mesh → minimal re-home.

The operable-service half of the multi-pod stream (ROADMAP "Elastic
multi-pod operations"). A pod dies mid-stream; this module rebuilds the
system on the surviving ``(pods-1, shards_per_pod)`` mesh from the last
snapshot and moves ONLY the dead pod's state:

    Heartbeat.dead_peers_by_pod() fires (whole pod stale / never beat)
        │
        ▼
    checkpoint.restore(snapshot_dir)      — last full DFAState + period
        │
        ▼
    survivor_config / survivor_system     — pods-1, same total port set,
        │                                   home_nodes minus the dead
        │                                   pod's node ids
        ▼
    rehome_state                          — survivors' blocks move bitwise
        │                                   (flow ids encode stable node
        │                                   ids); dead-node ring entries
        │                                   re-home by HRW over survivors
        ▼
    device_put on the new mesh → resume stream() from the restored period

Why this can be *bitwise* correct (modulo the replay window, pinned in
tests/test_elastic_equiv.py):

* ``flow_home="rendezvous"`` homes each key on an HRW winner over the
  ``home_nodes`` roster. HRW's restriction property: removing nodes never
  changes the winner among the survivors — so every surviving flow keeps
  its node, its flow id, its ring row, its history counter. Only the dead
  node's ~1/pods of flows move.
* Reporter state is per-PORT and port-major-global (PR 5): the survivor
  mesh hosts the same total port set (more ports per device), so the
  reporter arrays transfer unchanged — the report streams and per-port
  seq numbering replay identically.
* Ring payloads store the five-tuple (words 8-12), so a dead flow's new
  home is recomputable from the entry itself; word 0 is rewritten to the
  new ``node_id * fps + slot`` id and the rotate-xor checksum (word 14)
  is refolded. The slot hash does not depend on the node set, so the
  ring ROW index (slot) is preserved — only the node block changes.

What cannot move bitwise: nothing in the happy path; slot collisions
involving a dead-node flow (a second key sharing the same ring slot and
landing on the same survivor node) interleave two flows' entries and a
shared history counter that cannot be split — probability ~#flows/ring
capacity per dead flow, and the differential test's traces are
collision-free for their fixed seeds.

Replay window: work since the last snapshot is lost and must be re-fed
(at most ``cfg.snapshot_every_periods`` periods); the differential test
replays it and requires exact equality with a clean run.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as CKPT
from repro.core import collector as COLL
from repro.core import protocol as PROTO
from repro.core import reporter as REP
from repro.core import translator as TRANS
from repro.core import wire as WIRE
from repro.core.pipeline import DFAState, DFASystem
from repro.distributed.monitor import Heartbeat
from repro.launch.mesh import make_dfa_mesh


def survivor_config(system: DFASystem, dead_pod: int):
    """The dead-pod-removed config: pods-1, SAME total port set (the
    survivor mesh absorbs the dead pod's ports), home_nodes minus the
    dead pod's node ids."""
    cfg = system.cfg
    if cfg.flow_home != "rendezvous":
        raise ValueError(
            f"elastic recovery needs flow_home='rendezvous', got "
            f"{cfg.flow_home!r}: the range-sharded 'hash' scheme renumbers "
            "every flow when the device count changes, so a pod loss would "
            "reshuffle the whole keyspace instead of ~1/pods of it")
    pods, S = system.mesh_pods, system.shards_per_pod
    if pods < 2:
        raise ValueError("cannot remove a pod from a single-pod mesh")
    if not 0 <= dead_pod < pods:
        raise ValueError(f"dead_pod={dead_pod} not in [0, {pods})")
    if system.total_ports % (pods - 1):
        raise ValueError(
            f"total ports {system.total_ports} do not spread over "
            f"{pods - 1} surviving pods")
    survivors = (system.home_nodes[:dead_pod * S]
                 + system.home_nodes[(dead_pod + 1) * S:])
    return dataclasses.replace(
        cfg, pods=pods - 1,
        ports_per_pod=system.total_ports // (pods - 1),
        home_nodes=survivors)


def survivor_system(system: DFASystem, dead_pod: int,
                    devices=None) -> DFASystem:
    """A DFASystem on the ``(pods-1, shards_per_pod)`` mesh (by default on
    a prefix of ``jax.devices()`` — single-host simulation; pass the
    surviving processes' devices on a real fleet)."""
    cfg = survivor_config(system, dead_pod)
    mesh = make_dfa_mesh(cfg.pods, system.shards_per_pod, devices=devices)
    return DFASystem(cfg, mesh, infer_fn=system.infer_fn)


class RehomeStats(NamedTuple):
    """What a membership-change state move actually did."""
    moved_rows: int               # ring rows that changed node
    unsplittable_collisions: int  # rows whose entries disagree on a home
    scanned_rows: int = 0         # live rows examined (= moved on shrink)


def _np_tree(tree):
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)


def _row_winners(mem_row: np.ndarray, ev: np.ndarray,
                 nodes_arr: jax.Array,
                 wf: WIRE.WireFormat) -> np.ndarray:
    """HRW winner positions for EVERY live entry of one ring row (each
    entry stores its own five-tuple, words 8-12). A collision-free row
    yields one distinct position; a slot collision whose keys disagree
    on a home yields several — the unsplittable case."""
    live = np.nonzero(ev)[0]
    keys = jnp.asarray(mem_row[live][:, wf.payload_tuple_slice])
    kh = REP.hash_u32(keys)
    return np.asarray(TRANS.rendezvous_position(kh, nodes_arr))


def _handle_unsplittable(count: int, policy: str, where: str) -> None:
    """The documented re-homing gap, surfaced instead of silently
    corrupting the ring: ``policy`` comes off
    ``DFAConfig.rehome_collision_policy`` ("fail" default / "warn")."""
    if count == 0:
        return
    msg = (f"{where}: {count} ring slot(s) hold entries from flows with "
           "different HRW homes — the shared row and history counter "
           "cannot be split during re-homing. Entries were moved by "
           "their FIRST live entry's key; the other flow's history is "
           "interleaved at the new home. Set "
           "rehome_collision_policy='warn' to accept this, or resize "
           "the ring (flows_per_shard) to make collisions rarer.")
    if policy == "warn":
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
    elif policy == "fail":
        raise RuntimeError(msg)
    else:
        raise ValueError(
            f"unknown rehome_collision_policy={policy!r} "
            "(expected 'fail' or 'warn')")


def _refold_checksum(payload: np.ndarray,
                     wf: WIRE.WireFormat) -> np.ndarray:
    """Recompute the checksum word after a word-0 rewrite (host-side)."""
    covered = jnp.asarray(payload[..., list(wf.csum_covered)])
    pos = jnp.asarray(wf.csum_covered, jnp.uint32)
    out = payload.copy()
    out[..., wf.csum_word] = np.asarray(
        PROTO.xor_checksum(covered, pos))
    return out


def rehome_state(state: DFAState, old_system: DFASystem,
                 new_system: DFASystem, dead_pod: int
                 ) -> Tuple[DFAState, RehomeStats]:
    """Move a full-mesh DFAState onto the survivor roster (host-side).

    Survivor node blocks copy bitwise to their new pod-major positions;
    the dead pod's ring entries re-home per entry via HRW over the
    survivor roster (the stored five-tuple is the key), with flow-id
    word 0 rewritten and the checksum refolded. Per-device merge-only
    stats (last_seq, scalar counters) fold the dead devices' values into
    survivor device 0 — the merged view (elementwise max / sum) is what
    the pod-count-invariance contract defines, and it is preserved.

    Ring slot collisions on a dead row (two flows sharing the slot whose
    survivor homes DISAGREE) cannot be split — the row and its history
    counter are one unit. They are detected per entry and surfaced via
    ``new_system.cfg.rehome_collision_policy``: "fail" (default) raises
    with the count, "warn" moves the row by its first live entry's key
    and warns. Returns ``(new_state, RehomeStats)``.
    """
    st = _np_tree(state)
    wf = old_system.wire
    S = old_system.shards_per_pod
    fps = old_system.cfg.flows_per_shard
    H = old_system.cfg.history
    old_nodes = list(old_system.home_nodes)
    new_nodes = list(new_system.home_nodes)
    dead_pos = list(range(dead_pod * S, (dead_pod + 1) * S))
    surv_pos = [i for i in range(len(old_nodes)) if i not in dead_pos]
    n_new = len(new_nodes)
    assert [old_nodes[i] for i in surv_pos] == new_nodes

    # reporter: port-major global arrays — the survivor mesh hosts the
    # same total port set, so they transfer unchanged
    rep = st.reporter

    # translator + collector: per-node blocks move to new positions
    hist = np.zeros((n_new * fps,), st.translator.hist_counter.dtype)
    mem = np.zeros((n_new * fps,) + st.collector.memory.shape[1:],
                   st.collector.memory.dtype)
    valid = np.zeros((n_new * fps, H), st.collector.entry_valid.dtype)
    nseq = np.zeros((n_new, wf.n_reporters), st.collector.last_seq.dtype)
    old_seq = st.collector.last_seq.reshape(len(old_nodes),
                                            wf.n_reporters)
    scalars = {k: np.zeros((n_new,), getattr(st.collector, k).dtype)
               for k in ("bad_checksum", "seq_anomalies", "received",
                         "lost_reports")}
    for new_i, old_i in enumerate(surv_pos):
        src = slice(old_i * fps, (old_i + 1) * fps)
        dst = slice(new_i * fps, (new_i + 1) * fps)
        hist[dst] = st.translator.hist_counter[src]
        mem[dst] = st.collector.memory[src]
        valid[dst] = st.collector.entry_valid[src]
        nseq[new_i] = old_seq[old_i]
        for k in scalars:
            scalars[k][new_i] = getattr(st.collector, k)[old_i]

    # dead pod: re-home each ring row by the stored five-tuple
    nodes_arr = jnp.asarray(new_nodes, jnp.uint32)
    moved_rows = 0
    unsplittable = 0
    for old_i in dead_pos:
        base = old_i * fps
        rows = np.nonzero(st.collector.entry_valid[base:base + fps]
                          .any(axis=1))[0]
        for r in rows:
            ev = st.collector.entry_valid[base + r]
            winners = _row_winners(st.collector.memory[base + r], ev,
                                   nodes_arr, wf)
            if len(set(winners.tolist())) > 1:
                unsplittable += 1
            pos = int(winners[0])
            node = new_nodes[pos]
            dst = pos * fps + r             # slot hash is roster-free
            pay = st.collector.memory[base + r].copy()
            live = ev.astype(bool)
            pay[live, 0] = np.uint32(node * fps + r)
            pay[live] = _refold_checksum(pay[live], wf)
            mem[dst, live] = pay[live]
            valid[dst] |= ev
            # the history counter travels with the flow (all entries of a
            # collision-free row share one key → one destination)
            hist[dst] = st.translator.hist_counter[base + r]
            moved_rows += 1
        # merge-only per-device stats fold into survivor 0
        nseq[0] = np.maximum(nseq[0], old_seq[old_i])
        for k in scalars:
            scalars[k][0] += getattr(st.collector, k)[old_i]
    _handle_unsplittable(unsplittable,
                         new_system.cfg.rehome_collision_policy,
                         f"rehome_state(dead_pod={dead_pod})")

    coll = COLL.CollectorState(
        memory=mem, entry_valid=valid, last_seq=nseq.reshape(-1),
        bad_checksum=scalars["bad_checksum"],
        seq_anomalies=scalars["seq_anomalies"],
        received=scalars["received"],
        lost_reports=scalars["lost_reports"])
    return (DFAState(rep, TRANS.TranslatorState(hist), coll),
            RehomeStats(moved_rows, unsplittable, moved_rows))


def recover_from_snapshot(system: DFASystem, snapshot_dir: str,
                          dead_pod: int, devices=None,
                          step: Optional[int] = None
                          ) -> Tuple[DFASystem, DFAState, int]:
    """Full recovery: restore the last snapshot, rebuild on the survivor
    mesh, re-home the dead pod's flows, place on-device.

    Returns ``(new_system, new_state, period)`` — resume by re-feeding
    the trace from ``period`` (the replay window), e.g.
    ``new_system.stream(new_state, events[period:], nows[period:],
    snapshot_start=period)``.
    """
    restored, period = CKPT.restore(snapshot_dir, step=step)
    new_system = survivor_system(system, dead_pod, devices=devices)
    rehomed, stats = rehome_state(restored, system, new_system, dead_pod)
    # callers keep the historical 3-tuple; the move accounting rides on
    # the survivor system for anyone who wants it
    new_system.last_rehome_stats = stats
    placed = jax.tree.map(
        lambda a, s: jax.device_put(jnp.asarray(a), s),
        rehomed, new_system.state_shardings())
    return new_system, placed, int(period)


def whole_dead_pods(hb: Heartbeat) -> List[int]:
    """Pods whose EVERY registered process is stale or never beat.

    Requires ``hb.expected_peers`` (the roster is what makes a process
    that died before its first beat visible at all — monitor satellite)."""
    expected = hb._expected()
    if not expected:
        return []
    stale = hb.dead_peers()
    per_pod: Dict[int, List[int]] = {}
    for idx, pod in expected.items():
        per_pod.setdefault(pod, []).append(idx)
    return sorted(pod for pod, procs in per_pod.items()
                  if all(i in stale for i in procs))


def maybe_recover(hb: Heartbeat, system: DFASystem, snapshot_dir: str,
                  devices=None, ignore_pods: Sequence[int] = ()
                  ) -> Optional[Tuple[DFASystem, DFAState, int]]:
    """The pod-loss trigger: if a whole pod is dead per the heartbeat
    roster, recover onto the survivor mesh; None when all pods live.

    ``ignore_pods``: pods ALREADY recovered from — a heartbeat can keep
    reporting a removed pod as dead (its processes never beat again), and
    recovering from the same loss twice would re-home state that already
    moved. Callers pass their removed set; a trip that only names ignored
    pods is a no-op (idempotent recovery)."""
    dead = [d for d in whole_dead_pods(hb) if d not in set(ignore_pods)]
    if not dead:
        return None
    return recover_from_snapshot(system, snapshot_dir, dead[0],
                                 devices=devices)


# -- pod join (grow) -------------------------------------------------------

def join_config(system: DFASystem, new_nodes: Sequence[int]):
    """The pod-added config: pods+1, SAME total port set (each pod hosts
    fewer ports), home_nodes extended with the new pod's node ids.

    The new ids must sort strictly above the existing roster: the new pod
    appends at the pod-major END of the mesh, and ``rendezvous_position``
    requires a sorted roster for mesh-invariant tie-breaks — so new ids
    above the old maximum keep positions and node ids aligned without
    renumbering a single survivor."""
    cfg = system.cfg
    if cfg.flow_home != "rendezvous":
        raise ValueError(
            f"pod join needs flow_home='rendezvous', got "
            f"{cfg.flow_home!r}: the range-sharded 'hash' scheme "
            "renumbers every flow when the device count changes")
    pods, S = system.mesh_pods, system.shards_per_pod
    new_nodes = tuple(int(n) for n in new_nodes)
    if len(new_nodes) != S:
        raise ValueError(
            f"a joining pod contributes one node id per shard: got "
            f"{len(new_nodes)} ids for {S} shards_per_pod")
    if list(new_nodes) != sorted(set(new_nodes)):
        raise ValueError(f"new node ids {new_nodes} must be strictly "
                         "increasing")
    if system.home_nodes and min(new_nodes) <= max(system.home_nodes):
        raise ValueError(
            f"new node ids {new_nodes} must all exceed the current "
            f"roster maximum {max(system.home_nodes)} — the joining pod "
            "appends at the sorted end of the pod-major roster")
    if system.total_ports % (pods + 1):
        raise ValueError(
            f"total ports {system.total_ports} do not spread over "
            f"{pods + 1} pods")
    return dataclasses.replace(
        cfg, pods=pods + 1,
        ports_per_pod=system.total_ports // (pods + 1),
        home_nodes=tuple(system.home_nodes) + new_nodes)


def join_system(system: DFASystem, new_nodes: Sequence[int],
                devices=None) -> DFASystem:
    """A DFASystem on the ``(pods+1, shards_per_pod)`` mesh."""
    cfg = join_config(system, new_nodes)
    mesh = make_dfa_mesh(cfg.pods, system.shards_per_pod,
                         devices=devices)
    return DFASystem(cfg, mesh, infer_fn=system.infer_fn)


def expand_state(state: DFAState, old_system: DFASystem,
                 new_system: DFASystem) -> Tuple[DFAState, RehomeStats]:
    """Move a DFAState onto the grown roster (host-side) — the inverse of
    :func:`rehome_state`, closing the ROADMAP pod-join remainder.

    HRW's restriction property runs both ways: adding nodes only moves
    the flows whose winner over the grown roster IS a new node —
    ~1/(pods+1) of every device's live rows in expectation, nothing else.
    So this scans every LIVE ring row on the existing devices (unlike the
    shrink direction, which only walks the dead pod's rows), re-scores
    the stored five-tuple over the grown roster, and moves the winners:
    word 0 rewritten to ``new_node * fps + slot``, checksum refolded,
    history counter travelling with the flow, source row cleared — so the
    end state is bitwise what a clean run on the larger mesh would have
    produced (modulo the replay window, pinned by the grow differential).
    Reporter state is port-major global and transfers unchanged.

    Slot collisions whose entries disagree on a home are unsplittable,
    surfaced via ``rehome_collision_policy`` exactly as in the shrink
    direction ("warn" keeps such rows at their first entry's home).
    """
    st = _np_tree(state)
    wf = old_system.wire
    fps = old_system.cfg.flows_per_shard
    H = old_system.cfg.history
    old_nodes = list(old_system.home_nodes)
    new_nodes = list(new_system.home_nodes)
    n_old, n_new = len(old_nodes), len(new_nodes)
    assert new_nodes[:n_old] == old_nodes

    hist = np.zeros((n_new * fps,), st.translator.hist_counter.dtype)
    mem = np.zeros((n_new * fps,) + st.collector.memory.shape[1:],
                   st.collector.memory.dtype)
    valid = np.zeros((n_new * fps, H), st.collector.entry_valid.dtype)
    nseq = np.zeros((n_new, wf.n_reporters), st.collector.last_seq.dtype)
    old_seq = st.collector.last_seq.reshape(n_old, wf.n_reporters)
    scalars = {k: np.zeros((n_new,), getattr(st.collector, k).dtype)
               for k in ("bad_checksum", "seq_anomalies", "received",
                         "lost_reports")}
    # existing devices keep their pod-major positions: prefix-copy
    hist[:n_old * fps] = st.translator.hist_counter
    mem[:n_old * fps] = st.collector.memory
    valid[:n_old * fps] = st.collector.entry_valid
    nseq[:n_old] = old_seq
    for k in scalars:
        scalars[k][:n_old] = getattr(st.collector, k)

    nodes_arr = jnp.asarray(new_nodes, jnp.uint32)
    moved_rows = 0
    scanned_rows = 0
    unsplittable = 0
    for old_i in range(n_old):
        base = old_i * fps
        rows = np.nonzero(st.collector.entry_valid[base:base + fps]
                          .any(axis=1))[0]
        scanned_rows += len(rows)
        for r in rows:
            ev = st.collector.entry_valid[base + r]
            winners = _row_winners(st.collector.memory[base + r], ev,
                                   nodes_arr, wf)
            if len(set(winners.tolist())) > 1:
                unsplittable += 1
            pos = int(winners[0])
            if pos < n_old:
                continue                    # restriction: flow stays put
            node = new_nodes[pos]
            dst = pos * fps + r             # slot hash is roster-free
            pay = st.collector.memory[base + r].copy()
            live = ev.astype(bool)
            pay[live, 0] = np.uint32(node * fps + r)
            pay[live] = _refold_checksum(pay[live], wf)
            mem[dst, live] = pay[live]
            valid[dst] |= ev
            hist[dst] = st.translator.hist_counter[base + r]
            # clear the source: a clean larger-mesh run never wrote here
            mem[base + r] = 0
            valid[base + r] = False
            hist[base + r] = 0
            moved_rows += 1
    _handle_unsplittable(unsplittable,
                         new_system.cfg.rehome_collision_policy,
                         f"expand_state(+{n_new - n_old} nodes)")

    coll = COLL.CollectorState(
        memory=mem, entry_valid=valid, last_seq=nseq.reshape(-1),
        bad_checksum=scalars["bad_checksum"],
        seq_anomalies=scalars["seq_anomalies"],
        received=scalars["received"],
        lost_reports=scalars["lost_reports"])
    return (DFAState(st.reporter, TRANS.TranslatorState(hist), coll),
            RehomeStats(moved_rows, unsplittable, scanned_rows))
