"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — under
scan-over-layers that under-reports FLOPs by ~L× (verified empirically in
EXPERIMENTS.md §Dry-run methodology). This module re-derives per-device
costs from the optimized HLO text, weighting every computation by the
``known_trip_count`` backend config of the while ops that call it:

  * dot FLOPs       — 2 · |result| · |contracting dims| per dot
  * HBM bytes       — Σ (operand + result bytes) of compute ops (post-fusion
                      HLO materializes buffers between ops, so this is a
                      first-order read+write traffic estimate)
  * collective bytes — per kind (all-gather / all-reduce / reduce-scatter /
                      all-to-all / collective-permute), result-shape sized
  * cpu_f32_artifact_bytes — f32 buffers that are 2× copies of bf16 buffers
                      (XLA:CPU upcasts bf16 dots; a TPU build would not) —
                      reported so memory numbers can be read honestly.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

_DT_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_TYPE_RE = re.compile(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                      r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLEE_RE = re.compile(r"(?:calls|body|condition|to)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DT_BYTES:
            out.append((dt, tuple(int(x) for x in dims.split(","))
                        if dims else ()))
    return out


def _nbytes(shapes) -> int:
    tot = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n * _DT_BYTES[dt]
    return tot


class Computation:
    def __init__(self, name):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = defaultdict(float)
        self.coll_counts = defaultdict(float)
        # (callee, weight, propagate_bytes) triples
        self.calls: List[Tuple[str, float, bool]] = []
        self.symtab: Dict[str, List] = {}


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(mc.group(2))
            comps[cur.name] = cur
            if mc.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rest = mo.groups()
        mt = _TYPE_RE.match(rest)
        if not mt:
            continue
        type_str, opcode = mt.groups()
        shapes = _shape_list(type_str)
        cur.symtab[name] = shapes
        base = opcode.replace("-start", "")
        # --- while / fusion / call children.
        # Fusion internals never touch HBM (they are VMEM-resident), so
        # their bytes are NOT propagated — only the fusion op's own
        # operands/results count. FLOPs DO propagate through fusions
        # (XLA:CPU wraps dots in fusions). While bodies are sequential
        # programs: both flops and bytes propagate, weighted by trip count.
        if opcode == "while":
            trip = 1.0
            m = _TRIP_RE.search(rest)
            if m:
                trip = float(m.group(1))
            for cm in _CALLEE_RE.finditer(rest):
                cur.calls.append((cm.group(1), trip, True))
            continue
        if opcode in ("call", "conditional"):
            for cm in _CALLEE_RE.finditer(rest):
                cur.calls.append((cm.group(1), 1.0, True))
        elif opcode in ("fusion", "map", "reduce", "reduce-window", "sort",
                        "scatter", "select-and-scatter"):
            for cm in _CALLEE_RE.finditer(rest):
                cur.calls.append((cm.group(1), 1.0, False))
        # --- collectives
        if base in _COLLECTIVES:
            sizes = [_nbytes([s]) for s in shapes]
            b = max(sizes) if ("-start" in opcode and len(sizes) > 1) \
                else sum(sizes)
            cur.coll[base] += b
            cur.coll_counts[base] += 1
        # --- dot flops
        if opcode == "dot":
            lhs_m = _OPERAND_RE.search(rest[rest.index("("):])
            lhs_shapes = cur.symtab.get(lhs_m.group(1)) if lhs_m else None
            cdims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
            if lhs_shapes and cdims_m and shapes:
                lhs = lhs_shapes[0][1]
                contract = 1
                for ix in cdims_m.group(1).split(","):
                    if ix:
                        contract *= lhs[int(ix)]
                res_elems = 1
                for d in shapes[0][1]:
                    res_elems *= d
                cur.flops += 2.0 * res_elems * contract
        # --- bytes (op-specific: slicing reads only the slice; in-place
        # dynamic-update-slice moves ~2x the update, not the target)
        if opcode not in _SKIP_BYTES_OPS and opcode != "while":
            if opcode in ("slice", "dynamic-slice", "gather",
                          "dynamic-update-slice", "scatter", "pad",
                          "broadcast", "reshape", "transpose", "copy",
                          "convert"):
                # result-proportional traffic (roughly read+write of the
                # produced/updated bytes)
                b = 2 * _nbytes(shapes)
                if opcode in ("slice", "dynamic-slice", "gather"):
                    b = 2 * _nbytes(shapes)
                elif opcode == "dynamic-update-slice":
                    # update operand (last-ish) dominates; approximate with
                    # the smallest operand x2
                    paren = rest[rest.index("("):] if "(" in rest else ""
                    cut = paren.split(")")[0] if paren else ""
                    ops = [_nbytes(cur.symtab[om.group(1)])
                           for om in _OPERAND_RE.finditer(cut)
                           if om.group(1) in cur.symtab]
                    b = 2 * (min(ops) if ops else _nbytes(shapes))
                cur.bytes += b
            else:
                b = _nbytes(shapes)
                # operands resolvable in the same computation
                paren = rest[rest.index("("):] if "(" in rest else ""
                depth_cut = paren.split(")")[0] if paren else ""
                for om in _OPERAND_RE.finditer(depth_cut):
                    if om.group(1) in cur.symtab:
                        b += _nbytes(cur.symtab[om.group(1)])
                cur.bytes += b
    return comps, entry


def analyze_hlo(text: str) -> Dict:
    comps, entry = parse_hlo(text)
    memo: Dict[str, Tuple[float, float, Dict[str, float],
                          Dict[str, float]]] = {}

    def cost(name: str, stack: Set[str]):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or name in stack:
            return 0.0, 0.0, {}, {}
        stack = stack | {name}
        fl, by = c.flops, c.bytes
        coll = dict(c.coll)
        cnts = dict(c.coll_counts)
        for callee, w, prop_bytes in c.calls:
            f2, b2, co2, cn2 = cost(callee, stack)
            fl += w * f2
            if prop_bytes:
                by += w * b2
            for k, v in co2.items():
                coll[k] = coll.get(k, 0.0) + w * v
            for k, v in cn2.items():
                cnts[k] = cnts.get(k, 0.0) + w * v
        memo[name] = (fl, by, coll, cnts)
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": {},
                "collective_counts": {}, "collective_total": 0.0}
    fl, by, coll, cnts = cost(entry, set())
    return {"flops": fl, "bytes": by, "collective_bytes": coll,
            "collective_counts": cnts,
            "collective_total": sum(coll.values())}


def f32_artifact_bytes(text: str) -> int:
    """Bytes of f32 buffers that mirror a bf16 buffer of identical dims —
    the XLA:CPU bf16-upcast artifact (absent on TPU builds)."""
    bf16 = set()
    f32 = {}
    for dt, dims in _SHAPE_RE.findall(text):
        if dt == "bf16":
            bf16.add(dims)
        elif dt == "f32":
            f32.setdefault(dims, 0)
    tot = 0
    for dims in f32:
        if dims in bf16 and dims:
            n = 1
            for d in dims.split(","):
                n *= int(d)
            tot += 4 * n
    return tot
