"""Continuous online serving: the paper's sub-20 ms loop, closed.

Everything upstream of this module is batch-shaped — pre-staged device
arrays, a fixed T, offline streaming. This is the real serving driver:

    host trace-replay source (data.replay, paced at an offered rate)
        │ fixed-shape period batch (numpy)
        ▼
    HostIngestRing — double-buffered ``jax.device_put`` staging: period
        │             t+1's events upload while period t computes (the
        │             host-boundary extension of PR 3's on-device overlap)
        ▼
    donated ``dfa_step`` per period (ingest ∘ enrich ∘ inference)
        │
        ▼
    per-period wall-clock latency vs the SLO budget; p50/p99/p999
    percentiles; exact drop accounting; graceful drain on shutdown.

Latency methodology: one sample per period, measured on the host from
step dispatch to ``jax.block_until_ready`` on that period's outputs —
i.e. the full verdict latency a consumer observes, including the
overlapped upload of the next period's events. Percentiles use
``np.percentile`` linear interpolation (tested against hand-computed
samples in tests/test_serving.py).

Backpressure: the source paces arrivals in virtual time (one budget per
period — deterministic; see data.replay), so offering faster than the
batch-capacity rate ``batch_events / budget`` is exactly "ingest outruns
the budget": the host queue fills, the drop policy sheds events, and the
per-period accounting stays exact (``offered == processed + dropped``
each period when ``queue_events == 0``, cumulatively after drain
otherwise). Wall-clock overruns are tracked separately as SLO
``violations`` so CPU-container jitter never perturbs the accounting.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P
from repro.data.replay import PeriodAccounting, TraceReplaySource


def latency_summary(samples_us) -> Dict[str, float]:
    """p50/p99/p999 of per-period wall latencies (µs), linear-interp
    percentiles (``np.percentile`` default) — the bench/gate contract.

    ``count`` rides along so a consumer can tell "no samples" (count 0,
    percentiles NaN — an EXPLICIT empty summary, not a crash or a
    silent 0.0 that would read as an impossibly fast period) from a real
    distribution, and can spot a one-sample summary where all three
    percentiles collapse to the same value by construction."""
    arr = np.asarray(list(samples_us), dtype=float)
    if arr.size == 0:
        return {"p50": float("nan"), "p99": float("nan"),
                "p999": float("nan"), "count": 0}
    p50, p99, p999 = np.percentile(arr, [50.0, 99.0, 99.9])
    return {"p50": float(p50), "p99": float(p99), "p999": float(p999),
            "count": int(arr.size)}


class HostIngestRing:
    """Double-buffered host→device staging for period batches.

    Two slots, used round-robin: staging period t+1 issues its
    ``jax.device_put`` while period t's step is still in flight, and the
    slot keeps a reference so the upload's target buffers stay alive
    until the following stage overwrites the slot (t+2's stage — by
    which point t has been consumed)."""

    def __init__(self, system, events_per_shard: int):
        _, specs = system.event_specs(events_per_shard)
        mesh = system.mesh
        self._shardings = {k: NamedSharding(mesh, s)
                           for k, s in specs.items()}
        self._now_sharding = NamedSharding(mesh, P())
        self._slots: List = [None, None]
        self.staged = 0

    def stage(self, batch: Dict[str, np.ndarray], now) -> Tuple[Dict, jax.Array]:
        dev = {k: jax.device_put(np.asarray(v), self._shardings[k])
               for k, v in batch.items()}
        dnow = jax.device_put(jnp.uint32(now), self._now_sharding)
        self._slots[self.staged & 1] = (dev, dnow)
        self.staged += 1
        return dev, dnow


@dataclasses.dataclass
class ServingReport:
    """What one :meth:`ServingLoop.run` produced."""

    periods: int                      # main-loop periods
    drained_periods: int              # extra periods run by the drain
    budget_us: int                    # the SLO
    offered: int
    processed: int
    dropped: int
    violations: int                   # periods with wall latency > SLO
    latency_us: List[float]           # one sample per period (incl drain)
    per_period: List[PeriodAccounting]
    last: object = dataclasses.field(default=None, repr=False)
    snapshots: int = 0                # async DFAState checkpoints written
    # -- live in-loop recovery (its own SLO bucket, NOT in latency_us:
    # a membership change is a planned stall, not a per-period verdict
    # latency — the gate prices it separately) --------------------------
    recoveries: int = 0               # dead pods absorbed mid-serve
    recovery_stall_us: List[float] = dataclasses.field(
        default_factory=list)         # wall stall per recovery
    duplicate_recovery_skips: int = 0  # re-trips for already-removed pods
    journal_replayed: int = 0         # journal periods re-fed on recovery

    @property
    def latency(self) -> Dict[str, float]:
        return latency_summary(self.latency_us)

    @property
    def balanced(self) -> bool:
        """The exact-accounting invariant (always true after a drain)."""
        return self.offered == self.processed + self.dropped

    @property
    def sustained_eps(self) -> float:
        """Events actually served per second of budgeted period time
        (0.0 for a zero-period run — no time was budgeted)."""
        total = self.periods + self.drained_periods
        if total == 0:
            return 0.0
        return self.processed / (total * self.budget_us / 1e6)


def build_source(system, events, nows=None,
                 batch_events: Optional[int] = None) -> TraceReplaySource:
    """A replay source wired to the system's serving knobs (the same
    fields ``DFASystem.describe()`` reports)."""
    cfg = system.cfg
    return TraceReplaySource(
        events, nows,
        batch_events=batch_events or system.n_shards * cfg.event_block,
        offered_eps=cfg.serve_offered_eps,
        budget_us=cfg.serve_budget_resolved_us(),
        queue_events=cfg.serve_queue_events,
        drop_policy=cfg.drop_policy)


class ServingLoop:
    """The continuous period loop.

    Per iteration: dispatch the donated ``dfa_step`` on the staged batch
    (async), immediately pull + stage the NEXT period's batch through the
    ingest ring so host work and upload hide behind the in-flight step,
    then block on the step's outputs and take the latency sample. On
    shutdown the source stops offering arrivals and the loop keeps
    running until the host queue is empty, so every admitted event is
    either processed or accounted as dropped — never lost in flight."""

    def __init__(self, system, source: TraceReplaySource,
                 budget_us: Optional[int] = None,
                 snapshot_dir: Optional[str] = None,
                 heartbeat=None,
                 chaos: Optional[Callable[[int], Sequence[int]]] = None,
                 recovery_devices=None):
        if source.batch_events % system.n_shards:
            raise ValueError(
                f"batch_events={source.batch_events} must divide across "
                f"{system.n_shards} shards")
        self.system = system
        self.source = source
        self.budget_us = int(budget_us
                             or system.cfg.serve_budget_resolved_us())
        self.ring = HostIngestRing(
            system, source.batch_events // system.n_shards)
        self._step = system.jit_step(donate=True)
        # elastic: snapshot the full DFAState every N completed periods
        # (cfg.snapshot_every_periods; 0 disables). The save's device_get
        # happens after block_until_ready and BEFORE the next donated
        # dispatch consumes the state, so only the file IO rides the
        # background thread — the double-buffered upload never stalls.
        self.snapshot_dir = (snapshot_dir if snapshot_dir is not None
                             else (system.cfg.snapshot_dir or None))
        self.snapshot_every = int(system.cfg.snapshot_every_periods)
        # -- live recovery (ROADMAP elastic remainder) ------------------
        # journal: the last snapshot-window's period batches, host-side.
        # Depth snapshot_every+1 covers the worst replay (recovery one
        # period before the next snapshot: snapshot_every-1 completed
        # periods to re-feed) plus the already-staged pending batch.
        # ``heartbeat`` (distributed.monitor.Heartbeat with a roster)
        # trips recovery when a whole pod goes stale; ``chaos`` is the
        # test hook — ``chaos(t) -> pods to declare dead after period
        # t`` (original pod numbering, like the heartbeat roster).
        self.heartbeat = heartbeat
        self.chaos = chaos
        self.recovery_devices = recovery_devices
        self._journal: collections.deque = collections.deque(
            maxlen=max(self.snapshot_every, 1) + 1)
        # original pod id -> live flag; recovery renumbers mesh positions
        # but heartbeat/chaos speak original ids, and a second trip for a
        # removed pod must be a counted no-op, not a second rehome
        self._live_pods: List[int] = list(range(system.mesh_pods))
        self._removed_pods: set = set()
        self._dup_skips = 0

    # -- live recovery internals ------------------------------------------

    def _dead_pods(self, t: int) -> List[int]:
        """Original pod ids newly declared dead after period ``t`` (chaos
        hook + whole-pod heartbeat trips), double-recovery filtered."""
        declared: List[int] = []
        if self.chaos is not None:
            declared.extend(int(d) for d in self.chaos(t))
        if self.heartbeat is not None:
            from repro.launch import elastic as EL
            declared.extend(EL.whole_dead_pods(self.heartbeat))
        fresh = []
        for d in dict.fromkeys(declared):       # de-dup, keep order
            if d in self._removed_pods:
                self._dup_skips += 1            # idempotence, not a crash
            else:
                fresh.append(d)
        return fresh

    def _recover(self, dead_orig: int, t: int):
        """Absorb a dead pod WITHOUT leaving the serving loop: restore the
        newest snapshot, rebuild on the survivor mesh, re-home the dead
        pod's flows, then re-feed the journal window — the loop continues
        on the smaller mesh with bitwise the state an offline
        ``recover_from_snapshot`` + trace replay would have produced,
        except no external trace access is needed. Returns the recovered
        on-device state; the wall stall is the caller's SLO bucket."""
        from repro.checkpoint import checkpoint as CKPT
        from repro.launch import elastic as EL
        pos = self._live_pods.index(dead_orig)  # current mesh position
        if self.snapshot_dir is None:
            raise RuntimeError(
                "live recovery needs snapshots: construct the loop with "
                "snapshot_dir (and cfg.snapshot_every_periods > 0) so a "
                "restore point exists inside the journal window")
        new_system, state, period = EL.recover_from_snapshot(
            self.system, self.snapshot_dir, pos,
            devices=self.recovery_devices)
        if self.source.batch_events % new_system.n_shards:
            raise ValueError(
                f"batch_events={self.source.batch_events} does not "
                f"divide across the {new_system.n_shards} survivor "
                "shards")
        new_ring = HostIngestRing(
            new_system,
            self.source.batch_events // new_system.n_shards)
        new_step = new_system.jit_step(donate=True)
        replayed = 0
        for idx, b, nw in sorted(self._journal, key=lambda e: e[0]):
            if period < idx <= t:
                out = new_step(state, *new_ring.stage(b, nw))
                state = out.state
                replayed += 1
        if period + replayed != t:
            raise RuntimeError(
                f"journal window does not reach the snapshot: restored "
                f"period {period}, journal replayed {replayed} of the "
                f"{t - period} periods since — raise "
                "snapshot_every_periods/journal depth or snapshot more "
                "often")
        jax.block_until_ready(state)
        self.system = new_system
        self.ring = new_ring
        self._step = new_step
        self._live_pods.pop(pos)
        self._removed_pods.add(dead_orig)
        if self.heartbeat is not None:
            self.heartbeat.retire_pod(dead_orig)
        return state, replayed

    def run(self, periods: int, drain: bool = True,
            state=None) -> ServingReport:
        if periods < 0:
            raise ValueError("periods must be >= 0")
        if periods == 0:
            # explicit empty run: nothing offered, nothing measured —
            # the report carries the empty latency summary (count=0,
            # NaN percentiles) and a 0.0 sustained rate, so callers that
            # size their period count dynamically never divide by zero
            total = self.source.total
            return ServingReport(
                periods=0, drained_periods=0, budget_us=self.budget_us,
                offered=total.offered, processed=total.processed,
                dropped=total.dropped, violations=0, latency_us=[],
                per_period=[], last=None, snapshots=0, recoveries=0,
                recovery_stall_us=[], duplicate_recovery_skips=0,
                journal_replayed=0)
        system, source = self.system, self.source
        if state is None:
            state = system.init_sharded_state()
        latencies: List[float] = []
        accounts: List[PeriodAccounting] = []
        violations = 0
        drained = 0
        out = None
        snapshots = 0
        snap_threads: List = []
        recoveries = 0
        stalls: List[float] = []
        replayed_total = 0
        dup0 = self._dup_skips
        snap_on = self.snapshot_every > 0 and self.snapshot_dir is not None
        if snap_on:
            from repro.checkpoint import checkpoint as CKPT

        batch, now, acct = source.next_batch()      # period 0, staged
        staged = self.ring.stage(batch, now)        # before the loop
        self._journal.append((1, batch, now))       # consumed by period 1
        t = 0
        while True:
            accounts.append(acct)
            t0 = time.perf_counter()
            out = self._step(state, *staged)        # async dispatch
            # pull + stage period t+1 while t computes (the overlap)
            t += 1
            if t >= periods and drain:
                source.begin_drain()                # graceful shutdown
            has_next = (t < periods
                        or (drain and source.pending > 0))
            if has_next:
                batch, now, acct = source.next_batch()
                staged = self.ring.stage(batch, now)
                self._journal.append((t + 1, batch, now))
                if t >= periods:
                    drained += 1
            state = out.state
            jax.block_until_ready(out)              # period t-1 done
            lat_us = (time.perf_counter() - t0) * 1e6
            latencies.append(lat_us)
            if lat_us > self.budget_us:
                violations += 1
            if snap_on and (t % self.snapshot_every == 0 or not has_next):
                # out.state is fully materialized (block_until_ready just
                # returned) and the next donated dispatch hasn't happened
                # yet: save() copies to host synchronously here, then the
                # writer thread owns the IO. The final period always
                # snapshots, so a drain never strands a partial window.
                th = CKPT.save(state, self.snapshot_dir, step=t,
                               keep=system.cfg.snapshot_keep, async_=True)
                if th is not None:
                    snap_threads.append(th)
                snapshots += 1
            # live recovery: a heartbeat-declared (or chaos-injected)
            # dead pod is absorbed HERE, between periods — snapshot
            # threads must land first so the restore point exists
            for dead in self._dead_pods(t):
                for th in snap_threads:
                    th.join()
                snap_threads.clear()
                stall0 = time.perf_counter()
                state, replayed = self._recover(dead, t)
                stalls.append((time.perf_counter() - stall0) * 1e6)
                recoveries += 1
                replayed_total += replayed
                system = self.system            # the survivor system
                if has_next:
                    # the pending batch was staged on the dead mesh:
                    # re-stage on the survivor ring (it is also in the
                    # journal, but replay stops at t — the pending
                    # period t+1 runs in the normal loop path)
                    staged = self.ring.stage(batch, now)
            if not has_next:
                break

        for th in snap_threads:
            th.join()
        total = source.total
        return ServingReport(
            periods=periods, drained_periods=drained,
            budget_us=self.budget_us,
            offered=total.offered, processed=total.processed,
            dropped=total.dropped, violations=violations,
            latency_us=latencies, per_period=accounts, last=out,
            snapshots=snapshots,
            recoveries=recoveries, recovery_stall_us=stalls,
            duplicate_recovery_skips=self._dup_skips - dup0,
            journal_replayed=replayed_total)


def serve_trace(system, events, nows=None, periods: int = 100,
                drain: bool = True) -> ServingReport:
    """One-call serving run: replay ``events`` through the continuous
    loop for ``periods`` periods under the system's serving knobs."""
    source = build_source(system, events, nows)
    return ServingLoop(system, source).run(periods, drain=drain)
