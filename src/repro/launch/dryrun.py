import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax pins the device count at first init.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell this AOT-lowers the
train/prefill/decode step with ShapeDtypeStruct stand-ins (no allocation),
compiles it, and records:
  * memory_analysis()  — per-device bytes (argument/output/temp) vs 16 GB HBM
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * collective bytes   — parsed from the compiled HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)
  * the three roofline terms + dominant bottleneck (§Roofline)

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import math
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import (SHAPES, get_config, get_shape, list_archs,
                           shape_applicable)
from repro.configs.base import TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as ST
from repro.launch.hlo_analysis import analyze_hlo, f32_artifact_bytes
from repro.models import param as PM
from repro.models.registry import (Model, decode_axes, get_model,
                                   input_specs, train_batch_axes)

# ---- hardware constants (TPU v5e-class target) -----------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per chip per link (aggregate assumed 1)
HBM_BYTES = 16 * 1024 ** 3   # 16 GiB per chip

_DT_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
             "token": 0}

_COLL_RE = re.compile(
    r"=\s*(\(?[^=()]*(?:\([^)]*\))?[^=]*?)\s+"
    r"(all-gather-start|all-reduce-start|collective-permute-start|"
    r"all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok: Tuple[str, str]) -> int:
    dt, dims = tok
    if dt not in _DT_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DT_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in the HLO."""
    per_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        result, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        shapes = _SHAPE_RE.findall(result)
        if not shapes:
            continue
        sizes = [_shape_bytes(s) for s in shapes]
        if "-start" in m.group(2) and len(sizes) > 1:
            b = max(sizes)          # (operand, output) tuple: count once
        else:
            b = sum(sizes)
        per_kind[kind] = per_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_per_kind": per_kind, "counts": counts,
            "total": sum(per_kind.values())}


def count_active_params(model: Model) -> Tuple[int, int]:
    """(total, active) parameter counts (MoE: top_k/num_experts of experts)."""
    cfg = model.cfg
    descs = model.param_descs()
    total = active = 0
    for path, d in PM._leaf_paths(descs):
        n = int(np.prod(d.shape))
        total += n
        if (cfg.moe is not None and "moe" in path
                and d.shape and d.shape[-0] == cfg.moe.num_experts
                and len(d.shape) >= 3):
            active += n * cfg.moe.top_k // cfg.moe.num_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape, n_active: int) -> float:
    """'Useful' model FLOPs for the step (the 6ND / 2ND convention)."""
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 tok/seq


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               donate: bool = True):
    """Build + AOT-lower one cell. Returns (lowered, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg.family, shape):
        return None, {"skipped": True, "reason":
                      "quadratic-attention arch at 500k decode "
                      "(DESIGN.md §5)"}
    model = get_model(cfg, mesh)
    tcfg = TrainConfig()
    B, S = shape.global_batch, shape.seq_len
    batch_sds, batch_specs = input_specs(cfg, shape, mesh)
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs)

    with mesh:
        if shape.kind == "train":
            state_sds = ST.abstract_train_state(model, tcfg)
            state_sh = ST.train_state_shardings(model, tcfg)
            step = ST.make_train_step(model, tcfg)
            rep = NamedSharding(mesh, P())
            out_sh = (state_sh, {"loss": rep, "gnorm": rep, "lr": rep})
            jf = jax.jit(step, in_shardings=(state_sh, bshard),
                         out_shardings=out_sh,
                         donate_argnums=(0,) if donate else ())
            lowered = jf.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            psh = model.param_shardings()
            psds = model.abstract_params()
            cache_sh = ST.cache_shardings(model, B, S)
            baxes = train_batch_axes(mesh, B)
            rep = NamedSharding(mesh, P(baxes or None, None))
            step = ST.make_prefill_step(model)
            jf = jax.jit(step, in_shardings=(psh, bshard),
                         out_shardings=(rep, cache_sh))
            lowered = jf.lower(psds, batch_sds)
        else:  # decode
            psh = ST.serve_param_shardings(model, B)
            psds = model.abstract_params()
            cache_sh = ST.cache_shardings(model, B, S)
            cache_sds = PM.abstract(model.cache_descs(B, S))
            baxes, _ = decode_axes(mesh, B, S)
            rep = NamedSharding(mesh, P(baxes or None, None))
            step = ST.make_decode_step(model, S)
            jf = jax.jit(step,
                         in_shardings=(psh, bshard["token"], bshard["pos"],
                                       cache_sh),
                         out_shardings=(rep, cache_sh),
                         donate_argnums=(3,) if donate else ())
            lowered = jf.lower(psds, batch_sds["token"], batch_sds["pos"],
                               cache_sds)
    n_total, n_active = count_active_params(model)
    meta = {"skipped": False, "arch": arch, "shape": shape_name,
            "multi_pod": multi_pod, "devices": int(math.prod(
                mesh.devices.shape)),
            "params_total": n_total, "params_active": n_active}
    return lowered, meta


def analyze(lowered, meta, shape) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    ana = analyze_hlo(hlo)          # trip-count-weighted (see hlo_analysis)
    coll = {"bytes_per_kind": ana["collective_bytes"],
            "counts": ana["collective_counts"],
            "total": ana["collective_total"]}
    flops_dev = float(ana["flops"])
    bytes_dev = float(ana["bytes"])
    coll_dev = float(coll["total"])
    artifact = f32_artifact_bytes(hlo)
    n_dev = meta["devices"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(get_config(meta["arch"]), shape, meta["params_active"])
    hbm_used = int(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                   + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        **meta,
        "compile_seconds": round(compile_s, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "hbm_used_bytes": hbm_used,
            "hbm_budget_bytes": HBM_BYTES,
            "fits_hbm": bool(hbm_used <= HBM_BYTES),
            "cpu_f32_artifact_bytes": int(artifact),
            "fits_hbm_tpu_adjusted": bool(
                max(hbm_used - artifact, 0) <= HBM_BYTES),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
                 "xla_cost_analysis_bytes": float(
                     cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": {
            **terms,
            "dominant": dominant,
            "step_time_lower_bound_s": max(terms.values()),
            "model_flops_total": mf,
            "hlo_flops_total": flops_dev * n_dev,
            "useful_flops_ratio": (mf / (flops_dev * n_dev)
                                   if flops_dev else 0.0),
            "roofline_fraction": (mf / n_dev / PEAK_FLOPS)
            / max(max(terms.values()), 1e-12),
        },
    }
    return result


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None) -> Dict[str, Any]:
    shape = get_shape(shape_name)
    lowered, meta = lower_cell(arch, shape_name, multi_pod)
    if lowered is None:
        res = {**meta, "arch": arch, "shape": shape_name,
               "multi_pod": multi_pod}
    else:
        res = analyze(lowered, meta, shape)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if (args.both_meshes or args.all)
              else [args.multi_pod])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        t0 = time.time()
        try:
            res = run_cell(a, s, mp, args.out)
            if res.get("skipped"):
                print(f"[dryrun] {a} {s} pod{2 if mp else 1}: SKIP "
                      f"({res['reason']})", flush=True)
                continue
            r = res["roofline"]
            m = res["memory"]
            print(f"[dryrun] {a} {s} pod{2 if mp else 1}: OK "
                  f"compile={res['compile_seconds']}s "
                  f"hbm={m['hbm_used_bytes']/2**30:.2f}GiB "
                  f"fits={m['fits_hbm']} "
                  f"compute={r['compute_s']:.4f}s "
                  f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                  f"dom={r['dominant']} "
                  f"roofline_frac={r['roofline_fraction']:.3f}", flush=True)
        except Exception as e:  # noqa: BLE001 — sweep must survive a cell
            print(f"[dryrun] {a} {s} pod{2 if mp else 1}: FAIL "
                  f"{type(e).__name__}: {str(e)[:300]}", flush=True)


if __name__ == "__main__":
    main()
