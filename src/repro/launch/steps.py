"""Step builders shared by train.py, dryrun.py, tests and benchmarks."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import param as PM
from repro.models.registry import Model, decode_axes, input_specs
from repro.optim import adamw
from repro.optim.schedule import lr_at

Tree = Any


# ------------------------------------------------------------- training ----

def make_train_step(model: Model, tcfg: TrainConfig):
    """(state, batch) -> (state, metrics); state = {"params", "opt"}."""
    cfg = model.cfg

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(state, batch):
        params, opt = state["params"], state["opt"]
        if tcfg.grad_accum > 1:
            a = tcfg.grad_accum

            def micro(carry, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), ()

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            mbs = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                batch)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), mbs)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = lr_at(opt.step, tcfg)
        params, opt, gnorm = adamw.apply(params, grads, opt, tcfg, lr)
        return ({"params": params, "opt": opt},
                {"loss": loss, "gnorm": gnorm, "lr": lr})

    return step


def init_train_state(model: Model, tcfg: TrainConfig, key) -> Dict:
    params = model.init(key)
    opt = adamw.init(params, tcfg, model.cfg.opt_state_dtype)
    return {"params": params, "opt": opt}


def abstract_train_state(model: Model, tcfg: TrainConfig) -> Dict:
    params = model.abstract_params()
    opt = adamw.abstract_state(params, tcfg, model.cfg.opt_state_dtype)
    return {"params": params, "opt": opt}


def train_state_shardings(model: Model, tcfg: TrainConfig) -> Dict:
    pshard = model.param_shardings()
    rep = NamedSharding(model.mesh, P())
    return {"params": pshard,
            "opt": adamw.OptState(
                step=rep,
                mu=jax.tree.map(lambda s: s, pshard),
                nu=jax.tree.map(lambda s: s, pshard))}


# -------------------------------------------------------------- serving ----

def cache_shardings(model: Model, batch: int, seq: int) -> Tree:
    """Cache shardings consistent with decode_axes(batch, seq)."""
    baxes, saxes = decode_axes(model.mesh, batch, seq)
    rules = PM.default_rules(model.mesh)
    r = dict(rules.rules)
    r["batch"] = baxes
    r["kv_seq"] = saxes
    rules2 = PM.LogicalRules(rules=r,
                             mesh_axis_sizes=rules.mesh_axis_sizes)
    return PM.shardings(model.cache_descs(batch, seq), model.mesh, rules2)


def serve_param_shardings(model: Model, batch: int) -> Tree:
    """Decode-time parameter layout: MoE experts sharded over the wide EP
    axes chosen by decode_ep_axes, so no per-layer FSDP weight gathers
    (§Perf: deepseek-v3 decode hillclimb)."""
    from repro.models import moe as M
    rules = PM.default_rules(model.mesh)
    if model.cfg.moe is not None:
        ep = M.decode_ep_axes(model.cfg, model.mesh, batch)
        r = dict(rules.rules)
        r["experts"] = ep
        rules = PM.LogicalRules(rules=r,
                                mesh_axis_sizes=rules.mesh_axis_sizes)
    return PM.shardings(model.param_descs(), model.mesh, rules)


def make_decode_step(model: Model, cache_seq: int):
    def step(params, token, pos, cache):
        return model.decode(params, token, pos, cache, cache_seq)
    return step


def make_prefill_step(model: Model):
    def step(params, batch):
        return model.prefill(params, batch)
    return step
