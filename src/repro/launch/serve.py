"""Batched serving driver: prefill + decode loop for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --reduced --batch 4 --prompt 32 --gen 16

This is the consumer side of DFA: examples/serve_traffic_inference.py feeds
this loop with collector-enriched feature prefixes. Reports tokens/s and
validates prefill/decode consistency (decode logits at position P must match
a full forward at P).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import tokens as DATA
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh
from repro.models import param as PM
from repro.models.registry import get_model


def build_cache(model, prefill_cache, B, S_cache):
    """Splice a prefill cache into a fixed-size decode cache."""
    cfg = model.cfg
    descs = model.cache_descs(B, S_cache)
    big = PM.materialize(descs, jax.random.key(0))
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return [jax.tree.map(
            lambda z, c: jax.lax.dynamic_update_slice_in_dim(
                z, c.astype(z.dtype), 0, axis=1), big[l], prefill_cache[l])
            for l in range(len(big))]
    if fam == "encdec":
        out = []
        for l in range(len(big)):
            e = dict(big[l])
            e["xk"], e["xv"] = prefill_cache[l]["xk"], prefill_cache[l]["xv"]
            for kk in ("k", "v"):
                e[kk] = jax.lax.dynamic_update_slice_in_dim(
                    e[kk], prefill_cache[l][kk].astype(e[kk].dtype), 0,
                    axis=1)
            out.append(e)
        return out
    if fam == "hybrid":
        out = []
        for l in range(len(big)):
            e = dict(big[l])
            e["mamba"] = jax.tree.map(
                lambda c, z: c.astype(z.dtype), prefill_cache[l]["mamba"],
                e["mamba"])
            for kk in ("attn_k", "attn_v"):
                e[kk] = jax.lax.dynamic_update_slice_in_dim(
                    e[kk], prefill_cache[l][kk].astype(e[kk].dtype), 0,
                    axis=1)
            out.append(e)
        return out
    # ssm: the recurrent state IS the cache
    return jax.tree.map(lambda c, z: c.astype(z.dtype), prefill_cache, big)


def serve(model, params, batch, prompt_len, gen_steps, S_cache,
          greedy=True):
    """Returns (generated tokens (B, gen_steps), tokens/s)."""
    with model.mesh:
        prefill = jax.jit(lambda p, b: model.prefill(p, b))
        decode = jax.jit(lambda p, t, po, c: model.decode(p, t, po, c,
                                                          S_cache),
                         donate_argnums=(3,))
        t0 = time.time()
        logits, pcache = prefill(params, batch)
        cache = build_cache(model, pcache, batch["tokens"].shape[0],
                            S_cache)
        B = batch["tokens"].shape[0]
        pos = jnp.full((B,), prompt_len, jnp.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(gen_steps - 1):
            logits, cache = decode(params, tok, pos, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            pos = pos + 1
            out.append(tok)
        toks = jnp.concatenate(out, axis=1)
        toks.block_until_ready()
        dt = time.time() - t0
    return toks, (B * gen_steps) / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = make_local_mesh()
    cfg = get_config(args.arch, reduced=args.reduced)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(args.seed))
    prompt = {"tokens": jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt), 0, cfg.vocab_size,
        dtype=jnp.int32)}
    prompt = DATA.add_modality_stub(prompt, cfg, 0, args.seed)
    n_prefix = (cfg.vision.num_patches if cfg.family == "vlm" else 0)
    toks, tps = serve(model, params, prompt, args.prompt + n_prefix,
                      args.gen, args.cache)
    print(f"[serve] {args.arch}: generated {toks.shape} at {tps:.1f} tok/s")
    assert np.asarray(toks).min() >= 0
    return toks


if __name__ == "__main__":
    main()
