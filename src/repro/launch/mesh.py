"""Mesh construction. Functions, never module-level constants — importing
this module must not touch jax device state (the dry-run sets the fake
device count before any jax initialization)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh


def _mk(shape, axes, devices=None) -> Mesh:
    return make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips/pod; multi-pod adds the 2-pod axis (512 chips).

    With 512 fake host devices the single-pod mesh uses the first 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 512 if multi_pod else 256
    devs = jax.devices()
    devices = devs[:need] if len(devs) >= need else None
    return _mk(shape, axes, devices)


def make_dfa_mesh(pods: int = 1, shards_per_pod: int = 0,
                  devices=None) -> Mesh:
    """2D ``(pod, shard)`` mesh for the multi-pod DFA stream
    (``DFAConfig.flow_home == "hash"``). The pod axis MUST lead so the
    pod-major device order matches the range sharding of the global flow
    keyspace (pipeline._derive_topology asserts this).

    ``shards_per_pod`` defaults to spreading every available device; pass
    ``devices`` to build on a prefix (how the differential suite puts a
    (1, S), (2, S) and (4, S//2) mesh on one host). Raises with the
    factorization spelled out when the device count doesn't divide —
    callers that want a skip instead (pytest) check first.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    if shards_per_pod <= 0:
        if len(devs) % pods:
            raise ValueError(
                f"{len(devs)} devices do not factor into {pods} pods "
                f"(need a multiple of {pods})")
        shards_per_pod = len(devs) // pods
    need = pods * shards_per_pod
    if len(devs) < need:
        raise ValueError(
            f"mesh ({pods}, {shards_per_pod}) needs {need} devices, "
            f"have {len(devs)}")
    return _mk((pods, shards_per_pod), ("pod", "shard"), devs[:need])


def make_local_mesh() -> Mesh:
    """Single-host mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return _mk((n // model, model), ("data", "model"))
