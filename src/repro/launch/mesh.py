"""Mesh construction. Functions, never module-level constants — importing
this module must not touch jax device state (the dry-run sets the fake
device count before any jax initialization)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh


def _mk(shape, axes, devices=None) -> Mesh:
    return make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips/pod; multi-pod adds the 2-pod axis (512 chips).

    With 512 fake host devices the single-pod mesh uses the first 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 512 if multi_pod else 256
    devs = jax.devices()
    devices = devs[:need] if len(devs) >= need else None
    return _mk(shape, axes, devices)


def make_local_mesh() -> Mesh:
    """Single-host mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return _mk((n // model, model), ("data", "model"))
