"""jax API compatibility shims (pinned jax 0.4.37 vs newer releases).

Three spellings changed between the pinned jax and current releases; every
call site in this repo goes through this module so the code runs on both:

* ``make_mesh`` — the ``axis_types=(AxisType.Auto, ...)`` kwarg (and
  ``jax.sharding.AxisType`` itself) only exist from jax 0.5+.
* ``shard_map`` — new jax exposes ``jax.shard_map(..., check_vma=)``;
  0.4.x has ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
* ``axis_size`` — ``jax.lax.axis_size`` is new; ``psum(1, name)`` is the
  portable equivalent inside a mapped context.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence[Any]] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (check_vma=) or the 0.4.x experimental equivalent
    (check_rep=); ``check`` maps onto whichever knob exists."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def axis_size(name):
    """Size of a mapped mesh axis, usable inside shard_map bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    # psum of the literal 1 is constant-folded to the axis size at trace time
    return jax.lax.psum(1, name)
