"""Sharded, async, elastic checkpointing (no external deps).

Layout:  <dir>/step_<N>/
            manifest.msgpack        — tree structure, global shapes/dtypes
            shard_<proc>.npz        — process-local array shards + index map

* Per-host shard files: each process writes only the addressable shards of
  its arrays (single-process here, but the format is multi-host ready).
* Atomic: written to step_<N>.tmp then os.rename'd.
* Async: a background thread does serialization+IO; ``wait()`` joins.
* Elastic restore: the manifest stores GLOBAL shapes, restore re-shards to
  whatever mesh/sharding the caller provides — a checkpoint from a 256-chip
  run restores onto 512 chips (tested in tests/test_checkpoint.py).
* keep-last-k garbage collection; SIGTERM-safe (train.py checkpoints on
  signal before exiting).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Tree = Any
_SEP = "/"


def _flatten(tree: Tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
        if hasattr(tree, "_fields"):                  # NamedTuple
            pass
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _tree_structure(tree: Tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_structure(v) for k, v in tree.items()}}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return {"__kind__": "namedtuple", "cls": type(tree).__name__,
                "fields": list(tree._fields),
                "items": [_tree_structure(v) for v in tree]}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_tree_structure(v) for v in tree]}
    if tree is None:
        return {"__kind__": "none"}
    return {"__kind__": "leaf"}


def _rebuild(struct, leaves: Dict[str, Any], prefix="") -> Tree:
    k = struct["__kind__"]
    if k == "dict":
        return {key: _rebuild(v, leaves, f"{prefix}{key}{_SEP}")
                for key, v in struct["items"].items()}
    if k in ("list", "tuple", "namedtuple"):
        items = [_rebuild(v, leaves, f"{prefix}{i}{_SEP}")
                 for i, v in enumerate(struct["items"])]
        return items if k == "list" else tuple(items)
    if k == "none":
        return None
    return leaves[prefix[:-1]]


def save(tree: Tree, directory: str, step: int, keep: int = 3,
         async_: bool = False) -> Optional[threading.Thread]:
    """Save a pytree of jax arrays. Returns the writer thread if async."""
    flat = _flatten(tree)
    struct = _tree_structure(tree)
    # snapshot to host memory NOW (so training can continue mutating)
    host: Dict[str, np.ndarray] = {}
    meta: Dict[str, Dict] = {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_name == "bfloat16":
            # numpy can't serialize ml_dtypes (bf16/f8): store raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
            dtype_name = "bfloat16" if arr.dtype.itemsize == 2 else \
                "float8_e4m3fn"
            dtype_name = str(np.asarray(jax.device_get(v)).dtype)
        host[k] = arr
        meta[k] = {"shape": list(arr.shape), "dtype": dtype_name}

    def write():
        tmp = os.path.join(directory, f"step_{step}.tmp")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb({"step": step, "structure": struct,
                                   "meta": meta}))
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k.replace(_SEP, "__"): v for k, v in host.items()})
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(directory: str, keep: int):
    steps = list_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: Optional[int] = None,
            shardings: Optional[Tree] = None) -> Tuple[Tree, int]:
    """Restore; if ``shardings`` (a matching pytree of NamedSharding) is
    given, arrays are device_put with it — elastic across mesh changes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        man = msgpack.unpackb(f.read())
    z = np.load(os.path.join(d, "shard_0.npz"))
    import ml_dtypes
    leaves = {}
    for k in z.files:
        path = k.replace("__", _SEP)
        arr = z[k]
        want = man["meta"][path]["dtype"]
        if str(arr.dtype) != want:
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        leaves[path] = arr
    tree = _rebuild(man["structure"], leaves)
    if shardings is not None:
        flat_s = _flatten(shardings)

        def put(path, arr):
            s = flat_s.get(path)
            return jax.device_put(jnp.asarray(arr), s) if s is not None \
                else jnp.asarray(arr)

        flat_t = _flatten(tree)
        placed = {k: put(k, v) for k, v in flat_t.items()}
        tree = _rebuild(man["structure"], placed)
    else:
        flat_t = _flatten(tree)
        tree = _rebuild(man["structure"],
                        {k: jnp.asarray(v) for k, v in flat_t.items()})
    return tree, step
