"""Sharded, async, elastic checkpointing (no external deps).

Layout:  <dir>/step_<N>/
            manifest.msgpack        — tree structure, global shapes/dtypes
            shard_<proc>.npz        — process-local array shards + index map

* Per-host shard files: each process writes only the addressable shards of
  its arrays (single-process here, but the format is multi-host ready).
* Atomic: written to step_<N>.tmp then os.rename'd.
* Async: a background thread does serialization+IO; ``wait()`` joins.
* Elastic restore: the manifest stores GLOBAL shapes, restore re-shards to
  whatever mesh/sharding the caller provides — a checkpoint from a 256-chip
  run restores onto 512 chips (tested in tests/test_checkpoint.py).
* NamedTuple-faithful: restored trees rebuild the registered NamedTuple
  classes (DFAState & friends), so ``state.reporter.regs`` works after a
  round-trip; unknown classes rebuild as a dynamic namedtuple of the same
  name/fields rather than silently degrading to a plain tuple.
* keep-last-k garbage collection; SIGTERM-safe (train.py checkpoints on
  signal before exiting).

Concurrency: all directory mutation (rename + GC) and manifest/shard reads
happen under a module lock, so overlapping async saves and a restore racing
a save's GC are serialized instead of corrupting each other.
"""
from __future__ import annotations

import collections
import importlib
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Tree = Any
_SEP = "/"

# serializes directory mutation (tmp->final rename, GC) and reads against
# each other; held only around IO, never around device_get/serialization
_IO_LOCK = threading.Lock()

# NamedTuple classes restorable by name. Populated lazily with the DFA
# state classes; extend via register_namedtuple for user trees.
_NT_REGISTRY: Dict[str, Type] = {}
_BUILTIN_NT = (
    ("repro.core.pipeline", ("DFAState", "RoutedBatch", "StepOutputs")),
    ("repro.core.reporter", ("ReporterState",)),
    ("repro.core.translator", ("TranslatorState",)),
    ("repro.core.collector", ("CollectorState",)),
)


def register_namedtuple(cls: Type) -> Type:
    """Register a NamedTuple class so restore rebuilds it by name.

    Usable as a decorator; returns ``cls`` unchanged.
    """
    _NT_REGISTRY[cls.__name__] = cls
    return cls


def _resolve_namedtuple(name: str, fields: List[str]) -> Type:
    cls = _NT_REGISTRY.get(name)
    if cls is None:
        # lazy import: checkpoint must not import the core modules at module
        # load (they import jax-heavy deps and would cycle through train.py)
        for mod, names in _BUILTIN_NT:
            if name not in names:
                continue
            try:
                m = importlib.import_module(mod)
            except ImportError:
                continue
            found = getattr(m, name, None)
            if found is not None:
                _NT_REGISTRY[name] = found
                cls = found
    if cls is not None and list(getattr(cls, "_fields", ())) == list(fields):
        return cls
    # unknown class, or its fields drifted since the save: a dynamic
    # namedtuple keeps attribute access working (a plain tuple would not)
    return collections.namedtuple(name, fields)  # type: ignore[misc]


def _flatten(tree: Tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _tree_structure(tree: Tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_structure(v) for k, v in tree.items()}}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return {"__kind__": "namedtuple", "cls": type(tree).__name__,
                "fields": list(tree._fields),
                "items": [_tree_structure(v) for v in tree]}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_tree_structure(v) for v in tree]}
    if tree is None:
        return {"__kind__": "none"}
    return {"__kind__": "leaf"}


def _rebuild(struct, leaves: Dict[str, Any], prefix="") -> Tree:
    k = struct["__kind__"]
    if k in ("list", "tuple", "namedtuple"):
        items = [_rebuild(v, leaves, f"{prefix}{i}{_SEP}")
                 for i, v in enumerate(struct["items"])]
        if k == "list":
            return items
        if k == "namedtuple":
            cls = _resolve_namedtuple(struct["cls"], struct["fields"])
            return cls(*items)
        return tuple(items)
    if k == "dict":
        return {key: _rebuild(v, leaves, f"{prefix}{key}{_SEP}")
                for key, v in struct["items"].items()}
    if k == "none":
        return None
    return leaves[prefix[:-1]]


def save(tree: Tree, directory: str, step: int, keep: int = 3,
         async_: bool = False) -> Optional[threading.Thread]:
    """Save a pytree of jax arrays. Returns the writer thread if async."""
    flat = _flatten(tree)
    struct = _tree_structure(tree)
    # snapshot to host memory NOW (so training can continue mutating)
    host: Dict[str, np.ndarray] = {}
    meta: Dict[str, Dict] = {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        dtype_name = str(arr.dtype)
        if arr.dtype.type.__module__ == "ml_dtypes":
            # numpy can't serialize extension dtypes (bf16 is void-kind,
            # float8_e5m2 even claims kind 'f' but np.load rejects '<f1'):
            # store raw bits, remember the true name once — restore views
            # the bits back through ml_dtypes
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        host[k] = arr
        meta[k] = {"shape": list(arr.shape), "dtype": dtype_name}

    def write():
        tmp = os.path.join(directory, f"step_{step}.tmp")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb({"step": step, "structure": struct,
                                   "meta": meta}))
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k.replace(_SEP, "__"): v for k, v in host.items()})
        with _IO_LOCK:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(directory, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(directory: str, keep: int):
    # caller holds _IO_LOCK
    steps = list_steps(directory)
    doomed = steps if keep <= 0 else steps[:-keep]
    for s in doomed:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: Optional[int] = None,
            shardings: Optional[Tree] = None) -> Tuple[Tree, int]:
    """Restore; if ``shardings`` (a matching pytree of NamedSharding) is
    given, arrays are device_put with it — elastic across mesh changes."""
    import ml_dtypes
    with _IO_LOCK:
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {directory}")
        d = os.path.join(directory, f"step_{step}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            man = msgpack.unpackb(f.read())
        z = np.load(os.path.join(d, "shard_0.npz"))
        leaves = {}
        for k in z.files:
            path = k.replace("__", _SEP)
            arr = z[k]
            want = man["meta"][path]["dtype"]
            if str(arr.dtype) != want:
                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            leaves[path] = arr
    tree = _rebuild(man["structure"], leaves)
    if shardings is not None:
        flat_s = _flatten(shardings)

        def put(path, arr):
            s = flat_s.get(path)
            return jax.device_put(jnp.asarray(arr), s) if s is not None \
                else jnp.asarray(arr)

        flat_t = _flatten(tree)
        placed = {k: put(k, v) for k, v in flat_t.items()}
        tree = _rebuild(man["structure"], placed)
    else:
        flat_t = _flatten(tree)
        tree = _rebuild(man["structure"],
                        {k: jnp.asarray(v) for k, v in flat_t.items()})
    return tree, step
