"""zamba2-2.7b — hybrid: Mamba2 trunk + shared full-attention blocks.
[arXiv:2411.15242; hf]"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),  # 64 was tried: halves decay traffic but
    # doubles inter-chunk state r/w -> net worse (§Perf iteration 2)
    hybrid=HybridConfig(attn_every=6, shared_attn=True, num_shared_blocks=2),
    source="arXiv:2411.15242",
)

REDUCED = CONFIG.replace(
    name="zamba2-2.7b-reduced",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=32),
    hybrid=HybridConfig(attn_every=2, shared_attn=True, num_shared_blocks=2),
    remat="none",
)
