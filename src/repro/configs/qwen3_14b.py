"""qwen3-14b — dense GQA with qk-norm.
[hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B (family)",
)

REDUCED = CONFIG.replace(
    name="qwen3-14b-reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, remat="none",
)
