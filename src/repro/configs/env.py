"""The single registry of every ``REPRO_*`` environment override.

Before this module existed, each env var was parsed at its point of use
with its own ad-hoc semantics: the kernel dispatch layer validated its
three choice vars fail-loud, while ``REPRO_BENCH_TINY`` treated any
string but ``""``/``"0"`` as true (so ``REPRO_BENCH_TINY=false`` meant
*tiny*) and ``REPRO_REGEN_GOLDENS`` accepted anything truthy. Now every
override is declared here once, with one parsing rule per kind and one
fail-loud contract: a malformed value raises ``ValueError`` naming the
variable and what it accepts — it is never silently ignored, because a
typo'd override that loses quietly is indistinguishable from one that
worked.

Kinds:

``choice``
    One of a fixed set of strings. Unset, ``""`` and ``"auto"`` all mean
    "defer to the next stage of the precedence ladder" (see
    ``repro.kernels.dispatch``); anything else must be a registered
    choice.
``flag``
    Boolean. Unset/``""``/``"0"``/``"false"``/``"no"``/``"off"`` are
    false; ``"1"``/``"true"``/``"yes"``/``"on"`` are true (case
    insensitive). Anything else raises.
``str``
    A free-form string (a filesystem path, typically). Unset/``""`` ->
    None; the raw value otherwise — NOT lowercased, paths are
    case-sensitive. Validation of the content (does the file exist,
    does it parse) belongs to the consumer, which must still fail loud.

The full table (also rendered by :func:`env_table` for docs):

=======================  ======  =================  =========================
variable                 kind    values             consumed by
=======================  ======  =================  =========================
REPRO_KERNEL_BACKEND     choice  ref|pallas|        kernels.dispatch backend
                                 interpret          precedence (beats
                                                    DFAConfig.kernel_backend,
                                                    loses to explicit
                                                    ``backend=``)
REPRO_GATHER_VARIANT     choice  full|hbm           gather_enrich memory
                                                    strategy
REPRO_INGEST_VARIANT     choice  block|hbm          ingest_update event-
                                                    stream strategy
REPRO_BENCH_TINY         flag                       benchmarks/: shrink
                                                    problem sizes + iters
                                                    (set by run.py --tiny)
REPRO_REGEN_GOLDENS      flag                       tests/test_run_periods_
                                                    golden.py: refresh all
                                                    committed fingerprints
REPRO_WIRE_FORMAT        choice  v1|v2              core.wire active wire
                                                    schema (beats
                                                    DFAConfig.wire_format)
REPRO_TUNING_REGISTRY    str     path               kernels.tuning tuned-
                                                    config registry JSON
                                                    (beats DFAConfig.
                                                    tuning_registry)
=======================  ======  =================  =========================
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("", "0", "false", "no", "off")


@dataclass(frozen=True)
class EnvSpec:
    """One registered override: its name, kind, and legal values."""

    name: str
    kind: str                         # "choice" | "flag" | "str"
    choices: Tuple[str, ...] = ()     # kind == "choice" only
    description: str = ""
    consumer: str = ""                # module that reads it

    def __post_init__(self):
        if self.kind not in ("choice", "flag", "str"):
            raise ValueError(f"unknown env kind {self.kind!r}")
        if self.kind == "choice" and not self.choices:
            raise ValueError(f"{self.name}: choice spec needs choices")


_REGISTRY: Dict[str, EnvSpec] = {}


def register(spec: EnvSpec) -> EnvSpec:
    """Register (or re-register, for tests) one override."""
    _REGISTRY[spec.name] = spec
    return spec


def registered() -> Dict[str, EnvSpec]:
    return dict(_REGISTRY)


def spec(name: str) -> EnvSpec:
    if name not in _REGISTRY:
        raise KeyError(
            f"unregistered env override {name!r}; registered: "
            f"{sorted(_REGISTRY)} (declare it in repro.configs.env)")
    return _REGISTRY[name]


def read_choice(name: str) -> Optional[str]:
    """The validated value of a choice var, or ``None`` when it defers.

    Unset / ``""`` / ``"auto"`` -> None (the precedence ladder moves on);
    a registered choice -> that choice; anything else raises listing the
    registered values — even when a stronger setting (an explicit
    ``backend=`` argument) would win, so a typo can never lose silently.
    """
    s = spec(name)
    if s.kind != "choice":
        raise ValueError(f"{name} is a {s.kind} var, not a choice")
    raw = os.environ.get(name, "").strip().lower()
    if raw in ("", "auto"):
        return None
    if raw not in s.choices:
        raise ValueError(
            f"unknown value {raw!r} from env var {name}; registered: "
            f"{list(s.choices)} (or 'auto')")
    return raw


def read_flag(name: str) -> bool:
    """The validated value of a flag var (unset -> False; junk raises)."""
    s = spec(name)
    if s.kind != "flag":
        raise ValueError(f"{name} is a {s.kind} var, not a flag")
    raw = os.environ.get(name, "").strip().lower()
    if raw in _FALSE:
        return False
    if raw in _TRUE:
        return True
    raise ValueError(
        f"unknown value {raw!r} from env var {name}; a flag accepts "
        f"{list(_TRUE)} / {list(_FALSE)}")


def read_str(name: str) -> Optional[str]:
    """The raw value of a string var, or ``None`` when unset/empty.

    No lowercasing (paths are case-sensitive) and no content validation
    here — the consumer validates what the string points at, fail-loud.
    """
    s = spec(name)
    if s.kind != "str":
        raise ValueError(f"{name} is a {s.kind} var, not a str")
    raw = os.environ.get(name, "").strip()
    return raw or None


def env_table() -> str:
    """Markdown table of every registered override (for README/docs)."""
    lines = ["| variable | kind | values | consumed by |",
             "|---|---|---|---|"]
    for name in sorted(_REGISTRY):
        s = _REGISTRY[name]
        vals = ("\\|".join(s.choices) if s.kind == "choice"
                else "0/1" if s.kind == "flag" else "path")
        lines.append(f"| `{name}` | {s.kind} | {vals} | {s.consumer}: "
                     f"{s.description} |")
    return "\n".join(lines)


# -- the in-tree overrides ---------------------------------------------------

KERNEL_BACKEND = register(EnvSpec(
    "REPRO_KERNEL_BACKEND", "choice", ("ref", "pallas", "interpret"),
    description="kernel backend (beats DFAConfig.kernel_backend, loses "
                "to an explicit backend= argument)",
    consumer="repro.kernels.dispatch"))

GATHER_VARIANT = register(EnvSpec(
    "REPRO_GATHER_VARIANT", "choice", ("full", "hbm"),
    description="gather_enrich memory strategy (full-block VMEM vs "
                "HBM-resident tiled DMA)",
    consumer="repro.kernels.dispatch"))

INGEST_VARIANT = register(EnvSpec(
    "REPRO_INGEST_VARIANT", "choice", ("block", "hbm"),
    description="ingest_update event-stream strategy (BlockSpec-tiled "
                "VMEM vs HBM-resident double-buffered DMA)",
    consumer="repro.kernels.dispatch"))

BENCH_TINY = register(EnvSpec(
    "REPRO_BENCH_TINY", "flag",
    description="bench-smoke mode: tiny problem sizes, 2 timed iters "
                "(set by benchmarks/run.py --tiny)",
    consumer="benchmarks.common"))

REGEN_GOLDENS = register(EnvSpec(
    "REPRO_REGEN_GOLDENS", "flag",
    description="refresh every committed golden fingerprint in one run",
    consumer="tests.test_run_periods_golden"))

TUNING_REGISTRY = register(EnvSpec(
    "REPRO_TUNING_REGISTRY", "str",
    description="path to a tuned-config registry JSON "
                "(kernels.tuning; produced by the *_scaling.py sweeps' "
                "--tune flag; beats DFAConfig.tuning_registry)",
    consumer="repro.kernels.tuning"))

WIRE_FORMAT = register(EnvSpec(
    "REPRO_WIRE_FORMAT", "choice", ("v1", "v2"),
    description="active wire schema (v1 = the paper's 8-bit "
                "reporter_id/seq layout, v2 = the widened u16 layout; "
                "beats DFAConfig.wire_format)",
    consumer="repro.core.wire"))
