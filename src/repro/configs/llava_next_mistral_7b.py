"""llava-next-mistral-7b — mistral-7b backbone + vision-prefix stub.
The anyres tiling / CLIP tower is upstream of this system: input_specs()
provides precomputed patch embeddings (already projected to d_model).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    vision=VisionStubConfig(num_patches=2880),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

REDUCED = CONFIG.replace(
    name="llava-next-mistral-7b-reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16,
    vision=VisionStubConfig(num_patches=16),
    remat="none",
)
