"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; sub-family
options (MoE, MLA, SSM, hybrid schedule, encoder/decoder, modality stubs)
are nested optional dataclasses so a single registry can instantiate all ten
architectures plus reduced smoke variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style top-k routing)."""

    num_experts: int
    top_k: int
    d_ff_expert: int                  # per-expert FFN hidden width
    num_shared_experts: int = 0       # always-on experts (deepseek-v3 style)
    d_ff_shared: int = 0              # hidden width of the shared expert(s)
    capacity_factor: float = 1.25     # per-expert buffer slack for dispatch
    router_dtype: str = "float32"
    # Layers [0, first_moe_layer) use a dense FFN of width ``d_ff_dense``.
    first_moe_layer: int = 0
    d_ff_dense: int = 0
    # deepseek-v3 routing details
    routed_scaling_factor: float = 1.0
    score_func: str = "softmax"       # "softmax" | "sigmoid" (deepseek-v3)
    moe_every: int = 1                # MoE FFN every k-th layer (llama4: 1)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (deepseek-v3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2/SSD block configuration (zamba2) or RWKV6 time-mix options."""

    state_dim: int = 64               # N — SSM state size per head
    head_dim: int = 64                # P — channels per head
    expand: int = 2                   # d_inner = expand * d_model
    conv_width: int = 4               # causal conv1d kernel size
    chunk_size: int = 128             # SSD chunked-scan block length
    n_groups: int = 1                 # B/C groups (mamba2)


@dataclass(frozen=True)
class HybridConfig:
    """Hybrid block schedule (zamba2: Mamba2 trunk + shared attention)."""

    attn_every: int = 6               # full attention block every k layers
    shared_attn: bool = True          # attention blocks share one weight set
    num_shared_blocks: int = 2        # zamba2 has 2 alternating shared blocks


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder split (whisper). The conv frontend is a STUB: the
    data pipeline / input_specs provide precomputed frame embeddings."""

    num_encoder_layers: int = 4
    num_frames: int = 1500            # whisper 30 s @ 50 Hz after conv stride 2


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub (llava-next). input_specs provide precomputed patch
    embeddings already projected to d_model; anyres tiling is upstream."""

    num_patches: int = 2880           # anyres 5 tiles x 576 patches
    patch_embed_dim: int = 0          # 0 => already projected to d_model


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. Families:

    dense   — decoder-only transformer (GQA/MQA/MHA)
    moe     — decoder-only with MoE FFN (optionally MLA attention)
    hybrid  — Mamba2 trunk with interleaved (shared) attention blocks
    ssm     — attention-free (rwkv6)
    encdec  — encoder-decoder (whisper)
    vlm     — decoder-only with vision-prefix stub (llava-next)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"                 # FFN activation (gated)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    mtp_depth: int = 0                # multi-token-prediction heads (deepseek)
    # numerics / memory policy
    dtype: str = "bfloat16"           # activation/param compute dtype
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for XXL models to fit HBM
    remat: str = "full"               # "none" | "full" — scan remat policy
    loss_chunk: int = 2048            # sequence chunk for CE loss (memory)
    attn_chunk: int = 1024            # KV chunk for online-softmax attention
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class DFAConfig:
    """The paper's own system configuration (Table I / Figs 2, 4).

    Defaults mirror the Tofino deployment: 2^17 flows per pipeline shard,
    10-entry history ring, 64 B RoCEv2 payload (45 B Marina vector + pad),
    20 ms monitoring period target.
    """

    flows_per_shard: int = 1 << 17        # 131,072 — classification table size
    history: int = 10                      # Fig 4 ring depth
    payload_words: int = 16                # 64 B / 4 B words (RoCEv2 pow-2 pad)
    feature_words: int = 8                 # 8 x 4 B Table-I statistics
    monitoring_period_us: int = 20_000     # 20 ms target interval
    logstar_bits: int = 7                  # mantissa bits kept by the log* LUT
    counter_bits: int = 8                  # per-flow history counter (paper: 8b)
    seq_check: bool = True                 # per-reporter sequence ids (sec VI-B)
    event_block: int = 1024                # packet events per extraction block
    report_capacity: int = 4096            # max reports routed per step/shard
    derived_dim: int = 96                  # Marina-style derived feature count
    flow_tile: int = 512                   # kernel flow-block tile
    # kernel implementation selection: "auto" | "ref" | "pallas" |
    # "interpret" — see repro.kernels.dispatch (REPRO_KERNEL_BACKEND env
    # var overrides this field; an explicit backend= argument beats both)
    kernel_backend: str = "auto"
    # wire schema version (repro.core.wire registry): "v1" = the paper's
    # bit-faithful 8-bit reporter_id/seq layout (256-port cap, every
    # committed golden); "v2" = widened u16 fields lifting the port/seq
    # caps. REPRO_WIRE_FORMAT env var overrides this field; unknown
    # names fail loud at DFASystem construction.
    wire_format: str = "v1"
    # gather_enrich memory strategy: "auto" | "full" (ring region pinned
    # in VMEM) | "hbm" (ring stays HBM-resident, per-report-tile DMA).
    # auto = VMEM-budget heuristic in dispatch.resolve_gather_variant;
    # REPRO_GATHER_VARIANT env var overrides this field.
    gather_variant: str = "auto"
    # per-core VMEM the auto heuristic may plan against (TPU v4/v5e have
    # ~16 MB; the full-block kernel is chosen only while its ring region
    # + tile working set fit under this)
    vmem_budget_mb: int = 16
    # ingest_update event-stream strategy: "auto" | "block" (sorted event
    # stream streams through BlockSpec-tiled VMEM) | "hbm" (stream stays
    # HBM-resident, per-event_tile double-buffered DMA — events/shard can
    # grow to 2^20 with VMEM = O(event_tile)). auto = VMEM-budget
    # heuristic in dispatch.resolve_ingest_variant; REPRO_INGEST_VARIANT
    # env var overrides this field.
    ingest_variant: str = "auto"
    # sorted-event tile the fused ingest kernels process per grid step;
    # clamped to 256 (the u16-half matmul exactness bound) and to the
    # block's event count
    event_tile: int = 256
    # streaming driver: software-pipeline the period stream so period t's
    # enrich(+inference) half runs in the same scan body as period t+1's
    # ingest half (pipeline.run_periods_overlapped); False = strictly
    # sequential per-period chain (pipeline.run_periods). Output-identical
    # by construction — the knob trades enrich latency out of the ingest
    # budget.
    overlap_periods: bool = False
    # optional inference head applied to the (R, derived_dim) enriched
    # features inside the enrich half: "none" | "linear" | "mlp" (built
    # from models.registry.get_flow_head unless the caller passes its own
    # infer_fn to DFASystem)
    inference_head: str = "none"
    inference_classes: int = 8         # verdict classes the head emits
    inference_hidden: int = 64         # mlp hidden width (linear ignores)
    # -- multi-pod (pod, shard) mesh streaming ---------------------------
    # how a flow's home collector ring is chosen:
    #   "ingest" — legacy 1D scheme: flow ids are minted from the ingest
    #              shard's range (shard * flows_per_shard + slot), so every
    #              report's home IS its ingest shard (the all_to_all is an
    #              identity permutation);
    #   "hash"   — mesh-shape-independent scheme: flow id = FNV-1a hash of
    #              the stored five-tuple into the GLOBAL ring keyspace
    #              (n_devices * flows_per_shard), home device = range shard
    #              of that id (pod-major), delivery is two-stage
    #              (intra-pod all_to_all over shard, then a cross-pod
    #              exchange over pod). A flow observed on ANY port lands in
    #              exactly one ring, which is what makes the (pod, shard)
    #              factorization of the mesh invisible in the merged state.
    #   "rendezvous" — elastic scheme: highest-random-weight hashing over
    #              the ``home_nodes`` roster; flow id = node_id *
    #              flows_per_shard + slot hash. A pod join/leave re-homes
    #              only the affected node's ~1/pods of flows (HRW
    #              restriction property) instead of reshuffling the whole
    #              range-sharded keyspace.
    flow_home: str = "ingest"
    # pod axis size ``launch.mesh.make_dfa_mesh`` builds the mesh with
    # (the mesh, not this field, is authoritative inside DFASystem)
    pods: int = 1
    # reporter ports per pod; 0 = one port per shard device (legacy).
    # total_ports = mesh_pods * ports_per_pod must be a multiple of the
    # device count — each device hosts total_ports / n_devices independent
    # per-port Marina tables, so the merged reporter state depends only on
    # the port set, never on how ports pack onto devices.
    ports_per_pod: int = 0
    # per-PORT Marina classification-table size; 0 = flows_per_shard.
    # Splitting this from flows_per_shard lets the collector ring space
    # (flows_per_shard per device) shrink as the mesh grows while every
    # port's table — and therefore its report stream — stays fixed.
    reporter_slots: int = 0
    # per-PORT due-report capacity; 0 = report_capacity // total_ports
    port_report_capacity: int = 0
    # stage-2 (cross-pod) exchange strategy:
    #   "padded" — worst-case fixed-capacity buckets (every committed
    #              golden; structurally drop-free)
    #   "ragged" — compact per-destination segments: pod-local reports
    #              never enter the exchange, remote reports are
    #              pre-merged flow-major at the source and only
    #              ``crosspod_capacity`` rows per destination pod cross
    #              the scarce inter-pod link. Bitwise-identical to
    #              "padded" at auto capacity (see crosspod_capacity);
    #              adds crosspod_sent/crosspod_messages metrics.
    crosspod_exchange: str = "padded"
    # per-destination-pod segment rows for the ragged exchange; 0 = the
    # worst-case stage-2 capacity (shards_per_pod x stage-1 bucket), at
    # which compaction cannot drop and the ragged path is bitwise ≡ the
    # padded one. Smaller values trade exchange volume for counted
    # bucket_drops — DTA's lossy-telemetry trade, now on the pod link.
    crosspod_capacity: int = 0
    # tuned-config registry JSON consulted by kernels.dispatch before
    # its VMEM heuristics ("" = off; REPRO_TUNING_REGISTRY env var
    # overrides). Produced by the *_scaling.py sweeps' --tune flag.
    tuning_registry: str = ""
    # -- elastic operations (launch.elastic) -----------------------------
    # logical node roster for flow_home="rendezvous": one stable node id
    # per mesh device (pod-major, strictly increasing); () = 0..n_devices-1.
    # HRW homes flows onto node IDS, so removing a pod shrinks the roster
    # without renumbering survivors — their flows (and ring state) stay put.
    home_nodes: Tuple[int, ...] = ()
    # snapshot the full DFAState every N completed periods (0 = never);
    # the replay window after a pod loss is at most this many periods
    snapshot_every_periods: int = 0
    # where stream()/ServingLoop write snapshots ("" = caller must pass
    # a directory explicitly to enable snapshotting)
    snapshot_dir: str = ""
    # keep-last-k snapshot GC (checkpoint.save's ``keep``)
    snapshot_keep: int = 3
    # -- continuous online serving (launch.serving) ----------------------
    # offered event rate the trace-replay source feeds the serving loop,
    # in events/second across the whole mesh; 0 = line rate (exactly one
    # full event batch per period, no queueing)
    serve_offered_eps: float = 0.0
    # per-period latency budget (the SLO) in µs; 0 = monitoring_period_us
    serve_budget_us: int = 0
    # host-side ingest queue capacity in events, on top of the in-flight
    # period batch; 0 = no carry-over queue (arrivals beyond one batch
    # are dropped the period they arrive — per-period drop accounting is
    # then exact by construction)
    serve_queue_events: int = 0
    # which events to shed when arrivals overflow the host queue:
    #   "newest" — tail drop: the just-arrived events are discarded
    #   "oldest" — head drop: evict the oldest queued events to admit
    #              the new ones (freshness-biased telemetry)
    drop_policy: str = "newest"
    # -- transport fault injection (data.faults) -------------------------
    # optional data.faults.FaultSpec applied between translation and
    # collector ingest (the lossy RDMA segment). Typed Any so configs
    # stays import-light; FaultSpec is frozen, keeping the config
    # hashable/jit-static. None = fault path compiled out entirely.
    fault_spec: Optional[Any] = None
    # what launch.elastic does when re-homing hits an unsplittable ring
    # slot (two live flows in one slot with different HRW winners):
    #   "fail" — raise with the collision count (default: fail loud)
    #   "warn" — count + warnings.warn, move the slot by its first entry
    rehome_collision_policy: str = "fail"

    def serve_budget_resolved_us(self) -> int:
        """The serving loop's per-period SLO (falls back to the paper's
        monitoring period)."""
        return self.serve_budget_us or self.monitoring_period_us

    def reporter_table_slots(self) -> int:
        """Per-port Marina table size (falls back to flows_per_shard)."""
        return self.reporter_slots or self.flows_per_shard

    def ring_region_bytes(self) -> int:
        """Shard-local collector ring region footprint (entries+validity)."""
        return self.flows_per_shard * self.history * (
            self.payload_words * 4 + 4)

    def total_flows(self, shards: int) -> int:
        return self.flows_per_shard * shards


@dataclass(frozen=True)
class TrainConfig:
    """Training-driver configuration."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_accum: int = 1
    seed: int = 0
    # fault tolerance
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    # distributed optimization
    grad_compression: str = "none"    # "none" | "int8_ef"
    donate_state: bool = True


@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh description; the production meshes are fixed."""

    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes
