"""deepseek-v3-671b — MLA attention, 1 shared + 256 routed top-8 MoE, MTP.
First 3 layers use a dense FFN (width 18432) per the paper.
opt_state_dtype bf16 so param+Adam state fits 512 x 16 GB HBM (DESIGN.md §4).
[arXiv:2412.19437; hf]"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                      # dense layers' FFN width
    vocab_size=129280,
    head_dim=128,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        first_moe_layer=3,
        d_ff_dense=18432,
        capacity_factor=1.25,
        routed_scaling_factor=2.5,
        score_func="sigmoid",
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    opt_state_dtype="bfloat16",
    source="arXiv:2412.19437",
)

REDUCED = CONFIG.replace(
    name="deepseek-v3-671b-reduced",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=192,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                  num_shared_experts=1, d_ff_shared=64, first_moe_layer=1,
                  d_ff_dense=192, capacity_factor=2.0,
                  routed_scaling_factor=2.5, score_func="sigmoid"),
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    mtp_depth=1,
    opt_state_dtype="float32",
    remat="none",
)
