"""The paper's own DFA system configuration (defaults = Tofino deployment).

PAPER      — faithful Tofino-scale config: 2^17 flows/shard, 10-entry ring,
             64 B payload, 20 ms monitoring period.
REDUCED    — CPU-testable miniature with the same structure.
"""
from repro.configs.base import DFAConfig

PAPER = DFAConfig()

REDUCED = DFAConfig(
    flows_per_shard=256,
    history=10,
    payload_words=16,
    feature_words=8,
    monitoring_period_us=20_000,
    logstar_bits=7,
    event_block=128,
    report_capacity=128,
    derived_dim=96,
    flow_tile=64,
)
