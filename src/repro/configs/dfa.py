"""The paper's own DFA system configuration (defaults = Tofino deployment).

PAPER      — faithful Tofino-scale config: 2^17 flows/shard, 10-entry ring,
             64 B payload, 20 ms monitoring period. At this scale the ring
             region is ~84 MB/shard, so gather_variant="auto" resolves to
             the HBM-resident tiled kernel (ring stays in HBM, VMEM holds
             only double-buffered report tiles).
REDUCED    — CPU-testable miniature with the same structure; its ~170 KB
             ring region fits VMEM, so auto resolves to the full-block
             kernel.
"""
import dataclasses

from repro.configs.base import DFAConfig

PAPER = DFAConfig(
    gather_variant="auto",     # budget heuristic -> "hbm" at 2^17 flows
    vmem_budget_mb=16,         # TPU v4/v5e per-core VMEM
)

REDUCED = DFAConfig(
    flows_per_shard=256,
    history=10,
    payload_words=16,
    feature_words=8,
    monitoring_period_us=20_000,
    logstar_bits=7,
    event_block=128,
    report_capacity=128,
    derived_dim=96,
    flow_tile=64,
    gather_variant="auto",     # budget heuristic -> "full" at 256 flows
    vmem_budget_mb=16,
    event_tile=64,             # multiple event tiles per 128-event block
)

# REDUCED shapes forced onto the Tofino-scale memory strategy: the
# equivalence suite / benchmarks use this to exercise the HBM-tiled path
# without allocating a 2^17-flow ring.
REDUCED_HBM = dataclasses.replace(REDUCED, gather_variant="hbm")

# REDUCED with the software-pipelined streaming driver: period t's enrich
# half overlaps period t+1's ingest half (run_periods_overlapped).
REDUCED_OVERLAP = dataclasses.replace(REDUCED, overlap_periods=True)

# ... and with the immediate-inference hook armed: enriched features feed
# a linear verdict head (models.registry.get_flow_head) inside the same
# scan body — the paper's "features land on the accelerator and are
# consumed in the same monitoring period" headline, end to end.
REDUCED_INFER = dataclasses.replace(REDUCED, overlap_periods=True,
                                    inference_head="linear",
                                    inference_classes=8)

# REDUCED scaled to the 2D (pod, shard) mesh: flow homes are hashed into
# the global ring keyspace (flow_home="hash"), each pod owns a disjoint
# set of reporter ports (2 per pod here), and report delivery is the
# two-stage intra-pod/cross-pod exchange. Pair with
# launch.mesh.make_dfa_mesh(pods=2, ...); reporter tables are pinned to
# 128 slots per port so the merged reporter state is independent of how
# the mesh factors the same port set.
REDUCED_MULTIPOD = dataclasses.replace(
    REDUCED,
    flow_home="hash",
    pods=2,
    ports_per_pod=2,
    reporter_slots=128,
    flows_per_shard=128,
    port_report_capacity=32,
)

# REDUCED_MULTIPOD under the widened V2 wire schema (u16 reporter_id /
# seq — repro.core.wire.V2): the same 2D mesh structure with the 256-port
# cap lifted. The per-port shapes shrink so wide-port meshes (hundreds of
# virtual ports per device) stay CPU-testable; the V2 differential suite
# overrides ports_per_pod per grid point.
REDUCED_MULTIPOD_V2 = dataclasses.replace(
    REDUCED_MULTIPOD,
    wire_format="v2",
    reporter_slots=8,
    flows_per_shard=2048,
    port_report_capacity=2,
)
