"""Assigned input shapes. Each LM-family architecture is exercised on all
four shapes (decode/long shapes lower ``serve_step``, not ``train_step``)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Architectures whose every attention path is quadratic cannot run the 500k
# decode cell (no sub-quadratic path exists in the architecture). Recorded as
# SKIP in the roofline table; see DESIGN.md §5.
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def shape_applicable(family: str, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return family in SUBQUADRATIC_FAMILIES
    return True
