"""qwen1.5-32b — dense, near-MHA (kv=40), QKV bias.
[hf:Qwen/Qwen1.5-0.5B family scaling; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B (family)",
)

REDUCED = CONFIG.replace(
    name="qwen1.5-32b-reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=192,
    vocab_size=256, head_dim=16, remat="none",
)
