"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (DFAConfig, MeshConfig, MLAConfig, MoEConfig,
                                ModelConfig, SSMConfig, TrainConfig)
from repro.configs.shapes import (SHAPES, ShapeConfig, shape_applicable)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "granite-3-2b": "granite_3_2b",
    "qwen1.5-32b": "qwen15_32b",
    "qwen3-14b": "qwen3_14b",
    "granite-20b": "granite_20b",
    "zamba2-2.7b": "zamba2_2p7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-3b": "rwkv6_3b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def get_dfa_config(reduced: bool = False) -> DFAConfig:
    mod = importlib.import_module("repro.configs.dfa")
    return mod.REDUCED if reduced else mod.PAPER


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "DFAConfig", "MeshConfig", "MLAConfig", "MoEConfig", "ModelConfig",
    "SSMConfig", "TrainConfig", "ShapeConfig", "SHAPES",
    "shape_applicable", "list_archs", "get_config", "get_dfa_config",
    "get_shape",
]
