"""llama4-scout-17b-16e — MoE 16 experts top-1 + shared expert, early fusion.
Early-fusion multimodality is stubbed the same way as llava (prefix embeds).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,                        # dense-path FFN width
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
        capacity_factor=1.25,
        score_func="sigmoid",
    ),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

REDUCED = CONFIG.replace(
    name="llama4-scout-17b-a16e-reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128,
                  num_shared_experts=1, d_ff_shared=128,
                  capacity_factor=2.0, score_func="sigmoid"),
    remat="none",
)
