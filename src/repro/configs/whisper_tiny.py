"""whisper-tiny — encoder-decoder; conv/mel frontend is a STUB (input_specs
provides precomputed frame embeddings at d_model).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,                     # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    encdec=EncDecConfig(num_encoder_layers=4, num_frames=1500),
    source="arXiv:2212.04356",
)

REDUCED = CONFIG.replace(
    name="whisper-tiny-reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    encdec=EncDecConfig(num_encoder_layers=2, num_frames=32),
    remat="none",
)
