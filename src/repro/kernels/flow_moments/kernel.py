"""flow_moments — per-flow Table-I register accumulation (Pallas TPU).

The Tofino stateful-ALU scatter (one random 32-bit register update per
packet) has no TPU equivalent; the TPU-native reformulation turns the
scatter into a ONE-HOT MATMUL on the MXU:

    regs[f] += sum_e onehot[f, e] * deltas[e]        (mod 2^32)

Exactness trick: u32 deltas are split into u16 halves and accumulated as
f32 matmuls — with EVENT_BLOCK <= 256 each partial sum is < 2^24, so the
f32 mantissa holds it exactly; the halves are recombined in u32 where the
natural wraparound restores P4's mod-2^32 register semantics.

Grid: (flow_tiles, event_blocks). The register tile lives in VMEM across
the inner event dimension (revisited output block, initialized at block 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EVENT_BLOCK = 256       # <= 256 keeps u16-half partial sums exact in f32
N_REG = 7
REG_PAD = 8             # lane-friendly padded register count


def _kernel(slots_ref, dlo_ref, dhi_ref, regs_in_ref, regs_out_ref, *,
            flow_tile: int):
    ft = pl.program_id(0)
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        regs_out_ref[...] = regs_in_ref[...]

    slots = slots_ref[...]                                # (E,) i32 global
    base = ft * flow_tile
    local = slots - base                                  # (E,)
    flows = jax.lax.broadcasted_iota(jnp.int32, (flow_tile, EVENT_BLOCK), 0)
    onehot = (flows == local[None, :]).astype(jnp.float32)  # (F_t, E)
    acc_lo = jnp.dot(onehot, dlo_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)   # (F_t, 8)
    acc_hi = jnp.dot(onehot, dhi_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    add = (acc_lo.astype(jnp.uint32)
           + (acc_hi.astype(jnp.uint32) << 16))
    regs_out_ref[...] = regs_out_ref[...] + add


@functools.partial(jax.jit, static_argnames=("flow_tile", "interpret"))
def flow_moments_pallas(regs: jax.Array, slots: jax.Array,
                        deltas: jax.Array, valid: jax.Array,
                        flow_tile: int = 512,
                        interpret: bool = True) -> jax.Array:
    """regs: (F, 7) u32; slots: (E,) i32; deltas: (E, 7) u32; valid: (E,).

    Returns updated regs. F % flow_tile == 0; E padded to EVENT_BLOCK.
    """
    F, _ = regs.shape
    E = slots.shape[0]
    assert F % flow_tile == 0, (F, flow_tile)
    Ep = ((E + EVENT_BLOCK - 1) // EVENT_BLOCK) * EVENT_BLOCK
    slots = jnp.where(valid, slots, -1)                   # -1 never matches
    slots = jnp.pad(slots, (0, Ep - E), constant_values=-1)
    deltas = jnp.pad(deltas, ((0, Ep - E), (0, REG_PAD - N_REG)))
    dlo = (deltas & jnp.uint32(0xFFFF)).astype(jnp.int32)
    dhi = (deltas >> 16).astype(jnp.int32)
    regs_p = jnp.pad(regs, ((0, 0), (0, REG_PAD - N_REG)))

    grid = (F // flow_tile, Ep // EVENT_BLOCK)
    out = pl.pallas_call(
        functools.partial(_kernel, flow_tile=flow_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((EVENT_BLOCK,), lambda f, e: (e,)),
            pl.BlockSpec((EVENT_BLOCK, REG_PAD), lambda f, e: (e, 0)),
            pl.BlockSpec((EVENT_BLOCK, REG_PAD), lambda f, e: (e, 0)),
            pl.BlockSpec((flow_tile, REG_PAD), lambda f, e: (f, 0)),
        ],
        out_specs=pl.BlockSpec((flow_tile, REG_PAD), lambda f, e: (f, 0)),
        out_shape=jax.ShapeDtypeStruct((F, REG_PAD), jnp.uint32),
        interpret=interpret,
    )(slots, dlo, dhi, regs_p)
    return out[:, :N_REG]
