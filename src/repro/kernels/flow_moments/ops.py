"""Dispatching wrapper: Pallas on TPU, interpret-mode Pallas or the jnp
oracle elsewhere. This is the ``accumulate_fn`` plugged into
repro.core.reporter.ingest."""
from __future__ import annotations

import jax

from repro.kernels.flow_moments.kernel import flow_moments_pallas
from repro.kernels.flow_moments.ref import flow_moments_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flow_moments(regs, slots, deltas, valid, flow_tile: int = 512,
                 force: str = "auto"):
    """force: "auto" | "pallas" | "interpret" | "ref"."""
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return flow_moments_ref(regs, slots, deltas, valid)
    interpret = (force == "interpret") or not _on_tpu()
    ft = min(flow_tile, regs.shape[0])
    while regs.shape[0] % ft:
        ft -= 1
    return flow_moments_pallas(regs, slots, deltas, valid, flow_tile=ft,
                               interpret=interpret)
