"""Registry client for flow_moments — the ``accumulate_fn`` plugged into
repro.core.reporter.ingest. Backend selection and tile negotiation live in
repro.kernels.dispatch."""
from __future__ import annotations

from repro.kernels import dispatch


def flow_moments(regs, slots, deltas, valid, flow_tile=None,
                 backend=None, cfg=None, force=None):
    """regs: (F, 7) u32; slots: (E,) i32; deltas: (E, 7) u32; valid: (E,).

    An explicit ``flow_tile`` wins; ``cfg.flow_tile`` is only the default.
    ``force`` is the legacy name for ``backend`` (kept for callers)."""
    b, impl = dispatch.lookup("flow_moments", backend or force, cfg)
    if b == "ref":
        return impl(regs, slots, deltas, valid)
    if flow_tile is None:
        flow_tile = cfg.flow_tile if cfg is not None else 512
    ft = dispatch.negotiate_tile(regs.shape[0], flow_tile)
    return impl(regs, slots, deltas, valid, flow_tile=ft,
                interpret=dispatch.interpret_flag(b))
