"""Pure-jnp oracle for flow_moments: scatter-add with u32 wraparound."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flow_moments_ref(regs: jax.Array, slots: jax.Array, deltas: jax.Array,
                     valid: jax.Array) -> jax.Array:
    F = regs.shape[0]
    idx = jnp.where(valid, slots, F)
    return regs.at[idx].add(deltas.astype(jnp.uint32), mode="drop")
