"""Oracle for derived_features: repro.core.enrich.derive_ref."""
from repro.core.enrich import derive_ref as derived_features_ref  # noqa: F401
