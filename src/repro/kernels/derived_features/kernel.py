"""derived_features — the collector's enrichment stage (Pallas TPU).

The paper runs Marina's ~100 derived-feature computation "on CUDA cores";
here one VPU-bound Pallas kernel decodes the Table-I moment registers of a
(flow_tile, history, 16-word) collector tile into the derived feature block
(flow_tile, derived_dim). All selection (newest entry) is done with
iota/one-hot — no gathers. The math is identical to
repro.core.enrich (the jnp oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import wire as WIRE
from repro.core.enrich import PER_ENTRY, entry_features

WORDS = 16


def derive_block(entries: jax.Array, valid: jax.Array,
                 derived_dim: int,
                 wire: WIRE.WireFormat = WIRE.V1) -> jax.Array:
    """(T, H, 16) u32 entries + (T, H) bool -> (T, derived_dim) f32.

    The feature math shared by this kernel and the fused gather_enrich
    kernel; all selection (newest entry) is iota/one-hot — no gathers —
    and the hist_idx decode comes off the wire schema's Field helpers
    (plain u32 bit ops), so it lowers cleanly inside any Pallas body.
    Mirrors repro.core.enrich.derive_ref.
    """
    T, H, _ = entries.shape
    stats = entries[:, :, wire.payload_stats_slice].astype(jnp.uint32)
    hist_idx = wire.payload_hist.extract(entries).astype(jnp.float32)
    feats = entry_features(stats)                    # (T, H, PER_ENTRY)
    vmask = valid.astype(jnp.float32)[..., None]
    feats = feats * vmask
    nvalid = jnp.maximum(valid.sum(-1, keepdims=True), 1).astype(
        jnp.float32)                                 # (T, 1)
    count = jnp.where(valid, stats[..., 0], 0)       # (T, H)
    newest = jnp.argmax(count, axis=-1)              # (T,)
    sel = (jax.lax.broadcasted_iota(jnp.int32, (T, H), 1)
           == newest[:, None]).astype(jnp.float32)   # (T, H) one-hot
    newest_f = jnp.sum(feats * sel[..., None], axis=1)       # (T, PER_ENTRY)
    mean_w = feats.sum(1) / nvalid
    # two-pass (masked) variance — same formulation as enrich.derive_ref
    dev = (feats - mean_w[:, None, :]) * vmask
    var_w = (dev * dev).sum(1) / nvalid
    std_w = jnp.sqrt(var_w)
    delta = newest_f - mean_w
    maxhist = jnp.max(jnp.where(valid, hist_idx, 0.0), axis=-1,
                      keepdims=True)
    out = jnp.concatenate([newest_f, mean_w, std_w, delta, nvalid,
                           maxhist], axis=-1)
    D = out.shape[-1]
    if D < derived_dim:
        out = jnp.pad(out, ((0, 0), (0, derived_dim - D)))
    return out[:, :derived_dim]


def _kernel(entries_ref, valid_ref, out_ref, *, derived_dim: int,
            wire: WIRE.WireFormat):
    out_ref[...] = derive_block(entries_ref[...], valid_ref[...] > 0,
                                derived_dim, wire=wire)


@functools.partial(jax.jit,
                   static_argnames=("derived_dim", "flow_tile", "interpret",
                                    "wire"))
def derived_features_pallas(entries: jax.Array, valid: jax.Array,
                            derived_dim: int = 96, flow_tile: int = 256,
                            interpret: bool = True,
                            wire: WIRE.WireFormat = WIRE.V1) -> jax.Array:
    """entries: (F, H, 16) u32; valid: (F, H) bool -> (F, derived_dim) f32."""
    F, H, W = entries.shape
    assert F % flow_tile == 0 and W == WORDS

    return pl.pallas_call(
        functools.partial(_kernel, derived_dim=derived_dim, wire=wire),
        grid=(F // flow_tile,),
        in_specs=[
            pl.BlockSpec((flow_tile, H, WORDS), lambda f: (f, 0, 0)),
            pl.BlockSpec((flow_tile, H), lambda f: (f, 0)),
        ],
        out_specs=pl.BlockSpec((flow_tile, derived_dim), lambda f: (f, 0)),
        out_shape=jax.ShapeDtypeStruct((F, derived_dim), jnp.float32),
        interpret=interpret,
    )(entries, valid.astype(jnp.int32))
