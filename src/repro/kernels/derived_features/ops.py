"""Dispatching wrapper for derived_features."""
from __future__ import annotations

import jax

from repro.configs.base import DFAConfig
from repro.kernels.derived_features.kernel import derived_features_pallas
from repro.kernels.derived_features.ref import derived_features_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def derived_features(entries, valid, cfg: DFAConfig, force: str = "auto"):
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return derived_features_ref(entries, valid, cfg)
    interpret = (force == "interpret") or not _on_tpu()
    ft = min(cfg.flow_tile, entries.shape[0])
    while entries.shape[0] % ft:
        ft -= 1
    return derived_features_pallas(entries, valid,
                                   derived_dim=cfg.derived_dim,
                                   flow_tile=ft, interpret=interpret)
