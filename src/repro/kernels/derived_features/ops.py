"""Registry client for derived_features (the enrichment stage)."""
from __future__ import annotations

from repro.core import wire as WIRE
from repro.kernels import dispatch


def derived_features(entries, valid, cfg, backend=None, force=None):
    """entries: (F, H, 16) u32; valid: (F, H) -> (F, derived_dim) f32.

    ``force`` is the legacy name for ``backend`` (kept for callers)."""
    b, impl = dispatch.lookup("derived_features", backend or force, cfg)
    if b == "ref":
        return impl(entries, valid, cfg)
    ft = dispatch.negotiate_tile(entries.shape[0], cfg.flow_tile)
    return impl(entries, valid, derived_dim=cfg.derived_dim, flow_tile=ft,
                interpret=dispatch.interpret_flag(b),
                wire=WIRE.resolve(cfg))
