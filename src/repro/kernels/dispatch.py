"""Unified kernel backend registry — the dispatch layer for the DFA hot path.

Every kernel family registers up to three implementations:

* ``ref``       — pure-jnp oracle (portable; bit-exact semantics contract)
* ``pallas``    — compiled Pallas TPU kernel
* ``interpret`` — the same Pallas kernel run by the Pallas interpreter
                  (works on CPU; CI uses it for equivalence vs ``ref``)

Families shipped here: ``flow_moments`` (reporter accumulate),
``ring_scatter`` (collector placement), ``derived_features`` (enrichment),
``gather_enrich`` (fused history-gather + enrichment) and
``flash_attention`` (model serving path).

Backend selection precedence (strongest first):

1. an explicit ``backend=`` argument at the call site (``"auto"`` defers)
2. the ``REPRO_KERNEL_BACKEND`` environment variable
3. ``DFAConfig.kernel_backend``
4. auto: ``pallas`` on TPU, ``ref`` everywhere else

An unrecognized value raises ValueError listing the registered backends no
matter where it sits in the precedence chain — a typo'd env var must fail
loudly even at call sites that pass an explicit ``backend=``, not silently
lose to the stronger setting.

``gather_enrich`` additionally carries a memory-strategy *variant*: the
``full`` kernel pins the shard's whole (F, H, 16) ring region in VMEM,
the ``hbm`` kernel keeps it HBM-resident and DMAs per-report tiles into
double-buffered scratch. ``resolve_gather_variant`` picks one by a
VMEM-budget heuristic (full while the ring region fits, hbm beyond),
overridable via ``DFAConfig.gather_variant`` or ``REPRO_GATHER_VARIANT``.

``ingest_update`` (reporter-side fused sort-once / segment-reduce ingest)
mirrors that scheme on the *event* axis: the ``block`` kernel streams the
sorted event arrays through BlockSpec-tiled VMEM blocks, the ``hbm``
kernel keeps them HBM-resident (``pltpu.ANY``) and double-buffers
per-``event_tile`` DMA slices with scalar-prefetched run-boundary
metadata, so events_per_shard can grow to 2^20 with VMEM = O(event_tile).
``resolve_ingest_variant`` picks block while the whole sorted stream fits
the VMEM budget, overridable via ``DFAConfig.ingest_variant`` or
``REPRO_INGEST_VARIANT``.

Both variant resolvers — and the ``resolve_event_tile`` /
``resolve_report_tile`` helpers the ops wrappers call — consult the
measurement-driven tuned-config registry (``repro.kernels.tuning``,
armed via ``REPRO_TUNING_REGISTRY`` / ``DFAConfig.tuning_registry``)
INSIDE their heuristic tier: a sweep-measured winner for the exact
(shape, backend) beats the VMEM model, while any explicit setting
(argument, env var, non-"auto" config attr) still beats the measurement.

Resolution happens at trace time: a step traced under one setting keeps it
until re-traced (jit caches are keyed on shapes, not on this env var).
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.configs import env as ENV

BACKENDS = ENV.KERNEL_BACKEND.choices
ENV_VAR = ENV.KERNEL_BACKEND.name

GATHER_VARIANTS = ENV.GATHER_VARIANT.choices
GATHER_ENV_VAR = ENV.GATHER_VARIANT.name
INGEST_VARIANTS = ENV.INGEST_VARIANT.choices
INGEST_ENV_VAR = ENV.INGEST_VARIANT.name
WORDS = 16               # collector entry words (64 B RoCEv2 payload)
EVENT_WORDS = 5          # sorted-event-stream words: slot/ts/ps/base_ts/first
VMEM_BYTES_PER_MB = 1 << 20

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_BUILTIN_LOADED = False


def register(family: str, backend: str, fn: Optional[Callable] = None):
    """Register ``fn`` as ``family``'s ``backend`` implementation.

    Usable directly (``register("fam", "ref", impl)``) or as a decorator
    (``@register("fam", "ref")``). Re-registration overwrites.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")

    def _set(f: Callable) -> Callable:
        _REGISTRY.setdefault(family, {})[backend] = f
        return f

    return _set(fn) if fn is not None else _set


def families() -> List[str]:
    _ensure_builtin()
    return sorted(_REGISTRY)


def implementations(family: str) -> List[str]:
    _ensure_builtin()
    return sorted(_REGISTRY.get(family, {}))


def negotiate_tile(size: int, preferred: int) -> int:
    """Largest tile <= ``preferred`` that divides ``size`` exactly (>= 1).

    Every Pallas family tiles its leading (flow/report) dimension; this is
    the single negotiation rule all ops.py wrappers share.
    """
    size, preferred = int(size), int(preferred)
    t = max(1, min(preferred, size))
    while size % t:
        t -= 1
    return t


def _check_choice(value: str, valid: Tuple[str, ...], source: str) -> None:
    if value not in valid:
        raise ValueError(
            f"unknown value {value!r} from {source}; registered: "
            f"{list(valid)} (or 'auto')")


def _resolve_choice(explicit: Optional[str], cfg, *, env_var: str,
                    choices: Tuple[str, ...], cfg_attr: str, heuristic,
                    arg_source: str) -> str:
    """The one selection-precedence ladder every knob shares: explicit
    argument > ``env_var`` > ``DFAConfig.<cfg_attr>`` > ``heuristic()``.

    The env var is read through the ``repro.configs.env`` registry, so a
    malformed value raises even when a stronger setting (explicit
    argument) would win: a typo'd env var silently losing the precedence
    fight is indistinguishable from it working.
    """
    env = ENV.read_choice(env_var)       # fail-loud registry validation
    if explicit in (None, "auto", ""):
        cfg_value = (getattr(cfg, cfg_attr, "auto")
                     if cfg is not None else "auto") or "auto"
        if env is not None:
            explicit = env
        elif cfg_value != "auto":
            _check_choice(cfg_value, choices, f"DFAConfig.{cfg_attr}")
            explicit = cfg_value
        else:
            explicit = heuristic()
    _check_choice(explicit, choices, arg_source)
    return explicit


def resolve_backend(backend: Optional[str] = None, cfg=None) -> str:
    """Apply the selection precedence; returns one of BACKENDS (auto:
    ``pallas`` on TPU, ``ref`` everywhere else)."""
    return _resolve_choice(
        backend, cfg, env_var=ENV_VAR, choices=BACKENDS,
        cfg_attr="kernel_backend",
        heuristic=lambda: ("pallas" if jax.default_backend() == "tpu"
                           else "ref"),
        arg_source="backend= argument")


# -- measurement-driven tuned-config registry -------------------------------

def _tuned_value(cfg, knob: str, key):
    """Consult the tuned-config registry (kernels.tuning), keyed by the
    RESOLVED backend — a winner measured under the interpreter says
    nothing about compiled pallas. Returns None when no registry is
    armed or no exact (knob, backend, key) measurement exists, letting
    the VMEM heuristic decide. Sits INSIDE the heuristic tier, so an
    explicit argument, env var or explicit DFAConfig attr still wins."""
    from repro.kernels import tuning  # lazy: dispatch stays import-light
    if tuning.resolve_path(cfg) is None:
        return None
    return tuning.lookup_value(cfg, knob, resolve_backend(None, cfg), key)


def _tuned_tile(cfg, knob: str, key, fallback: int) -> int:
    tuned = _tuned_value(cfg, knob, key)
    if tuned is None:
        return int(fallback)
    t = int(tuned)
    if t < 1:
        raise ValueError(
            f"tuned {knob} for key {tuple(key)} is {t}; tiles must be "
            ">= 1 — the registry file is corrupt")
    return t


def resolve_event_tile(cfg, events: int) -> int:
    """The ingest_update event tile: a tuned measurement for this event
    count beats the static ``DFAConfig.event_tile`` default (arming a
    registry is an explicit opt-in). Kernel-bound clamping stays with
    the caller (``clamp_tile``)."""
    return _tuned_tile(cfg, "ingest_update.event_tile", (int(events),),
                       int(getattr(cfg, "event_tile", 256)))


def resolve_report_tile(cfg, reports: int) -> int:
    """The gather_enrich report tile: a tuned measurement for this
    report count beats the static ``DFAConfig.flow_tile`` default."""
    return _tuned_tile(cfg, "gather_enrich.report_tile",
                       (int(reports),),
                       int(getattr(cfg, "flow_tile", 512)))


# -- gather_enrich memory-strategy variant ----------------------------------

def ring_vmem_bytes(flows: int, history: int, words: int = WORDS) -> int:
    """VMEM the full-block gather_enrich kernel pins for the shard ring
    region: (F, H, words) u32 entries + (F, H) i32 validity."""
    return flows * history * (words * 4 + 4)


def gather_vmem_bytes(variant: str, flows: int, history: int,
                      report_tile: int, derived_dim: int,
                      words: int = WORDS) -> int:
    """Estimated peak VMEM working set of one gather_enrich variant.

    full: whole ring region + one report-tile scratch pair + out tile.
    hbm:  two double-buffered report-tile scratch pairs + out tile —
          independent of F (the ring region stays in HBM).
    """
    tile = report_tile * history * (words * 4 + 4)   # entries + validity
    out = report_tile * derived_dim * 4
    if variant == "full":
        return ring_vmem_bytes(flows, history, words) + tile + out
    if variant == "hbm":
        return 2 * tile + out
    raise ValueError(f"unknown gather variant {variant!r}; "
                     f"registered: {list(GATHER_VARIANTS)}")


def resolve_gather_variant(variant: Optional[str], cfg, flows: int,
                           history: int, report_tile: int,
                           derived_dim: int) -> str:
    """full-block while its working set fits the VMEM budget, hbm beyond.

    Same precedence (and same fail-loud env validation) as backends:
    explicit ``variant=`` argument > ``REPRO_GATHER_VARIANT`` >
    ``DFAConfig.gather_variant`` > tuned-config registry (an exact
    measurement for this shape, when one is armed) > the budget
    heuristic against ``DFAConfig.vmem_budget_mb``.
    """
    def heuristic():
        tuned = _tuned_value(cfg, "gather_enrich.variant",
                             (flows, history, report_tile, derived_dim))
        if tuned is not None:
            _check_choice(str(tuned), GATHER_VARIANTS, "tuning registry")
            return str(tuned)
        budget = int(getattr(cfg, "vmem_budget_mb", 16)
                     ) * VMEM_BYTES_PER_MB
        need = gather_vmem_bytes(
            "full", flows, history, report_tile, derived_dim,
            words=int(getattr(cfg, "payload_words", WORDS)))
        return "full" if need <= budget else "hbm"

    return _resolve_choice(
        variant, cfg, env_var=GATHER_ENV_VAR, choices=GATHER_VARIANTS,
        cfg_attr="gather_variant", heuristic=heuristic,
        arg_source="variant= argument")


# -- ingest_update event-stream variant -------------------------------------

def ingest_vmem_bytes(variant: str, events: int, event_tile: int) -> int:
    """Estimated peak VMEM working set of one ingest_update variant.

    Both kernels share the per-tile working set: the five sorted-stream
    input words, the (event_tile, event_tile) segment mask the MXU
    reduction contracts against, and the u16-half / output tiles.

    block: the whole padded sorted stream is staged through VMEM blocks
           by the Pallas pipeline (conservatively modeled as resident).
    hbm:   two double-buffered event-tile scratch slots — independent of
           E (the sorted stream stays in HBM), which is what lets one
           shard ingest the 2^20-events-per-period blocks.
    """
    tile_ws = (event_tile * EVENT_WORDS * 4          # input tile words
               + event_tile * event_tile * 4         # segment mask (f32)
               + 3 * event_tile * 8 * 4)             # lo/hi halves + out
    if variant == "block":
        return events * EVENT_WORDS * 4 + tile_ws
    if variant == "hbm":
        return 2 * event_tile * EVENT_WORDS * 4 + tile_ws
    raise ValueError(f"unknown ingest variant {variant!r}; "
                     f"registered: {list(INGEST_VARIANTS)}")


def resolve_ingest_variant(variant: Optional[str], cfg, events: int,
                           event_tile: int) -> str:
    """block while the sorted event stream fits the VMEM budget, hbm
    beyond. Same precedence (and same fail-loud env validation) as the
    gather variant: explicit ``variant=`` argument >
    ``REPRO_INGEST_VARIANT`` > ``DFAConfig.ingest_variant`` >
    tuned-config registry (an exact measurement for this event count,
    when one is armed) > the budget heuristic against
    ``DFAConfig.vmem_budget_mb``."""
    def heuristic():
        tuned = _tuned_value(cfg, "ingest_update.variant",
                             (events,))
        if tuned is not None:
            _check_choice(str(tuned), INGEST_VARIANTS, "tuning registry")
            return str(tuned)
        budget = int(getattr(cfg, "vmem_budget_mb", 16)
                     ) * VMEM_BYTES_PER_MB
        need = ingest_vmem_bytes("block", events, event_tile)
        return "block" if need <= budget else "hbm"

    return _resolve_choice(
        variant, cfg, env_var=INGEST_ENV_VAR, choices=INGEST_VARIANTS,
        cfg_attr="ingest_variant", heuristic=heuristic,
        arg_source="variant= argument")


def interpret_flag(backend: str) -> bool:
    """Whether a Pallas impl must run interpreted (also forced off-TPU, so a
    'pallas' request never feeds Mosaic a CPU target). The downgrade is
    loud: interpreter-mode timings must never be mistaken for compiled
    pallas numbers."""
    if backend == "interpret":
        return True
    if jax.default_backend() != "tpu":
        warnings.warn(
            f"kernel backend 'pallas' requested on "
            f"{jax.default_backend()!r}: running in Pallas INTERPRETER "
            "mode (orders of magnitude slower; not compiled-kernel "
            "performance)", RuntimeWarning, stacklevel=3)
        return True
    return False


def lookup(family: str, backend: Optional[str] = None,
           cfg=None) -> Tuple[str, Callable]:
    """Resolve (backend_name, implementation) for one call site."""
    _ensure_builtin()
    if family not in _REGISTRY:
        raise KeyError(f"unknown kernel family {family!r}; "
                       f"known: {sorted(_REGISTRY)}")
    b = resolve_backend(backend, cfg)
    impls = _REGISTRY[family]
    if b not in impls:
        raise KeyError(f"family {family!r} has no {b!r} implementation "
                       f"(has: {sorted(impls)})")
    return b, impls[b]


def _ensure_builtin() -> None:
    """Lazy-register the in-tree families (import cycle-free: kernel/ref
    modules never import ops.py or this module)."""
    global _BUILTIN_LOADED
    if _BUILTIN_LOADED:
        return
    from repro.kernels.derived_features import kernel as df_k
    from repro.kernels.derived_features import ref as df_r
    from repro.kernels.flash_attention import kernel as fa_k
    from repro.kernels.flash_attention import ref as fa_r
    from repro.kernels.flow_moments import kernel as fm_k
    from repro.kernels.flow_moments import ref as fm_r
    from repro.kernels.gather_enrich import kernel as ge_k
    from repro.kernels.gather_enrich import ref as ge_r
    from repro.kernels.ingest_update import kernel as iu_k
    from repro.kernels.ingest_update import ref as iu_r
    from repro.kernels.ring_scatter import kernel as rs_k
    from repro.kernels.ring_scatter import ref as rs_r

    register("flow_moments", "ref", fm_r.flow_moments_ref)
    register("flow_moments", "pallas", fm_k.flow_moments_pallas)
    register("flow_moments", "interpret", fm_k.flow_moments_pallas)

    register("ring_scatter", "ref", rs_r.ring_scatter_ref)
    register("ring_scatter", "pallas", rs_k.ring_scatter_pallas)
    register("ring_scatter", "interpret", rs_k.ring_scatter_pallas)

    register("derived_features", "ref", df_r.derived_features_ref)
    register("derived_features", "pallas", df_k.derived_features_pallas)
    register("derived_features", "interpret", df_k.derived_features_pallas)

    register("gather_enrich", "ref", ge_r.gather_enrich_ref)
    register("gather_enrich", "pallas", ge_k.gather_enrich_pallas)
    register("gather_enrich", "interpret", ge_k.gather_enrich_pallas)

    # HBM-resident memory-strategy variant (same semantics, ring region
    # stays in HBM; selected by resolve_gather_variant)
    register("gather_enrich_hbm", "ref", ge_r.gather_enrich_ref)
    register("gather_enrich_hbm", "pallas", ge_k.gather_enrich_hbm_pallas)
    register("gather_enrich_hbm", "interpret",
             ge_k.gather_enrich_hbm_pallas)

    # reporter-side fused ingest (sort-once, segment-reduce); the ref
    # backend keeps the pre-fusion multipass shape as the bitwise oracle
    register("ingest_update", "ref", iu_r.ingest_update_ref)
    register("ingest_update", "pallas", iu_k.ingest_update_pallas)
    register("ingest_update", "interpret", iu_k.ingest_update_pallas)

    # HBM-resident event-stream variant (same semantics, sorted stream
    # stays in HBM; selected by resolve_ingest_variant)
    register("ingest_update_hbm", "ref", iu_r.ingest_update_ref)
    register("ingest_update_hbm", "pallas", iu_k.ingest_update_hbm_pallas)
    register("ingest_update_hbm", "interpret",
             iu_k.ingest_update_hbm_pallas)

    register("flash_attention", "ref", fa_r.flash_attention_ref)
    register("flash_attention", "pallas", fa_k.flash_attention_pallas)
    register("flash_attention", "interpret", fa_k.flash_attention_pallas)

    # only after every family registered: a failed import above stays
    # retryable instead of leaving a partial registry behind
    _BUILTIN_LOADED = True
