"""Unified kernel backend registry — the dispatch layer for the DFA hot path.

Every kernel family registers up to three implementations:

* ``ref``       — pure-jnp oracle (portable; bit-exact semantics contract)
* ``pallas``    — compiled Pallas TPU kernel
* ``interpret`` — the same Pallas kernel run by the Pallas interpreter
                  (works on CPU; CI uses it for equivalence vs ``ref``)

Families shipped here: ``flow_moments`` (reporter accumulate),
``ring_scatter`` (collector placement), ``derived_features`` (enrichment),
``gather_enrich`` (fused history-gather + enrichment) and
``flash_attention`` (model serving path).

Backend selection precedence (strongest first):

1. an explicit ``backend=`` argument at the call site (``"auto"`` defers)
2. the ``REPRO_KERNEL_BACKEND`` environment variable
3. ``DFAConfig.kernel_backend``
4. auto: ``pallas`` on TPU, ``ref`` everywhere else

Resolution happens at trace time: a step traced under one setting keeps it
until re-traced (jit caches are keyed on shapes, not on this env var).
"""
from __future__ import annotations

import os
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax

BACKENDS = ("ref", "pallas", "interpret")
ENV_VAR = "REPRO_KERNEL_BACKEND"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_BUILTIN_LOADED = False


def register(family: str, backend: str, fn: Optional[Callable] = None):
    """Register ``fn`` as ``family``'s ``backend`` implementation.

    Usable directly (``register("fam", "ref", impl)``) or as a decorator
    (``@register("fam", "ref")``). Re-registration overwrites.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")

    def _set(f: Callable) -> Callable:
        _REGISTRY.setdefault(family, {})[backend] = f
        return f

    return _set(fn) if fn is not None else _set


def families() -> List[str]:
    _ensure_builtin()
    return sorted(_REGISTRY)


def implementations(family: str) -> List[str]:
    _ensure_builtin()
    return sorted(_REGISTRY.get(family, {}))


def negotiate_tile(size: int, preferred: int) -> int:
    """Largest tile <= ``preferred`` that divides ``size`` exactly (>= 1).

    Every Pallas family tiles its leading (flow/report) dimension; this is
    the single negotiation rule all ops.py wrappers share.
    """
    size, preferred = int(size), int(preferred)
    t = max(1, min(preferred, size))
    while size % t:
        t -= 1
    return t


def resolve_backend(backend: Optional[str] = None, cfg=None) -> str:
    """Apply the selection precedence; returns one of BACKENDS."""
    if backend in (None, "auto", ""):
        env = os.environ.get(ENV_VAR, "").strip().lower()
        cfg_backend = (getattr(cfg, "kernel_backend", "auto")
                       if cfg is not None else "auto") or "auto"
        if env not in ("", "auto"):
            backend = env
        elif cfg_backend != "auto":
            backend = cfg_backend
        else:
            backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of "
            f"{BACKENDS} or 'auto'")
    return backend


def interpret_flag(backend: str) -> bool:
    """Whether a Pallas impl must run interpreted (also forced off-TPU, so a
    'pallas' request never feeds Mosaic a CPU target). The downgrade is
    loud: interpreter-mode timings must never be mistaken for compiled
    pallas numbers."""
    if backend == "interpret":
        return True
    if jax.default_backend() != "tpu":
        warnings.warn(
            f"kernel backend 'pallas' requested on "
            f"{jax.default_backend()!r}: running in Pallas INTERPRETER "
            "mode (orders of magnitude slower; not compiled-kernel "
            "performance)", RuntimeWarning, stacklevel=3)
        return True
    return False


def lookup(family: str, backend: Optional[str] = None,
           cfg=None) -> Tuple[str, Callable]:
    """Resolve (backend_name, implementation) for one call site."""
    _ensure_builtin()
    if family not in _REGISTRY:
        raise KeyError(f"unknown kernel family {family!r}; "
                       f"known: {sorted(_REGISTRY)}")
    b = resolve_backend(backend, cfg)
    impls = _REGISTRY[family]
    if b not in impls:
        raise KeyError(f"family {family!r} has no {b!r} implementation "
                       f"(has: {sorted(impls)})")
    return b, impls[b]


def _ensure_builtin() -> None:
    """Lazy-register the in-tree families (import cycle-free: kernel/ref
    modules never import ops.py or this module)."""
    global _BUILTIN_LOADED
    if _BUILTIN_LOADED:
        return
    from repro.kernels.derived_features import kernel as df_k
    from repro.kernels.derived_features import ref as df_r
    from repro.kernels.flash_attention import kernel as fa_k
    from repro.kernels.flash_attention import ref as fa_r
    from repro.kernels.flow_moments import kernel as fm_k
    from repro.kernels.flow_moments import ref as fm_r
    from repro.kernels.gather_enrich import kernel as ge_k
    from repro.kernels.gather_enrich import ref as ge_r
    from repro.kernels.ring_scatter import kernel as rs_k
    from repro.kernels.ring_scatter import ref as rs_r

    register("flow_moments", "ref", fm_r.flow_moments_ref)
    register("flow_moments", "pallas", fm_k.flow_moments_pallas)
    register("flow_moments", "interpret", fm_k.flow_moments_pallas)

    register("ring_scatter", "ref", rs_r.ring_scatter_ref)
    register("ring_scatter", "pallas", rs_k.ring_scatter_pallas)
    register("ring_scatter", "interpret", rs_k.ring_scatter_pallas)

    register("derived_features", "ref", df_r.derived_features_ref)
    register("derived_features", "pallas", df_k.derived_features_pallas)
    register("derived_features", "interpret", df_k.derived_features_pallas)

    register("gather_enrich", "ref", ge_r.gather_enrich_ref)
    register("gather_enrich", "pallas", ge_k.gather_enrich_pallas)
    register("gather_enrich", "interpret", ge_k.gather_enrich_pallas)

    register("flash_attention", "ref", fa_r.flash_attention_ref)
    register("flash_attention", "pallas", fa_k.flash_attention_pallas)
    register("flash_attention", "interpret", fa_k.flash_attention_pallas)

    # only after every family registered: a failed import above stays
    # retryable instead of leaving a partial registry behind
    _BUILTIN_LOADED = True
