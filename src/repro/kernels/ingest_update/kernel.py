"""ingest_update — fused sort-once, segment-reduce reporter ingest (Pallas).

The multipass ingest processes every event block as ~6 separate jnp
passes: hash -> admit (gather + two scatters) -> resolve_iat (argsort +
inverse argsort) -> event_deltas (a materialized (E, 7) u32 array fed by
four log* pipelines) -> scatter-accumulate -> last_ts scatter. The fused
family keeps the one insight all of those already share — a stable sort
by slot makes each slot's events one contiguous, arrival-ordered run —
and does everything else in a single pass over the sorted stream:

* per-event IAT / first-packet flags fall out of the run boundaries
  (run head reads the last_ts register, everyone else reads the
  in-block predecessor);
* the seven Table-I deltas are formed INLINE and segment-reduced per
  slot run inside the kernel — the per-event (E, 7) delta array exists
  only as a VMEM tile, never in HBM;
* one scatter-add per slot run (plus one scatter-set each for last_ts /
  keys / active) replaces the two-argsorts-plus-three-scatters shape.

Segment reduction is a masked MXU matmul: within one <=256-event tile,
``M[r, r'] = (slot[r'] == slot[r]) & (r' <= r)`` contracts the delta
columns to per-row run-prefix sums; rows selected by the caller (run
tails and tile cuts) carry exact per-(tile-)segment sums. Exactness uses
the flow_moments u16-half trick: u32 deltas split into halves, each
partial sum < 2^24 stays exact in f32, halves recombine mod 2^32.

Two event-stream memory strategies (mirroring gather_enrich):

``ingest_update_pallas`` (block)
    The five sorted stream words are BlockSpec-tiled into VMEM by the
    Pallas pipeline. Right while the stream fits the VMEM budget.

``ingest_update_hbm_pallas`` (HBM-resident)
    The stream stays in HBM (``pltpu.ANY``); run-boundary metadata (the
    count of non-sentinel rows per tile) is scalar-prefetched into SMEM
    and a double-buffered ``pltpu.make_async_copy`` loop pulls each
    event tile into 2-slot VMEM scratch while the previous tile's
    reduction computes. VMEM = O(event_tile) regardless of E, so
    events_per_shard can grow to 2^20; all-pad tiles skip the matmuls.

Variant selection (VMEM-budget heuristic + overrides) lives in
repro.kernels.dispatch; all implementations are bitwise-identical to the
multipass oracle (all-integer math, wrap-safe by construction).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import logstar as LS

N_REG = 7
REG_PAD = 8              # lane-friendly padded register count
MAX_EVENT_TILE = 256     # u16-half partial sums stay exact in f32


def clamp_tile(event_tile: int, events: int) -> int:
    """Largest legal tile: <= the exactness bound, <= the block size."""
    return max(1, min(int(event_tile), MAX_EVENT_TILE, int(events)))


class SortedStream(NamedTuple):
    """The one-sort product every fused engine consumes. All arrays are
    padded to ``n_tiles * tile`` rows; pad/invalid rows live in the
    sentinel slot F at the tail of the sort order and are dropped by the
    sentinel-index scatters in :func:`apply_updates`."""
    s_slot: jax.Array     # (Ep,) i32 — slot, F = invalid/pad sentinel
    s_ts: jax.Array       # (Ep,) u32 — timestamps (arrival order per run)
    s_ps: jax.Array       # (Ep,) u32 — packet sizes
    s_key: jax.Array      # (Ep, 5) u32 — five-tuples
    base_ts: jax.Array    # (Ep,) u32 — IAT predecessor timestamp
    first: jax.Array      # (Ep,) bool — first packet of a new flow
    head_idx: jax.Array   # (Ep,) i32 — index of the event's run head
    run_tail: jax.Array   # (Ep,) bool — last event of its slot run
    install: jax.Array    # (Ep,) bool — run head claiming an empty slot
    collide: jax.Array    # (Ep,) bool — key mismatch vs resident/installed
    tile: int             # negotiated event tile
    n_events: int         # unpadded E (telemetry only)


def stream_prep(last_ts: jax.Array, keys: jax.Array, active: jax.Array,
                slots: jax.Array, ts: jax.Array, ps: jax.Array,
                five_tuple: jax.Array, valid: jax.Array,
                event_tile: int) -> SortedStream:
    """THE one sort plus the O(E) run-boundary / admission resolution.

    Stable argsort by slot keeps arrival order within a run, which is
    what makes the run head the sequential winner for key install and
    the run tail the wrap-safe last_ts update (see core.reporter)."""
    F = last_ts.shape[0]
    E = slots.shape[0]
    tile = clamp_tile(event_tile, E)
    pad = (-E) % tile
    safe = jnp.where(valid, slots.astype(jnp.int32), F)
    order = jnp.argsort(safe, stable=True)

    def srt(a, c=0):
        out = a[order]
        if pad:
            out = jnp.pad(out, [(0, pad)] + [(0, 0)] * (a.ndim - 1),
                          constant_values=c)
        return out

    s_slot = srt(safe, F)
    s_ts = srt(ts.astype(jnp.uint32))
    s_ps = srt(ps.astype(jnp.uint32))
    s_key = srt(five_tuple.astype(jnp.uint32))
    cl = jnp.clip(s_slot, 0, F - 1)
    reg_last = last_ts[cl]
    reg_active = (s_slot < F) & active[cl]
    reg_key = keys[cl]
    change = s_slot[1:] != s_slot[:-1]
    run_head = jnp.concatenate([jnp.ones((1,), bool), change])
    run_tail = jnp.concatenate([change, jnp.ones((1,), bool)])
    prev_ts = jnp.concatenate([jnp.zeros((1,), s_ts.dtype), s_ts[:-1]])
    base_ts = jnp.where(run_head, reg_last, prev_ts)
    first = run_head & ~reg_active
    # admission in the sorted domain: the run head is the first-come
    # winner; the whole run compares against the resident key (occupied
    # slot) or the head's installed key (previously empty slot)
    idx = jnp.arange(s_slot.shape[0], dtype=jnp.int32)
    head_idx = jax.lax.cummax(jnp.where(run_head, idx, 0))
    eff_key = jnp.where(reg_active[:, None], reg_key, s_key[head_idx])
    match = jnp.all(s_key == eff_key, axis=-1)
    install = run_head & ~reg_active & (s_slot < F)
    collide = (s_slot < F) & ~match & ~install
    return SortedStream(s_slot, s_ts, s_ps, s_key, base_ts, first,
                        head_idx, run_tail, install, collide, tile, E)


def apply_updates(regs: jax.Array, last_ts: jax.Array, keys: jax.Array,
                  active: jax.Array, collisions: jax.Array,
                  st: SortedStream, run_sums: jax.Array,
                  sum_rows: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                             jax.Array]:
    """One scatter-add per slot run (``sum_rows`` marks the rows of
    ``run_sums`` carrying a (partial) segment sum) plus the per-slot
    last_ts / keys / active scatter-sets; sentinel indices drop."""
    F = regs.shape[0]
    real = st.s_slot < F
    upd = jnp.where(sum_rows & real, st.s_slot, F)
    regs = regs.at[upd].add(run_sums[:, :N_REG], mode="drop")
    tail = jnp.where(st.run_tail & real, st.s_slot, F)
    last_ts = last_ts.at[tail].set(st.s_ts, mode="drop")
    inst = jnp.where(st.install, st.s_slot, F)
    keys = keys.at[inst].set(st.s_key, mode="drop")
    active = active.at[inst].set(True, mode="drop")
    collisions = collisions + jnp.sum(st.collide).astype(jnp.uint32)
    return regs, last_ts, keys, active, collisions


def delta_cols(iat: jax.Array, ps: jax.Array, bits: int, log_lut,
               exp_lut):
    """The seven Table-I delta columns (iat already zeroed for firsts).
    The log*/exp* LUTs arrive as arrays so kernel bodies can feed the
    refs they received as inputs (a captured jnp constant is illegal
    inside pallas_call)."""
    def pw(x, n):
        return LS.approx_pow_with_luts(x, n, bits, log_lut, exp_lut)

    return (jnp.ones_like(ps), iat, pw(iat, 2), pw(iat, 3),
            ps, pw(ps, 2), pw(ps, 3))


def _tile_sums(slot, ts, ps, base, first, log_lut, exp_lut, *,
               bits: int):
    """(tile,) sorted inputs -> (tile, 8) u32 run-prefix segment sums.

    Row r holds the sum of its run's deltas from the run's first row
    inside this tile through r; run tails / tile cuts are therefore
    exact per-(tile-)segment sums. u16-half matmul keeps u32 exactness
    (tile <= 256 -> each half partial sum < 2^24 fits f32)."""
    tile = slot.shape[0]
    iat = jnp.where(first != 0, jnp.uint32(0), ts - base)
    d = delta_cols(iat, ps, bits, log_lut, exp_lut)
    D = jnp.stack(d + (jnp.zeros_like(ps),), axis=-1)   # (tile, 8) VMEM
    lo = (D & jnp.uint32(0xFFFF)).astype(jnp.float32)
    hi = (D >> 16).astype(jnp.float32)
    r = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1)
    m = ((slot[None, :] == slot[:, None]) & (c <= r)).astype(jnp.float32)
    acc_lo = jnp.dot(m, lo, preferred_element_type=jnp.float32)
    acc_hi = jnp.dot(m, hi, preferred_element_type=jnp.float32)
    return (acc_lo.astype(jnp.uint32)
            + (acc_hi.astype(jnp.uint32) << 16))


# ---------------------------------------------------------------------------
# block variant: sorted stream BlockSpec-tiled through VMEM
# ---------------------------------------------------------------------------

def _block_kernel(slot_ref, ts_ref, ps_ref, base_ref, first_ref,
                  loglut_ref, explut_ref, out_ref, *, bits: int):
    out_ref[...] = _tile_sums(slot_ref[...], ts_ref[...], ps_ref[...],
                              base_ref[...], first_ref[...],
                              loglut_ref[...], explut_ref[...], bits=bits)


@functools.partial(jax.jit,
                   static_argnames=("bits", "event_tile", "interpret"))
def segment_sums_pallas(s_slot, s_ts, s_ps, base_ts, first_i32, *,
                        bits: int, event_tile: int,
                        interpret: bool = True) -> jax.Array:
    """(Ep,) sorted stream -> (Ep, 8) per-tile-segment sums (block)."""
    Ep = s_slot.shape[0]
    assert Ep % event_tile == 0, (Ep, event_tile)
    et = event_tile
    log_lut, exp_lut = (jnp.asarray(t) for t in LS._luts(bits))
    n_lut = 1 << bits
    return pl.pallas_call(
        functools.partial(_block_kernel, bits=bits),
        grid=(Ep // et,),
        in_specs=[pl.BlockSpec((et,), lambda i: (i,))] * 5
        + [pl.BlockSpec((n_lut,), lambda i: (0,))] * 2,
        out_specs=pl.BlockSpec((et, REG_PAD), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Ep, REG_PAD), jnp.uint32),
        interpret=interpret,
    )(s_slot, s_ts, s_ps, base_ts, first_i32, log_lut, exp_lut)


# ---------------------------------------------------------------------------
# HBM-resident variant: stream stays in HBM, double-buffered tile DMA
# ---------------------------------------------------------------------------

N_SLOTS = 2          # double buffering: fetch tile i+1 while tile i computes
N_STREAMS = 5        # slot / ts / ps / base_ts / first


def _hbm_kernel(meta_ref, slot_hbm, ts_hbm, ps_hbm, base_hbm, first_hbm,
                loglut_ref, explut_ref, out_ref, slot_s, ts_s, ps_s,
                base_s, first_s, sems, *, bits: int, event_tile: int,
                n_tiles: int):
    """Grid step i: wait for tile i's five stream slices (prefetched by
    step i-1, or by the prologue for i == 0), kick off tile i+1's DMAs
    into the other scratch slot, then reduce tile i. ``meta_ref`` is the
    scalar-prefetched run-boundary metadata: the count of non-sentinel
    rows per tile, so all-pad tiles skip the matmul work entirely."""
    i = pl.program_id(0)
    et = event_tile

    def _copies(tile, buf):
        sl = pl.ds(tile * et, et)
        return [pltpu.make_async_copy(hbm.at[sl], scr.at[buf],
                                      sems.at[buf, j])
                for j, (hbm, scr) in enumerate(
                    [(slot_hbm, slot_s), (ts_hbm, ts_s), (ps_hbm, ps_s),
                     (base_hbm, base_s), (first_hbm, first_s)])]

    def start_tile(tile, buf):
        for dma in _copies(tile, buf):
            dma.start()

    def wait_tile(tile, buf):
        for dma in _copies(tile, buf):
            dma.wait()

    @pl.when(i == 0)
    def _prologue():
        start_tile(0, 0)

    @pl.when(i + 1 < n_tiles)
    def _prefetch_next():
        start_tile(i + 1, (i + 1) % N_SLOTS)

    buf = i % N_SLOTS
    wait_tile(i, buf)

    @pl.when(meta_ref[i] > 0)
    def _reduce():
        out_ref[...] = _tile_sums(slot_s[buf], ts_s[buf], ps_s[buf],
                                  base_s[buf], first_s[buf],
                                  loglut_ref[...], explut_ref[...],
                                  bits=bits)

    @pl.when(meta_ref[i] == 0)
    def _pad_tile():
        out_ref[...] = jnp.zeros((et, REG_PAD), jnp.uint32)


@functools.partial(jax.jit,
                   static_argnames=("bits", "event_tile", "interpret"))
def segment_sums_hbm_pallas(tile_nreal, s_slot, s_ts, s_ps, base_ts,
                            first_i32, *, bits: int, event_tile: int,
                            interpret: bool = True) -> jax.Array:
    """Same contract as :func:`segment_sums_pallas`, but the five stream
    arrays never leave HBM as whole blocks: VMEM holds two
    (event_tile,)-slot scratch sets, so E is unbounded by VMEM.
    ``tile_nreal`` (n_tiles,) i32 is the scalar-prefetched count of
    non-sentinel rows per tile."""
    Ep = s_slot.shape[0]
    assert Ep % event_tile == 0, (Ep, event_tile)
    et = event_tile
    n_tiles = Ep // et
    log_lut, exp_lut = (jnp.asarray(t) for t in LS._luts(bits))
    n_lut = 1 << bits
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,            # tile_nreal -> SMEM, whole array
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * N_STREAMS
        + [pl.BlockSpec((n_lut,), lambda i, meta: (0,))] * 2,
        out_specs=pl.BlockSpec((et, REG_PAD), lambda i, meta: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((N_SLOTS, et), jnp.int32),     # slot
            pltpu.VMEM((N_SLOTS, et), jnp.uint32),    # ts
            pltpu.VMEM((N_SLOTS, et), jnp.uint32),    # ps
            pltpu.VMEM((N_SLOTS, et), jnp.uint32),    # base_ts
            pltpu.VMEM((N_SLOTS, et), jnp.int32),     # first
            pltpu.SemaphoreType.DMA((N_SLOTS, N_STREAMS)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_hbm_kernel, bits=bits, event_tile=et,
                          n_tiles=n_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Ep, REG_PAD), jnp.uint32),
        interpret=interpret,
    )(tile_nreal, s_slot, s_ts, s_ps, base_ts, first_i32, log_lut,
      exp_lut)


# ---------------------------------------------------------------------------
# full-contract entry points (what dispatch registers)
# ---------------------------------------------------------------------------

def _fused_pallas(regs, last_ts, keys, active, collisions, slots, ts, ps,
                  five_tuple, valid, *, logstar_bits, event_tile,
                  interpret, hbm):
    st = stream_prep(last_ts, keys, active, slots, ts, ps, five_tuple,
                     valid, event_tile)
    first_i32 = st.first.astype(jnp.int32)
    if hbm:
        et = st.tile
        n_tiles = st.s_slot.shape[0] // et
        n_real = jnp.sum(st.s_slot < regs.shape[0]).astype(jnp.int32)
        tile_nreal = jnp.clip(
            n_real - jnp.arange(n_tiles, dtype=jnp.int32) * et, 0, et)
        sums = segment_sums_hbm_pallas(
            tile_nreal, st.s_slot, st.s_ts, st.s_ps, st.base_ts,
            first_i32, bits=logstar_bits, event_tile=et,
            interpret=interpret)
    else:
        sums = segment_sums_pallas(
            st.s_slot, st.s_ts, st.s_ps, st.base_ts, first_i32,
            bits=logstar_bits, event_tile=st.tile, interpret=interpret)
    # a run's sum is cut at every tile boundary it crosses; the scatter
    # re-merges the partials (one contributing row per run per tile)
    idx = jnp.arange(st.s_slot.shape[0], dtype=jnp.int32)
    tile_cut = (idx % st.tile) == (st.tile - 1)
    return apply_updates(regs, last_ts, keys, active, collisions, st,
                         sums, st.run_tail | tile_cut)


def ingest_update_pallas(regs, last_ts, keys, active, collisions, slots,
                         ts, ps, five_tuple, valid, *, logstar_bits: int,
                         event_tile: int = MAX_EVENT_TILE,
                         interpret: bool = True):
    """Fused ingest, block event-stream strategy (contract: ref.py)."""
    return _fused_pallas(regs, last_ts, keys, active, collisions, slots,
                         ts, ps, five_tuple, valid,
                         logstar_bits=logstar_bits, event_tile=event_tile,
                         interpret=interpret, hbm=False)


def ingest_update_hbm_pallas(regs, last_ts, keys, active, collisions,
                             slots, ts, ps, five_tuple, valid, *,
                             logstar_bits: int,
                             event_tile: int = MAX_EVENT_TILE,
                             interpret: bool = True):
    """Fused ingest, HBM-resident event-stream strategy."""
    return _fused_pallas(regs, last_ts, keys, active, collisions, slots,
                         ts, ps, five_tuple, valid,
                         logstar_bits=logstar_bits, event_tile=event_tile,
                         interpret=interpret, hbm=True)
