"""Pure-jnp oracle for ingest_update: the pre-fusion multipass reporter
ingest — admit, stable-sort IAT resolution, a materialized per-event
(E, 7) delta array, and a per-event scatter-accumulate. Every fused
implementation (jnp sort-once engine and both Pallas kernels) must match
it BITWISE on regs / last_ts / keys / active / collisions: the math is
all-integer (u32 mod 2^32), so there is no tolerance to hide behind."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.core.reporter import (accumulate_ref, admit_arrays,
                                 event_deltas, resolve_iat)


def ingest_update_ref(regs: jax.Array, last_ts: jax.Array, keys: jax.Array,
                      active: jax.Array, collisions: jax.Array,
                      slots: jax.Array, ts: jax.Array, ps: jax.Array,
                      five_tuple: jax.Array, valid: jax.Array, *,
                      logstar_bits: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array]:
    """regs (F,7) u32 | last_ts (F,) u32 | keys (F,5) u32 | active (F,)
    bool | collisions () u32 | slots (E,) i32 | ts/ps (E,) u32 |
    five_tuple (E,5) u32 | valid (E,) bool -> the five updated arrays."""
    pre_active = active                  # admissions see themselves as new
    keys, active, collisions = admit_arrays(keys, active, collisions,
                                            slots, five_tuple, valid)
    iat, first, last_ts = resolve_iat(slots, ts, valid, last_ts,
                                      pre_active)
    deltas = event_deltas(iat, ps, first, valid, logstar_bits)
    regs = accumulate_ref(regs, slots, deltas, valid)
    return regs, last_ts, keys, active, collisions
