"""Registry client for the fused ingest_update family (reporter stage 1).

Besides backend resolution (ref / pallas / interpret) this wrapper owns
the event-stream policy the kernels don't:

* memory-strategy variant selection — ``dispatch.resolve_ingest_variant``
  picks the block kernel while the sorted event stream fits the VMEM
  budget and the HBM-resident tiled kernel beyond (2^20 events/shard),
  with ``DFAConfig.ingest_variant`` / ``REPRO_INGEST_VARIANT`` overrides;
* the ``event_tile`` clamp — tiles are capped at 256 (the u16-half
  matmul exactness bound) and E is padded up to a tile multiple inside
  ``stream_prep`` (pad rows ride the invalid-sentinel slot).

``ingest_update_fused`` is the portable pure-jnp expression of the same
sort-once algorithm (one argsort, per-column cumsum segment reduction,
one scatter-add per slot run) — the fused path on backends without a
Pallas lowering, the CPU side of the fused-vs-multipass benchmark, and a
second independent implementation the bitwise equivalence suite pins
against the kernels.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import logstar as LS
from repro.kernels import dispatch
from repro.kernels.ingest_update import kernel as K


def ingest_update(regs, last_ts, keys, active, collisions, slots, ts, ps,
                  five_tuple, valid, cfg, backend=None, variant=None):
    """(F,·) reporter registers + one (E,) event block -> the five
    updated register arrays, via the selected backend and event-stream
    variant. Contract and bitwise semantics: ref.ingest_update_ref."""
    b = dispatch.resolve_backend(backend, cfg)   # validate env even if E=0
    E = slots.shape[0]
    if E == 0:                 # all backends: a zero-length block no-ops
        return regs, last_ts, keys, active, collisions
    if b == "ref":
        _, impl = dispatch.lookup("ingest_update", "ref", cfg)
        return impl(regs, last_ts, keys, active, collisions, slots, ts,
                    ps, five_tuple, valid, logstar_bits=cfg.logstar_bits)
    tile = K.clamp_tile(dispatch.resolve_event_tile(cfg, E), E)
    v = dispatch.resolve_ingest_variant(variant, cfg, E, tile)
    family = "ingest_update" if v == "block" else "ingest_update_hbm"
    _, impl = dispatch.lookup(family, b, cfg)
    return impl(regs, last_ts, keys, active, collisions, slots, ts, ps,
                five_tuple, valid, logstar_bits=cfg.logstar_bits,
                event_tile=tile, interpret=dispatch.interpret_flag(b))


def ingest_update_fused(regs, last_ts, keys, active, collisions, slots,
                        ts, ps, five_tuple, valid, cfg):
    """Pure-jnp fused engine: sort once, form the seven delta columns on
    the sorted stream, segment-reduce each by cumsum differences at run
    boundaries, apply one scatter-add per slot run. Bitwise-identical to
    the oracle (u32 cumsum wraps mod 2^32, so boundary differences are
    exact segment sums) without ever stacking a per-event (E, 7) delta
    array — only the per-RUN sums are materialized for the scatter."""
    E = slots.shape[0]
    if E == 0:
        return regs, last_ts, keys, active, collisions
    st = K.stream_prep(last_ts, keys, active, slots, ts, ps, five_tuple,
                       valid, cfg.event_tile)
    iat = jnp.where(st.first, jnp.uint32(0), st.s_ts - st.base_ts)
    log_lut, exp_lut = (jnp.asarray(t)
                        for t in LS._luts(cfg.logstar_bits))
    sums = []
    for c in K.delta_cols(iat, st.s_ps, cfg.logstar_bits, log_lut,
                          exp_lut):
        cs = jnp.cumsum(c)                     # u32: wraps mod 2^32
        excl = cs - c                          # exclusive prefix
        sums.append(cs - excl[st.head_idx])    # run-prefix sum at row r
    run_sums = jnp.stack(sums, axis=-1)        # per-run totals at tails
    return K.apply_updates(regs, last_ts, keys, active, collisions, st,
                           run_sums, st.run_tail)
