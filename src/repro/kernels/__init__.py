# Kernel layer: one package per compute hot-spot the paper itself
# optimizes (flow_moments, ring_scatter, derived_features, gather_enrich,
# flash_attention). Each family ships ref.py (jnp oracle), kernel.py
# (Pallas) and ops.py (thin registry client); backend selection lives in
# repro.kernels.dispatch.
