"""Measurement-driven tuned-config registry for the kernel dispatch layer.

The ``*_scaling.py`` sweeps already time every (shape, variant, tile)
combination this repo cares about; this module persists their winners so
``dispatch.resolve_*`` can consult MEASUREMENTS before falling back to
the static VMEM-budget heuristics. The day a TPU runner appears, tuning
becomes a bench run (``benchmarks/ingest_scaling.py --tune tuned.json``)
instead of a code change.

File format (``schema: "repro-tuning-v1"``)::

    {"schema": "repro-tuning-v1",
     "entries": [{"knob": "ingest_update.variant",
                  "backend": "interpret",
                  "key": [4096],
                  "value": "hbm",
                  "us_per_call": 812.4,
                  "source": "ingest_scaling"}, ...]}

Registered knobs and their shape keys:

* ``gather_enrich.variant``     — key ``[flows, history, report_tile,
  derived_dim]``, value ``"full" | "hbm"``
* ``gather_enrich.report_tile`` — key ``[reports]``, value int tile
* ``ingest_update.variant``     — key ``[events]``, value
  ``"block" | "hbm"``
* ``ingest_update.event_tile``  — key ``[events]``, value int tile

Lookups are exact-match on ``(knob, backend, key)`` — a tuned winner for
one shape says nothing about another, so there is deliberately no
nearest-shape interpolation. ``record`` keeps the fastest entry per key.

Precedence: the registry slots INSIDE dispatch's heuristic tier —
explicit argument > env var > explicit ``DFAConfig`` attr > tuned
registry > VMEM heuristic. Arming a registry path is an explicit opt-in
(``REPRO_TUNING_REGISTRY`` env var > ``DFAConfig.tuning_registry``), and
a malformed file or unknown knob fails loud at first lookup rather than
silently degrading to the heuristic.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs import env as ENV

SCHEMA = "repro-tuning-v1"
KNOBS = ("gather_enrich.variant", "gather_enrich.report_tile",
         "ingest_update.variant", "ingest_update.event_tile")

_Key = Tuple[str, str, Tuple[int, ...]]


class TuningRegistry:
    """In-memory view of one tuned-config file (load/record/save)."""

    def __init__(self) -> None:
        self.entries: Dict[_Key, Dict[str, Any]] = {}

    @staticmethod
    def _key(knob: str, backend: str, key: Sequence[int]) -> _Key:
        if knob not in KNOBS:
            raise ValueError(
                f"unknown tuning knob {knob!r}; registered: {list(KNOBS)}")
        return (knob, str(backend), tuple(int(k) for k in key))

    def record(self, knob: str, backend: str, key: Sequence[int],
               value: Any, us_per_call: float, source: str = "") -> bool:
        """Insert a measured winner; on a key collision the FASTER entry
        wins (so re-running a sweep can only improve the registry).
        Returns whether the entry was stored."""
        if not isinstance(value, (str, int)):
            raise TypeError(
                f"tuned value must be str or int, got {type(value)}")
        k = self._key(knob, backend, key)
        old = self.entries.get(k)
        if old is not None and old["us_per_call"] <= float(us_per_call):
            return False
        self.entries[k] = {"value": value,
                           "us_per_call": float(us_per_call),
                           "source": str(source)}
        return True

    def lookup(self, knob: str, backend: str,
               key: Sequence[int]) -> Optional[Any]:
        """The tuned value for an exact (knob, backend, key) match, or
        None (no measurement for this shape — heuristic decides)."""
        e = self.entries.get(self._key(knob, backend, key))
        return None if e is None else e["value"]

    # -- persistence -------------------------------------------------------
    @classmethod
    def load(cls, path: str) -> "TuningRegistry":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != SCHEMA:
            raise ValueError(
                f"{path}: schema {doc.get('schema')!r} is not {SCHEMA!r} "
                "— refusing to guess at an unknown tuning layout")
        reg = cls()
        for i, e in enumerate(doc.get("entries", [])):
            try:
                reg.record(e["knob"], e["backend"], e["key"], e["value"],
                           e["us_per_call"], e.get("source", ""))
            except (KeyError, TypeError, ValueError) as err:
                raise ValueError(
                    f"{path}: bad tuning entry #{i}: {err}") from err
        return reg

    def save(self, path: str) -> None:
        rows: List[Dict[str, Any]] = []
        for (knob, backend, key), e in sorted(self.entries.items()):
            rows.append({"knob": knob, "backend": backend,
                         "key": list(key), **e})
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump({"schema": SCHEMA, "entries": rows}, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)


# -- cached file access (dispatch consults per kernel call) ----------------

_lock = threading.Lock()
_cache: Dict[str, Tuple[float, TuningRegistry]] = {}


def load_cached(path: str) -> TuningRegistry:
    """mtime-checked registry cache: repeated dispatch consults cost a
    stat, not a parse, and an updated file is picked up without a
    process restart."""
    mtime = os.stat(path).st_mtime
    with _lock:
        hit = _cache.get(path)
        if hit is not None and hit[0] == mtime:
            return hit[1]
        reg = TuningRegistry.load(path)
        _cache[path] = (mtime, reg)
        return reg


def resolve_path(cfg) -> Optional[str]:
    """The armed registry path: ``REPRO_TUNING_REGISTRY`` env var >
    ``DFAConfig.tuning_registry`` > None (registry off)."""
    env = ENV.read_str(ENV.TUNING_REGISTRY.name)
    if env is not None:
        return env
    p = getattr(cfg, "tuning_registry", "") if cfg is not None else ""
    return p or None


def lookup_value(cfg, knob: str, backend: str,
                 key: Sequence[int]) -> Optional[Any]:
    """One-call consult for dispatch: resolve the armed path (None =
    registry off) and look up the exact (knob, backend, key). A path
    that is armed but unreadable/malformed raises — an operator who
    pointed at a registry wants to know it is not being used."""
    path = resolve_path(cfg)
    if path is None:
        return None
    return load_cached(path).lookup(knob, backend, key)
