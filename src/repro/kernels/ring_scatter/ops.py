"""Dispatching wrapper for ring_scatter (collector scatter_fn slot-in)."""
from __future__ import annotations

import jax

from repro.kernels.ring_scatter.kernel import ring_scatter_pallas
from repro.kernels.ring_scatter.ref import ring_scatter_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ring_scatter(memory, payloads, flow, hist, mask, flow_tile: int = 512,
                 force: str = "auto"):
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return ring_scatter_ref(memory, payloads, flow, hist, mask)
    interpret = (force == "interpret") or not _on_tpu()
    ft = min(flow_tile, memory.shape[0])
    while memory.shape[0] % ft:
        ft -= 1
    return ring_scatter_pallas(memory, payloads, flow, hist, mask,
                               flow_tile=ft, history=memory.shape[1],
                               interpret=interpret)


def ring_scatter_collector(memory, entry_valid, payloads, flow, hist, mask,
                           force: str = "interpret"):
    """Adapter matching repro.core.collector.scatter_fn signature."""
    mem = ring_scatter(memory, payloads, flow, hist, mask, force=force)
    import jax.numpy as jnp
    F, H, _ = memory.shape
    ev = entry_valid.reshape(F * H).at[
        jnp.where(mask, flow * H + hist, F * H)].set(True, mode="drop")
    return mem, ev.reshape(F, H)
