"""Registry client for ring_scatter (collector scatter_fn slot-in)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch


def ring_scatter(memory, payloads, flow, hist, mask, flow_tile=None,
                 backend=None, cfg=None, force=None):
    """memory: (F, H, 16) u32; payloads: (R, 16) u32; flow/hist: (R,) i32.

    An explicit ``flow_tile`` wins; ``cfg.flow_tile`` is only the default.
    ``force`` is the legacy name for ``backend`` (kept for callers)."""
    b, impl = dispatch.lookup("ring_scatter", backend or force, cfg)
    if b == "ref":
        return impl(memory, payloads, flow, hist, mask)
    if flow_tile is None:
        flow_tile = cfg.flow_tile if cfg is not None else 512
    ft = dispatch.negotiate_tile(memory.shape[0], flow_tile)
    return impl(memory, payloads, flow, hist, mask, flow_tile=ft,
                history=memory.shape[1], interpret=dispatch.interpret_flag(b))


def ring_scatter_collector(memory, entry_valid, payloads, flow, hist, mask,
                           backend=None, cfg=None, force=None):
    """Adapter matching repro.core.collector.scatter_fn's signature:
    placement via the dispatched kernel + jnp validity-bit update."""
    mem = ring_scatter(memory, payloads, flow, hist, mask,
                       backend=backend or force, cfg=cfg)
    F, H, _ = memory.shape
    ev = entry_valid.reshape(F * H).at[
        jnp.where(mask, flow * H + hist, F * H)].set(True, mode="drop")
    return mem, ev.reshape(F, H)
