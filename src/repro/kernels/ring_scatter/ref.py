"""Pure-jnp oracle for ring_scatter (last-write-wins placement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_scatter_ref(memory: jax.Array, payloads: jax.Array,
                     flow: jax.Array, hist: jax.Array, mask: jax.Array
                     ) -> jax.Array:
    F, H, W = memory.shape
    flat = memory.reshape(F * H, W)
    idx = jnp.where(mask, flow * H + hist, F * H)
    flat = flat.at[idx].set(payloads, mode="drop")
    return flat.reshape(F, H, W)
