"""ring_scatter — RDMA-WRITE placement into the Fig-4 ring buffer (Pallas).

The GPUDirect analogue: payloads are written VERBATIM at translator-computed
(flow, history) coordinates, in report order (last write wins), directly in
device memory. The collector tile (flow_tile, H, 16 words) is pinned in VMEM
while a sequential fori_loop replays the payload stream — matching the
ordering semantics of RDMA WRITE-Only onto a queue pair. The buffer is
donated/aliased so placement is genuinely in-place (no staging copy — the
exact property Fig 9 measures DFA against).

Grid: (flow_tiles,). Payload count is the sequential dimension; payloads not
belonging to the tile are masked stores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORDS = 16


def _kernel(coords_ref, payload_ref, mem_in_ref, mem_out_ref, *,
            flow_tile: int, history: int):
    ft = pl.program_id(0)
    base = ft * flow_tile
    mem_out_ref[...] = mem_in_ref[...]
    R = payload_ref.shape[0]

    def body(r, _):
        flow = coords_ref[r, 0] - base
        hist = coords_ref[r, 1]
        ok = jnp.logical_and(flow >= 0, flow < flow_tile)
        ok = jnp.logical_and(ok, coords_ref[r, 2] > 0)

        @pl.when(ok)
        def _store():
            row = payload_ref[r, :]
            mem_out_ref[flow, hist, :] = row
        return 0

    jax.lax.fori_loop(0, R, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("flow_tile", "history", "interpret"))
def ring_scatter_pallas(memory: jax.Array, payloads: jax.Array,
                        flow: jax.Array, hist: jax.Array, mask: jax.Array,
                        flow_tile: int = 512, history: int = 10,
                        interpret: bool = True) -> jax.Array:
    """memory: (F, H, 16) u32; payloads: (R, 16) u32; flow/hist: (R,) i32.

    Returns updated memory (donation-aliased: in-place on device)."""
    F, H, W = memory.shape
    R = payloads.shape[0]
    assert F % flow_tile == 0 and W == WORDS
    coords = jnp.stack([flow.astype(jnp.int32), hist.astype(jnp.int32),
                        mask.astype(jnp.int32)], axis=1)      # (R, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, flow_tile=flow_tile, history=H),
        grid=(F // flow_tile,),
        in_specs=[
            pl.BlockSpec((R, 3), lambda f: (0, 0)),
            pl.BlockSpec((R, WORDS), lambda f: (0, 0)),
            pl.BlockSpec((flow_tile, H, WORDS), lambda f: (f, 0, 0)),
        ],
        out_specs=pl.BlockSpec((flow_tile, H, WORDS), lambda f: (f, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, H, WORDS), jnp.uint32),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(coords, payloads, memory)
    return out
