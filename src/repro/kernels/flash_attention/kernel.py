"""flash_attention — fused causal attention forward (Pallas TPU).

The §Roofline tables show every train/prefill cell is memory-dominant under
vanilla XLA because (q·kᵀ) score blocks round-trip HBM. This kernel keeps
the online-softmax state (m, l, acc) in VMEM scratch across the KV grid
dimension, so scores never leave VMEM — the standard flash tiling, with
GQA handled by the K/V BlockSpec index map (bh -> bh // group) instead of
materializing repeated heads.

Grid: (B*H, nq, nk), nk innermost (the output block is revisited across nk
and written on the last step). Block shapes are MXU-aligned by the ops.py
wrapper (q_block x head_dim multiples of 128 when the shape allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, D)
    k = k_ref[0]                                   # (bk, D)
    v = v_ref[0]                                   # (bk, Dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "causal", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, group: int = 1, causal: bool = True,
                           scale: float | None = None, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q: (BHq, Sq, D); k/v: (BHkv, Sk, D|Dv) with BHq == BHkv * group.

    GQA: query head i reads kv head i // group via the BlockSpec index map
    (no repeated-KV materialization). Returns (BHq, Sq, Dv).
    """
    BH, Sq, D = q.shape
    Sk, Dv = k.shape[1], v.shape[2]
    scale = D ** -0.5 if scale is None else scale
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, Dv),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
