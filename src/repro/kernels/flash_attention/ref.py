"""Oracle for the flash_attention kernel: plain masked softmax attention
over the flattened (BH, S, D) layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, group: int = 1, causal: bool = True,
                        scale=None):
    BH, Sq, D = q.shape
    Sk, Dv = k.shape[1], v.shape[2]
    scale = D ** -0.5 if scale is None else scale
    kv_idx = jnp.arange(BH) // group
    kk = k[kv_idx]                                # (BH, Sk, D)
    vv = v[kv_idx]
    s = jnp.einsum("bqd,bkd->bqk", q, kk,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bke->bqe", p.astype(v.dtype), vv
                      ).astype(q.dtype)
