"""Registry client for the fused attention forward.

Model code keeps the pure-JAX flash path (attention.chunked_attention) as
the portable default; on TPU this kernel replaces the forward hot loop
(the §Roofline memory term's dominant contributor)."""
from __future__ import annotations

from repro.kernels import dispatch


def flash_attention(q, k, v, *, group: int = 1, causal: bool = True,
                    scale=None, backend=None, cfg=None, force=None):
    """q: (BH, Sq, D); k/v: (BH//group, Sk, D|Dv) -> (BH, Sq, Dv).

    ``force`` is the legacy name for ``backend`` (kept for callers)."""
    b, impl = dispatch.lookup("flash_attention", backend or force, cfg)
    if b == "ref":
        return impl(q, k, v, group=group, causal=causal, scale=scale)
    bq = dispatch.negotiate_tile(q.shape[1], 128)
    bk = dispatch.negotiate_tile(k.shape[1], 128)
    return impl(q, k, v, group=group, causal=causal, scale=scale,
                bq=bq, bk=bk, interpret=dispatch.interpret_flag(b))
