"""Dispatching wrapper for the fused attention forward.

Model code keeps the pure-JAX flash path (attention.chunked_attention) as
the portable default; on TPU this kernel replaces the forward hot loop
(the §Roofline memory term's dominant contributor)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick(size: int, target: int) -> int:
    target = max(1, min(target, size))
    for c in range(target, 0, -1):
        if size % c == 0:
            return c
    return size


def flash_attention(q, k, v, *, group: int = 1, causal: bool = True,
                    scale=None, force: str = "auto"):
    """q: (BH, Sq, D); k/v: (BH//group, Sk, D|Dv) -> (BH, Sq, Dv)."""
    if force == "ref" or (force == "auto" and not _on_tpu()):
        return flash_attention_ref(q, k, v, group=group, causal=causal,
                                   scale=scale)
    interpret = (force == "interpret") or not _on_tpu()
    bq = _pick(q.shape[1], 128)
    bk = _pick(k.shape[1], 128)
    return flash_attention_pallas(q, k, v, group=group, causal=causal,
                                  scale=scale, bq=bq, bk=bk,
                                  interpret=interpret)
