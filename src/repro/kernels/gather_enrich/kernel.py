"""gather_enrich — fused history gather + feature derivation (Pallas).

The unfused enrichment path gathers each routed report's (H, 16)-word ring
history out of collector memory into an (R, H, 16) intermediate, then runs
derived_features over it: one full round trip of 640 B/flow through HBM
before the compute even starts. This kernel fuses the two stages: per
report tile, a sequential gather loop pulls each flow's ring rows straight
into a VMEM scratch tile and the derived-feature block is computed in
place — the (R, H, 16) array never exists in HBM. This is the TPU shape of
the paper's "build derived features on CUDA cores right next to the
GDR-placed telemetry" argument (§III-C).

Grid: (report_tiles,). Collector memory is presented as one un-tiled block
(shard-local F; for Tofino-scale F keep shards small enough that the ring
region fits VMEM, or fall back to the ref path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.derived_features.kernel import derive_block

WORDS = 16


def _kernel(flows_ref, mem_ref, valid_ref, out_ref, ent_scratch,
            val_scratch, *, derived_dim: int):
    T = flows_ref.shape[0]

    def gather(r, _):
        f = flows_ref[r]
        ent_scratch[pl.ds(r, 1)] = mem_ref[pl.ds(f, 1)]
        val_scratch[pl.ds(r, 1)] = valid_ref[pl.ds(f, 1)]
        return 0

    jax.lax.fori_loop(0, T, gather, 0)
    out_ref[...] = derive_block(ent_scratch[...], val_scratch[...] > 0,
                                derived_dim)


@functools.partial(jax.jit,
                   static_argnames=("derived_dim", "report_tile",
                                    "interpret"))
def gather_enrich_pallas(memory: jax.Array, entry_valid: jax.Array,
                         local_flow: jax.Array, derived_dim: int = 96,
                         report_tile: int = 128,
                         interpret: bool = True) -> jax.Array:
    """memory: (F, H, 16) u32; entry_valid: (F, H); local_flow: (R,) i32
    in [0, F) -> (R, derived_dim) f32."""
    F, H, W = memory.shape
    R = local_flow.shape[0]
    assert R % report_tile == 0 and W == WORDS, (R, report_tile, W)
    flows = jnp.clip(local_flow.astype(jnp.int32), 0, F - 1)

    return pl.pallas_call(
        functools.partial(_kernel, derived_dim=derived_dim),
        grid=(R // report_tile,),
        in_specs=[
            pl.BlockSpec((report_tile,), lambda r: (r,)),
            pl.BlockSpec((F, H, WORDS), lambda r: (0, 0, 0)),
            pl.BlockSpec((F, H), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((report_tile, derived_dim), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, derived_dim), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((report_tile, H, WORDS), jnp.uint32),
            pltpu.VMEM((report_tile, H), jnp.int32),
        ],
        interpret=interpret,
    )(flows, memory, entry_valid.astype(jnp.int32))
