"""gather_enrich — fused history gather + feature derivation (Pallas).

The unfused enrichment path gathers each routed report's (H, 16)-word ring
history out of collector memory into an (R, H, 16) intermediate, then runs
derived_features over it: one full round trip of 640 B/flow through HBM
before the compute even starts. Both kernels here fuse the two stages so
the (R, H, 16) array never exists in HBM — the TPU shape of the paper's
"build derived features on CUDA cores right next to the GDR-placed
telemetry" argument (§III-C). Two memory strategies:

``gather_enrich_pallas`` (full-block)
    Collector memory is presented as one un-tiled VMEM block and rows are
    copied scratch-to-scratch inside the kernel. Fastest when the shard
    ring region fits VMEM (reduced configs); impossible at Tofino scale —
    2^17 flows x 10 x 64 B is ~84 MB against ~16 MB of VMEM.

``gather_enrich_hbm_pallas`` (HBM-resident, tiled)
    Collector memory stays in HBM (``pltpu.ANY``); the routed flow ids are
    scalar-prefetched into SMEM and a per-report-tile double-buffered DMA
    loop (``pltpu.make_async_copy`` into two scratch slots) pulls each
    flow's (H, 16) ring rows into VMEM while the previous tile's
    derive_block computes. VMEM footprint is O(report_tile * H * 16)
    regardless of F, which is what lets one shard own the paper's full
    2^17-flow table.

Variant selection (VMEM-budget heuristic + overrides) lives in
repro.kernels.dispatch; both kernels compute bit-identical features.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import wire as WIRE
from repro.kernels.derived_features.kernel import derive_block

WORDS = 16


# ---------------------------------------------------------------------------
# full-block variant: ring region pinned in VMEM
# ---------------------------------------------------------------------------

def _full_kernel(flows_ref, mem_ref, valid_ref, out_ref, ent_scratch,
                 val_scratch, *, derived_dim: int, wire: WIRE.WireFormat):
    T = flows_ref.shape[0]

    def gather(r, _):
        f = flows_ref[r]
        ent_scratch[pl.ds(r, 1)] = mem_ref[pl.ds(f, 1)]
        val_scratch[pl.ds(r, 1)] = valid_ref[pl.ds(f, 1)]
        return 0

    jax.lax.fori_loop(0, T, gather, 0)
    out_ref[...] = derive_block(ent_scratch[...], val_scratch[...] > 0,
                                derived_dim, wire=wire)


@functools.partial(jax.jit,
                   static_argnames=("derived_dim", "report_tile",
                                    "interpret", "wire"))
def gather_enrich_pallas(memory: jax.Array, entry_valid: jax.Array,
                         local_flow: jax.Array, derived_dim: int = 96,
                         report_tile: int = 128,
                         interpret: bool = True,
                         wire: WIRE.WireFormat = WIRE.V1) -> jax.Array:
    """memory: (F, H, 16) u32; entry_valid: (F, H); local_flow: (R,) i32
    in [0, F) -> (R, derived_dim) f32."""
    F, H, W = memory.shape
    R = local_flow.shape[0]
    assert R % report_tile == 0 and W == WORDS, (R, report_tile, W)
    flows = jnp.clip(local_flow.astype(jnp.int32), 0, F - 1)

    return pl.pallas_call(
        functools.partial(_full_kernel, derived_dim=derived_dim,
                          wire=wire),
        grid=(R // report_tile,),
        in_specs=[
            pl.BlockSpec((report_tile,), lambda r: (r,)),
            pl.BlockSpec((F, H, WORDS), lambda r: (0, 0, 0)),
            pl.BlockSpec((F, H), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((report_tile, derived_dim), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, derived_dim), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((report_tile, H, WORDS), jnp.uint32),
            pltpu.VMEM((report_tile, H), jnp.int32),
        ],
        interpret=interpret,
    )(flows, memory, entry_valid.astype(jnp.int32))


# ---------------------------------------------------------------------------
# HBM-resident variant: ring region stays in HBM, per-tile DMA gather
# ---------------------------------------------------------------------------

N_SLOTS = 2          # double buffering: fetch tile i+1 while tile i computes
SEM_ENT, SEM_VAL = 0, 1


def _hbm_kernel(flows_ref, mem_ref, valid_ref, out_ref, ent_scratch,
                val_scratch, sems, *, derived_dim: int, report_tile: int,
                n_tiles: int, wire: WIRE.WireFormat):
    """Grid step i: wait for tile i's rows (prefetched by step i-1, or by
    the prologue for i == 0), kick off tile i+1's DMAs into the other
    scratch slot, then derive tile i in place."""
    i = pl.program_id(0)

    def _row_copies(tile, slot, r):
        f = flows_ref[tile * report_tile + r]
        ent = pltpu.make_async_copy(mem_ref.at[f], ent_scratch.at[slot, r],
                                    sems.at[slot, SEM_ENT])
        val = pltpu.make_async_copy(valid_ref.at[f], val_scratch.at[slot, r],
                                    sems.at[slot, SEM_VAL])
        return ent, val

    def start_tile(tile, slot):
        def row(r, _):
            ent, val = _row_copies(tile, slot, r)
            ent.start()
            val.start()
            return 0
        jax.lax.fori_loop(0, report_tile, row, 0)

    def wait_tile(tile, slot):
        def row(r, _):
            ent, val = _row_copies(tile, slot, r)
            ent.wait()
            val.wait()
            return 0
        jax.lax.fori_loop(0, report_tile, row, 0)

    @pl.when(i == 0)
    def _prologue():
        start_tile(0, 0)

    @pl.when(i + 1 < n_tiles)
    def _prefetch_next():
        start_tile(i + 1, (i + 1) % N_SLOTS)

    slot = i % N_SLOTS
    wait_tile(i, slot)
    out_ref[...] = derive_block(ent_scratch[slot], val_scratch[slot] > 0,
                                derived_dim, wire=wire)


@functools.partial(jax.jit,
                   static_argnames=("derived_dim", "report_tile",
                                    "interpret", "wire"))
def gather_enrich_hbm_pallas(memory: jax.Array, entry_valid: jax.Array,
                             local_flow: jax.Array, derived_dim: int = 96,
                             report_tile: int = 128,
                             interpret: bool = True,
                             wire: WIRE.WireFormat = WIRE.V1) -> jax.Array:
    """Same contract as gather_enrich_pallas, but ``memory``/``entry_valid``
    never leave HBM as whole blocks: VMEM holds only two
    (report_tile, H, 16) scratch slots, so F is unbounded by VMEM."""
    F, H, W = memory.shape
    R = local_flow.shape[0]
    assert R % report_tile == 0 and W == WORDS, (R, report_tile, W)
    n_tiles = R // report_tile
    flows = jnp.clip(local_flow.astype(jnp.int32), 0, F - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,            # flows -> SMEM, whole array
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # ring region (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),     # validity (HBM)
        ],
        out_specs=pl.BlockSpec((report_tile, derived_dim),
                               lambda i, flows: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((N_SLOTS, report_tile, H, WORDS), jnp.uint32),
            pltpu.VMEM((N_SLOTS, report_tile, H), jnp.int32),
            pltpu.SemaphoreType.DMA((N_SLOTS, 2)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_hbm_kernel, derived_dim=derived_dim,
                          report_tile=report_tile, n_tiles=n_tiles,
                          wire=wire),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, derived_dim), jnp.float32),
        interpret=interpret,
    )(flows, memory, entry_valid.astype(jnp.int32))
