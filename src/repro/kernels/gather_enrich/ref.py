"""Pure-jnp oracle for gather_enrich: explicit history gather followed by
the enrichment oracle — materializes the (R, H, 16) intermediate the fused
kernel exists to avoid."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.enrich import derive_ref


def gather_enrich_ref(memory: jax.Array, entry_valid: jax.Array,
                      local_flow: jax.Array, cfg) -> jax.Array:
    """memory: (F, H, 16) u32; entry_valid: (F, H) bool;
    local_flow: (R,) i32 in [0, F) -> (R, derived_dim) f32."""
    lf = jnp.clip(local_flow.astype(jnp.int32), 0, memory.shape[0] - 1)
    return derive_ref(memory[lf], entry_valid[lf], cfg)
