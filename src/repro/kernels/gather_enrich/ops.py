"""Registry client for the fused gather_enrich op (pipeline stage 6)."""
from __future__ import annotations

from repro.kernels import dispatch


def gather_enrich(memory, entry_valid, local_flow, cfg, backend=None):
    """(F,H,16) memory + (F,H) validity + (R,) local flow ids
    -> (R, derived_dim) f32 enriched features, via the selected backend."""
    b, impl = dispatch.lookup("gather_enrich", backend, cfg)
    if b == "ref":
        return impl(memory, entry_valid, local_flow, cfg)
    rt = dispatch.negotiate_tile(local_flow.shape[0], cfg.flow_tile)
    return impl(memory, entry_valid, local_flow,
                derived_dim=cfg.derived_dim, report_tile=rt,
                interpret=dispatch.interpret_flag(b))
