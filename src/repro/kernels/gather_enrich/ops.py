"""Registry client for the fused gather_enrich op (pipeline stage 6).

Besides backend resolution (ref / pallas / interpret) this wrapper owns
two pieces of shape policy the kernels don't:

* memory-strategy variant selection — ``dispatch.resolve_gather_variant``
  picks the full-block kernel while the shard ring region fits the VMEM
  budget and the HBM-resident tiled kernel beyond (2^17 flows/shard), with
  ``DFAConfig.gather_variant`` / ``REPRO_GATHER_VARIANT`` overrides;
* report padding — R is padded up to a multiple of the report tile
  (clamped flow id 0 for pad rows, output rows sliced off) so callers can
  route any report count, power of two or not, without shrinking the tile.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import wire as WIRE
from repro.kernels import dispatch


def _tile_and_pad(R: int, preferred: int):
    """(tile, padded_R): tile = min(preferred, R), R padded to a multiple.

    Unlike ``negotiate_tile`` (which shrinks the tile to a divisor — fine
    for scatter families that index the whole array) this keeps the tile
    large for awkward R: a prime R costs pad rows, not a degenerate tile.
    """
    t = max(1, min(int(preferred), int(R)))
    pad = (-R) % t
    return t, R + pad


def gather_enrich(memory, entry_valid, local_flow, cfg, backend=None,
                  variant=None):
    """(F,H,16) memory + (F,H) validity + (R,) local flow ids
    -> (R, derived_dim) f32 enriched features, via the selected backend
    and memory-strategy variant."""
    b = dispatch.resolve_backend(backend, cfg)
    if b == "ref":
        _, impl = dispatch.lookup("gather_enrich", "ref", cfg)
        return impl(memory, entry_valid, local_flow, cfg)

    F, H = memory.shape[0], memory.shape[1]
    R = local_flow.shape[0]
    if R == 0:
        return jnp.zeros((0, cfg.derived_dim), jnp.float32)
    rt, Rp = _tile_and_pad(R, dispatch.resolve_report_tile(cfg, R))
    v = dispatch.resolve_gather_variant(variant, cfg, F, H, rt,
                                        cfg.derived_dim)
    family = "gather_enrich" if v == "full" else "gather_enrich_hbm"
    _, impl = dispatch.lookup(family, b, cfg)
    flows = local_flow
    if Rp != R:
        flows = jnp.concatenate(
            [local_flow, jnp.zeros((Rp - R,), local_flow.dtype)])
    out = impl(memory, entry_valid, flows, derived_dim=cfg.derived_dim,
               report_tile=rt, interpret=dispatch.interpret_flag(b),
               wire=WIRE.resolve(cfg))
    return out[:R]
