"""Synthetic traffic-trace generator for the DFA pipeline.

Flow model follows the measurement literature the paper targets: heavy-tailed
flow sizes (Pareto), lognormal packet inter-arrivals, bimodal packet sizes
(ACK-ish small vs MTU-ish large), a TCP/UDP mix, and flow churn. Stateless
per step (seed, step) like the token pipeline.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def gen_flows(n_flows: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    five = np.zeros((n_flows, 5), np.uint32)
    five[:, 0] = rng.integers(0x0A000000, 0x0AFFFFFF, n_flows)  # 10.0.0.0/8
    five[:, 1] = rng.integers(0xC0A80000, 0xC0A8FFFF, n_flows)
    sport = rng.integers(1024, 65535, n_flows).astype(np.uint32)
    dport = rng.choice([80, 443, 8080, 53, 1935, 3478], n_flows).astype(
        np.uint32)
    five[:, 2] = (sport << 16) | dport
    five[:, 3] = rng.choice([6, 17], n_flows, p=[0.8, 0.2])     # tcp/udp
    # heavy-tailed mean rate per flow (pkts/s)
    rate = np.clip((rng.pareto(1.3, n_flows) + 1) * 50, 10, 5e4)
    return {"five_tuple": five, "rate": rate,
            "class": (rng.random(n_flows) * 8).astype(np.int32)}


def gen_events(flows: Dict[str, np.ndarray], t0_us: int, window_us: int,
               n_events: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Sample ``n_events`` packets in [t0, t0+window) across the flow set,
    arrival intensity proportional to per-flow rate."""
    rng = np.random.default_rng(seed)
    p = flows["rate"] / flows["rate"].sum()
    fidx = rng.choice(len(p), size=n_events, p=p)
    ts = np.sort(t0_us + rng.integers(0, window_us, n_events)).astype(
        np.uint32)
    small = rng.random(n_events) < 0.45
    size = np.where(small, rng.integers(40, 120, n_events),
                    rng.integers(900, 1514, n_events)).astype(np.uint32)
    return {"ts": ts, "size": size,
            "five_tuple": flows["five_tuple"][fidx],
            "valid": np.ones(n_events, bool),
            "flow_idx": fidx}


def events_for_shards(flows, step: int, n_shards: int, events_per_shard: int,
                      window_us: int = 20_000, seed: int = 0):
    """Global event batch: each reporter shard sees its own traffic slice."""
    out = []
    for s in range(n_shards):
        out.append(gen_events(flows, t0_us=step * window_us,
                              window_us=window_us,
                              n_events=events_per_shard,
                              seed=seed * 100003 + step * 131 + s))
    cat = {k: np.concatenate([o[k] for o in out]) for k in
           ("ts", "size", "five_tuple", "valid")}
    return cat


def period_batches(n_shards: int, T: int, events_per_shard: int,
                   n_flows: int = 32, flow_seed: int = 0,
                   period_us: int = 100_000):
    """Stacked streaming input: (T, n_shards*E, …) event batches + (T,)
    ``nows`` u32 — the exact shape ``run_periods`` /
    ``run_periods_overlapped`` consume (shared by the streaming tests,
    benchmarks and examples so the batch layout has one definition)."""
    import jax.numpy as jnp   # keep the generator itself numpy-only

    flows = gen_flows(n_flows, seed=flow_seed)
    evs = [events_for_shards(flows, t, n_shards, events_per_shard)
           for t in range(T)]
    events = {k: jnp.stack([jnp.asarray(e[k]) for e in evs])
              for k in evs[0]}
    nows = jnp.asarray([(t + 1) * period_us for t in range(T)], jnp.uint32)
    return events, nows
