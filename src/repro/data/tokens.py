"""Deterministic synthetic LM data pipeline.

Stateless and step-keyed: batch(step) is a pure function of (seed, step,
shape), so crash-restart resumes EXACTLY (no data-loader state to
checkpoint) and any host can materialize any shard — the property that
makes the pipeline elastic across mesh changes.

A Zipf-ish unigram mixture with per-document structure (repeated n-grams)
gives losses that actually decrease during the example runs, unlike uniform
noise.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _zipf_logits(vocab: int) -> jax.Array:
    r = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -jnp.log(r)                       # p(r) ∝ 1/r


def batch_at(step: int, cfg: ModelConfig, batch: int, seq: int,
             seed: int = 0) -> Dict[str, jax.Array]:
    """-> {tokens, targets, mask} (+ modality stubs added by caller)."""
    key = jax.random.fold_in(jax.random.key(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = _zipf_logits(cfg.vocab_size)
    base = jax.random.categorical(k1, logits, shape=(batch, seq + 1))
    # inject learnable structure: each sequence repeats an 8-gram motif
    motif = jax.random.categorical(k2, logits, shape=(batch, 8))
    pos = jnp.arange(seq + 1)
    use_motif = (pos // 8) % 4 == 0          # 25% of positions
    motif_tok = motif[:, pos % 8]
    toks = jnp.where(use_motif[None, :], motif_tok, base).astype(jnp.int32)
    return {"tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": jnp.ones((batch, seq), jnp.float32)}


def add_modality_stub(batch: Dict[str, jax.Array], cfg: ModelConfig,
                      step: int, seed: int = 0) -> Dict[str, jax.Array]:
    B = batch["tokens"].shape[0]
    key = jax.random.fold_in(jax.random.key(seed + 7), step)
    if cfg.family == "vlm":
        batch["patches"] = 0.02 * jax.random.normal(
            key, (B, cfg.vision.num_patches, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.encdec.num_frames, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    return batch
