"""Scenario library for the multi-pod differential test harness.

Each scenario builds a MESH-INDEPENDENT traffic trace for a fixed set of
reporter PORTS: ``(events, nows)`` with events shaped
``(T, total_ports * events_per_port, ...)`` in port-major order. Because
the pipeline assigns ports to devices in pod-major contiguous ranges
(``total_ports / n_devices`` ports per device), the SAME global arrays
drive a ``(1, S)``, ``(2, S)`` or ``(4, S//2)`` mesh — only the sharding
of the leading event dim changes. That is the whole trick behind the
pod-count-invariance suite (tests/test_multipod_equiv.py): one trace,
three mesh factorizations, bitwise-identical merged state.

Every generator is numpy + fixed seeds (stateless, reproducible); events
within one (port, period) block are in arrival order (the reporter
contract), which for the u32-wrap scenario means sorted by UNWRAPPED time
before the cast — exactly the stream a wrapped µs clock produces.

Scenarios (names are the registry keys):

  elephants_mice   heavy-tailed shared flow population seen by EVERY port
                   (maximally cross-pod: each flow's home pod sees reports
                   from all pods)
  port_local       each port observes only its own disjoint flow set (the
                   pod-local-heavy port assignment; homes still hash
                   anywhere, but ingest is disjoint)
  flow_churn       half of the flow population is replaced every period
                   (admission/eviction pressure on the Marina tables)
  collision_storm  flow count >> per-port table slots, forcing hash
                   collisions and resident-flow attribution
  bursty_iat       packets arrive in tight bursts with long gaps (stresses
                   the IAT moment registers and log* approximation)
  u32_wrap         the µs clock wraps 2^32 mid-trace (timestamps AND
                   ``nows`` wrap; wrap-safe IAT/due logic must hold on
                   every mesh identically)
  cross_pod_mix    half the ports share one global flow set, half are
                   port-local (the cross-pod-heavy vs pod-local-heavy
                   split on one trace)
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.data import packets as PK

PERIOD_US = 100_000


def _assemble(per_port: list, T: int, nows=None):
    """per_port: [port][period] -> event dict; -> stacked global arrays.

    Port-major concatenation per period matches the pod-major port ->
    device placement, so one array serves every mesh factorization."""
    keys = ("ts", "size", "five_tuple", "valid")
    events = {k: np.stack([
        np.concatenate([per_port[p][t][k] for p in range(len(per_port))])
        for t in range(T)]) for k in keys}
    if nows is None:
        nows = np.asarray([(t + 1) * PERIOD_US for t in range(T)],
                          np.uint32)
    return events, np.asarray(nows, np.uint32)


def _port_events(flows, port: int, t: int, n_events: int, seed: int):
    ev = PK.gen_events(flows, t0_us=t * PERIOD_US, window_us=PERIOD_US,
                       n_events=n_events,
                       seed=seed * 1_000_003 + t * 131 + port * 7919)
    return {k: ev[k] for k in ("ts", "size", "five_tuple", "valid")}


def elephants_mice(total_ports: int, events_per_port: int, T: int,
                   seed: int = 0):
    """3 elephants + a tail of mice, the SAME population on every port."""
    flows = PK.gen_flows(24, seed=seed)
    flows["rate"][:3] *= 50.0                      # elephants
    per_port = [[_port_events(flows, p, t, events_per_port, seed)
                 for t in range(T)] for p in range(total_ports)]
    return _assemble(per_port, T)


def port_local(total_ports: int, events_per_port: int, T: int,
               seed: int = 0):
    """Disjoint per-port flow sets (seeded per port, distinct subnets)."""
    per_port = []
    for p in range(total_ports):
        flows = PK.gen_flows(8, seed=seed * 677 + p + 1)
        # force disjoint identities across ports even under seed overlap
        flows["five_tuple"][:, 0] = (0x0A000000 + (p << 16)
                                     + np.arange(8)).astype(np.uint32)
        per_port.append([_port_events(flows, p, t, events_per_port, seed)
                         for t in range(T)])
    return _assemble(per_port, T)


def flow_churn(total_ports: int, events_per_port: int, T: int,
               seed: int = 0):
    """Half the population churns every period (new keys appear, old ones
    go quiet — admissions happen mid-trace on every port)."""
    per_port = [[] for _ in range(total_ports)]
    stable = PK.gen_flows(8, seed=seed)
    for t in range(T):
        fresh = PK.gen_flows(8, seed=seed * 31 + 1000 + t)
        fresh["five_tuple"][:, 1] = (0xC0A90000 + t * 256
                                     + np.arange(8)).astype(np.uint32)
        merged = {
            "five_tuple": np.concatenate([stable["five_tuple"],
                                          fresh["five_tuple"]]),
            "rate": np.concatenate([stable["rate"], fresh["rate"]]),
        }
        for p in range(total_ports):
            per_port[p].append(_port_events(merged, p, t, events_per_port,
                                            seed))
    return _assemble(per_port, T)


def collision_storm(total_ports: int, events_per_port: int, T: int,
                    seed: int = 0):
    """Far more distinct keys than table slots: admission races, stored-
    key mismatches and resident-flow attribution dominate."""
    flows = PK.gen_flows(512, seed=seed)
    per_port = [[_port_events(flows, p, t, events_per_port, seed)
                 for t in range(T)] for p in range(total_ports)]
    return _assemble(per_port, T)


def bursty_iat(total_ports: int, events_per_port: int, T: int,
               seed: int = 0):
    """Bursts: all packets of a period land in a handful of 200 µs
    windows, separated by silence (extreme IAT bimodality)."""
    flows = PK.gen_flows(12, seed=seed)
    rng = np.random.default_rng(seed + 17)
    per_port = []
    for p in range(total_ports):
        rows = []
        for t in range(T):
            ev = _port_events(flows, p, t, events_per_port, seed)
            bursts = rng.integers(0, PERIOD_US - 200, size=4)
            ev["ts"] = np.sort(
                t * PERIOD_US
                + bursts[rng.integers(0, 4, events_per_port)]
                + rng.integers(0, 200, events_per_port)).astype(np.uint32)
            rows.append(ev)
        per_port.append(rows)
    return _assemble(per_port, T)


def u32_wrap(total_ports: int, events_per_port: int, T: int,
             seed: int = 0):
    """The u32 µs clock wraps mid-trace: period t covers unwrapped time
    [W - 1.5 periods + t*period, ...), cast to u32. IAT, due-elapsed and
    last-report tracking must all survive the wrap identically on every
    mesh."""
    base = (1 << 32) - (3 * PERIOD_US) // 2        # wraps inside period 1
    flows = PK.gen_flows(10, seed=seed)
    rng = np.random.default_rng(seed + 29)
    per_port = []
    for p in range(total_ports):
        rows = []
        for t in range(T):
            ev = _port_events(flows, p, t, events_per_port, seed)
            unwrapped = base + t * PERIOD_US + np.sort(
                rng.integers(0, PERIOD_US, events_per_port))
            ev["ts"] = (unwrapped & 0xFFFFFFFF).astype(np.uint32)
            rows.append(ev)
        per_port.append(rows)
    nows = ((base + np.arange(1, T + 1, dtype=np.uint64) * PERIOD_US)
            & 0xFFFFFFFF).astype(np.uint32)
    return _assemble(per_port, T, nows=nows)


def cross_pod_mix(total_ports: int, events_per_port: int, T: int,
                  seed: int = 0):
    """First half of the ports share one global flow set (cross-pod
    heavy), second half are port-local (pod-local heavy)."""
    shared = PK.gen_flows(16, seed=seed + 3)
    per_port = []
    for p in range(total_ports):
        if p < total_ports // 2:
            flows = shared
        else:
            flows = PK.gen_flows(6, seed=seed * 131 + p)
            flows["five_tuple"][:, 0] = (0x0B000000 + (p << 12)
                                         + np.arange(6)).astype(np.uint32)
        per_port.append([_port_events(flows, p, t, events_per_port, seed)
                         for t in range(T)])
    return _assemble(per_port, T)


def wide_port_sweep(total_ports: int, events_per_port: int, T: int,
                    seed: int = 0):
    """Hundreds-of-ports scaling scenario (the wide wire-format regime):
    fully vectorized generation — every port owns two disjoint local
    flows and all ports share one global elephant, so one trace
    exercises both pod-local and maximally cross-pod homing. No
    per-port/per-flow python loops, so it stays cheap at the >256-port
    counts the V2 schema admits (where the other generators crawl)."""
    P, E = total_ports, events_per_port
    rng = np.random.default_rng(seed + 101)
    local_src = 0x0C000000 + np.arange(P, dtype=np.uint32)
    shared = np.asarray(
        [0x0D000001, 0xD0000001, (443 << 16) | 443, 6, 0], np.uint32)
    rows = {k: [] for k in ("ts", "size", "five_tuple", "valid")}
    for t in range(T):
        choice = rng.integers(0, 3, size=(P, E)).astype(np.uint32)
        is_local = choice < 2
        tup = np.zeros((P, E, 5), np.uint32)
        tup[..., 0] = np.where(is_local, local_src[:, None], shared[0])
        tup[..., 1] = np.where(
            is_local,
            0xC0000000 + 2 * np.arange(P, dtype=np.uint32)[:, None]
            + (choice & 1), shared[1])
        tup[..., 2] = np.where(is_local,
                               ((1000 + choice) << 16) | 2000, shared[2])
        tup[..., 3] = np.where(is_local, 17, shared[3])
        offs = np.sort(rng.integers(0, PERIOD_US, size=(P, E)), axis=1)
        rows["ts"].append(
            (t * PERIOD_US + offs).astype(np.uint32).reshape(P * E))
        rows["size"].append(
            rng.integers(64, 1500, size=(P, E)).astype(np.uint32)
            .reshape(P * E))
        rows["five_tuple"].append(tup.reshape(P * E, 5))
        rows["valid"].append(np.ones((P * E,), bool))
    events = {k: np.stack(v) for k, v in rows.items()}
    nows = np.asarray([(t + 1) * PERIOD_US for t in range(T)], np.uint32)
    return events, nows


SCENARIOS: Dict[str, Callable[..., Tuple[dict, np.ndarray]]] = {
    "elephants_mice": elephants_mice,
    "port_local": port_local,
    "flow_churn": flow_churn,
    "collision_storm": collision_storm,
    "bursty_iat": bursty_iat,
    "u32_wrap": u32_wrap,
    "cross_pod_mix": cross_pod_mix,
    "wide_port_sweep": wide_port_sweep,
}


def build(name: str, total_ports: int, events_per_port: int, T: int,
          seed: int = 0):
    """Registry entry point; raises KeyError listing known scenarios."""
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
    return SCENARIOS[name](total_ports, events_per_port, T, seed=seed)
