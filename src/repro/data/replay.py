"""Trace-replay source for the continuous serving loop.

``launch.serving`` consumes fixed-shape period batches (the pipeline's
event arrays are static: ``n_shards * events_per_shard`` rows every
period), but a live tap does not arrive in tidy period-sized chunks.
:class:`TraceReplaySource` bridges the two: it flattens a pre-built trace
(any ``data.packets.period_batches`` / ``data.scenarios.build`` output)
into one endless host-side event stream and re-offers it at a
configurable rate, with the host-queue semantics a real ingest boundary
has — a bounded carry-over queue, a drop policy when arrivals outrun the
queue, and *exact* per-period accounting.

Arrival pacing is virtual-time: every serving period is assumed to take
exactly one period budget, so ``offered_eps`` events/second translate to
``offered_eps * budget_us / 1e6`` arrivals per period (fractional
remainders carry, so the long-run rate is exact). This keeps replay fully
deterministic — the forced-overrun tests and the nightly latency bench
replay the identical arrival sequence on every run — while still
exercising real backpressure: offering faster than the batch-capacity
rate ``batch_events / budget_us`` grows the queue and forces drops,
which is precisely "ingest outruns the 20 ms budget".

Accounting contract (tested in tests/test_serving.py):

* every period: ``offered == admitted_to_queue + dropped`` and the queue
  never exceeds ``queue_events``;
* with ``queue_events == 0`` there is no carry-over, so per period
  ``offered == processed + dropped`` exactly;
* cumulatively, ``offered == processed + dropped + queued``, and after
  :meth:`begin_drain` + draining batches, ``offered == processed +
  dropped``.

Drop policies: ``"newest"`` tail-drops the just-arrived events (classic
NIC ring overflow); ``"oldest"`` evicts queued events to admit the new
ones (freshness-biased telemetry — stale periods are worthless to a
sub-RTT monitor).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np

DROP_POLICIES = ("newest", "oldest")


class PeriodAccounting(NamedTuple):
    """Exact event bookkeeping for one serving period."""

    offered: int        # events that arrived this period
    processed: int      # valid events placed into this period's batch
    dropped: int        # events shed by the drop policy this period
    queued: int         # events still waiting in the host queue after


class TraceReplaySource:
    """Replays a stacked trace as a paced, queued host event stream.

    Parameters
    ----------
    events, nows:
        A ``period_batches``-shaped trace: dict of ``(T, N, ...)`` arrays
        (keys ts/size/five_tuple/valid) — device or numpy. ``nows`` is
        unused beyond validation; serving re-times events onto its own
        period clock so the stream can run forever (the trace is cycled).
    batch_events:
        N — the fixed event-batch size the pipeline consumes per period.
    offered_eps:
        Offered rate in events/second. 0 (default) means line rate:
        exactly one full batch arrives per period, no queueing, no drops.
    budget_us:
        The period budget used for virtual-time pacing (and re-timing).
    queue_events:
        Host carry-over queue capacity, on top of the in-flight batch.
    drop_policy:
        ``"newest"`` | ``"oldest"`` (see module docstring).
    """

    def __init__(self, events: Dict, nows=None, *, batch_events: int,
                 offered_eps: float = 0.0, budget_us: int = 20_000,
                 queue_events: int = 0, drop_policy: str = "newest"):
        if drop_policy not in DROP_POLICIES:
            raise ValueError(f"unknown drop_policy {drop_policy!r}; "
                             f"known: {list(DROP_POLICIES)}")
        if batch_events <= 0:
            raise ValueError("batch_events must be positive")
        if offered_eps < 0:
            raise ValueError("offered_eps must be >= 0")
        ts = np.asarray(events["ts"])
        if ts.ndim != 2:
            raise ValueError(
                f"expected a stacked (T, N, ...) trace, got ts shape "
                f"{ts.shape}")
        valid = np.asarray(events["valid"]).reshape(-1)
        # flatten to one host stream of real events, trace order
        self._five = np.asarray(events["five_tuple"]).reshape(
            -1, 5)[valid].astype(np.uint32)
        self._size = np.asarray(events["size"]).reshape(
            -1)[valid].astype(np.uint32)
        if len(self._size) == 0:
            raise ValueError("trace has no valid events to replay")
        self.batch_events = int(batch_events)
        self.offered_eps = float(offered_eps)
        self.budget_us = int(budget_us)
        self.queue_events = int(queue_events)
        self.drop_policy = drop_policy
        self._cursor = 0                 # position in the cyclic stream
        self._acc = 0.0                  # fractional-arrival carry
        self._queue: list = []           # [(five_row, size)] FIFO
        self._period = 0
        self._draining = False
        self.total = PeriodAccounting(0, 0, 0, 0)

    # -- the paced stream --------------------------------------------------

    def _arrivals_this_period(self) -> int:
        if self._draining:
            return 0
        if self.offered_eps == 0.0:      # line rate: one batch, no queue
            return self.batch_events
        self._acc += self.offered_eps * self.budget_us / 1e6
        n = int(self._acc)
        self._acc -= n
        return n

    def _take_stream(self, n: int):
        """Next n events of the cyclic flattened trace."""
        idx = (self._cursor + np.arange(n)) % len(self._size)
        self._cursor = int((self._cursor + n) % len(self._size))
        return list(zip(self._five[idx], self._size[idx]))

    def next_batch(self) -> Tuple[Dict[str, np.ndarray], np.uint32,
                                  PeriodAccounting]:
        """One serving period: admit arrivals, apply the drop policy,
        dequeue up to ``batch_events`` into a fixed-shape batch (short
        periods pad with ``valid=False`` rows), and account exactly."""
        offered = self._arrivals_this_period()
        arrivals = self._take_stream(offered)
        dropped = 0
        if self.offered_eps == 0.0 and not self._draining:
            # line rate bypasses the queue entirely: batch == arrivals
            pending = arrivals
        else:
            # room = carry-over queue + the one in-flight batch
            room = self.queue_events + self.batch_events
            self._queue.extend(arrivals)
            excess = len(self._queue) - room
            if excess > 0:
                dropped = excess
                if self.drop_policy == "newest":
                    del self._queue[-excess:]
                else:                    # "oldest": evict the head
                    del self._queue[:excess]
            pending = self._queue[:self.batch_events]
            del self._queue[:self.batch_events]
        processed = len(pending)
        batch = self._assemble(pending)
        now = np.uint32(((self._period + 1) * self.budget_us)
                        & 0xFFFFFFFF)
        self._period += 1
        acct = PeriodAccounting(offered, processed, dropped,
                                len(self._queue))
        self.total = PeriodAccounting(
            self.total.offered + offered,
            self.total.processed + processed,
            self.total.dropped + dropped,
            len(self._queue))
        return batch, now, acct

    def _assemble(self, pending) -> Dict[str, np.ndarray]:
        N = self.batch_events
        t0 = (self._period * self.budget_us) & 0xFFFFFFFF
        n = len(pending)
        five = np.zeros((N, 5), np.uint32)
        size = np.zeros(N, np.uint32)
        valid = np.zeros(N, bool)
        if n:
            five[:n] = np.stack([p[0] for p in pending])
            size[:n] = [p[1] for p in pending]
            valid[:n] = True
        # re-time onto the serving period window, evenly spaced in
        # arrival order (the reporter contract: sorted within a period)
        ts = ((t0 + (np.arange(N, dtype=np.uint64) * self.budget_us)
               // N) & 0xFFFFFFFF).astype(np.uint32)
        return {"ts": ts, "size": size, "five_tuple": five,
                "valid": valid}

    # -- graceful shutdown -------------------------------------------------

    def begin_drain(self) -> None:
        """Stop offering new arrivals; subsequent batches flush the
        queue. After :attr:`pending` hits 0,
        ``total.offered == total.processed + total.dropped`` exactly."""
        self._draining = True

    @property
    def pending(self) -> int:
        """Events still queued on the host (0 once drained)."""
        return len(self._queue)
