"""Deterministic wire-level fault injection for the DFA transport.

DFA's reports travel as one-way RDMA WRITEs from the switch — the
translator computes the ring address ON the switch (§III-B), then the
payload crosses a lossy fabric the collector never acknowledges. The
paper's §VI-B sequence numbers and the Fig 4 checksum exist precisely
because that segment can drop, duplicate, reorder, corrupt, or replay
reports in flight. This module injects exactly those faults, seeded and
composable, on packed payload batches at the one faithful point: AFTER
translation (the address and history index already ride the payload, as
they would on the wire) and BEFORE collector ingest.

Fault taxonomy (all rates are independent per-row probabilities; victim
classes are disjoint by construction, so one physical report suffers at
most one fault and the accounting identities stay exact):

==============  ========================================================
fault           wire meaning / detection obligation
==============  ========================================================
drop            the WRITE never lands. Detected as a per-reporter seq
                GAP (collector ``lost_reports``) once a later seq from
                the same reporter arrives.
bit-flip        in-flight corruption: one random bit of one random word
                is inverted. Detected by the position-dependent
                rotate-xor checksum (``bad_checksum``); the payload is
                discarded, so its seq ALSO surfaces as a gap — a
                corrupted report is a lost report that happened to
                arrive (``lost_reports`` counts drops + flips; flips
                are separable as ``lost - bad`` exactly).
duplicate       the fabric delivers the same WRITE twice. The copy is
                byte-identical and arrives after the original; the
                collector's §VI-B dup tracking rejects it
                (``seq_anomalies``), leaving ring state bitwise equal
                to the clean run.
stale replay    an adversarial/garbled re-send: same (reporter, seq)
                identity, scrambled stats words, VALID checksum (a
                well-formed packet — integrity checks cannot catch it;
                only the seq identity can). Must be rejected BEFORE
                placement or it would silently corrupt the ring.
bounded reorder the fabric delivers a window of WRITEs out of order.
                Applied to original rows only, within blocks of
                ``reorder_window`` rows. The collector is
                order-invariant for distinct (flow, hist) targets, so a
                reorder-only run is bitwise identical to clean.
==============  ========================================================

Drop and flip victims are chosen among rows that are NOT their
reporter's highest seq in the batch, so the resulting gap is detectable
in the SAME period (another accepted report with a higher seq arrives
alongside) — this is what makes the per-period identity
``Δlost_reports == injected_drops + injected_flips`` exact rather than
lagged. Tail losses (the reporter's last report of a period) are real
too; the collector detects them one period late, which the unit suite
covers separately — the injector just doesn't produce them, by design.

Duplicate/replay copies are appended in a second R-row region after the
originals, so a copy's row index always exceeds its original's: the
collector's first-arrival-wins rule then deterministically keeps the
original, which is what the bitwise differential requires (and what a
real replay looks like — the copy is, by causality, later).

Determinism: the PRNG key folds (spec.seed, period timestamp, device
index), so a fault schedule is a pure function of the spec and the
stream position — the differential suites replay it exactly.

Accounting is in the UNWRAPPED seq regime (the §VI-B dup window and the
gap tracker both assume the per-reporter wire seq has not wrapped); the
property suite keeps its traces inside one wrap, matching the
collector's documented regime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import protocol as PROTO
from repro.core import wire as WIRE

# ledger codes (metrics["fault_kind"]): one per injected-fault class
KIND_NONE = 0
KIND_DROP = 1
KIND_DUP = 2
KIND_FLIP = 3
KIND_REPLAY = 4

COUNT_KEYS = ("injected_drops", "injected_dups", "injected_flips",
              "injected_replays", "injected_reorders")
LEDGER_KEYS = ("fault_kind", "fault_flow", "fault_hist")


@dataclass(frozen=True)
class FaultSpec:
    """A seeded, composable transport-fault schedule.

    Frozen + hashable so it can ride ``DFAConfig.fault_spec`` (the config
    stays a jit-static argument). All-zero rates mean "not armed": the
    pipeline skips injection entirely at trace time, so an unconfigured
    fault path costs nothing."""

    seed: int = 0
    drop_rate: float = 0.0
    dup_rate: float = 0.0
    flip_rate: float = 0.0
    replay_rate: float = 0.0
    reorder_rate: float = 0.0      # per-BLOCK probability of a shuffle
    reorder_window: int = 4        # max displacement bound (block size)

    def __post_init__(self):
        for f in ("drop_rate", "dup_rate", "flip_rate", "replay_rate",
                  "reorder_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f}={v} must be a probability")
        if (self.drop_rate + self.dup_rate + self.flip_rate
                + self.replay_rate) > 1.0:
            raise ValueError(
                "drop+dup+flip+replay rates exceed 1.0 — victim classes "
                "are disjoint slices of one uniform draw, so their rates "
                "must sum to at most 1")
        if self.reorder_window < 2:
            raise ValueError("reorder_window must be >= 2")

    @property
    def armed(self) -> bool:
        return (self.drop_rate > 0 or self.dup_rate > 0
                or self.flip_rate > 0 or self.replay_rate > 0
                or self.reorder_rate > 0)

    @property
    def appends_copies(self) -> bool:
        """Whether inject() returns a 2R-row batch (copy region)."""
        return self.dup_rate > 0 or self.replay_rate > 0

    def describe(self) -> str:
        if not self.armed:
            return "none"
        parts = [f"{k}={getattr(self, k):g}" for k in
                 ("drop_rate", "dup_rate", "flip_rate", "replay_rate",
                  "reorder_rate") if getattr(self, k) > 0]
        return f"seed={self.seed}," + ",".join(parts)


def _blockwise_permutation(key, R: int, window: int, rate: float,
                           ) -> jax.Array:
    """A bounded-displacement permutation of ``range(R)``: rows move only
    within their ``window``-sized block, and each block shuffles with
    probability ``rate`` (identity otherwise)."""
    blk = jnp.arange(R, dtype=jnp.int32) // window
    n_blk = (R + window - 1) // window
    k_act, k_rank = jax.random.split(key)
    active = jax.random.uniform(k_act, (n_blk,)) < rate
    pos = (jnp.arange(R, dtype=jnp.int32) % window).astype(jnp.float32)
    rank = jnp.where(active[blk], jax.random.uniform(k_rank, (R,)), pos)
    # two-pass stable argsort = lexsort by (block, rank): blocks stay in
    # order, active blocks get a uniform shuffle inside
    o1 = jnp.argsort(rank, stable=True)
    return o1[jnp.argsort(blk[o1], stable=True)]


def inject(payloads: jax.Array, mask: jax.Array, spec: FaultSpec,
           wire: WIRE.WireFormat, now: jax.Array, salt: jax.Array
           ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array],
                      Dict[str, jax.Array]]:
    """Apply ``spec`` to one translated payload batch.

    payloads: (R, payload_words) u32; mask: (R,) bool. ``now`` is the
    period timestamp and ``salt`` the device index — both fold into the
    PRNG key so every (period, device) gets an independent, reproducible
    schedule.

    Returns ``(payloads', mask', counts, ledger)``; the row count is R,
    or 2R when the spec injects duplicate/replay copies (the second
    region holds the copies, masked on only where one was injected).
    ``counts`` holds the per-class injected totals (scalars, to be
    psum'd into the period metrics); ``ledger`` holds per-row arrays
    (``fault_kind``/``fault_flow``/``fault_hist``) the differential
    suites use to reconstruct the expected end state.
    """
    R = payloads.shape[0]
    key = jax.random.fold_in(jax.random.key(spec.seed),
                             now.astype(jnp.uint32))
    key = jax.random.fold_in(key, salt.astype(jnp.uint32))
    k_reord, k_u, k_word, k_bit, k_scram = jax.random.split(key, 5)

    pay, m = payloads, mask
    n_moved = jnp.zeros((), jnp.uint32)
    if spec.reorder_rate > 0:
        perm = _blockwise_permutation(k_reord, R, spec.reorder_window,
                                      spec.reorder_rate)
        pay, m = pay[perm], m[perm]
        n_moved = jnp.sum(m & (perm != jnp.arange(R))).astype(jnp.uint32)

    rep = wire.payload_reporter.extract(pay)
    seq = wire.payload_seq.extract(pay)
    n_rep = wire.n_reporters
    # per-reporter batch-max seq: a row holding it is the reporter's
    # "tail" this period — losing it would defer gap detection by a
    # period, so drop/flip victims exclude tails (see module docstring)
    ridx = jnp.where(m, rep.astype(jnp.int32), n_rep)
    bmax = jnp.zeros((n_rep + 1,), jnp.uint32).at[ridx].max(
        seq + 1, mode="drop")
    tail = m & (seq + 1 == bmax[jnp.clip(ridx, 0, n_rep)])

    u = jax.random.uniform(k_u, (R,))
    f0 = spec.flip_rate
    d0 = f0 + spec.drop_rate
    p0 = d0 + spec.dup_rate
    r0 = p0 + spec.replay_rate
    flip = m & ~tail & (u < f0) if spec.flip_rate > 0 \
        else jnp.zeros_like(m)
    drop = m & ~tail & (u >= f0) & (u < d0) if spec.drop_rate > 0 \
        else jnp.zeros_like(m)
    dup = m & (u >= d0) & (u < p0) if spec.dup_rate > 0 \
        else jnp.zeros_like(m)
    repl = m & (u >= p0) & (u < r0) if spec.replay_rate > 0 \
        else jnp.zeros_like(m)

    flow0 = pay[:, 0]
    hist0 = wire.payload_hist.extract(pay)
    kind = jnp.zeros((R,), jnp.uint32)

    if spec.flip_rate > 0:
        W = wire.payload_words
        w_sel = jax.random.randint(k_word, (R,), 0, W)
        b_sel = jax.random.randint(k_bit, (R,), 0, 32)
        bitval = jnp.left_shift(jnp.uint32(1), b_sel.astype(jnp.uint32))
        hit = (jnp.arange(W)[None, :] == w_sel[:, None]) & flip[:, None]
        pay = pay ^ jnp.where(hit, bitval[:, None], jnp.uint32(0))
        kind = jnp.where(flip, jnp.uint32(KIND_FLIP), kind)
    if spec.drop_rate > 0:
        m = m & ~drop
        kind = jnp.where(drop, jnp.uint32(KIND_DROP), kind)

    counts = {
        "injected_drops": jnp.sum(drop).astype(jnp.uint32),
        "injected_dups": jnp.sum(dup).astype(jnp.uint32),
        "injected_flips": jnp.sum(flip).astype(jnp.uint32),
        "injected_replays": jnp.sum(repl).astype(jnp.uint32),
        "injected_reorders": n_moved,
    }

    if not spec.appends_copies:
        ledger = {"fault_kind": kind, "fault_flow": flow0,
                  "fault_hist": hist0}
        return pay, m, counts, ledger

    # copy region: duplicates are byte-identical; replays keep the
    # (reporter, seq, flow, hist) identity but scramble the stats words
    # and re-fold a VALID checksum — only the seq defense can catch them
    cp = pay
    cmask = dup | repl
    ckind = jnp.where(dup, jnp.uint32(KIND_DUP),
                      jnp.where(repl, jnp.uint32(KIND_REPLAY),
                                jnp.uint32(KIND_NONE)))
    if spec.replay_rate > 0:
        sl = wire.payload_stats_slice
        n_stats = sl.stop - sl.start
        scram = jax.random.randint(
            k_scram, (R, n_stats), 1, 1 << 30).astype(jnp.uint32)
        stats = jnp.where(repl[:, None], cp[:, sl] ^ scram, cp[:, sl])
        cp = cp.at[:, sl].set(stats)
        covered = cp[:, jnp.asarray(wire.csum_covered)]
        csum = PROTO.xor_checksum(
            covered, jnp.asarray(wire.csum_covered, jnp.uint32))
        cp = cp.at[:, wire.csum_word].set(
            jnp.where(repl, csum, cp[:, wire.csum_word]))

    pay2 = jnp.concatenate([pay, cp], axis=0)
    m2 = jnp.concatenate([m, cmask], axis=0)
    ledger = {
        "fault_kind": jnp.concatenate([kind, ckind]),
        "fault_flow": jnp.concatenate([flow0, cp[:, 0]]),
        "fault_hist": jnp.concatenate(
            [hist0, wire.payload_hist.extract(cp)]),
    }
    return pay2, m2, counts, ledger
