"""Mixture-of-Experts FFN with expert parallelism.

Experts are sharded over the "model" mesh axis (EP=TP axis). Because our
activations are TP-replicated over "model" between blocks, dispatch does NOT
need an all_to_all: every rank sees every token, gathers only the pairs owned
by its local experts into capacity-bounded buffers (argsort ranking — the
TPU-native replacement for random scatter), runs its experts, and the partial
outputs are psum-combined over "model". Communication per token is one
all-reduce of (T, d) — the same volume as GShard's double all_to_all at k=8,
with far simpler code and no load-dependent message sizes. See DESIGN.md §4.

Routing follows the config: softmax or sigmoid scores (deepseek-v3), top-k,
renormalized, optional routed scaling factor; shared experts bypass routing.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size as _axis_size, shard_map as _shard_map
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamDesc

Tree = Any


def moe_descs(cfg: ModelConfig) -> Tree:
    m = cfg.moe
    dt = cfg.param_dtype
    E, d, f = m.num_experts, cfg.d_model, m.d_ff_expert
    t = {
        "router": ParamDesc((d, E), "float32", ("embed", None)),
        "gate": ParamDesc((E, d, f), dt, ("experts", "embed", None)),
        "up": ParamDesc((E, d, f), dt, ("experts", "embed", None)),
        "down": ParamDesc((E, f, d), dt, ("experts", None, "embed")),
    }
    if m.score_func == "sigmoid":
        t["bias"] = ParamDesc((E,), "float32", (None,), init="zeros")
    if m.num_shared_experts:
        f_sh = m.d_ff_shared * m.num_shared_experts
        t["shared"] = {
            "gate": L.linear_descs(d, f_sh, dt, in_axis="embed",
                                   out_axis="model"),
            "up": L.linear_descs(d, f_sh, dt, in_axis="embed",
                                 out_axis="model"),
            "down": L.linear_descs(f_sh, d, dt, in_axis="model",
                                   out_axis="embed"),
        }
    return t


def route(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d) -> (weights (T,k) f32, experts (T,k) i32)."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ params["router"]        # (T, E)
    if m.score_func == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["bias"][None, :]               # bias only for selection
        w, idx = jax.lax.top_k(sel, m.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)        # weight w/o bias
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        w = w * m.routed_scaling_factor
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx


def _expert_gather_compute(x_flat, w_pair, e_pair, params_loc, E_loc, C,
                           my_first):
    """Masked local dispatch on one EP rank.

    x_flat: (T, d) all tokens (replicated); e_pair/w_pair: (T*k,) routing.
    Returns partial output (T, d) — nonzero only for pairs owned here.
    """
    T, d = x_flat.shape
    Pairs = e_pair.shape[0]
    k = Pairs // T
    le = e_pair - my_first
    valid = (le >= 0) & (le < E_loc)
    key = jnp.where(valid, le, E_loc).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)                    # (Pairs,)
    sorted_le = key[order]
    start = jnp.searchsorted(sorted_le, jnp.arange(E_loc), side="left")
    rank_in_e = jnp.arange(Pairs) - start[jnp.clip(sorted_le, 0, E_loc - 1)]
    ok = (sorted_le < E_loc) & (rank_in_e < C)
    slot = jnp.where(ok, sorted_le * C + rank_in_e, E_loc * C)
    pair_tok = order // k                                    # token of pair
    # slot-space bookkeeping: (E_loc*C+1,) — NEVER pair-space (T*k, d)
    # tensors (a (T*k, d) combine buffer is the memory bug this replaces)
    buf_tok = jnp.full((E_loc * C + 1,), T, jnp.int32)
    buf_tok = buf_tok.at[slot].set(jnp.where(ok, pair_tok, T))
    w_slot = jnp.zeros((E_loc * C + 1,), jnp.float32)
    w_slot = w_slot.at[slot].set(jnp.where(ok, w_pair[order], 0.0))
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)], 0)
    buf = x_pad[buf_tok[:-1]].reshape(E_loc, C, d)
    # expert FFN (silu-gated)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params_loc["gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params_loc["up"])
    out = jnp.einsum("ecf,efd->ecd", h, params_loc["down"])  # (E_loc,C,d)
    out_flat = out.reshape(E_loc * C, d)
    # combine: weight each SLOT row, scatter-add to its token
    rows = out_flat * w_slot[:-1, None].astype(out_flat.dtype)
    contrib = jnp.zeros((T + 1, d), out_flat.dtype)
    contrib = contrib.at[buf_tok[:-1]].add(rows)
    return contrib[:T]


def decode_ep_axes(cfg: ModelConfig, mesh: Mesh, tokens: int
                   ) -> Tuple[str, ...]:
    """EP axes for the SERVING path: widen EP over ("model","data") when
    the expert count divides and the token activations are small enough to
    replicate — then every device holds whole experts and the per-layer
    FSDP weight gathers disappear (EXPERIMENTS.md §Perf, deepseek decode)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = []
    prod = 1
    for ax in ("model", "data", "pod"):
        if ax in sizes and cfg.moe.num_experts % (prod * sizes[ax]) == 0:
            axes.append(ax)
            prod *= sizes[ax]
    # replicating x must stay cheap (decode: ~128 tokens)
    if tokens * cfg.d_model * 2 > 64 * 2**20:
        return ("model",)
    return tuple(axes) if axes else ("model",)


def moe_ffn(params, x, cfg: ModelConfig, mesh: Mesh,
            batch_axes: Tuple[str, ...],
            ep_axes: Tuple[str, ...] = ("model",)) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Experts sharded over ``ep_axes``.

    ep_axes == ("model",): training layout — activations replicated over
    "model", expert d/f dims FSDP-sharded over "data" (gathered per layer).
    Wider ep_axes (serving): x replicated over all ep axes, experts whole
    per device, combine = one psum over ep_axes."""
    m = cfg.moe
    B, S, d = x.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = math.prod([sizes[a] for a in ep_axes])
    E_loc = m.num_experts // ep
    rep_x = len(ep_axes) > 1                  # x fully replicated mode
    if rep_x:
        T_loc = B * S
        ba = None
    else:
        bsz = math.prod([sizes[a] for a in batch_axes]) if batch_axes else 1
        T_loc = (B // bsz) * S
        ba = batch_axes if batch_axes else None
    C = max(1, int(math.ceil(T_loc * m.top_k * m.capacity_factor
                             / m.num_experts)))
    bias = params.get("bias")
    if bias is None:
        bias = jnp.zeros((m.num_experts,), jnp.float32)

    def local(xb, router, b, gate, up, down):
        T = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(T, d)
        p = {"router": router, "gate": gate, "up": up, "down": down,
             "bias": b}
        w, idx = route(p, xf, cfg)
        my_rank = jnp.zeros((), jnp.int32)
        for a in ep_axes:
            my_rank = my_rank * _axis_size(a) + jax.lax.axis_index(a)
        my_first = my_rank * E_loc
        out = _expert_gather_compute(
            xf, w.reshape(-1), idx.reshape(-1).astype(jnp.int32),
            p, E_loc, C, my_first)
        out = jax.lax.psum(out, ep_axes)
        return out.reshape(xb.shape).astype(xb.dtype)

    espec = ep_axes[0] if len(ep_axes) == 1 else tuple(ep_axes)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(ba, None, None), P(None, None), P(None),
                  P(espec, None, None), P(espec, None, None),
                  P(espec, None, None)),
        out_specs=P(ba, None, None), check=False)
    y = fn(x, params["router"], bias, params["gate"], params["up"],
           params["down"])
    if m.num_shared_experts:
        y = y + L.ffn(params["shared"], x)
    return y


def load_balance_loss(params, x, cfg: ModelConfig) -> jax.Array:
    """Auxiliary load-balancing loss (Switch-style), computed on a token
    sample outside the shard_map (train-time regularizer)."""
    m = cfg.moe
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    probs = jax.nn.softmax(xf @ params["router"], axis=-1)   # (T, E)
    _, idx = jax.lax.top_k(probs, m.top_k)
    onehot = jax.nn.one_hot(idx[..., 0], m.num_experts)
    frac_tokens = jnp.mean(onehot, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
