"""zamba2 hybrid assembly: Mamba2 trunk with shared full-attention blocks.

Layers are grouped into segments of ``attn_every`` Mamba2 blocks followed by
one shared attention+FFN block; the ``num_shared_blocks`` (2) weight sets
alternate across segments (zamba2's per-invocation LoRA adapters are omitted —
noted in DESIGN.md §11). The outer scan runs over segments, the inner scan
over the Mamba2 layers of a segment, so HLO stays depth-independent.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.param import ParamDesc

Tree = Any


def _plan(cfg: ModelConfig) -> Tuple[int, int]:
    k = cfg.hybrid.attn_every
    assert cfg.num_layers % k == 0, "hybrid: num_layers % attn_every != 0"
    return cfg.num_layers // k, k          # (num_segments, mamba per segment)


def hybrid_descs(cfg: ModelConfig) -> Tree:
    nseg, per = _plan(cfg)
    mamba = L.stack_descs(L.stack_descs(
        {"ln": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
         "mamba": S.mamba2_descs(cfg)}, per), nseg)
    shared = L.stack_descs(
        {"ln1": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
         "attn": A.attn_descs(cfg),
         "ln2": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
         "ffn": L.ffn_descs(cfg)}, cfg.hybrid.num_shared_blocks)
    return {"embed": L.embed_descs(cfg),
            "final_norm": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
            "trunk": mamba, "shared": shared}


def _select_shared(params_shared, seg_idx, n_blocks):
    sel = seg_idx % n_blocks
    return jax.tree.map(lambda a: a[sel], params_shared)


def _shared_attn_train(sp, x, cfg, mesh, batch_axes):
    h = L.rms_norm(sp["ln1"], x, cfg.norm_eps)
    h = A.attn_train(sp["attn"], h, cfg, mesh=mesh, batch_axes=batch_axes)
    x = x + h
    h = L.rms_norm(sp["ln2"], x, cfg.norm_eps)
    return x + L.ffn(sp["ffn"], h, cfg.act)


def hybrid_hidden(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    nseg, per = _plan(cfg)
    x = L.embed(params["embed"], batch["tokens"])

    def seg_body(h, xs):
        seg_params, seg_idx = xs

        def mamba_body(hh, lp):
            hh = hh + S.mamba2_train(lp["mamba"],
                                     L.rms_norm(lp["ln"], hh, cfg.norm_eps),
                                     cfg)
            return hh, ()

        inner = jax.checkpoint(mamba_body) if cfg.remat == "full" \
            else mamba_body
        h, _ = jax.lax.scan(inner, h, seg_params)
        sp = _select_shared(params["shared"], seg_idx,
                            cfg.hybrid.num_shared_blocks)
        h = _shared_attn_train(sp, h, cfg, mesh, batch_axes)
        return L.seq_shard(h, mesh, batch_axes), ()

    body = jax.checkpoint(seg_body) if cfg.remat == "full" else seg_body
    x, _ = jax.lax.scan(body, x, (params["trunk"], jnp.arange(nseg)))
    return L.rms_norm(params["final_norm"], x, cfg.norm_eps)


def hybrid_loss(params, batch, cfg, mesh, batch_axes):
    x = hybrid_hidden(params, batch, cfg, mesh, batch_axes)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    return L.chunked_ce_loss(params["embed"], x, batch["targets"], mask,
                             cfg.tie_embeddings, cfg.loss_chunk,
                             mesh, batch_axes)


# -------------------------------------------------------------- caches -----

def hybrid_cache_descs(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    """LIST of per-segment caches (1:1 donation aliasing — see lm.py)."""
    nseg, per = _plan(cfg)
    D = cfg.resolved_head_dim
    seg = lambda: {
        "mamba": L.stack_descs(S.mamba2_state_descs(cfg, batch), per),
        "attn_k": ParamDesc((batch, seq, cfg.num_kv_heads, D), cfg.dtype,
                            ("batch", "kv_seq", None, None), init="zeros"),
        "attn_v": ParamDesc((batch, seq, cfg.num_kv_heads, D), cfg.dtype,
                            ("batch", "kv_seq", None, None), init="zeros"),
    }
    return [seg() for _ in range(nseg)]


def hybrid_prefill(params, batch, cfg, mesh, batch_axes):
    """Prefill: run train-style forward but collect mamba final states and
    attention K/V per segment."""
    nseg, per = _plan(cfg)
    x = L.embed(params["embed"], batch["tokens"])

    def seg_body(h, xs):
        seg_params, seg_idx = xs

        def mamba_body(hh, lp):
            hn = L.rms_norm(lp["ln"], hh, cfg.norm_eps)
            s = cfg.ssm
            Bsz, S_, d = hn.shape
            d_inner = s.expand * d
            H = d_inner // s.head_dim
            z = L.linear(lp["mamba"]["in_z"], hn)
            xin = L.linear(lp["mamba"]["in_x"], hn)
            Bv = L.linear(lp["mamba"]["in_b"], hn)
            Cv = L.linear(lp["mamba"]["in_c"], hn)
            dtv = L.linear(lp["mamba"]["in_dt"], hn)
            conv_x_state = xin.astype(jnp.float32)[:, -(s.conv_width - 1):]
            conv_b_state = Bv.astype(jnp.float32)[:, -(s.conv_width - 1):]
            conv_c_state = Cv.astype(jnp.float32)[:, -(s.conv_width - 1):]
            xin = jax.nn.silu(S._causal_conv(xin, lp["mamba"]["conv_x"]["w"],
                                             lp["mamba"]["conv_x"]["b"]))
            Bv = jax.nn.silu(S._causal_conv(Bv, lp["mamba"]["conv_b"]["w"],
                                            lp["mamba"]["conv_b"]["b"]))
            Cv = jax.nn.silu(S._causal_conv(Cv, lp["mamba"]["conv_c"]["w"],
                                            lp["mamba"]["conv_c"]["b"]))
            dtv = jax.nn.softplus(dtv.astype(jnp.float32) +
                                  lp["mamba"]["dt_bias"][None, None, :])
            Av = -jnp.exp(lp["mamba"]["A_log"])
            xh = xin.astype(jnp.float32).reshape(Bsz, S_, H, s.head_dim)
            Bh = Bv.astype(jnp.float32).reshape(Bsz, S_, s.n_groups,
                                                s.state_dim)
            Ch = Cv.astype(jnp.float32).reshape(Bsz, S_, s.n_groups,
                                                s.state_dim)
            y, ssm_state = S.ssd_chunked(xh, dtv, Av, Bh, Ch,
                                         lp["mamba"]["D"], s.chunk_size)
            y = y.reshape(Bsz, S_, d_inner).astype(hn.dtype)
            y = L.rms_norm(lp["mamba"]["norm"], y * jax.nn.silu(z),
                           cfg.norm_eps)
            hh = hh + L.linear(lp["mamba"]["out"], y)
            st = {"ssm": ssm_state, "conv_x": conv_x_state,
                  "conv_b": conv_b_state, "conv_c": conv_c_state}
            return hh, st

        h, mstates = jax.lax.scan(mamba_body, h, seg_params)
        sp = _select_shared(params["shared"], seg_idx,
                            cfg.hybrid.num_shared_blocks)
        hn = L.rms_norm(sp["ln1"], h, cfg.norm_eps)
        a, (k, v) = A.attn_train(sp["attn"], hn, cfg, return_kv=True)
        h = h + a
        hn = L.rms_norm(sp["ln2"], h, cfg.norm_eps)
        h = h + L.ffn(sp["ffn"], hn, cfg.act)
        return h, (mstates, k, v)

    x, (mstates, ks, vs) = jax.lax.scan(
        seg_body, x, (params["trunk"], jnp.arange(nseg)))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x[:, -1:, :],
                         cfg.tie_embeddings)[:, 0]
    cache = [{"mamba": jax.tree.map(lambda a: a[i], mstates),
              "attn_k": ks[i], "attn_v": vs[i]} for i in range(nseg)]
    return logits, cache


def hybrid_decode(params, token, pos, cache, cfg, mesh, batch_axes,
                  seq_axes):
    nseg, per = _plan(cfg)
    x = L.embed(params["embed"], token)

    # unrolled over segments: per-segment cache leaves alias 1:1
    new_cache = list(cache)
    for seg in range(nseg):
        seg_params = jax.tree.map(lambda a: a[seg], params["trunk"])
        seg_cache = cache[seg]

        def mamba_body(hh, xs2):
            lp, st = xs2
            y, st2 = S.mamba2_decode(lp["mamba"],
                                     L.rms_norm(lp["ln"], hh, cfg.norm_eps),
                                     cfg, st)
            return hh + y, st2

        x, new_mamba = jax.lax.scan(mamba_body, x,
                                    (seg_params, seg_cache["mamba"]))
        sp = jax.tree.map(
            lambda a: a[seg % cfg.hybrid.num_shared_blocks],
            params["shared"])
        hn = L.rms_norm(sp["ln1"], x, cfg.norm_eps)
        a, k_c, v_c = A.attn_decode(sp["attn"], hn, cfg,
                                    seg_cache["attn_k"],
                                    seg_cache["attn_v"], pos,
                                    mesh=mesh, seq_axes=seq_axes,
                                    batch_axes=batch_axes)
        x = x + a
        hn = L.rms_norm(sp["ln2"], x, cfg.norm_eps)
        x = x + L.ffn(sp["ffn"], hn, cfg.act)
        new_cache[seg] = {"mamba": new_mamba, "attn_k": k_c,
                          "attn_v": v_c}
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, new_cache
