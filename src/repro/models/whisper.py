"""whisper-tiny encoder-decoder. The conv/mel frontend is a STUB: batches
carry precomputed frame embeddings (B, F, d_model) — see input_specs().
Pre-LN transformer with learned positions, GELU MLPs, cross-attention."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.param import ParamDesc

Tree = Any


def _enc_block_descs(cfg):
    return {"ln1": L.layer_norm_descs(cfg.d_model, cfg.param_dtype),
            "attn": A.attn_descs(cfg),
            "ln2": L.layer_norm_descs(cfg.d_model, cfg.param_dtype),
            "ffn": L.ffn_descs(cfg)}


def _dec_block_descs(cfg):
    t = _enc_block_descs(cfg)
    t["ln_x"] = L.layer_norm_descs(cfg.d_model, cfg.param_dtype)
    t["xattn"] = A.attn_descs(cfg)
    return t


def whisper_descs(cfg: ModelConfig) -> Tree:
    e = cfg.encdec
    return {
        "embed": L.embed_descs(cfg),
        "pos_dec": ParamDesc((4096 if cfg.vocab_size > 1000 else 64,
                              cfg.d_model), cfg.param_dtype, (None, "embed"),
                             init="embed"),
        "pos_enc": ParamDesc((e.num_frames, cfg.d_model), cfg.param_dtype,
                             (None, "embed"), init="embed"),
        "encoder": L.stack_descs(_enc_block_descs(cfg), e.num_encoder_layers),
        "enc_norm": L.layer_norm_descs(cfg.d_model, cfg.param_dtype),
        "decoder": L.stack_descs(_dec_block_descs(cfg), cfg.num_layers),
        "final_norm": L.layer_norm_descs(cfg.d_model, cfg.param_dtype),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    F = frames.shape[1]
    x = frames + params["pos_enc"][None, :F]

    def body(h, lp):
        hn = L.layer_norm(lp["ln1"], h, cfg.norm_eps)
        h = h + A.attn_train(lp["attn"], hn, cfg, causal=False, rope=False)
        hn = L.layer_norm(lp["ln2"], h, cfg.norm_eps)
        h = h + L.ffn(lp["ffn"], hn, cfg.act)
        return h, ()

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.layer_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_positions(params, tokens, offset=0):
    S = tokens.shape[1]
    pos_table = params["pos_dec"]
    idx = jnp.clip(offset + jnp.arange(S), 0, pos_table.shape[0] - 1)
    return pos_table[idx]


def _cross_kv(lp, enc, cfg):
    B, F, _ = enc.shape
    D = cfg.resolved_head_dim
    k = L.linear(lp["xattn"]["k"], enc).reshape(B, F, cfg.num_kv_heads, D)
    v = L.linear(lp["xattn"]["v"], enc).reshape(B, F, cfg.num_kv_heads, D)
    return k, v


def _cross_attend(lp, h, xk, xv, cfg):
    B, S, _ = h.shape
    D = cfg.resolved_head_dim
    q = L.linear(lp["xattn"]["q"], h).reshape(B, S, cfg.num_heads, D)
    o = A.full_attention(q, xk, xv)
    return L.linear(lp["xattn"]["o"], o.reshape(B, S, -1))


def decoder_hidden(params, tokens, enc, cfg: ModelConfig, mesh=None,
                   batch_axes=()):
    x = L.embed(params["embed"], tokens) + _dec_positions(params, tokens)

    def body(h, lp):
        hn = L.layer_norm(lp["ln1"], h, cfg.norm_eps)
        h = h + A.attn_train(lp["attn"], hn, cfg, causal=True, rope=False)
        hn = L.layer_norm(lp["ln_x"], h, cfg.norm_eps)
        xk, xv = _cross_kv(lp, enc, cfg)
        h = h + _cross_attend(lp, hn, xk, xv, cfg)
        hn = L.layer_norm(lp["ln2"], h, cfg.norm_eps)
        h = h + L.ffn(lp["ffn"], hn, cfg.act)
        return L.seq_shard(h, mesh, batch_axes), ()

    body = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body, x, params["decoder"])
    return L.layer_norm(params["final_norm"], x, cfg.norm_eps)


def whisper_loss(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    enc = encode(params, batch["frames"], cfg)
    x = decoder_hidden(params, batch["tokens"], enc, cfg, mesh, batch_axes)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    return L.chunked_ce_loss(params["embed"], x, batch["targets"], mask,
                             cfg.tie_embeddings, cfg.loss_chunk,
                             mesh, batch_axes)


def whisper_cache_descs(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    """LIST of per-layer caches (1:1 donation aliasing — see lm.py)."""
    D = cfg.resolved_head_dim
    F = cfg.encdec.num_frames
    kv = lambda s: ParamDesc((batch, s, cfg.num_kv_heads, D), cfg.dtype,
                             ("batch", "kv_seq", None, None), init="zeros")
    xkv = lambda: ParamDesc((batch, F, cfg.num_kv_heads, D), cfg.dtype,
                            ("batch", None, None, None), init="zeros")
    return [{"k": kv(seq), "v": kv(seq), "xk": xkv(), "xv": xkv()}
            for _ in range(cfg.num_layers)]


def whisper_prefill(params, batch, cfg: ModelConfig, mesh: Mesh,
                    batch_axes):
    """Encode audio + run decoder over the prompt, building all caches."""
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens) + _dec_positions(params, tokens)

    def body(h, lp):
        hn = L.layer_norm(lp["ln1"], h, cfg.norm_eps)
        a, (k, v) = A.attn_train(lp["attn"], hn, cfg, causal=True,
                                 return_kv=True, rope=False)
        h = h + a
        hn = L.layer_norm(lp["ln_x"], h, cfg.norm_eps)
        xk, xv = _cross_kv(lp, enc, cfg)
        h = h + _cross_attend(lp, hn, xk, xv, cfg)
        hn = L.layer_norm(lp["ln2"], h, cfg.norm_eps)
        h = h + L.ffn(lp["ffn"], hn, cfg.act)
        return h, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["decoder"])
    x = L.layer_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x[:, -1:, :],
                         cfg.tie_embeddings)[:, 0]
    cache = [{"k": ks[i], "v": vs[i], "xk": xks[i], "xv": xvs[i]}
             for i in range(cfg.num_layers)]
    return logits, cache


def whisper_decode(params, token, pos, cache, cfg: ModelConfig, mesh: Mesh,
                   batch_axes, seq_axes):
    pos_table = params["pos_dec"]
    x = L.embed(params["embed"], token) + pos_table[
        jnp.clip(pos, 0, pos_table.shape[0] - 1)][:, None, :]

    new_cache = list(cache)
    for l in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[l], params["decoder"])
        lc = cache[l]
        hn = L.layer_norm(lp["ln1"], x, cfg.norm_eps)
        B = hn.shape[0]
        D = cfg.resolved_head_dim
        q = L.linear(lp["attn"]["q"], hn).reshape(B, 1, cfg.num_heads, D)
        k = L.linear(lp["attn"]["k"], hn).reshape(B, 1, cfg.num_kv_heads, D)
        v = L.linear(lp["attn"]["v"], hn).reshape(B, 1, cfg.num_kv_heads, D)
        out, k_c, v_c = A.flash_decode(
            q[:, 0], lc["k"], lc["v"], k[:, 0], v[:, 0], pos, mesh=mesh,
            seq_axes=seq_axes, batch_axes=batch_axes)
        x = x + L.linear(lp["attn"]["o"], out.reshape(B, 1, -1))
        hn = L.layer_norm(lp["ln_x"], x, cfg.norm_eps)
        x = x + _cross_attend_cached(lp, hn, lc["xk"], lc["xv"], cfg)
        hn = L.layer_norm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.ffn(lp["ffn"], hn, cfg.act)
        new_cache[l] = {"k": k_c.astype(lc["k"].dtype),
                        "v": v_c.astype(lc["v"].dtype),
                        "xk": lc["xk"], "xv": lc["xv"]}
    x = L.layer_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, new_cache


def _cross_attend_cached(lp, h, xk, xv, cfg):
    B, S, _ = h.shape
    D = cfg.resolved_head_dim
    q = L.linear(lp["xattn"]["q"], h).reshape(B, S, cfg.num_heads, D)
    o = A.full_attention(q, xk, xv)
    return L.linear(lp["xattn"]["o"], o.reshape(B, S, -1))
