"""Decoder-only LM assembly for the dense / vlm / moe families.

Layer stacks are scanned (HLO size independent of depth); MoE models with a
dense prefix (deepseek-v3: first 3 layers) use two scans. VLM/early-fusion
models prepend stub patch embeddings to the token sequence. MTP (deepseek)
adds one multi-token-prediction block on the train path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models.param import ParamDesc

Tree = Any


# ------------------------------------------------------------- descs -------

def block_descs(cfg: ModelConfig, kind: str) -> Tree:
    """One transformer block. kind: "dense" | "moe"."""
    t = {"ln1": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
         "ln2": L.rms_norm_descs(cfg.d_model, cfg.param_dtype)}
    t["attn"] = A.mla_descs(cfg) if cfg.mla else A.attn_descs(cfg)
    if kind == "moe":
        t["moe"] = M.moe_descs(cfg)
    else:
        d_ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                else cfg.d_ff)
        t["ffn"] = L.ffn_descs(cfg, d_ff)
    return t


def _segments(cfg: ModelConfig):
    """[(kind, n_layers)] — contiguous uniform stacks for scanning."""
    if cfg.family == "moe":
        nd = cfg.moe.first_moe_layer
        seg = []
        if nd:
            seg.append(("dense", nd))
        seg.append(("moe", cfg.num_layers - nd))
        return seg
    return [("dense", cfg.num_layers)]


def lm_descs(cfg: ModelConfig) -> Tree:
    t = {"embed": L.embed_descs(cfg),
         "final_norm": L.rms_norm_descs(cfg.d_model, cfg.param_dtype)}
    for i, (kind, n) in enumerate(_segments(cfg)):
        t[f"stack_{i}_{kind}"] = L.stack_descs(block_descs(cfg, kind), n)
    if cfg.mtp_depth:
        t["mtp"] = {
            "proj": L.linear_descs(2 * cfg.d_model, cfg.d_model,
                                   cfg.param_dtype, in_axis="embed"),
            "norm_h": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
            "norm_e": L.rms_norm_descs(cfg.d_model, cfg.param_dtype),
            "block": block_descs(cfg, "dense" if not cfg.moe else "moe"),
        }
    return t


# ------------------------------------------------------------- blocks ------

def block_train(params, x, cfg: ModelConfig, kind: str, mesh: Mesh,
                batch_axes, q_offset: int = 0):
    h = L.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        h = A.mla_train(params["attn"], h, cfg, q_offset=q_offset,
                        mesh=mesh, batch_axes=batch_axes)
    else:
        h = A.attn_train(params["attn"], h, cfg, q_offset=q_offset,
                         mesh=mesh, batch_axes=batch_axes)
    x = x + h
    h = L.rms_norm(params["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        h = M.moe_ffn(params["moe"], h, cfg, mesh, batch_axes)
    else:
        h = L.ffn(params["ffn"], h, cfg.act)
    return L.seq_shard(x + h, mesh, batch_axes)


def block_prefill(params, x, cfg, kind, mesh, batch_axes):
    """Like train but returns the KV-cache contribution."""
    h = L.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        h, kv = A.mla_train(params["attn"], h, cfg, return_kv=True,
                            mesh=mesh, batch_axes=batch_axes)
    else:
        h, kv = A.attn_train(params["attn"], h, cfg, return_kv=True,
                             mesh=mesh, batch_axes=batch_axes)
    x = x + h
    h = L.rms_norm(params["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        h = M.moe_ffn(params["moe"], h, cfg, mesh, batch_axes)
    else:
        h = L.ffn(params["ffn"], h, cfg.act)
    return x + h, kv


def block_decode(params, x, cfg, kind, mesh, batch_axes, seq_axes, cache,
                 pos, ep_axes=("model",)):
    h = L.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        h, ckv, kr = A.mla_decode(params["attn"], h, cfg, cache["ckv"],
                                  cache["kr"], pos, mesh=mesh,
                                  seq_axes=seq_axes, batch_axes=batch_axes)
        new_cache = {"ckv": ckv, "kr": kr}
    else:
        h, k, v = A.attn_decode(params["attn"], h, cfg, cache["k"],
                                cache["v"], pos, mesh=mesh,
                                seq_axes=seq_axes, batch_axes=batch_axes)
        new_cache = {"k": k, "v": v}
    x = x + h
    h = L.rms_norm(params["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        h = M.moe_ffn(params["moe"], h, cfg, mesh, batch_axes,
                      ep_axes=ep_axes)
    else:
        h = L.ffn(params["ffn"], h, cfg.act)
    return x + h, new_cache


# ------------------------------------------------------------ assembly -----

def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _embed_input(params, batch, cfg: ModelConfig):
    """Token embeddings, with VLM/early-fusion prefix if present."""
    x = L.embed(params["embed"], batch["tokens"])
    n_prefix = 0
    if "patches" in batch and batch["patches"] is not None:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        n_prefix = batch["patches"].shape[1]
    return x, n_prefix


def lm_hidden(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    """Full forward to final hidden states (B, S_total, d)."""
    x, n_prefix = _embed_input(params, batch, cfg)

    for i, (kind, n) in enumerate(_segments(cfg)):
        stack = params[f"stack_{i}_{kind}"]

        def body(h, layer_params, _kind=kind):
            h = block_train(layer_params, h, cfg, _kind, mesh, batch_axes)
            return h, ()

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, stack)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, n_prefix


def lm_loss(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    x, n_prefix = lm_hidden(params, batch, cfg, mesh, batch_axes)
    if n_prefix:
        x = x[:, n_prefix:]
    targets = batch["targets"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    loss = L.chunked_ce_loss(params["embed"], x, targets, mask,
                             cfg.tie_embeddings, cfg.loss_chunk,
                             mesh, batch_axes)
    if cfg.mtp_depth and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(params, x, batch, cfg, mesh,
                                      batch_axes)
    return loss


def _mtp_loss(params, h, batch, cfg: ModelConfig, mesh, batch_axes):
    """Single-depth multi-token prediction (deepseek-v3 §2.2): combine the
    main-path hidden for position t with the embedding of token t+1 and
    predict token t+2 through one extra block (shared embedding/head)."""
    p = params["mtp"]
    tokens, targets = batch["tokens"], batch["targets"]
    B, S = tokens.shape
    emb_next = L.embed(params["embed"], jnp.roll(tokens, -1, axis=1))
    comb = jnp.concatenate([L.rms_norm(p["norm_h"], h, cfg.norm_eps),
                            L.rms_norm(p["norm_e"], emb_next, cfg.norm_eps)],
                           axis=-1)
    x = L.linear(p["proj"], comb)
    kind = "moe" if (cfg.moe and "moe" in p["block"]) else "dense"
    x = block_train(p["block"], x, cfg, kind, mesh, batch_axes)
    mtp_targets = jnp.roll(targets, -1, axis=1)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    mask = mask * (jnp.arange(S)[None, :] < S - 1)
    return L.chunked_ce_loss(params["embed"], x, mtp_targets, mask,
                             cfg.tie_embeddings, cfg.loss_chunk,
                             mesh, batch_axes)


# -------------------------------------------------------------- caches -----

def cache_descs(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    """The cache is a LIST of per-layer dicts: independent leaves donate/
    alias 1:1 through jit (a stacked (L, ...) cache forces GSPMD remats or
    scan-carry double-buffering — found the hard way, see EXPERIMENTS.md)."""
    if cfg.mla:
        m = cfg.mla
        layer = lambda: {
            "ckv": ParamDesc((batch, seq, m.kv_lora_rank), cfg.dtype,
                             ("batch", "kv_seq", None), init="zeros"),
            "kr": ParamDesc((batch, seq, m.qk_rope_head_dim), cfg.dtype,
                            ("batch", "kv_seq", None), init="zeros")}
    else:
        D = cfg.resolved_head_dim
        layer = lambda: {
            "k": ParamDesc((batch, seq, cfg.num_kv_heads, D), cfg.dtype,
                           ("batch", "kv_seq", None, None), init="zeros"),
            "v": ParamDesc((batch, seq, cfg.num_kv_heads, D), cfg.dtype,
                           ("batch", "kv_seq", None, None), init="zeros")}
    return [layer() for _ in range(cfg.num_layers)]


def lm_prefill(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    """Returns (last-token logits, cache stacked (L, B, S_total, ...))."""
    x, n_prefix = _embed_input(params, batch, cfg)

    caches = []
    for i, (kind, n) in enumerate(_segments(cfg)):
        stack = params[f"stack_{i}_{kind}"]

        def body(h, layer_params, _kind=kind):
            h, kv = block_prefill(layer_params, h, cfg, _kind, mesh,
                                  batch_axes)
            return h, kv

        x, kv = jax.lax.scan(_maybe_remat(body, cfg), x, stack)
        caches.append(kv)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    last = x[:, -1:, :]
    logits = L.logits_fn(params["embed"], last, cfg.tie_embeddings)[:, 0]
    names = ("ckv", "kr") if cfg.mla else ("k", "v")
    cache = []
    for stacked in caches:               # per segment: tuple of (n, B, ...)
        n = stacked[0].shape[0]
        for l in range(n):
            cache.append({names[0]: stacked[0][l], names[1]: stacked[1][l]})
    return logits, cache


def lm_decode(params, token, pos, cache, cfg: ModelConfig, mesh: Mesh,
              batch_axes, seq_axes):
    """token: (B,1) i32; pos: (B,) i32; cache from cache_descs.

    Returns (logits (B, V), cache')."""
    x = L.embed(params["embed"], token)
    off = 0
    # Decode unrolls the layer loop over the per-layer cache list: each
    # layer cache leaf is read once and written once, so donation aliases
    # every buffer in place (stacked caches force GSPMD remats or scan
    # double-buffering). Per-layer decode op count is tiny, so the
    # unrolled HLO stays small.
    new_cache = list(cache)
    ep_axes = (M.decode_ep_axes(cfg, mesh, token.shape[0])
               if cfg.moe else ("model",))
    for i, (kind, n) in enumerate(_segments(cfg)):
        stack = params[f"stack_{i}_{kind}"]
        for l in range(n):
            lp = jax.tree.map(lambda a: a[l], stack)
            x, new_c = block_decode(lp, x, cfg, kind, mesh, batch_axes,
                                    seq_axes, cache[off + l], pos,
                                    ep_axes=ep_axes)
            new_cache[off + l] = jax.tree.map(
                lambda nc, c: nc.astype(c.dtype), new_c, cache[off + l])
        off += n
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, new_cache
