"""Shared building blocks: norms, rotary embeddings, gated FFNs, embeddings.

All layers are pure functions over explicit param dicts; every layer also
exposes a ``*_descs`` builder returning the matching ParamDesc tree. Large
projection matrices are kept 2-D with the flattened (heads*head_dim) or ff
dimension mapped to the "model" logical axis so the production mesh always
divides them evenly (see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDesc, tree_map_descs

Tree = Any


def seq_shard(x: jax.Array, mesh, batch_axes) -> jax.Array:
    """Sequence-parallel constraint on the residual stream (B, S, d):
    shard S over "model" between blocks so remat stashes / loss chunks are
    not replicated over the TP axis (Megatron-SP; DESIGN.md §4)."""
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    if m == 1 or x.ndim < 3 or x.shape[1] % m or x.shape[1] < m * 8:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    spec = P(batch_axes or None, "model", *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def head_shard(x: jax.Array, mesh, batch_axes) -> jax.Array:
    """Tensor-parallel constraint on per-head tensors (B, S, H, D): shard H
    over "model" so attention activations are not replicated on the TP
    axis (pairs with seq_shard on the residual stream)."""
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    if m == 1 or x.ndim != 4 or x.shape[2] % m:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(batch_axes or None, None, "model", None)))


def stack_descs(descs: Tree, n: int) -> Tree:
    """Prepend a layer dimension (unsharded) to every leaf — for scan."""
    return tree_map_descs(
        lambda p, d: ParamDesc((n,) + d.shape, d.dtype, (None,) + tuple(
            d.axes or (None,) * len(d.shape)), d.init, d.scale, d.const),
        descs)


# ---------------------------------------------------------------- norms ----

def rms_norm_descs(dim: int, dtype: str) -> Tree:
    return {"scale": ParamDesc((dim,), dtype, (None,), init="ones")}


def rms_norm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layer_norm_descs(dim: int, dtype: str) -> Tree:
    return {"scale": ParamDesc((dim,), dtype, (None,), init="ones"),
            "bias": ParamDesc((dim,), dtype, (None,), init="zeros")}


def layer_norm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return out.astype(dtype)


# --------------------------------------------------------------- linear ----

def linear_descs(d_in: int, d_out: int, dtype: str, *, bias: bool = False,
                 in_axis: Optional[str] = None, out_axis: Optional[str] = None,
                 init: str = "normal", scale: float = 0.02) -> Tree:
    t = {"w": ParamDesc((d_in, d_out), dtype, (in_axis, out_axis),
                        init=init, scale=scale)}
    if bias:
        t["b"] = ParamDesc((d_out,), dtype, (out_axis,), init="zeros")
    return t


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# --------------------------------------------------------------- rotary ----

def rotary(positions: jax.Array, head_dim: int, theta: float,
           dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for the given positions; positions: (...,)"""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., half)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D/2) or broadcastable (..., S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:                       # (S, half) -> (S, 1, half)
        cos, sin = cos[:, None, :], sin[:, None, :]
    else:                                   # (..., S, half)
        cos, sin = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ FFN ----

def ffn_descs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Tree:
    d_ff = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    if cfg.act == "gelu":                   # whisper: non-gated MLP w/ bias
        return {"up": linear_descs(cfg.d_model, d_ff, dt, bias=True,
                                   in_axis="embed", out_axis="model"),
                "down": linear_descs(d_ff, cfg.d_model, dt, bias=True,
                                     in_axis="model", out_axis="embed")}
    return {"gate": linear_descs(cfg.d_model, d_ff, dt,
                                 in_axis="embed", out_axis="model"),
            "up": linear_descs(cfg.d_model, d_ff, dt,
                               in_axis="embed", out_axis="model"),
            "down": linear_descs(d_ff, cfg.d_model, dt,
                                 in_axis="model", out_axis="embed")}


def ffn(params, x, act: str = "silu"):
    if "gate" in params:
        h = jax.nn.silu(linear(params["gate"], x)) * linear(params["up"], x)
    else:
        h = jax.nn.gelu(linear(params["up"], x))
    return linear(params["down"], h)


# ------------------------------------------------------------ embedding ----

def embed_descs(cfg: ModelConfig) -> Tree:
    t = {"tok": ParamDesc((cfg.vocab_size, cfg.d_model), cfg.param_dtype,
                          ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        t["unembed"] = ParamDesc((cfg.d_model, cfg.vocab_size),
                                 cfg.param_dtype, ("embed", "vocab"),
                                 init="normal")
    return t


def embed(params, tokens):
    return params["tok"][tokens]            # GSPMD handles the sharded gather


def logits_fn(embed_params, x, tie: bool):
    w = embed_params["tok"].T if tie else embed_params["unembed"]
    return x @ w


# --------------------------------------------------- chunked cross entropy ----

def chunked_ce_loss(embed_params, x, targets, mask, tie: bool,
                    chunk: int, mesh=None, batch_axes=()) -> jax.Array:
    """Cross-entropy over the vocab without materializing full (B,S,V).

    x: (B, S, d) final hidden; targets: (B, S) int32; mask: (B, S) {0,1}.
    Scans over sequence chunks; each chunk's logits stay sharded over
    "model" on the SEQUENCE dim (seq_shard), so the fp32 logits transient
    is (B_loc, chunk/TP, V) per device.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    V = (embed_params["tok"].shape[0] if tie
         else embed_params["unembed"].shape[1])
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    m_sz = sizes.get("model", 1)
    vocab_sharded = m_sz > 1 and V % m_sz == 0

    def one(x_c, t_c, m_c):
        if not vocab_sharded:
            x_c = seq_shard(x_c, mesh, batch_axes)
        lg = logits_fn(embed_params, x_c, tie).astype(jnp.float32)
        if vocab_sharded:
            # keep V sharded over "model": the unembed matrix is never
            # gathered, the fp32 logits transient is (B, C, V/TP)
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            lg = jax.lax.with_sharding_constraint(
                lg, NamedSharding(mesh, P(batch_axes or None, None,
                                          "model")))
            onehot = jax.nn.one_hot(t_c, V, dtype=lg.dtype)
            onehot = jax.lax.with_sharding_constraint(
                onehot, NamedSharding(mesh, P(batch_axes or None, None,
                                              "model")))
            picked = jnp.einsum("bcv,bcv->bc", lg, onehot)
        else:
            picked = jnp.take_along_axis(lg, t_c[..., None],
                                         axis=-1)[..., 0]
        lse = jax.nn.logsumexp(lg, axis=-1)
        return jnp.sum((lse - picked) * m_c), jnp.sum(m_c)

    def body(carry, xs):
        x_c, t_c, m_c = xs
        l, c = one(x_c, t_c, m_c)
        return (carry[0] + l, carry[1] + c), ()

    xs = (x[:, :n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1),
          targets[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1),
          mask[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1))
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), xs)
    if rem:
        l, c = one(x[:, n * chunk:], targets[:, n * chunk:],
                   mask[:, n * chunk:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
