"""Mamba2 (SSD — state-space duality) blocks for the zamba2 hybrid.

Chunked-scan training form (minimal-SSD): the sequence is split into chunks;
within-chunk terms use a masked decay matmul, cross-chunk terms propagate an
(H, P, N) state through a lax.scan. Decode is the O(1) recurrent update.
State math runs in float32.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamDesc

Tree = Any


def mamba2_descs(cfg: ModelConfig) -> Tree:
    s = cfg.ssm
    dt = cfg.param_dtype
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    gn = s.n_groups * s.state_dim
    return {
        "in_z": L.linear_descs(d, d_inner, dt, in_axis="embed",
                               out_axis="model"),
        "in_x": L.linear_descs(d, d_inner, dt, in_axis="embed",
                               out_axis="model"),
        "in_b": L.linear_descs(d, gn, dt, in_axis="embed"),
        "in_c": L.linear_descs(d, gn, dt, in_axis="embed"),
        "in_dt": L.linear_descs(d, H, dt, in_axis="embed"),
        "conv_x": {"w": ParamDesc((s.conv_width, d_inner), dt,
                                  (None, "model"), init="normal", scale=0.5),
                   "b": ParamDesc((d_inner,), dt, ("model",), init="zeros")},
        "conv_b": {"w": ParamDesc((s.conv_width, gn), dt, (None, None),
                                  init="normal", scale=0.5),
                   "b": ParamDesc((gn,), dt, (None,), init="zeros")},
        "conv_c": {"w": ParamDesc((s.conv_width, gn), dt, (None, None),
                                  init="normal", scale=0.5),
                   "b": ParamDesc((gn,), dt, (None,), init="zeros")},
        "A_log": ParamDesc((H,), "float32", (None,), init="const", const=0.0),
        "D": ParamDesc((H,), "float32", (None,), init="ones"),
        "dt_bias": ParamDesc((H,), "float32", (None,), init="zeros"),
        "norm": L.rms_norm_descs(d_inner, dt),
        "out": L.linear_descs(d_inner, d, dt, in_axis="model",
                              out_axis="embed"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,C); w: (W,C) -> (B,S,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):                      # W is tiny (4): unrolled taps
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _conv_step(x_t, conv_state, w, b):
    """x_t: (B,C); conv_state: (B,W-1,C) last inputs -> (y (B,C), state')."""
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", full, w) + b[None, :]
    return y, full[:, 1:, :]


def ssd_chunked(x, dt, A, B, C, D, chunk: int,
                state0: Optional[jax.Array] = None):
    """SSD scan. x: (b,s,H,P) f32; dt: (b,s,H) f32 (already softplus'ed);
    A: (H,) negative; B,C: (b,s,G,N). Returns (y (b,s,H,P), state (b,H,P,N)).
    """
    b, s, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    K = min(chunk, s)
    while s % K:
        K -= 1
    nc = s // K

    def r(t, trail):                        # (b,s,...) -> (nc,b,K,...)
        return t.reshape((b, nc, K) + trail).swapaxes(0, 1)

    # B/C stay in GROUP form — expanding them to H heads with jnp.repeat
    # costs (b,s,H,N) fp32 per tensor per layer (the zamba2 train_4k
    # memory hillclimb, EXPERIMENTS.md §Perf); einsums broadcast groups.
    xc, dtc = r(x, (H, Pd)), r(dt, (H,))
    Bc, Cc = r(B, (G, N)), r(C, (G, N))
    dA = dtc * A[None, None, None, :]       # (nc,b,K,H) <= 0
    lw = jnp.cumsum(dA, axis=2)             # inclusive cumulative log-decay
    xdt = xc * dtc[..., None]               # dt-weighted input

    def heads_of(t_g):
        """(..., G, N) group tensor -> broadcast view over heads."""
        return jnp.repeat(t_g, rep, axis=-2) if rep > 1 and G > 1 else t_g

    # intra-chunk: scores[t,s'] = C_t.B_s' * exp(lw_t - lw_s') for s'<=t
    def intra(args):
        Cc_, Bc_, lw_, xdt_ = args
        # group-level score matrix (b,G,K,K) — NOT per-head
        sc_g = jnp.einsum("bkgn,blgn->bgkl", Cc_, Bc_,
                          preferred_element_type=jnp.float32)
        dec = jnp.exp(jnp.clip(lw_[:, :, None, :] - lw_[:, None, :, :],
                               -60.0, 0.0))          # (b,K,K,H)
        mask = jnp.tril(jnp.ones((K, K), bool))
        xh = xdt_.reshape(xdt_.shape[0], K, G, rep, Pd)
        dech = dec.reshape(dec.shape[0], K, K, G, rep)
        y = jnp.einsum("bgkl,bklgr,blgrp->bkgrp", sc_g,
                       dech.transpose(0, 1, 2, 3, 4) * mask[None, :, :,
                                                            None, None],
                       xh)
        return y.reshape(y.shape[0], K, H, Pd)

    y_diag = jax.lax.map(intra, (Cc, Bc, lw, xdt))   # (nc,b,K,H,P)

    # chunk states: S_c = sum_s exp(lw_last - lw_s) B_s xdt_s
    decay_to_end = jnp.exp(jnp.clip(lw[:, :, -1:, :] - lw, -60.0, 0.0))

    def chunk_state(a):
        Bc_, xdt_dec = a                     # (b,K,G,N), (b,K,H,P) decayed
        xh = xdt_dec.reshape(xdt_dec.shape[0], K, G, rep, Pd)
        Sg = jnp.einsum("bkgn,bkgrp->bgrpn", Bc_, xh)
        return Sg.reshape(Sg.shape[0], H, Pd, N)

    S_chunks = jax.lax.map(
        chunk_state, (Bc, xdt * decay_to_end[..., None]))  # (nc,b,H,P,N)
    chunk_decay = jnp.exp(jnp.clip(lw[:, :, -1, :], -60.0, 0.0))  # (nc,b,H)

    def scan_fn(S_prev, xs):
        S_c_, cd_, Cc_, lw_ = xs
        dec_h = jnp.exp(jnp.clip(lw_, -60.0, 0.0))        # (b,K,H)
        Sg = S_prev.reshape(b, G, rep, Pd, N)
        y_off = jnp.einsum("bkgn,bkgr,bgrpn->bkgrp", Cc_,
                           dec_h.reshape(b, K, G, rep), Sg)
        y_off = y_off.reshape(b, K, H, Pd)
        S_new = S_prev * cd_[:, :, None, None] + S_c_
        return S_new, y_off

    S0 = (state0.astype(jnp.float32) if state0 is not None
          else jnp.zeros((b, H, Pd, N), jnp.float32))
    S_fin, y_off = jax.lax.scan(scan_fn, S0, (S_chunks, chunk_decay, Cc, lw))
    y = y_diag + y_off                                # (nc,b,K,H,P)
    y = y.swapaxes(0, 1).reshape(b, s, H, Pd)
    y = y + x * D[None, None, :, None]
    return y, S_fin


def mamba2_train(params, x, cfg: ModelConfig):
    """x: (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    Bsz, S, d = x.shape
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    z = L.linear(params["in_z"], x)
    xin = L.linear(params["in_x"], x)
    Bv = L.linear(params["in_b"], x)
    Cv = L.linear(params["in_c"], x)
    dt = L.linear(params["in_dt"], x)
    xin = jax.nn.silu(_causal_conv(xin, params["conv_x"]["w"],
                                   params["conv_x"]["b"]))
    Bv = jax.nn.silu(_causal_conv(Bv, params["conv_b"]["w"],
                                  params["conv_b"]["b"]))
    Cv = jax.nn.silu(_causal_conv(Cv, params["conv_c"]["w"],
                                  params["conv_c"]["b"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    xh = xin.astype(jnp.float32).reshape(Bsz, S, H, s.head_dim)
    Bh = Bv.astype(jnp.float32).reshape(Bsz, S, s.n_groups, s.state_dim)
    Ch = Cv.astype(jnp.float32).reshape(Bsz, S, s.n_groups, s.state_dim)
    y, _ = ssd_chunked(xh, dt, A, Bh, Ch, params["D"], s.chunk_size)
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return L.linear(params["out"], y)


def mamba2_state_descs(cfg: ModelConfig, batch: int) -> Tree:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    gn = s.n_groups * s.state_dim
    W = s.conv_width
    return {
        "ssm": ParamDesc((batch, H, s.head_dim, s.state_dim), "float32",
                         ("batch", None, None, None), init="zeros"),
        "conv_x": ParamDesc((batch, W - 1, d_inner), "float32",
                            ("batch", None, "model"), init="zeros"),
        "conv_b": ParamDesc((batch, W - 1, gn), "float32",
                            ("batch", None, None), init="zeros"),
        "conv_c": ParamDesc((batch, W - 1, gn), "float32",
                            ("batch", None, None), init="zeros"),
    }


def mamba2_decode(params, x, cfg: ModelConfig, state: Dict[str, jax.Array]):
    """x: (B,1,d); state: dict from mamba2_state_descs -> (y, state')."""
    s = cfg.ssm
    Bsz, _, d = x.shape
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    xt = x[:, 0]
    z = L.linear(params["in_z"], xt[:, None])[:, 0]
    xin = L.linear(params["in_x"], xt[:, None])[:, 0]
    Bv = L.linear(params["in_b"], xt[:, None])[:, 0]
    Cv = L.linear(params["in_c"], xt[:, None])[:, 0]
    dt = L.linear(params["in_dt"], xt[:, None])[:, 0]
    xin, cx = _conv_step(xin.astype(jnp.float32),
                         state["conv_x"], params["conv_x"]["w"].astype(
                             jnp.float32), params["conv_x"]["b"].astype(
                             jnp.float32))
    Bv, cb = _conv_step(Bv.astype(jnp.float32), state["conv_b"],
                        params["conv_b"]["w"].astype(jnp.float32),
                        params["conv_b"]["b"].astype(jnp.float32))
    Cv, cc = _conv_step(Cv.astype(jnp.float32), state["conv_c"],
                        params["conv_c"]["w"].astype(jnp.float32),
                        params["conv_c"]["b"].astype(jnp.float32))
    xin, Bv, Cv = jax.nn.silu(xin), jax.nn.silu(Bv), jax.nn.silu(Cv)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"])                      # (H,)
    xh = xin.reshape(Bsz, H, s.head_dim)
    rep = H // s.n_groups
    Bh = jnp.repeat(Bv.reshape(Bsz, s.n_groups, s.state_dim), rep, axis=1)
    Ch = jnp.repeat(Cv.reshape(Bsz, s.n_groups, s.state_dim), rep, axis=1)
    dA = jnp.exp(dt * A[None, :])                      # (B,H)
    S = state["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh * dt[..., None], Bh)
    y = jnp.einsum("bhpn,bhn->bhp", S, Ch) + xh * params["D"][None, :, None]
    y = y.reshape(Bsz, d_inner).astype(x.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = L.linear(params["out"], y[:, None])
    return y, {"ssm": S, "conv_x": cx, "conv_b": cb, "conv_c": cc}
