"""Parameter descriptors: single source of truth for shapes, dtypes, logical
sharding axes and initializers.

A model defines a pytree of ``ParamDesc``. From that one tree we derive:
  * materialized random params        (``materialize``)
  * abstract ShapeDtypeStructs        (``abstract``)      — for AOT dry-runs
  * NamedSharding / PartitionSpec     (``partition_specs``)

Logical axes are mapped to mesh axes by ``LogicalRules``; any mapping that
does not divide the dimension evenly is DROPPED (replicated) because jit
rejects unevenly sharded arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Tree = Any
AxisName = Optional[str]


@dataclass(frozen=True)
class ParamDesc:
    shape: Tuple[int, ...]
    dtype: str = "bfloat16"
    axes: Tuple[AxisName, ...] = ()
    init: str = "normal"      # normal | zeros | ones | embed | const
    scale: float = 0.02
    const: float = 0.0

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} rank != shape {self.shape}")


def is_desc(x) -> bool:
    return isinstance(x, ParamDesc)


def _leaf_paths(tree: Tree, prefix=()):
    if is_desc(tree):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, prefix + (str(i),))
    elif tree is None:
        return
    else:
        raise TypeError(f"bad desc tree node {type(tree)}")


def tree_map_descs(fn: Callable[[Tuple[str, ...], ParamDesc], Any],
                   tree: Tree) -> Tree:
    """Map over ParamDesc leaves preserving structure (dicts/lists/None)."""
    def rec(node, prefix):
        if is_desc(node):
            return fn(prefix, node)
        if isinstance(node, dict):
            return {k: rec(v, prefix + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, prefix + (str(i),))
                              for i, v in enumerate(node))
        if node is None:
            return None
        raise TypeError(f"bad desc tree node {type(node)}")
    return rec(tree, ())


def _init_leaf(path: Tuple[str, ...], d: ParamDesc, root_key) -> jax.Array:
    dtype = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "const":
        return jnp.full(d.shape, d.const, dtype)
    # deterministic per-leaf key from the path
    key = jax.random.fold_in(root_key, hash("/".join(path)) & 0x7FFFFFFF)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale
                ).astype(dtype)
    if d.init == "normal":
        fan_in = d.shape[0] if len(d.shape) >= 2 else 1
        scale = d.scale if d.scale else 1.0
        w = jax.random.normal(key, d.shape, jnp.float32)
        return (w * min(scale, 1.0 / np.sqrt(max(fan_in, 1)))).astype(dtype)
    raise ValueError(f"unknown init {d.init}")


def materialize(descs: Tree, key) -> Tree:
    return tree_map_descs(lambda p, d: _init_leaf(p, d, key), descs)


def abstract(descs: Tree) -> Tree:
    return tree_map_descs(
        lambda p, d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)), descs)


@dataclass(frozen=True)
class LogicalRules:
    """logical axis -> tuple of mesh axes (in order of preference)."""

    rules: Dict[str, Tuple[str, ...]]
    mesh_axis_sizes: Dict[str, int]

    def spec_for(self, d: ParamDesc) -> P:
        if not d.axes:
            return P()
        parts = []
        used: set = set()
        for dim, ax in zip(d.shape, d.axes):
            if ax is None or ax not in self.rules:
                parts.append(None)
                continue
            assigned = []
            prod = 1
            for mesh_ax in self.rules[ax]:
                if mesh_ax in used or mesh_ax not in self.mesh_axis_sizes:
                    continue
                sz = self.mesh_axis_sizes[mesh_ax]
                if dim % (prod * sz) == 0:
                    assigned.append(mesh_ax)
                    prod *= sz
            used.update(assigned)
            parts.append(tuple(assigned) if assigned else None)
        # PartitionSpec with tuples for multi-axis dims
        norm = [p[0] if (isinstance(p, tuple) and len(p) == 1) else p
                for p in parts]
        return P(*norm)


def default_rules(mesh: Mesh) -> LogicalRules:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    has_pod = "pod" in sizes
    batch_axes = ("pod", "data") if has_pod else ("data",)
    return LogicalRules(
        rules={
            "batch": batch_axes,
            "embed": ("data", "pod"),     # FSDP dims for params (zero-3)
            "embed_pod": ("pod", "data"),  # FSDP over pod too (XXL models)
            "model": ("model",),           # TP dim (flattened heads*dim / ff)
            "vocab": ("model",),
            "experts": ("model",),
            "kv_seq": ("model",),          # decode cache sequence sharding
            "seq": (),                     # unsharded by default in train
        },
        mesh_axis_sizes=sizes,
    )


def partition_specs(descs: Tree, rules: LogicalRules) -> Tree:
    return tree_map_descs(lambda p, d: rules.spec_for(d), descs)


def shardings(descs: Tree, mesh: Mesh, rules: Optional[LogicalRules] = None
              ) -> Tree:
    rules = rules or default_rules(mesh)
    return tree_map_descs(
        lambda p, d: NamedSharding(mesh, rules.spec_for(d)), descs)


def count_params(descs: Tree) -> int:
    return sum(int(np.prod(d.shape)) for _, d in _leaf_paths(descs))


def bytes_of(descs: Tree) -> int:
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
               for _, d in _leaf_paths(descs))
