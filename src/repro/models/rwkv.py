"""RWKV6 (Finch) — attention-free time-mix with data-dependent decay.

Training uses a chunked linear-attention form (factorized per-channel decay,
fp32, clipped exponents); decode is the O(1) recurrence carrying a per-head
(Dk, Dv) state plus the token-shift buffers. See arXiv:2404.05892.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamDesc

Tree = Any
LORA_R = 32          # decay / mixing LoRA rank (rwkv6-3b uses 32/64)
MIX_R = 32
CLIP = 60.0


def rwkv6_descs(cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    dt = cfg.param_dtype
    D = cfg.resolved_head_dim
    H = d // D
    return {
        "ln1": L.layer_norm_descs(d, dt),
        "ln2": L.layer_norm_descs(d, dt),
        "tm": {  # time mix
            # base token-shift lerp coefficients for (w,k,v,r,g) + ddlerp
            "maa_x": ParamDesc((d,), dt, (None,), init="zeros"),
            "maa_wkvrg": ParamDesc((5, d), dt, (None, None), init="zeros"),
            "maa_w1": ParamDesc((d, 5 * MIX_R), dt, ("embed", None),
                                init="normal"),
            "maa_w2": ParamDesc((5, MIX_R, d), dt, (None, None, "embed"),
                                init="normal"),
            "decay_base": ParamDesc((H, D), "float32", (None, None),
                                    init="const", const=-4.0),
            "decay_w1": ParamDesc((d, LORA_R), dt, ("embed", None),
                                  init="normal"),
            "decay_w2": ParamDesc((LORA_R, d), dt, (None, "embed"),
                                  init="normal"),
            "bonus": ParamDesc((H, D), "float32", (None, None),
                               init="normal", scale=1.0),
            "r": L.linear_descs(d, d, dt, in_axis="embed", out_axis="model"),
            "k": L.linear_descs(d, d, dt, in_axis="embed", out_axis="model"),
            "v": L.linear_descs(d, d, dt, in_axis="embed", out_axis="model"),
            "g": L.linear_descs(d, d, dt, in_axis="embed", out_axis="model"),
            "out": L.linear_descs(d, d, dt, in_axis="model",
                                  out_axis="embed"),
            "gn_scale": ParamDesc((d,), dt, (None,), init="ones"),
            "gn_bias": ParamDesc((d,), dt, (None,), init="zeros"),
        },
        "cm": {  # channel mix
            "maa_k": ParamDesc((d,), dt, (None,), init="zeros"),
            "maa_r": ParamDesc((d,), dt, (None,), init="zeros"),
            "k": L.linear_descs(d, cfg.d_ff, dt, in_axis="embed",
                                out_axis="model"),
            "v": L.linear_descs(cfg.d_ff, d, dt, in_axis="model",
                                out_axis="embed"),
            "r": L.linear_descs(d, d, dt, in_axis="embed", out_axis="model"),
        },
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,d) last token of previous segment."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    dx = xs - x
    xx = x + dx * p["maa_x"][None, None, :]
    a = jnp.tanh(xx @ p["maa_w1"])                     # (B,S,5R)
    B_, S_, _ = a.shape
    a = a.reshape(B_, S_, 5, MIX_R)
    delta = jnp.einsum("bsfr,frd->bsfd", a, p["maa_w2"])
    mix = p["maa_wkvrg"][None, None] + delta           # (B,S,5,d)
    return x[:, :, None, :] + dx[:, :, None, :] * mix  # (B,S,5,d)


def _group_norm(x, scale, bias, H, eps=64e-5):
    """Per-head group norm over (B,T,H*D)."""
    B_, T_, d = x.shape
    xh = x.reshape(B_, T_, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B_, T_, d) * scale + bias).astype(x.dtype)


def wkv6_chunked(r, k, v, lw, u, chunk: int, state0=None):
    """Chunked WKV. r,k,v: (B,S,H,D) f32; lw: (B,S,H,D) per-step log-decay
    (<=0); u: (H,D) bonus. Returns (y (B,S,H,D), state (B,H,D,D))."""
    B_, S_, H_, D_ = r.shape
    K = min(chunk, S_)
    while S_ % K:
        K -= 1
    nc = S_ // K

    def resh(t):
        return t.reshape(B_, nc, K, H_, D_).swapaxes(0, 1)

    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(lw)
    cs = jnp.cumsum(lwc, axis=2)                       # inclusive
    a = rc * jnp.exp(jnp.clip(cs - lwc, -CLIP, 0.0))   # r_t * exp(lw_{t-1})
    b = kc * jnp.exp(jnp.clip(-cs, None, CLIP))        # k_s * exp(-lw_s)
    kdec = kc * jnp.exp(jnp.clip(cs[:, :, -1:] - cs, -CLIP, 0.0))

    def intra(args):
        a_, b_, vc_, rc_, kc_ = args
        sc = jnp.einsum("bthd,bshd->bhts", a_, b_)
        mask = jnp.tril(jnp.ones((K, K), bool), k=-1)  # strict lower
        sc = sc * mask[None, None]
        y = jnp.einsum("bhts,bshd->bthd", sc, vc_)
        # bonus (diagonal) term
        y = y + jnp.einsum("bthd,bthd->bth", rc_ * u[None, None], kc_
                           )[..., None] * vc_
        return y

    y_diag = jax.lax.map(intra, (a, b, vc, rc, kc))    # (nc,B,K,H,D)
    S_chunks = jax.lax.map(
        lambda t: jnp.einsum("bshd,bshe->bhde", t[0], t[1]), (kdec, vc))
    chunk_decay = jnp.exp(jnp.clip(cs[:, :, -1], -CLIP, 0.0))  # (nc,B,H,D)

    def scan_fn(S_prev, xs):
        a_, Sc_, cd_ = xs
        y_off = jnp.einsum("bthd,bhde->bthe", a_, S_prev)
        S_new = S_prev * cd_[..., None] + Sc_
        return S_new, y_off

    S0 = (state0.astype(jnp.float32) if state0 is not None
          else jnp.zeros((B_, H_, D_, D_), jnp.float32))
    S_fin, y_off = jax.lax.scan(scan_fn, S0, (a, S_chunks, chunk_decay))
    y = (y_diag + y_off).swapaxes(0, 1).reshape(B_, S_, H_, D_)
    return y, S_fin


def _tm_wkvrg(p, x, xs, cfg):
    """Projections + decay for time-mix. Returns r,k,v,g,lw (B,S,H,D)."""
    D = cfg.resolved_head_dim
    H = cfg.d_model // D
    B_, S_, _ = x.shape
    mixed = _ddlerp(p, x, xs)                          # (B,S,5,d)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]
    r = L.linear(p["r"], xr).reshape(B_, S_, H, D).astype(jnp.float32)
    k = L.linear(p["k"], xk).reshape(B_, S_, H, D).astype(jnp.float32)
    v = L.linear(p["v"], xv).reshape(B_, S_, H, D).astype(jnp.float32)
    g = jax.nn.silu(L.linear(p["g"], xg))
    dec = p["decay_base"][None, None] + (
        jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]).reshape(
            B_, S_, H, D).astype(jnp.float32)
    lw = -jnp.exp(jnp.clip(dec, -8.0, 8.0))            # log w <= 0
    return r, k, v, g, lw


def time_mix_train(p, x, cfg: ModelConfig, chunk: int):
    """x: (B,S,d) normed input -> (B,S,d)."""
    B_, S_, d = x.shape
    D = cfg.resolved_head_dim
    H = d // D
    xs = _token_shift(x, jnp.zeros((B_, d), x.dtype))
    r, k, v, g, lw = _tm_wkvrg(p, x, xs, cfg)
    u = p["bonus"].astype(jnp.float32)
    y, _ = wkv6_chunked(r, k, v, lw, u, chunk)
    y = _group_norm(y.reshape(B_, S_, d).astype(x.dtype),
                    p["gn_scale"], p["gn_bias"], H)
    return L.linear(p["out"], y * g)


def channel_mix_train(p, x, cfg: ModelConfig):
    B_, S_, d = x.shape
    xs = _token_shift(x, jnp.zeros((B_, d), x.dtype))
    xk = x + (xs - x) * p["maa_k"][None, None]
    xr = x + (xs - x) * p["maa_r"][None, None]
    k = jnp.square(jax.nn.relu(L.linear(p["k"], xk)))
    return jax.nn.sigmoid(L.linear(p["r"], xr)) * L.linear(p["v"], k)


def rwkv6_state_descs(cfg: ModelConfig, batch: int) -> Tree:
    d = cfg.d_model
    D = cfg.resolved_head_dim
    H = d // D
    return {
        "tm_x": ParamDesc((batch, d), "float32", ("batch", None),
                          init="zeros"),
        "cm_x": ParamDesc((batch, d), "float32", ("batch", None),
                          init="zeros"),
        "wkv": ParamDesc((batch, H, D, D), "float32",
                         ("batch", None, None, None), init="zeros"),
    }


def rwkv6_block_train(params, x, cfg: ModelConfig):
    h = x + time_mix_train(params["tm"], L.layer_norm(params["ln1"], x,
                                                      cfg.norm_eps),
                           cfg, cfg.ssm.chunk_size)
    h = h + channel_mix_train(params["cm"], L.layer_norm(params["ln2"], h,
                                                         cfg.norm_eps), cfg)
    return h


def rwkv6_block_decode(params, x, cfg: ModelConfig, state: Dict):
    """x: (B,1,d); state from rwkv6_state_descs -> (y, state')."""
    B_, _, d = x.shape
    D = cfg.resolved_head_dim
    H = d // D
    xn = L.layer_norm(params["ln1"], x, cfg.norm_eps)
    xs = state["tm_x"].astype(xn.dtype)[:, None, :]
    p = params["tm"]
    r, k, v, g, lw = _tm_wkvrg(p, xn, xs, cfg)
    r, k, v, lw = r[:, 0], k[:, 0], v[:, 0], lw[:, 0]   # (B,H,D)
    u = p["bonus"].astype(jnp.float32)
    S = state["wkv"]                                    # (B,H,D,D)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    y = jnp.einsum("bhd,bhde->bhe", r, S + u[None, :, :, None] * kv)
    S = S * jnp.exp(lw)[..., None] + kv
    y = _group_norm(y.reshape(B_, 1, d).astype(x.dtype),
                    p["gn_scale"], p["gn_bias"], H)
    h = x + L.linear(p["out"], y * g)
    # channel mix
    hn = L.layer_norm(params["ln2"], h, cfg.norm_eps)
    cs = state["cm_x"].astype(hn.dtype)[:, None, :]
    pc = params["cm"]
    xk = hn + (cs - hn) * pc["maa_k"][None, None]
    xr = hn + (cs - hn) * pc["maa_r"][None, None]
    kk = jnp.square(jax.nn.relu(L.linear(pc["k"], xk)))
    h = h + jax.nn.sigmoid(L.linear(pc["r"], xr)) * L.linear(pc["v"], kk)
    new_state = {"tm_x": xn[:, 0].astype(jnp.float32),
                 "cm_x": hn[:, 0].astype(jnp.float32), "wkv": S}
    return h, new_state
