"""Model registry: family dispatch + the public Model facade used by the
launcher, dry-run, tests and benchmarks."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import DFAConfig, ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import hybrid as HY
from repro.models import lm as LM
from repro.models import param as PM
from repro.models import rwkv_lm as RW
from repro.models import whisper as WH

Tree = Any


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def train_batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Longest prefix of (pod, data) dividing the batch."""
    sizes = _mesh_sizes(mesh)
    axes = []
    prod = 1
    for ax in ("pod", "data"):
        if ax in sizes and global_batch % (prod * sizes[ax]) == 0:
            axes.append(ax)
            prod *= sizes[ax]
    return tuple(axes)


def decode_axes(mesh: Mesh, batch: int, seq: int
                ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """(batch_axes, seq_axes) for sequence-sharded decode caches."""
    sizes = _mesh_sizes(mesh)
    batch_axes = train_batch_axes(mesh, batch)
    seq_axes = tuple(ax for ax in mesh.axis_names if ax not in batch_axes)
    prod = math.prod(sizes[a] for a in seq_axes) if seq_axes else 1
    if seq % prod:
        # drop axes from the left until divisible (replicate over them)
        while seq_axes and seq % math.prod(sizes[a] for a in seq_axes):
            seq_axes = seq_axes[1:]
    return batch_axes, seq_axes


@dataclass
class Model:
    cfg: ModelConfig
    mesh: Mesh

    # ---- parameters -----------------------------------------------------
    def param_descs(self) -> Tree:
        fam = self.cfg.family
        if fam in ("dense", "vlm", "moe"):
            return LM.lm_descs(self.cfg)
        if fam == "hybrid":
            return HY.hybrid_descs(self.cfg)
        if fam == "ssm":
            return RW.rwkv_lm_descs(self.cfg)
        if fam == "encdec":
            return WH.whisper_descs(self.cfg)
        raise ValueError(self.cfg.family)

    def init(self, key) -> Tree:
        return PM.materialize(self.param_descs(), key)

    def abstract_params(self) -> Tree:
        return PM.abstract(self.param_descs())

    def param_shardings(self, rules: Optional[PM.LogicalRules] = None
                        ) -> Tree:
        return PM.shardings(self.param_descs(), self.mesh, rules)

    # ---- training -------------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        fam = self.cfg.family
        baxes = train_batch_axes(self.mesh, batch["tokens"].shape[0])
        if fam in ("dense", "vlm", "moe"):
            return LM.lm_loss(params, batch, self.cfg, self.mesh, baxes)
        if fam == "hybrid":
            return HY.hybrid_loss(params, batch, self.cfg, self.mesh, baxes)
        if fam == "ssm":
            return RW.rwkv_loss(params, batch, self.cfg, self.mesh, baxes)
        if fam == "encdec":
            return WH.whisper_loss(params, batch, self.cfg, self.mesh,
                                   baxes)
        raise ValueError(fam)

    # ---- serving --------------------------------------------------------
    def cache_descs(self, batch: int, seq: int) -> Tree:
        fam = self.cfg.family
        if fam in ("dense", "vlm", "moe"):
            return LM.cache_descs(self.cfg, batch, seq)
        if fam == "hybrid":
            return HY.hybrid_cache_descs(self.cfg, batch, seq)
        if fam == "ssm":
            return RW.rwkv_cache_descs(self.cfg, batch, seq)
        if fam == "encdec":
            return WH.whisper_cache_descs(self.cfg, batch, seq)
        raise ValueError(fam)

    def prefill(self, params, batch) -> Tuple[jax.Array, Tree]:
        fam = self.cfg.family
        baxes = train_batch_axes(self.mesh, batch["tokens"].shape[0])
        if fam in ("dense", "vlm", "moe"):
            return LM.lm_prefill(params, batch, self.cfg, self.mesh, baxes)
        if fam == "hybrid":
            return HY.hybrid_prefill(params, batch, self.cfg, self.mesh,
                                     baxes)
        if fam == "ssm":
            return RW.rwkv_prefill(params, batch, self.cfg, self.mesh,
                                   baxes)
        if fam == "encdec":
            return WH.whisper_prefill(params, batch, self.cfg, self.mesh,
                                      baxes)
        raise ValueError(fam)

    def decode(self, params, token, pos, cache, cache_seq: int
               ) -> Tuple[jax.Array, Tree]:
        fam = self.cfg.family
        B = token.shape[0]
        baxes, saxes = decode_axes(self.mesh, B, cache_seq)
        if fam in ("dense", "vlm", "moe"):
            return LM.lm_decode(params, token, pos, cache, self.cfg,
                                self.mesh, baxes, saxes)
        if fam == "hybrid":
            return HY.hybrid_decode(params, token, pos, cache, self.cfg,
                                    self.mesh, baxes, saxes)
        if fam == "ssm":
            return RW.rwkv_decode(params, token, pos, cache, self.cfg,
                                  self.mesh, baxes, saxes)
        if fam == "encdec":
            return WH.whisper_decode(params, token, pos, cache, self.cfg,
                                     self.mesh, baxes, saxes)
        raise ValueError(fam)


def get_model(cfg: ModelConfig, mesh: Mesh) -> Model:
    return Model(cfg, mesh)


# --------------------------------------------- DFA inference heads ---------

def get_flow_head(cfg: DFAConfig, key
                  ) -> Tuple[Tree, Callable[[Tree, jax.Array], jax.Array]]:
    """Inference head for DFA-enriched flow features (the paper's
    immediate-inference consumer): ``(params, apply)`` with
    ``apply(params, feats (R, derived_dim)) -> logits (R, classes)``.

    ``cfg.inference_head`` selects "linear" (one projection) or "mlp"
    (one hidden relu layer of ``cfg.inference_hidden``). Features are
    log1p-squashed inside ``apply`` — raw moment sums span ~9 decades,
    and the head must be safe to call straight off the enrich kernel
    output with no host round trip.
    """
    D, C, Hd = cfg.derived_dim, cfg.inference_classes, cfg.inference_hidden
    kind = cfg.inference_head
    if kind == "linear":
        params = {"w": 0.1 * jax.random.normal(key, (D, C), jnp.float32),
                  "b": jnp.zeros((C,), jnp.float32)}

        def apply(p, feats):
            x = jnp.log1p(jnp.abs(feats.astype(jnp.float32)))
            return x @ p["w"] + p["b"]

        return params, apply
    if kind == "mlp":
        k1, k2 = jax.random.split(key)
        params = {"w1": 0.1 * jax.random.normal(k1, (D, Hd), jnp.float32),
                  "b1": jnp.zeros((Hd,), jnp.float32),
                  "w2": 0.1 * jax.random.normal(k2, (Hd, C), jnp.float32),
                  "b2": jnp.zeros((C,), jnp.float32)}

        def apply(p, feats):
            x = jnp.log1p(jnp.abs(feats.astype(jnp.float32)))
            h = jax.nn.relu(x @ p["w1"] + p["b1"])
            return h @ p["w2"] + p["b2"]

        return params, apply
    raise ValueError(
        f"unknown inference_head {kind!r}; expected 'linear' or 'mlp' "
        "(use 'none' to disable the hook)")


# ------------------------------------------------------- input specs -------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Tuple[Dict[str, Any], Dict[str, P]]:
    """ShapeDtypeStruct stand-ins + PartitionSpecs for every model input.

    train/prefill: token batch (+ modality stubs); decode: single token +
    position + cache (cache specs come from cache_descs via param machinery).
    """
    B, S = shape.global_batch, shape.seq_len
    baxes = train_batch_axes(mesh, B) or None
    d = cfg.d_model
    if shape.kind == "train":
        batch = {"tokens": _sds((B, S), "int32"),
                 "targets": _sds((B, S), "int32"),
                 "mask": _sds((B, S), "float32")}
        specs = {"tokens": P(baxes, None), "targets": P(baxes, None),
                 "mask": P(baxes, None)}
        if cfg.family == "vlm":
            np_ = cfg.vision.num_patches
            batch["patches"] = _sds((B, np_, d), cfg.dtype)
            specs["patches"] = P(baxes, None, None)
        if cfg.family == "encdec":
            f = cfg.encdec.num_frames
            batch["frames"] = _sds((B, f, d), cfg.dtype)
            specs["frames"] = P(baxes, None, None)
        return batch, specs
    if shape.kind == "prefill":
        n_text = S - (cfg.vision.num_patches if cfg.family == "vlm" else 0)
        batch = {"tokens": _sds((B, n_text), "int32")}
        specs = {"tokens": P(baxes, None)}
        if cfg.family == "vlm":
            batch["patches"] = _sds((B, cfg.vision.num_patches, d),
                                    cfg.dtype)
            specs["patches"] = P(baxes, None, None)
        if cfg.family == "encdec":
            f = cfg.encdec.num_frames
            batch["frames"] = _sds((B, f, d), cfg.dtype)
            specs["frames"] = P(baxes, None, None)
        return batch, specs
    if shape.kind == "decode":
        batch = {"token": _sds((B, 1), "int32"), "pos": _sds((B,), "int32")}
        specs = {"token": P(baxes, None), "pos": P(baxes)}
        return batch, specs
    raise ValueError(shape.kind)
