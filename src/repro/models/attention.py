"""Attention: GQA/MQA/MHA with RoPE, qk-norm, biases; MLA (deepseek-v3).

Three execution paths:
  * train/prefill — chunked online-softmax causal attention (flash-style in
    pure JAX: q processed in blocks, kv scanned in chunks; O(S) memory).
  * decode       — distributed flash-decode: the KV cache's *sequence* dim is
    sharded over mesh axes (default "model"); each shard computes a partial
    softmax and the result is combined with pmax/psum — this is the TPU
    analogue of splitting one flow's history across collector shards.
  * cross        — full bidirectional attention (whisper cross-attn).

Projections are 2-D (d_model, H*D) so the "model" axis always divides them.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size as _axis_size, shard_map as _shard_map
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamDesc

Tree = Any
NEG_INF = -1e30


# ------------------------------------------------------------- descs -------

def attn_descs(cfg: ModelConfig) -> Tree:
    D = cfg.resolved_head_dim
    dt = cfg.param_dtype
    t = {
        "q": L.linear_descs(cfg.d_model, cfg.num_heads * D, dt,
                            bias=cfg.qkv_bias, in_axis="embed",
                            out_axis="model"),
        "k": L.linear_descs(cfg.d_model, cfg.num_kv_heads * D, dt,
                            bias=cfg.qkv_bias, in_axis="embed",
                            out_axis="model"),
        "v": L.linear_descs(cfg.d_model, cfg.num_kv_heads * D, dt,
                            bias=cfg.qkv_bias, in_axis="embed",
                            out_axis="model"),
        "o": L.linear_descs(cfg.num_heads * D, cfg.d_model, dt,
                            in_axis="model", out_axis="embed"),
    }
    if cfg.qk_norm:
        t["q_norm"] = L.rms_norm_descs(D, dt)
        t["k_norm"] = L.rms_norm_descs(D, dt)
    return t


# ------------------------------------------- chunked causal attention ------

def _pick_chunk(size: int, target: int) -> int:
    """Largest divisor of ``size`` that is <= target (static shapes only)."""
    target = max(1, min(target, size))
    for c in range(target, 0, -1):
        if size % c == 0:
            return c
    return size


def _online_softmax_block(q, k, v, q_pos, k_pos, causal, scale, bias=None):
    """One (q block) x (kv chunk) update. q: (B,Q,KH,G,D), k/v: (B,C,KH,D)."""
    s = jnp.einsum("bqkgd,bckd->bkgqc", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]          # (Q, C)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    if bias is not None:
        s = s + bias
    m = jnp.max(s, axis=-1)                              # (B,KH,G,Q)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def _flash_fwd_impl(q, k, v, causal, q_offset, q_chunk, kv_chunk, scale):
    """q: (B,Sq,KH,G,D); k: (B,Sk,KH,D); v: (B,Sk,KH,Dv).

    Returns (o (B,KH,G,Sq,Dv) f32, lse (B,KH,G,Sq) f32)."""
    B, Sq, KH, G, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    k_s = k.reshape(B, nk, kv_chunk, KH, D).swapaxes(0, 1)
    v_s = v.reshape(B, nk, kv_chunk, KH, Dv).swapaxes(0, 1)

    def q_block(qb, qi):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, xs):
            m, l, o = carry
            kc, vc, ki = xs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            mb, lb, ob = _online_softmax_block(qb, kc, vc, q_pos, k_pos,
                                               causal, scale)
            m_new = jnp.maximum(m, mb)
            c1 = jnp.exp(m - m_new)
            c2 = jnp.exp(mb - m_new)
            l = l * c1 + lb * c2
            o = o * c1[..., None] + ob * c2[..., None]
            return (m_new, l, o), ()

        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, KH, G, q_chunk, Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_step, (m0, l0, o0), (k_s, v_s, jnp.arange(nk)))
        o = o / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return o, lse                                     # per q block

    if nq == 1:
        o, lse = q_block(q, jnp.asarray(0))
    else:
        q_s = q.reshape(B, nq, q_chunk, KH, G, D).swapaxes(0, 1)
        o, lse = jax.lax.map(lambda xs: q_block(*xs),
                             (q_s, jnp.arange(nq)))
        o = jnp.moveaxis(o, 0, 3).reshape(B, KH, G, Sq, Dv)
        lse = jnp.moveaxis(lse, 0, 3).reshape(B, KH, G, Sq)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(q, k, v, causal, q_offset, q_chunk, kv_chunk, scale):
    o, _ = _flash_fwd_impl(q, k, v, causal, q_offset, q_chunk, kv_chunk,
                           scale)
    return o.astype(q.dtype)


def _flash_core_fwd(q, k, v, causal, q_offset, q_chunk, kv_chunk, scale):
    o, lse = _flash_fwd_impl(q, k, v, causal, q_offset, q_chunk, kv_chunk,
                             scale)
    o = o.astype(q.dtype)
    return o, (q, k, v, o, lse)


def _flash_core_bwd(causal, q_offset, q_chunk, kv_chunk, scale, res, do):
    """Flash-attention backward: recompute p per (q, kv) chunk pair; no
    autodiff residuals (this is why train fits HBM — see DESIGN.md §9)."""
    q, k, v, o, lse = res
    B, Sq, KH, G, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    do = do.astype(jnp.float32)
    Dsum = jnp.sum(do * o.astype(jnp.float32), axis=-1)   # (B,KH,G,Sq)
    q_s = q.reshape(B, nq, q_chunk, KH, G, D).swapaxes(0, 1)
    do_s = do.reshape(B, KH, G, nq, q_chunk, Dv).transpose(3, 0, 1, 2, 4, 5)
    ds_sum = Dsum.reshape(B, KH, G, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    lse_s = lse.reshape(B, KH, G, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    k_s = k.reshape(B, nk, kv_chunk, KH, D).swapaxes(0, 1)
    v_s = v.reshape(B, nk, kv_chunk, KH, Dv).swapaxes(0, 1)

    def kv_step(dq_acc, xs):
        kc, vc, ki = xs
        k_pos = ki * kv_chunk + jnp.arange(kv_chunk)

        def q_step(carry, xs2):
            dk_c, dv_c = carry
            qb, dob, dsb, lseb, qi = xs2
            q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qb, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])              # (B,KH,G,Q,C)
            dv_c = dv_c + jnp.einsum("bkgqc,bkgqe->bcke", p, dob,
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bkgqe,bcke->bkgqc", dob,
                            vc.astype(jnp.float32))
            ds = p * (dp - dsb[..., None]) * scale        # (B,KH,G,Q,C)
            dq_b = jnp.einsum("bkgqc,bckd->bqkgd", ds,
                              kc.astype(jnp.float32))
            dk_c = dk_c + jnp.einsum("bkgqc,bqkgd->bckd", ds,
                                     qb.astype(jnp.float32))
            return (dk_c, dv_c), dq_b

        dk0 = jnp.zeros((B, kv_chunk, KH, D), jnp.float32)
        dv0 = jnp.zeros((B, kv_chunk, KH, Dv), jnp.float32)
        (dk_c, dv_c), dq_bs = jax.lax.scan(
            q_step, (dk0, dv0),
            (q_s, do_s, ds_sum, lse_s, jnp.arange(nq)))
        # dq_bs: (nq, B, q_chunk, KH, G, D) -> flat (B, Sq, KH, G, D)
        dq_flat = dq_bs.swapaxes(0, 1).reshape(B, Sq, KH, G, D)
        return dq_acc + dq_flat, (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    dq, (dk_s, dv_s) = jax.lax.scan(
        kv_step, dq0, (k_s, v_s, jnp.arange(nk)))
    dk = dk_s.swapaxes(0, 1).reshape(B, Sk, KH, D)
    dv = dv_s.swapaxes(0, 1).reshape(B, Sk, KH, Dv)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _attn_tp_constraints(q5, k, v, mesh, batch_axes):
    """Shard attention activations over "model": the KV-head dim when it
    divides, else the query-group dim (MQA), else leave to GSPMD."""
    if mesh is None:
        return q5, k, v
    from jax.sharding import NamedSharding
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    if m == 1:
        return q5, k, v
    ba = batch_axes or None
    B, Sq, KH, G, D = q5.shape
    cons = lambda x, spec: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
    if KH % m == 0:
        q5 = cons(q5, P(ba, None, "model", None, None))
        k = cons(k, P(ba, None, "model", None))
        v = cons(v, P(ba, None, "model", None))
    elif G % m == 0:
        q5 = cons(q5, P(ba, None, None, "model", None))
    elif Sq % m == 0 and Sq >= m * 8:
        # heads not divisible by TP (40-head archs on a 16-way axis):
        # context-parallel queries — shard q's SEQ dim; K/V are gathered
        # once but q/scores/o stay sharded (the qwen3 prefill hillclimb,
        # EXPERIMENTS.md §Perf)
        q5 = cons(q5, P(ba, "model", None, None, None))
    return q5, k, v


def chunked_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                      q_chunk: int = 256, kv_chunk: int = 1024,
                      scale: Optional[float] = None, mesh=None,
                      batch_axes=()) -> jax.Array:
    """Flash attention (pure JAX, custom VJP). q: (B,Sq,H,D);
    k: (B,Sk,KH,D); v: (B,Sk,KH,Dv) -> (B,Sq,H,Dv)."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    q = q.reshape(B, Sq, KH, G, D)
    q, k, v = _attn_tp_constraints(q, k, v, mesh, batch_axes)
    q_chunk = _pick_chunk(Sq, q_chunk)
    kv_chunk = _pick_chunk(Sk, kv_chunk)
    o = _flash_core(q, k, v, causal, q_offset, q_chunk, kv_chunk, scale)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)


def full_attention(q, k, v, *, scale: Optional[float] = None) -> jax.Array:
    """Small unmasked attention (cross-attn). q:(B,Sq,H,D), k/v:(B,Sk,KH,D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qr = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qr, k,
                   preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v)
    return o.reshape(B, Sq, H, D)


# -------------------------------------------------- distributed decode -----

def _linear_axis_index(axes: Sequence[str]) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * _axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def _update_row(buf, row, idx, valid):
    """buf: (S_loc, ...); row: (1, ...) write at idx if valid (per-batch).

    Invalid writes re-write the OLD row (a no-op) instead of selecting over
    the whole buffer — a full-buffer jnp.where makes a cache-sized copy per
    layer and defeats in-place donation."""
    idx_c = jnp.clip(idx, 0, buf.shape[0] - 1)
    old = jax.lax.dynamic_slice_in_dim(buf, idx_c, 1, axis=0)
    newrow = jnp.where(valid, row.astype(buf.dtype), old)
    return jax.lax.dynamic_update_slice_in_dim(buf, newrow, idx_c, axis=0)


def flash_decode(q, k_cache, v_cache, k_new, v_new, pos, *, mesh: Mesh,
                 seq_axes: Tuple[str, ...], batch_axes: Tuple[str, ...],
                 scale: Optional[float] = None):
    """One decode step against a sequence-sharded KV cache.

    q:       (B, H, D)         — current-token queries (all heads, replicated
                                 over the seq axes; tiny at decode).
    k_cache: (B, S, KH, D)     — S sharded over ``seq_axes``.
    k_new:   (B, KH, D)        — this step's K/V, written at ``pos``.
    pos:     (B,) int32        — per-sequence write/attend position.
    Returns (out (B,H,D), k_cache', v_cache').
    """
    B, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5

    def local(qb, kc, vc, kn, vn, p):
        Bl = qb.shape[0]                                   # LOCAL batch
        S_loc = kc.shape[1]
        shard = _linear_axis_index(seq_axes) if seq_axes else jnp.zeros(
            (), jnp.int32)
        offset = shard * S_loc
        # -- write this step's kv into the owning shard
        lidx = p - offset                                  # (B,)
        valid = (lidx >= 0) & (lidx < S_loc)
        kc = jax.vmap(_update_row)(kc, kn[:, None], lidx, valid)
        vc = jax.vmap(_update_row)(vc, vn[:, None], lidx, valid)
        # -- partial attention over the local slice
        qr = qb.reshape(Bl, KH, G, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qr, kc,
                       preferred_element_type=jnp.float32) * scale
        kpos = offset + jnp.arange(S_loc)
        mask = kpos[None] <= p[:, None]                    # (B, S_loc)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1)                            # (B,KH,G)
        e = jnp.exp(s - m[..., None])
        e = jnp.where(mask[:, None, None], e, 0.0)
        l = jnp.sum(e, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", e.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        if seq_axes:
            M = jax.lax.pmax(m, seq_axes)
            c = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - M))
            l = jax.lax.psum(l * c, seq_axes)
            o = jax.lax.psum(o * c[..., None], seq_axes)
        out = (o / jnp.maximum(l[..., None], 1e-30)).astype(qb.dtype)
        return out.reshape(Bl, H, D), kc, vc

    ba = batch_axes if batch_axes else None
    sa = seq_axes if seq_axes else None
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, sa, None, None),
                  P(ba, sa, None, None), P(ba, None, None),
                  P(ba, None, None), P(ba)),
        out_specs=(P(ba, None, None), P(ba, sa, None, None),
                   P(ba, sa, None, None)),
        check=False)
    return fn(q, k_cache, v_cache, k_new, v_new, pos)


# --------------------------------------------------------- GQA block -------

def project_qkv(params, x, cfg: ModelConfig, positions, rope: bool = True):
    """x: (B,S,d) -> q (B,S,H,D), k/v (B,S,KH,D) with rope + qk-norm."""
    B, S, _ = x.shape
    D = cfg.resolved_head_dim
    q = L.linear(params["q"], x).reshape(B, S, cfg.num_heads, D)
    k = L.linear(params["k"], x).reshape(B, S, cfg.num_kv_heads, D)
    v = L.linear(params["v"], x).reshape(B, S, cfg.num_kv_heads, D)
    if cfg.qk_norm:
        q = L.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = L.rms_norm(params["k_norm"], k, cfg.norm_eps)
    if rope:
        cos, sin = L.rotary(positions, D, cfg.rope_theta)
        q = L.apply_rotary(q, cos, sin)
        k = L.apply_rotary(k, cos, sin)
    return q, k, v


def attn_train(params, x, cfg: ModelConfig, *, q_offset: int = 0,
               causal: bool = True, return_kv: bool = False,
               rope: bool = True, mesh=None, batch_axes=()):
    B, S, _ = x.shape
    positions = q_offset + jnp.arange(S)
    q, k, v = project_qkv(params, x, cfg, positions, rope=rope)
    o = chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                          q_chunk=min(cfg.attn_chunk // 2, 256) or S,
                          kv_chunk=cfg.attn_chunk, mesh=mesh,
                          batch_axes=batch_axes)
    y = L.linear(params["o"], o.reshape(B, S, -1))
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(params, x, cfg: ModelConfig, k_cache, v_cache, pos, *,
                mesh: Mesh, seq_axes, batch_axes):
    """x: (B,1,d); pos: (B,) — returns (y (B,1,d), k_cache', v_cache')."""
    B = x.shape[0]
    D = cfg.resolved_head_dim
    q, k, v = project_qkv(params, x, cfg, pos[:, None].astype(jnp.float32))
    out, k_cache, v_cache = flash_decode(
        q[:, 0], k_cache, v_cache, k[:, 0], v[:, 0], pos, mesh=mesh,
        seq_axes=seq_axes, batch_axes=batch_axes)
    y = L.linear(params["o"], out.reshape(B, 1, -1))
    return y, k_cache, v_cache


# ---------------------------------------------------------------- MLA ------

def mla_descs(cfg: ModelConfig) -> Tree:
    m = cfg.mla
    dt = cfg.param_dtype
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_down": L.linear_descs(cfg.d_model, m.q_lora_rank, dt,
                                 in_axis="embed"),
        "q_norm": L.rms_norm_descs(m.q_lora_rank, dt),
        "q_up": L.linear_descs(m.q_lora_rank, H * qk_dim, dt,
                               out_axis="model"),
        "kv_down": L.linear_descs(cfg.d_model,
                                  m.kv_lora_rank + m.qk_rope_head_dim, dt,
                                  in_axis="embed"),
        "kv_norm": L.rms_norm_descs(m.kv_lora_rank, dt),
        "k_up": L.linear_descs(m.kv_lora_rank, H * m.qk_nope_head_dim, dt,
                               out_axis="model"),
        "v_up": L.linear_descs(m.kv_lora_rank, H * m.v_head_dim, dt,
                               out_axis="model"),
        "o": L.linear_descs(H * m.v_head_dim, cfg.d_model, dt,
                            in_axis="model", out_axis="embed"),
    }


def _mla_qkv_latent(params, x, cfg: ModelConfig, positions):
    """Shared down-projections. Returns q (nope+rope'd), latent c_kv, k_rope."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ql = L.rms_norm(params["q_norm"], L.linear(params["q_down"], x),
                    cfg.norm_eps)
    q = L.linear(params["q_up"], ql).reshape(B, S, H, qk)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv = L.linear(params["kv_down"], x)
    c_kv = L.rms_norm(params["kv_norm"], kv[..., :m.kv_lora_rank],
                      cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:]                     # (B,S,rope_dim)
    cos, sin = L.rotary(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = L.apply_rotary(q_rope, cos, sin)
    k_rope = L.apply_rotary(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_train(params, x, cfg: ModelConfig, *, q_offset: int = 0,
              return_kv: bool = False, mesh=None, batch_axes=()):
    """Training/prefill MLA: expand latent to per-head K/V (standard path)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    positions = q_offset + jnp.arange(S)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(params, x, cfg, positions)
    k_nope = L.linear(params["k_up"], c_kv).reshape(B, S, H,
                                                    m.qk_nope_head_dim)
    v = L.linear(params["v_up"], c_kv).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o = chunked_attention(q, k, v, causal=True, q_offset=q_offset,
                          q_chunk=min(cfg.attn_chunk // 2, 256),
                          kv_chunk=cfg.attn_chunk, scale=scale, mesh=mesh,
                          batch_axes=batch_axes)
    y = L.linear(params["o"], o.reshape(B, S, -1))
    if return_kv:
        return y, (c_kv, k_rope)
    return y


def mla_decode(params, x, cfg: ModelConfig, ckv_cache, krope_cache, pos, *,
               mesh: Mesh, seq_axes, batch_axes):
    """Absorbed-weight MLA decode over the *latent* cache (beyond-paper perf:
    the cache stores (kv_lora + rope) per token instead of H*(D_k+D_v)).

    ckv_cache: (B, S, R) latent; krope_cache: (B, S, Dr).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    R = m.kv_lora_rank
    q_nope, q_rope, c_new, kr_new = _mla_qkv_latent(
        params, x, cfg, pos[:, None].astype(jnp.float32))
    # absorb k_up into q: q_abs[b,h,r] = sum_d q_nope[b,h,d] * Wk[r, h, d]
    Wk = params["k_up"]["w"].reshape(R, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], Wk)   # (B,H,R)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    def local(qa, qr, ckv, krope, cn, krn, p):
        S_loc = ckv.shape[1]
        shard = _linear_axis_index(seq_axes) if seq_axes else jnp.zeros(
            (), jnp.int32)
        offset = shard * S_loc
        lidx = p - offset
        valid = (lidx >= 0) & (lidx < S_loc)
        ckv = jax.vmap(_update_row)(ckv, cn, lidx, valid)
        krope = jax.vmap(_update_row)(krope, krn, lidx, valid)
        s = (jnp.einsum("bhr,bsr->bhs", qa, ckv,
                        preferred_element_type=jnp.float32) +
             jnp.einsum("bhd,bsd->bhs", qr, krope,
                        preferred_element_type=jnp.float32)) * scale
        kpos = offset + jnp.arange(S_loc)
        mask = kpos[None] <= p[:, None]
        s = jnp.where(mask[:, None], s, NEG_INF)
        mx = jnp.max(s, axis=-1)
        e = jnp.where(mask[:, None], jnp.exp(s - mx[..., None]), 0.0)
        l = jnp.sum(e, axis=-1)
        o = jnp.einsum("bhs,bsr->bhr", e.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)  # latent-space o
        if seq_axes:
            Mx = jax.lax.pmax(mx, seq_axes)
            c = jnp.where(mx <= NEG_INF / 2, 0.0, jnp.exp(mx - Mx))
            l = jax.lax.psum(l * c, seq_axes)
            o = jax.lax.psum(o * c[..., None], seq_axes)
        o = o / jnp.maximum(l[..., None], 1e-30)
        return o.astype(x.dtype), ckv, krope

    ba = batch_axes if batch_axes else None
    sa = seq_axes if seq_axes else None
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None, None), P(ba, sa, None),
                  P(ba, sa, None), P(ba, None, None), P(ba, None, None),
                  P(ba)),
        out_specs=(P(ba, None, None), P(ba, sa, None), P(ba, sa, None)),
        check=False)
    o_lat, ckv_cache, krope_cache = fn(
        q_abs, q_rope[:, 0], ckv_cache, krope_cache, c_new, kr_new, pos)
    # absorb v_up on the way out: o[b,h,p] = sum_r o_lat[b,h,r] Wv[r,h,p]
    Wv = params["v_up"]["w"].reshape(R, H, m.v_head_dim)
    o = jnp.einsum("bhr,rhp->bhp", o_lat, Wv)
    y = L.linear(params["o"], o.reshape(B, 1, -1))
    return y, ckv_cache, krope_cache
