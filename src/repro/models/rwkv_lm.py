"""rwkv6-3b full-model assembly (attention-free)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import rwkv as R

Tree = Any


def rwkv_lm_descs(cfg: ModelConfig) -> Tree:
    return {
        "embed": L.embed_descs(cfg),
        "ln0": L.layer_norm_descs(cfg.d_model, cfg.param_dtype),
        "blocks": L.stack_descs(R.rwkv6_descs(cfg), cfg.num_layers),
        "final_norm": L.layer_norm_descs(cfg.d_model, cfg.param_dtype),
    }


def rwkv_hidden(params, batch, cfg: ModelConfig, mesh=None,
                batch_axes=()):
    x = L.embed(params["embed"], batch["tokens"])
    x = L.layer_norm(params["ln0"], x, cfg.norm_eps)

    def body(h, lp):
        return L.seq_shard(R.rwkv6_block_train(lp, h, cfg), mesh,
                           batch_axes), ()

    body = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.layer_norm(params["final_norm"], x, cfg.norm_eps)


def rwkv_loss(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    x = rwkv_hidden(params, batch, cfg, mesh, batch_axes)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    return L.chunked_ce_loss(params["embed"], x, batch["targets"], mask,
                             cfg.tie_embeddings, cfg.loss_chunk,
                             mesh, batch_axes)


def rwkv_cache_descs(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    # seq is irrelevant: O(1) recurrent state (the long_500k enabler)
    return L.stack_descs(R.rwkv6_state_descs(cfg, batch), cfg.num_layers)


def rwkv_prefill(params, batch, cfg: ModelConfig, mesh: Mesh, batch_axes):
    """Sequential-scan prefill producing the recurrent state.

    Processes the prompt in train form per layer but carries states; for the
    linear-attention family prefill == train forward + state collection.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = L.layer_norm(params["ln0"], x, cfg.norm_eps)

    def body(h, lp):
        # time mix with state capture
        xn = L.layer_norm(lp["ln1"], h, cfg.norm_eps)
        B_, S_, d = xn.shape
        xs = R._token_shift(xn, jnp.zeros((B_, d), xn.dtype))
        r, k, v, g, lw = R._tm_wkvrg(lp["tm"], xn, xs, cfg)
        u = lp["tm"]["bonus"].astype(jnp.float32)
        y, wkv_state = R.wkv6_chunked(r, k, v, lw, u, cfg.ssm.chunk_size)
        H = d // cfg.resolved_head_dim
        y = R._group_norm(y.reshape(B_, S_, d).astype(xn.dtype),
                          lp["tm"]["gn_scale"], lp["tm"]["gn_bias"], H)
        h = h + L.linear(lp["tm"]["out"], y * g)
        tm_x = xn[:, -1].astype(jnp.float32)
        # channel mix
        hn = L.layer_norm(lp["ln2"], h, cfg.norm_eps)
        cs = R._token_shift(hn, jnp.zeros((B_, d), hn.dtype))
        pc = lp["cm"]
        xk = hn + (cs - hn) * pc["maa_k"][None, None]
        xr = hn + (cs - hn) * pc["maa_r"][None, None]
        kk = jnp.square(jax.nn.relu(L.linear(pc["k"], xk)))
        h = h + jax.nn.sigmoid(L.linear(pc["r"], xr)) * L.linear(pc["v"], kk)
        cm_x = hn[:, -1].astype(jnp.float32)
        return h, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv_state}

    x, states = jax.lax.scan(body, x, params["blocks"])
    x = L.layer_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x[:, -1:, :],
                         cfg.tie_embeddings)[:, 0]
    return logits, states


def rwkv_decode(params, token, pos, cache, cfg: ModelConfig, mesh: Mesh,
                batch_axes, seq_axes):
    x = L.embed(params["embed"], token)
    x = L.layer_norm(params["ln0"], x, cfg.norm_eps)

    def body(h, xs):
        lp, st = xs
        h, st2 = R.rwkv6_block_decode(lp, h, cfg, st)
        return h, st2

    x, new_states = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.layer_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.logits_fn(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, new_states
