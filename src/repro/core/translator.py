"""DFA Translator — report routing + RDMA address computation (§III-B/IV-B).

The Translator terminates the DTA transport and computes the collector
memory address for every report: ``address = f(flow_id, history_index)``
with an 8-bit per-flow counter cycling through the 10 history entries.
On TPU, "choosing the RDMA address" becomes choosing the owning collector
shard (range-sharded flow space) + the (local flow, history) coordinates;
cross-shard delivery is a fixed-capacity all_to_all over the mesh — the ICI
plays the role of the RoCEv2 fabric.

Beyond-paper: optional report batching (``batch`` > 1 packs several reports
per message — the paper's own future-work §VII), which amortizes per-message
header overhead exactly as the paper projects.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DFAConfig
from repro.core import protocol as PROTO
from repro.core import wire as WIRE

Tree = Any


class TranslatorState(NamedTuple):
    hist_counter: jax.Array   # (F_total_local_view,) u8-semantics counter
    # the translator tracks counters for the flows whose reports it carries;
    # we shard it identically to the collector (one entry per local flow)


def init_state(cfg: DFAConfig) -> TranslatorState:
    return TranslatorState(
        hist_counter=jnp.zeros((cfg.flows_per_shard,), jnp.uint32))


def compute_addresses(state: TranslatorState, local_flow: jax.Array,
                      mask: jax.Array, cfg: DFAConfig
                      ) -> Tuple[TranslatorState, jax.Array]:
    """History index per report + counter update (mod ``history``; the
    hardware register wraps at the schema's hist-field width — 8 bits in
    both registered formats, matching the paper).

    Multiple reports for the same flow in one batch get consecutive indices
    (cumulative per-flow rank), matching sequential switch processing.
    """
    wrap = jnp.uint32(WIRE.resolve(cfg).hist_counter_mask)
    F = state.hist_counter.shape[0]
    R = local_flow.shape[0]
    safe = jnp.where(mask, local_flow, F)
    # per-flow occurrence rank within this batch
    order = jnp.argsort(safe, stable=True)
    s = safe[order]
    seg_start = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    idx_in_run = jnp.arange(R) - jax.lax.cummax(
        jnp.where(seg_start, jnp.arange(R), 0), axis=0)
    rank = jnp.zeros((R,), jnp.int32).at[order].set(idx_in_run)
    base = state.hist_counter[jnp.clip(local_flow, 0, F - 1)]
    hist = ((base + rank.astype(jnp.uint32)) & wrap) % jnp.uint32(
        cfg.history)
    # counter += count of reports per flow
    counts = jnp.zeros((F + 1,), jnp.uint32).at[safe].add(
        mask.astype(jnp.uint32), mode="drop")
    new_counter = (state.hist_counter + counts[:F]) & wrap
    # paper semantics: reset to 0 when max history index is reached
    new_counter = new_counter % jnp.uint32(cfg.history)
    return TranslatorState(new_counter), hist


def translate(state: TranslatorState, reports: jax.Array, mask: jax.Array,
              shard_flow_base, cfg: DFAConfig
              ) -> Tuple[TranslatorState, jax.Array, Dict[str, jax.Array]]:
    """DTA reports (R, 14) -> RoCEv2 payloads (R, 16) + placement coords."""
    wf = WIRE.resolve(cfg)
    rep = PROTO.unpack_dta_report(reports, wire=wf)
    local_flow = (rep["flow_id"].astype(jnp.int32)
                  - jnp.asarray(shard_flow_base, jnp.int32))
    state, hist = compute_addresses(state, local_flow, mask, cfg)
    payload = PROTO.pack_rocev2_payload(rep, hist, wire=wf)
    payload = jnp.where(mask[:, None], payload, jnp.uint32(0))
    return state, payload, {"local_flow": local_flow, "hist": hist,
                            "mask": mask}


def route_by_dest(reports: jax.Array, mask: jax.Array, dest: jax.Array,
                  n_buckets: int, capacity_out: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bucket reports by a caller-computed destination index for a
    fixed-capacity exchange. reports: (R, W) u32, dest: (R,) i32 ->
    ((n_buckets, capacity_out, W), bucket mask, misroutes).

    Masked-out rows never enter a bucket (padding cannot leak across an
    exchange stage); overflowing a destination bucket drops the report
    (counted by caller via the returned mask sums) — the lossy-telemetry
    trade DTA makes too. A ``dest`` outside [0, n_buckets) marks a
    corrupt or hostile flow id: the row is routed to the overflow slot
    (never into a real bucket, so it cannot poison another shard's ring)
    and tallied in the returned ``misroutes`` scalar.

    Valid entries occupy a contiguous rank-ordered prefix of each bucket
    (stable sort + dense per-destination rank), a property the compact
    cross-pod exchange relies on to count message boundaries.
    """
    R, W = reports.shape
    in_range = (dest >= 0) & (dest < n_buckets)
    misroutes = jnp.sum(mask & ~in_range)
    dest = jnp.where(mask & in_range, dest, n_buckets)
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    start = jnp.searchsorted(d_sorted, jnp.arange(n_buckets), side="left")
    rank = jnp.arange(R) - start[jnp.clip(d_sorted, 0, n_buckets - 1)]
    ok = (d_sorted < n_buckets) & (rank < capacity_out)
    slot = jnp.where(ok, d_sorted * capacity_out + rank,
                     n_buckets * capacity_out)
    out = jnp.zeros((n_buckets * capacity_out + 1, W), jnp.uint32)
    out = out.at[slot].set(reports[order], mode="drop")
    out_mask = jnp.zeros((n_buckets * capacity_out + 1,), bool
                         ).at[slot].set(ok, mode="drop")
    return (out[:-1].reshape(n_buckets, capacity_out, W),
            out_mask[:-1].reshape(n_buckets, capacity_out),
            misroutes)


def route_reports(reports: jax.Array, mask: jax.Array, n_shards: int,
                  flows_per_shard: int, capacity_out: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bucket reports by owning collector shard (legacy 1D range scheme)
    for a fixed-capacity all_to_all: dest = flow_id // flows_per_shard.

    A flow id beyond the sharded keyspace yields an out-of-range dest
    (a huge u32 id even wraps negative in i32) which route_by_dest drops
    and counts as a misroute instead of clipping onto the last shard."""
    flow_id = reports[:, 0].astype(jnp.int32)
    dest = flow_id // flows_per_shard
    return route_by_dest(reports, mask, dest, n_shards, capacity_out)


def home_flow_ids(keys: jax.Array, total_flows: int) -> jax.Array:
    """Mesh-shape-independent flow identity: FNV-1a hash of the stored
    five-tuple into the GLOBAL ring keyspace [0, total_flows).

    A flow observed on any port/pod maps to the same global id, so it has
    exactly one home ring regardless of where it was ingested."""
    from repro.core.reporter import hash_slot
    return hash_slot(keys, total_flows).astype(jnp.uint32)


def home_coords(flow_id: jax.Array, flows_per_shard: int,
                shards_per_pod: int, n_devices: int
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Global flow id -> (home_pod, home_shard, home_device) under the
    pod-major range sharding of the global keyspace: device
    d = pod * shards_per_pod + shard owns flows
    [d * flows_per_shard, (d+1) * flows_per_shard).

    An id beyond the keyspace maps to an out-of-range device (negative
    after i32 overflow for hostile u32 ids); the pod coordinate then
    falls outside [0, n_devices // shards_per_pod) and route_by_dest
    counts the row as a misroute rather than homing it on the last
    device. (jnp ``//``/``%`` floor toward -inf, so the shard coordinate
    of a negative dev is still in range — the pod coordinate is the one
    that carries the out-of-range signal through both routing stages.)"""
    dev = flow_id.astype(jnp.int32) // flows_per_shard
    return dev // shards_per_pod, dev % shards_per_pod, dev


def _mix32(x: jax.Array) -> jax.Array:
    """Finalizer-style u32 bijection (xor-shift-multiply avalanche); keeps
    the per-node rendezvous scores independent of the raw FNV structure."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


# decorrelates the ring-slot hash from the per-node rendezvous scores
_HRW_SLOT_SALT = 0x9E3779B9


def rendezvous_position(key_hash: jax.Array, node_ids: jax.Array
                        ) -> jax.Array:
    """Highest-random-weight (HRW) winner for each key over ``node_ids``.

    Scores depend only on (key_hash, node id) — NOT on the node's position
    in the mesh — so removing a node leaves every other key's winner
    unchanged (the HRW restriction property). Returns the winner's
    POSITION in ``node_ids`` (i32); ties (~2^-32 per pair) break toward
    the lower position, which is mesh-invariant because ``node_ids`` is
    kept sorted.
    """
    nid = node_ids.astype(jnp.uint32)
    salt = _mix32(nid * jnp.uint32(0x9E3779B9) + jnp.uint32(1))
    scores = _mix32(key_hash.astype(jnp.uint32)[..., None] ^ salt)
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def rendezvous_flow_ids(keys: jax.Array, node_ids: jax.Array,
                        flows_per_shard: int) -> jax.Array:
    """Elastic flow identity: ``flow_id = node_id * fps + slot`` where
    ``node_id`` is the key's HRW winner over the *logical* node set and
    ``slot`` is an independent hash into the node's ring.

    Encoding the stable node id (not the mesh position) into the flow id
    is what lets surviving nodes' ring state move between meshes bitwise:
    their flows keep the same ids, only dead-node flows re-home."""
    from repro.core.reporter import hash_u32
    kh = hash_u32(keys)
    pos = rendezvous_position(kh, node_ids)
    slot = _mix32(kh ^ jnp.uint32(_HRW_SLOT_SALT))
    fps = int(flows_per_shard)
    if fps & (fps - 1) == 0:
        slot = slot & jnp.uint32(fps - 1)
    else:
        slot = slot % jnp.uint32(fps)
    return (node_ids.astype(jnp.uint32)[pos] * jnp.uint32(fps)
            + slot).astype(jnp.uint32)


def node_position(node: jax.Array, node_ids: jax.Array) -> jax.Array:
    """Stable node id -> its position in the sorted ``node_ids`` roster
    (= mesh device index, pod-major). Ids not in the roster clip to the
    nearest position; callers guarantee membership."""
    pos = jnp.searchsorted(node_ids.astype(jnp.uint32),
                           node.astype(jnp.uint32))
    return jnp.clip(pos, 0, node_ids.shape[0] - 1).astype(jnp.int32)


def canonical_order(reports: jax.Array, mask: jax.Array,
                    wire: WIRE.WireFormat = WIRE.V1
                    ) -> Tuple[jax.Array, jax.Array]:
    """Arrival-order canonicalization at the home translator: sort the
    received batch by (flow_id, reporter_id, seq), padding rows last.

    Reports for one flow reach its home ring from many ingest ports, and
    the interleaving the exchange produces depends on the mesh
    factorization (bucket packing order). History-index assignment and
    ring placement are order-sensitive, so the home shard re-establishes
    a total order that only depends on WHAT arrived — this is what makes
    the merged collector state pod-count invariant. The (flow, reporter)
    pair is unique within a batch (a port reports a flow at most once per
    period), so the order is deterministic; every registered wire format
    keeps the meta word monotone in (reporter_id, seq), making it the
    ready-made secondary sort key. Padding rows take the max-u32 key so
    they sort last."""
    f = jnp.where(mask, reports[:, wire.report_flow_word],
                  jnp.uint32(WIRE.PAD_FLOW_ID))
    meta = jnp.where(mask, reports[:, wire.report_meta_word],
                     jnp.uint32(WIRE.PAD_SORT_KEY))
    o1 = jnp.argsort(meta, stable=True)
    order = o1[jnp.argsort(f[o1], stable=True)]
    return reports[order], mask[order]


def crosspod_compact(reports: jax.Array, mask: jax.Array, own_pod,
                     n_pods: int, capacity: int, hpod_fn,
                     wire: WIRE.WireFormat = WIRE.V1
                     ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                jax.Array, jax.Array, jax.Array]:
    """Compact stage-2 segments for the ragged pod exchange (§VII report
    batching): only the rows whose home pod differs from ``own_pod``
    enter the exchange buffers, packed into per-destination segments of
    ``capacity`` rows instead of the worst-case padded buckets.

    ``hpod_fn`` maps a (R,) u32 flow-id vector to its home-pod index —
    a pure function of the flow word, so it can be recomputed after the
    pre-merge sort instead of permuting a precomputed vector alongside.

    The pod-local pre-merge: remote rows are canonically ordered
    (flow-major) BEFORE packing, so all reports for one flow are
    adjacent; route_by_dest's stable packing preserves that adjacency
    inside each destination segment, collapsing same-flow traffic into
    one contiguous batched message at the source. ``n_messages`` counts
    those (destination, flow)-run boundaries — the number of distinct
    messages a batching wire transport would actually send.

    Returns ``(local_rows, local_mask, buckets, bucket_mask, misroutes,
    n_messages)``. ``local_rows`` holds the pod-local deliveries (masked
    rows zeroed so buffer padding can never leak stale payloads into the
    downstream canonical re-sort); ``buckets``/``bucket_mask`` are the
    (n_pods, capacity, W) exchange segments.
    """
    hpod = hpod_fn(reports[:, wire.report_flow_word])
    is_local = mask & (hpod == own_pod)
    remote = mask & (hpod != own_pod)
    local_rows = jnp.where(is_local[:, None], reports, jnp.uint32(0))
    rr, rm = canonical_order(reports, remote, wire=wire)
    buckets, bmask, misroutes = route_by_dest(
        rr, rm, hpod_fn(rr[:, wire.report_flow_word]), n_pods, capacity)
    # valid rows form a contiguous prefix of each segment, so a message
    # boundary is simply "first valid row, or flow differs from the row
    # above" — countable without another sort
    flows = buckets[:, :, wire.report_flow_word]
    n_messages = (jnp.sum(bmask[:, :1])
                  + jnp.sum(bmask[:, 1:]
                            & (flows[:, 1:] != flows[:, :-1])))
    return local_rows, is_local, buckets, bmask, misroutes, n_messages


def batch_payloads(payloads: jax.Array, mask: jax.Array, batch: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Beyond-paper: pack ``batch`` 64 B payloads into one message
    (paper §VII: 'batching could double or triple the overall throughput').
    Returns (messages (R//batch, batch*W), message mask)."""
    R, W = payloads.shape
    n = R // batch
    msgs = payloads[:n * batch].reshape(n, batch * W)
    mmask = mask[:n * batch].reshape(n, batch).any(axis=-1)
    return msgs, mmask
