"""DFA Translator — report routing + RDMA address computation (§III-B/IV-B).

The Translator terminates the DTA transport and computes the collector
memory address for every report: ``address = f(flow_id, history_index)``
with an 8-bit per-flow counter cycling through the 10 history entries.
On TPU, "choosing the RDMA address" becomes choosing the owning collector
shard (range-sharded flow space) + the (local flow, history) coordinates;
cross-shard delivery is a fixed-capacity all_to_all over the mesh — the ICI
plays the role of the RoCEv2 fabric.

Beyond-paper: optional report batching (``batch`` > 1 packs several reports
per message — the paper's own future-work §VII), which amortizes per-message
header overhead exactly as the paper projects.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DFAConfig
from repro.core import protocol as PROTO

Tree = Any


class TranslatorState(NamedTuple):
    hist_counter: jax.Array   # (F_total_local_view,) u8-semantics counter
    # the translator tracks counters for the flows whose reports it carries;
    # we shard it identically to the collector (one entry per local flow)


def init_state(cfg: DFAConfig) -> TranslatorState:
    return TranslatorState(
        hist_counter=jnp.zeros((cfg.flows_per_shard,), jnp.uint32))


def compute_addresses(state: TranslatorState, local_flow: jax.Array,
                      mask: jax.Array, cfg: DFAConfig
                      ) -> Tuple[TranslatorState, jax.Array]:
    """History index per report + counter update (mod ``history``; the
    hardware register is 8-bit — we keep the & 0xFF semantics).

    Multiple reports for the same flow in one batch get consecutive indices
    (cumulative per-flow rank), matching sequential switch processing.
    """
    F = state.hist_counter.shape[0]
    R = local_flow.shape[0]
    safe = jnp.where(mask, local_flow, F)
    # per-flow occurrence rank within this batch
    order = jnp.argsort(safe, stable=True)
    s = safe[order]
    seg_start = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    idx_in_run = jnp.arange(R) - jax.lax.cummax(
        jnp.where(seg_start, jnp.arange(R), 0), axis=0)
    rank = jnp.zeros((R,), jnp.int32).at[order].set(idx_in_run)
    base = state.hist_counter[jnp.clip(local_flow, 0, F - 1)]
    hist = ((base + rank.astype(jnp.uint32)) & 0xFF) % jnp.uint32(
        cfg.history)
    # counter += count of reports per flow
    counts = jnp.zeros((F + 1,), jnp.uint32).at[safe].add(
        mask.astype(jnp.uint32), mode="drop")
    new_counter = (state.hist_counter + counts[:F]) & jnp.uint32(0xFF)
    # paper semantics: reset to 0 when max history index is reached
    new_counter = new_counter % jnp.uint32(cfg.history)
    return TranslatorState(new_counter), hist


def translate(state: TranslatorState, reports: jax.Array, mask: jax.Array,
              shard_flow_base, cfg: DFAConfig
              ) -> Tuple[TranslatorState, jax.Array, Dict[str, jax.Array]]:
    """DTA reports (R, 14) -> RoCEv2 payloads (R, 16) + placement coords."""
    rep = PROTO.unpack_dta_report(reports)
    local_flow = (rep["flow_id"].astype(jnp.int32)
                  - jnp.asarray(shard_flow_base, jnp.int32))
    state, hist = compute_addresses(state, local_flow, mask, cfg)
    payload = PROTO.pack_rocev2_payload(rep, hist)
    payload = jnp.where(mask[:, None], payload, jnp.uint32(0))
    return state, payload, {"local_flow": local_flow, "hist": hist,
                            "mask": mask}


def route_reports(reports: jax.Array, mask: jax.Array, n_shards: int,
                  flows_per_shard: int, capacity_out: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Bucket reports by owning collector shard for a fixed-capacity
    all_to_all. reports: (R, W) u32 -> (n_shards, capacity_out, W).

    Overflowing a destination bucket drops the report (counted by caller
    via the returned mask sums) — the lossy-telemetry trade DTA makes too.
    """
    R, W = reports.shape
    flow_id = reports[:, 0].astype(jnp.int32)
    dest = jnp.clip(flow_id // flows_per_shard, 0, n_shards - 1)
    dest = jnp.where(mask, dest, n_shards)
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    start = jnp.searchsorted(d_sorted, jnp.arange(n_shards), side="left")
    rank = jnp.arange(R) - start[jnp.clip(d_sorted, 0, n_shards - 1)]
    ok = (d_sorted < n_shards) & (rank < capacity_out)
    slot = jnp.where(ok, d_sorted * capacity_out + rank,
                     n_shards * capacity_out)
    out = jnp.zeros((n_shards * capacity_out + 1, W), jnp.uint32)
    out = out.at[slot].set(reports[order], mode="drop")
    out_mask = jnp.zeros((n_shards * capacity_out + 1,), bool
                         ).at[slot].set(ok, mode="drop")
    return (out[:-1].reshape(n_shards, capacity_out, W),
            out_mask[:-1].reshape(n_shards, capacity_out))


def batch_payloads(payloads: jax.Array, mask: jax.Array, batch: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Beyond-paper: pack ``batch`` 64 B payloads into one message
    (paper §VII: 'batching could double or triple the overall throughput').
    Returns (messages (R//batch, batch*W), message mask)."""
    R, W = payloads.shape
    n = R // batch
    msgs = payloads[:n * batch].reshape(n, batch * W)
    mmask = mask[:n * batch].reshape(n, batch).any(axis=-1)
    return msgs, mmask
