"""Feature enrichment — the collector's CUDA-kernel stage, on TPU (§III-C).

Marina derives ~100 statistical features from the moment sums before
inference; DFA moves that onto accelerator compute ("build derived features
on CUDA cores"). From the seven Table-I registers per history entry we
derive, per entry: means, variances, std-devs, coefficients of variation and
skewness for IAT and PS, volume and rate terms; plus cross-history deltas
and window aggregates — ``derived_dim`` (default 96) float32 features per
flow. The hot loop is the derived_features Pallas kernel; this module is
the jnp reference and the feature definitions (shared by both).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DFAConfig
from repro.core import wire as WIRE

EPS = 1e-6
PER_ENTRY = 18            # features derived per history entry


def entry_features(stats_u32: jax.Array) -> jax.Array:
    """(…, 7) u32 Table-I registers -> (…, PER_ENTRY) f32 derived features.

    Moment identities: mean = S1/n, var = S2/n - mean², skew via S3
    (all on the log*-approximated sums, like Marina's CPU stage).
    """
    s = stats_u32.astype(jnp.float32)
    n = jnp.maximum(s[..., 0], 1.0)
    iat1, iat2, iat3 = s[..., 1], s[..., 2], s[..., 3]
    ps1, ps2, ps3 = s[..., 4], s[..., 5], s[..., 6]

    def moments(s1, s2, s3):
        mean = s1 / n
        var = jnp.maximum(s2 / n - mean ** 2, 0.0)
        std = jnp.sqrt(var)
        cov = std / jnp.maximum(mean, EPS)
        m3 = s3 / n - 3 * mean * var - mean ** 3
        skew = m3 / jnp.maximum(std ** 3, EPS)
        return mean, var, std, cov, skew

    i_mean, i_var, i_std, i_cov, i_skew = moments(iat1, iat2, iat3)
    p_mean, p_var, p_std, p_cov, p_skew = moments(ps1, ps2, ps3)
    duration = jnp.maximum(iat1, 1.0)                    # µs total
    volume = ps1                                         # bytes
    rate_bps = volume * 8.0 / (duration / 1e6 + EPS)
    pps = n / (duration / 1e6 + EPS)
    return jnp.stack([
        n, i_mean, i_var, i_std, i_cov, i_skew,
        p_mean, p_var, p_std, p_cov, p_skew,
        volume, rate_bps, pps, duration,
        jnp.log1p(volume), jnp.log1p(rate_bps), jnp.log1p(n),
    ], axis=-1)


def derive_ref(memory_entries: jax.Array, entry_valid: jax.Array,
               cfg: DFAConfig) -> jax.Array:
    """(F, H, 16) u32 + (F, H) -> (F, derived_dim) f32 — jnp oracle.

    Layout: newest entry's PER_ENTRY | window mean/std over history of
    [n, iat_mean, ps_mean, rate] | deltas newest-vs-window | zero pad.
    """
    F, H, W = memory_entries.shape
    wf = WIRE.resolve(cfg)
    stats = memory_entries[..., wf.payload_stats_slice].astype(jnp.uint32)
    hist_idx = wf.payload_hist.extract(memory_entries).astype(jnp.int32)
    feats = entry_features(stats)                        # (F, H, PER_ENTRY)
    vmask = entry_valid.astype(jnp.float32)[..., None]
    feats = feats * vmask
    nvalid = jnp.maximum(entry_valid.sum(-1, keepdims=True), 1
                         ).astype(jnp.float32)
    # newest = entry with the largest packet count x recency proxy:
    # ring order isn't timestamped; use hist slot of the latest write =
    # argmax over valid entries of packet count (monotone within a flow)
    count = jnp.where(entry_valid, stats[..., 0], 0)
    newest = jnp.argmax(count, axis=-1)                  # (F,)
    newest_f = jnp.take_along_axis(
        feats, newest[:, None, None].repeat(PER_ENTRY, -1), axis=1)[:, 0]
    mean_w = feats.sum(1) / nvalid
    # two-pass (masked) variance: E[(x-mean)^2] avoids the E[x^2]-mean^2
    # cancellation, keeping ref and kernel paths within 1e-5 relative
    dev = (feats - mean_w[:, None, :]) * vmask
    var_w = (dev * dev).sum(1) / nvalid
    std_w = jnp.sqrt(var_w)
    delta = newest_f - mean_w
    maxhist = jnp.max(jnp.where(entry_valid, hist_idx.astype(jnp.float32),
                                0.0), axis=-1, keepdims=True)
    out = jnp.concatenate([newest_f, mean_w, std_w, delta, nvalid,
                           maxhist], axis=-1)
    D = out.shape[-1]
    if D < cfg.derived_dim:
        out = jnp.pad(out, ((0, 0), (0, cfg.derived_dim - D)))
    return out[:, :cfg.derived_dim]


def enrich_history(memory: jax.Array, entry_valid: jax.Array,
                   local_flow: jax.Array, cfg: DFAConfig, mask=None,
                   backend=None, variant=None) -> jax.Array:
    """Selector-routed fused gather + derivation: the public enrichment
    entry point. (F, H, 16) ring memory + (F, H) validity + (R,) local
    flow ids -> (R, derived_dim) f32.

    Routes through the gather_enrich dispatch family — backend per
    ``DFAConfig.kernel_backend`` / ``REPRO_KERNEL_BACKEND``, memory
    strategy (full-block VMEM vs HBM-resident tiled) per
    ``DFAConfig.gather_variant`` / ``REPRO_GATHER_VARIANT`` / the
    VMEM-budget heuristic. Never materializes the (R, H, 16) gather.

    ``mask`` (optional (R,) bool — the routed-report validity from the
    ingest half) zeroes masked-out output rows after the fused kernel.
    """
    from repro.kernels.gather_enrich.ops import gather_enrich  # no cycle
    out = gather_enrich(memory, entry_valid, local_flow, cfg,
                        backend=backend, variant=variant)
    if mask is not None:
        out = jnp.where(mask[..., None], out, 0.0)
    return out
