"""Versioned wire schema — the ONE source of truth for the report layout.

Every bit position of the DTA report (reporter -> translator) and the
RoCEv2 payload / collector ring entry (translator -> collector, Fig 4) is
declared here as a :class:`Field` (word, shift, width) inside a registered
:class:`WireFormat`. The packing/unpacking/repacking layers
(``core.protocol``, ``core.reporter``, ``core.translator``,
``core.collector``, ``core.pipeline``, ``core.enrich``,
``kernels.derived_features``, ``launch.elastic``) all consume the schema;
none of them re-derives a shift or a mask by hand. A grep-based lint
(``tools/lint_wire.py``, wired into the CI lint tier) keeps it that way.

Two formats are registered:

``V1`` (default) — bit-faithful to the paper's Figs 2/4:
    report  word 1  = reporter_id(8) << 24 | seq(8) << 16 | flags(16)
    payload word 13 = reporter_id(8) << 24 | seq(8) << 16 | hist_idx(8)
    payload word 15 = zero pad
  8-bit reporter_id / seq cap the system at 256 ports and a 256-report
  per-port dup-tracking window. Every committed golden is pinned against
  this layout; it must stay bitwise-identical forever.

``V2`` — the widened format (ROADMAP "wire-format widening"):
    report  word 1  = reporter_id(16) << 16 | seq(16)
    payload word 13 = reporter_id(16) << 16 | seq(16)
    payload word 15 = hist_idx(8)      (the former pad word)
  u16 reporter_id / seq lift both caps (65,536 ports, 65,536-seq dup
  window). The checksum word (14) and its covered set (words 0-13 and
  15) are unchanged — word 15 was always inside the fold, so moving
  hist_idx there keeps every payload bit integrity-protected.

Both formats keep the meta word's (reporter_id, seq) pair monotone in the
raw u32 word value, which is what lets the home translator's canonical
(flow, reporter, seq) re-sort keep using the meta word directly as its
secondary key (``translator.canonical_order``).

Everything here is hashable (frozen dataclasses), so a ``WireFormat`` can
ride as a ``static_argnames`` entry through ``jax.jit`` and into Pallas
kernel bodies; the helpers are plain u32 bit ops that lower inside any
kernel.

Resolution order for the active format: ``REPRO_WIRE_FORMAT`` env
override (fail-loud, via ``configs.env``) > ``DFAConfig.wire_format`` >
the ``"v1"`` default. Unknown names raise listing the registry.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# flow-id value marking a padding row in canonical sorts / emitted
# flow-id streams (flow ids are < total_flows << 2^32 - 1 by contract)
PAD_FLOW_ID = 0xFFFFFFFF
# meta-word sort key for padding rows (sorts after every real report)
PAD_SORT_KEY = 0xFFFFFFFF


@dataclass(frozen=True)
class Field:
    """One packed field: ``word`` index, bit ``shift``, bit ``width``.

    The helpers are the only sanctioned way to read/write the field —
    they work on u32 scalars/arrays, inside jit and inside Pallas bodies.
    """

    word: int
    shift: int
    width: int

    def __post_init__(self):
        if not (0 <= self.shift and self.shift + self.width <= 32):
            raise ValueError(f"field {self} does not fit a u32 word")

    @property
    def mask(self) -> int:
        """Value mask (pre-shift): ``(1 << width) - 1``."""
        return (1 << self.width) - 1

    @property
    def capacity(self) -> int:
        """Number of distinct values the field can hold."""
        return 1 << self.width

    def get(self, word_val: jax.Array) -> jax.Array:
        """Extract from the raw u32 word VALUE."""
        return ((word_val.astype(jnp.uint32) >> self.shift)
                & jnp.uint32(self.mask))

    def extract(self, words: jax.Array) -> jax.Array:
        """Extract from a ``(..., W)`` u32 word ARRAY."""
        return self.get(words[..., self.word])

    def place(self, value: jax.Array) -> jax.Array:
        """The field's contribution to its word: ``(value & mask) << shift``."""
        return (value.astype(jnp.uint32)
                & jnp.uint32(self.mask)) << self.shift

    def set_in(self, word_val: jax.Array, value: jax.Array) -> jax.Array:
        """Repack: replace this field inside an existing word value."""
        keep = jnp.uint32(~(self.mask << self.shift) & 0xFFFFFFFF)
        return (word_val.astype(jnp.uint32) & keep) | self.place(value)


@dataclass(frozen=True)
class WireFormat:
    """A complete report + payload layout (all offsets/shifts/widths).

    Word indices shared by both registered formats (the skeleton):

    ========  =======================  =========================
    position  DTA report (Fig 2)       RoCEv2 payload (Fig 4)
    ========  =======================  =========================
    word 0    flow_id                  flow_id
    stats     words 2-8 (Table I x7)   words 1-7
    tuple     words 9-13 (five-tuple)  words 8-12
    meta      word 1                   word 13 (+ word 15)
    csum      —                        word 14
    ========  =======================  =========================

    Only the FIELD packing inside the meta words differs per version.
    Slices are stored as (start, stop) tuples so the dataclass stays
    hashable (jit static arg); use the ``*_slice`` properties.
    """

    name: str
    # DTA report (reporter -> translator)
    report_words: int
    report_reporter: Field
    report_seq: Field
    report_stats: Tuple[int, int]
    report_tuple: Tuple[int, int]
    # RoCEv2 payload / collector ring entry (translator -> collector)
    payload_words: int
    payload_reporter: Field
    payload_seq: Field
    payload_hist: Field
    payload_stats: Tuple[int, int]
    payload_tuple: Tuple[int, int]
    csum_word: int
    csum_covered: Tuple[int, ...]

    def __post_init__(self):
        if self.report_reporter.width != self.payload_reporter.width:
            raise ValueError(
                f"{self.name}: reporter_id width differs between report "
                f"({self.report_reporter.width}) and payload "
                f"({self.payload_reporter.width}) — the translator copies "
                "the field verbatim, so the spaces must agree")
        if self.report_seq.width != self.payload_seq.width:
            raise ValueError(
                f"{self.name}: seq width differs between report and "
                "payload")
        if self.csum_word in self.csum_covered:
            raise ValueError(
                f"{self.name}: checksum word {self.csum_word} cannot "
                "cover itself")

    # -- derived geometry --------------------------------------------------
    @property
    def report_flow_word(self) -> int:
        return 0

    @property
    def report_meta_word(self) -> int:
        return self.report_reporter.word

    @property
    def payload_meta_word(self) -> int:
        return self.payload_reporter.word

    @property
    def n_reporters(self) -> int:
        """Reporter-id space = the port-count cap."""
        return self.report_reporter.capacity

    @property
    def reporter_width(self) -> int:
        return self.report_reporter.width

    @property
    def seq_width(self) -> int:
        return self.report_seq.width

    @property
    def seq_mask(self) -> int:
        return self.report_seq.mask

    @property
    def seq_dup_window(self) -> int:
        """§VI-B duplicate/replay detection window: how far below the
        per-reporter max a seq may sit and still count as a replay rather
        than a wrap. 1/32 of the seq space — the paper's 8 for the 8-bit
        V1 field, scaled with the width so V2's u16 space doesn't
        silently reuse the 8-deep window."""
        return 1 << max(self.seq_width - 5, 0)

    @property
    def hist_counter_mask(self) -> int:
        """Wrap mask of the translator's per-flow history counter (the
        hardware register the paper sizes at 8 bits = the hist_idx field
        width)."""
        return self.payload_hist.mask

    @property
    def report_stats_slice(self) -> slice:
        return slice(*self.report_stats)

    @property
    def report_tuple_slice(self) -> slice:
        return slice(*self.report_tuple)

    @property
    def payload_stats_slice(self) -> slice:
        return slice(*self.payload_stats)

    @property
    def payload_tuple_slice(self) -> slice:
        return slice(*self.payload_tuple)

    # -- pack / unpack / repack helpers ------------------------------------
    def pack_report_meta(self, reporter_id: jax.Array,
                         seq: jax.Array) -> jax.Array:
        """(reporter_id, seq) -> the report meta word value."""
        return self.report_reporter.place(reporter_id) \
            | self.report_seq.place(seq)

    def set_report_reporter(self, meta_word: jax.Array,
                            reporter_id: jax.Array) -> jax.Array:
        """Repack: overwrite the reporter-id field of a report meta word
        (the pipeline stamps the shard/port identity post-pack)."""
        return self.report_reporter.set_in(meta_word, reporter_id)

    def payload_meta_words(self, reporter_id: jax.Array, seq: jax.Array,
                           hist_idx: jax.Array
                           ) -> Dict[int, jax.Array]:
        """Meta-word values keyed by payload word index — every payload
        word that is not flow/stats/tuple/csum. V1 packs all three fields
        into word 13 (word 15 stays the zero pad); V2 splits hist_idx out
        to word 15."""
        zero = jnp.zeros_like(reporter_id.astype(jnp.uint32))
        # the pad word (last) is always emitted so packers can assemble a
        # full payload: V1 leaves it zero, V2 packs hist_idx there
        out = {self.payload_reporter.word: zero,
               self.payload_hist.word: zero,
               self.payload_words - 1: zero}
        for f, v in ((self.payload_reporter, reporter_id),
                     (self.payload_seq, seq),
                     (self.payload_hist, hist_idx)):
            out[f.word] = out[f.word] | f.place(v)
        return out


# -- the registered formats --------------------------------------------------

V1 = WireFormat(
    name="v1",
    report_words=14,
    report_reporter=Field(word=1, shift=24, width=8),
    report_seq=Field(word=1, shift=16, width=8),
    report_stats=(2, 9),
    report_tuple=(9, 14),
    payload_words=16,
    payload_reporter=Field(word=13, shift=24, width=8),
    payload_seq=Field(word=13, shift=16, width=8),
    payload_hist=Field(word=13, shift=0, width=8),
    payload_stats=(1, 8),
    payload_tuple=(8, 13),
    csum_word=14,
    csum_covered=tuple(range(14)) + (15,),
)

V2 = WireFormat(
    name="v2",
    report_words=14,
    report_reporter=Field(word=1, shift=16, width=16),
    report_seq=Field(word=1, shift=0, width=16),
    report_stats=(2, 9),
    report_tuple=(9, 14),
    payload_words=16,
    payload_reporter=Field(word=13, shift=16, width=16),
    payload_seq=Field(word=13, shift=0, width=16),
    payload_hist=Field(word=15, shift=0, width=8),
    payload_stats=(1, 8),
    payload_tuple=(8, 13),
    csum_word=14,
    csum_covered=tuple(range(14)) + (15,),
)

FORMATS: Dict[str, WireFormat] = {"v1": V1, "v2": V2}


def get(name: str) -> WireFormat:
    """Registry lookup; unknown names raise listing what exists."""
    if name not in FORMATS:
        raise ValueError(
            f"unknown wire format {name!r}; registered: "
            f"{sorted(FORMATS)} (declare new layouts in repro.core.wire)")
    return FORMATS[name]


def resolve(cfg=None) -> WireFormat:
    """The active format: ``REPRO_WIRE_FORMAT`` env override >
    ``cfg.wire_format`` > ``"v1"``. Both stages fail loud on junk."""
    from repro.configs import env as ENV
    name = ENV.read_choice("REPRO_WIRE_FORMAT")
    if name is None:
        name = getattr(cfg, "wire_format", "v1") or "v1"
    return get(name)
