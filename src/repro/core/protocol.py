"""DFA wire formats (paper Figs 2 and 4) — pack/unpack over the schema.

Every bit position lives in :mod:`repro.core.wire`: a versioned
:class:`~repro.core.wire.WireFormat` declares each field's word offset,
shift and width, and the functions here assemble/disassemble whole
reports against whichever format the caller passes (``wire=`` keyword;
the default is ``wire.V1``, the paper's bit-faithful layout, so every
historical call site is unchanged).

Everything is little-endian u32 words. The shared skeleton (identical in
every registered format — see ``WireFormat``'s class docstring for the
table):

DTA report (reporter -> translator), the Key-Write derivative:
  word 0           flow_id
  word  ``report_meta_word``   reporter_id | seq   (packing per format:
                   V1 = rid(8)<<24 | seq(8)<<16, V2 = rid(16)<<16 | seq(16))
  ``report_stats_slice``   the SEVEN Table-I data fields:
                   pkt_count, sum_iat, sum_iat2, sum_iat3,
                   sum_ps, sum_ps2, sum_ps3
  ``report_tuple_slice``   five-tuple: src_ip, dst_ip, (sport<<16|dport),
                   proto, pad
  -> 14 words = 56 B on the wire (45 B payload + base header, word aligned)

RoCEv2 WRITE payload (translator -> collector), padded to a power of two:
  word 0           flow_id
  ``payload_stats_slice``  seven data fields
  ``payload_tuple_slice``  five-tuple
  meta words       reporter_id | seq | hist_idx (V1: all in word 13, word
                   15 is the zero pad; V2: rid/seq in word 13, hist_idx in
                   word 15)
  ``csum_word``    checksum (position-dependent rotate-then-xor fold of
                   the ``csum_covered`` words — 0-13 and 15 in both
                   registered formats)
  -> 16 words = 64 B exactly (the paper's RoCEv2 pow-2 payload)

The checksum rotates each covered word left by its payload position before
folding, so (a) the same corruption mask applied to two different words no
longer cancels (plain xor-fold's blind spot) and (b) every non-checksum
word is inside the covered set — V1's pad and V2's hist_idx word can't be
flipped undetected.

Collector memory entry (Fig 4) uses the same 16-word layout, so a report is
placed into GPU/HBM memory VERBATIM — the zero-copy property DFA gets from
RDMA is preserved as a layout guarantee here.

The module-level constants (REPORT_WORDS, STATS_SLICE, META_WORD, ...)
are the V1 geometry, kept as aliases for the many call sites and tests
that predate the schema; format-dependent code should read them off the
``WireFormat`` instead.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import wire as WIRE

# V1-geometry aliases (see module docstring) — identical in V2 except for
# the field packing inside the meta words.
REPORT_WORDS = WIRE.V1.report_words      # DTA report
PAYLOAD_WORDS = WIRE.V1.payload_words    # RoCEv2 / collector entry (64 B)
N_STATS = 7              # Table-I exported fields
STATS_SLICE = WIRE.V1.payload_stats_slice        # in the RoCEv2 payload
TUPLE_SLICE = WIRE.V1.payload_tuple_slice
META_WORD = WIRE.V1.payload_meta_word
CSUM_WORD = WIRE.V1.csum_word
CSUM_COVERED = WIRE.V1.csum_covered      # 0-13 + pad

FIVE_TUPLE_BYTES = 17    # 4+4+2+2+1 (paper)
MARINA_VECTOR_BYTES = 45  # 7*4 + 17 (paper: "full feature vector requires 45B")
PAYLOAD_BYTES = PAYLOAD_WORDS * 4


def _rotl32(w: jax.Array, k: jax.Array) -> jax.Array:
    """Rotate-left each u32 by k bits (k in [0, 32), k=0 is identity)."""
    k = k.astype(jnp.uint32) % jnp.uint32(32)
    return (w << k) | (w >> ((jnp.uint32(32) - k) % jnp.uint32(32)))


def xor_checksum(words: jax.Array,
                 positions: jax.Array | None = None) -> jax.Array:
    """Position-dependent fold: XOR of rotl(word_i, pos_i); words
    (..., W) u32 -> (...,) u32.

    ``positions`` defaults to ``arange(W)`` — pass explicit payload word
    positions when the covered set is non-contiguous (``payload_valid``
    skips the stored checksum word itself). The rotation makes the fold
    sensitive to WHERE a corruption lands: equal masks on two different
    words rotate to different values and no longer cancel.
    """
    w = words.astype(jnp.uint32)
    if positions is None:
        positions = jnp.arange(words.shape[-1], dtype=jnp.uint32)
    rot = _rotl32(w, positions.astype(jnp.uint32))
    return jax.lax.reduce(rot, jnp.uint32(0), jax.lax.bitwise_xor,
                          (words.ndim - 1,))


def pack_dta_report(flow_id, reporter_id, seq, stats, five_tuple,
                    wire: WIRE.WireFormat = WIRE.V1) -> jax.Array:
    """-> (..., wire.report_words) u32.

    stats: (..., 7) u32; five_tuple: (..., 5) u32 (ip, ip, ports, proto, 0).
    """
    meta = wire.pack_report_meta(reporter_id, seq)
    return jnp.concatenate([
        flow_id[..., None].astype(jnp.uint32),
        meta[..., None],
        stats.astype(jnp.uint32),
        five_tuple.astype(jnp.uint32),
    ], axis=-1)


def unpack_dta_report(r: jax.Array, wire: WIRE.WireFormat = WIRE.V1
                      ) -> Dict[str, jax.Array]:
    return {
        "flow_id": r[..., wire.report_flow_word],
        "reporter_id": wire.report_reporter.extract(r),
        "seq": wire.report_seq.extract(r),
        "stats": r[..., wire.report_stats_slice],
        "five_tuple": r[..., wire.report_tuple_slice],
    }


def pack_rocev2_payload(rep: Dict[str, jax.Array], hist_idx: jax.Array,
                        wire: WIRE.WireFormat = WIRE.V1) -> jax.Array:
    """Translator: DTA report fields + history index -> 64 B payload."""
    meta = wire.payload_meta_words(rep["reporter_id"], rep["seq"],
                                   hist_idx)
    body = jnp.concatenate([
        rep["flow_id"][..., None].astype(jnp.uint32),
        rep["stats"].astype(jnp.uint32),
        rep["five_tuple"].astype(jnp.uint32),
        meta[wire.payload_meta_word][..., None],
    ], axis=-1)                                            # 14 words
    tail = meta[wire.payload_words - 1]
    # the fold covers the tail word at its true payload position (15): in
    # V1 it packs as zero and contributes rotl(0, 15) = 0 — only
    # tampering can change it; in V2 it carries hist_idx and the fold
    # protects it like every other word
    covered = jnp.concatenate([body, tail[..., None]], axis=-1)
    csum = xor_checksum(covered,
                        jnp.asarray(wire.csum_covered, jnp.uint32))
    return jnp.concatenate([body, csum[..., None], tail[..., None]],
                           axis=-1)


def unpack_payload(p: jax.Array, wire: WIRE.WireFormat = WIRE.V1
                   ) -> Dict[str, jax.Array]:
    return {
        "flow_id": p[..., 0],
        "stats": p[..., wire.payload_stats_slice],
        "five_tuple": p[..., wire.payload_tuple_slice],
        "reporter_id": wire.payload_reporter.extract(p),
        "seq": wire.payload_seq.extract(p),
        "hist_idx": wire.payload_hist.extract(p),
        "checksum": p[..., wire.csum_word],
    }


def payload_valid(p: jax.Array, wire: WIRE.WireFormat = WIRE.V1
                  ) -> jax.Array:
    """Collector-side integrity check (Fig 4 checksum): rotate-then-xor
    fold over the format's covered words (0-13 AND the tail word 15),
    each rotated by its payload position, compared against the stored
    checksum word."""
    covered = p[..., jnp.asarray(wire.csum_covered)]
    pos = jnp.asarray(wire.csum_covered, jnp.uint32)
    return xor_checksum(covered, pos) == p[..., wire.csum_word]


def pack_five_tuple(src_ip, dst_ip, sport, dport, proto) -> jax.Array:
    """-> (..., 5) u32 — 17 B of identity, word-aligned like the collector."""
    return jnp.stack([
        src_ip.astype(jnp.uint32),
        dst_ip.astype(jnp.uint32),
        ((sport.astype(jnp.uint32) & 0xFFFF) << 16)
        | (dport.astype(jnp.uint32) & 0xFFFF),
        proto.astype(jnp.uint32) & 0xFF,
        jnp.zeros_like(src_ip, jnp.uint32),
    ], axis=-1)
