"""Bit-faithful DFA wire formats (paper Figs 2 and 4).

Everything is expressed as little-endian u32 words:

DTA report (reporter -> translator), the Key-Write derivative:
  word 0      flow_id
  word 1      (reporter_id << 24) | (seq << 16) | flags      [sec VI-B seq ids]
  words 2-8   the SEVEN Table-I data fields:
              pkt_count, sum_iat, sum_iat2, sum_iat3, sum_ps, sum_ps2, sum_ps3
  words 9-13  five-tuple: src_ip, dst_ip, (sport<<16|dport), proto, pad
  -> 14 words = 56 B on the wire (45 B payload + base header, word aligned)

RoCEv2 WRITE payload (translator -> collector), padded to a power of two:
  word 0      flow_id
  words 1-7   seven data fields
  words 8-12  five-tuple
  word 13     (reporter_id << 24) | (seq << 16) | hist_idx
  word 14     checksum (position-dependent rotate-then-xor fold of words
              0-13 and the pad word 15)
  word 15     pad (zero)
  -> 16 words = 64 B exactly (the paper's RoCEv2 pow-2 payload)

The checksum rotates each covered word left by its payload position before
folding, so (a) the same corruption mask applied to two different words no
longer cancels (plain xor-fold's blind spot) and (b) the pad word is inside
the covered set — a flipped pad can't ride along undetected.

Collector memory entry (Fig 4) uses the same 16-word layout, so a report is
placed into GPU/HBM memory VERBATIM — the zero-copy property DFA gets from
RDMA is preserved as a layout guarantee here.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

REPORT_WORDS = 14        # DTA report
PAYLOAD_WORDS = 16       # RoCEv2 / collector entry (64 B)
N_STATS = 7              # Table-I exported fields
STATS_SLICE = slice(1, 8)        # in the RoCEv2 payload
TUPLE_SLICE = slice(8, 13)
META_WORD = 13
CSUM_WORD = 14

FIVE_TUPLE_BYTES = 17    # 4+4+2+2+1 (paper)
MARINA_VECTOR_BYTES = 45  # 7*4 + 17 (paper: "full feature vector requires 45B")
PAYLOAD_BYTES = PAYLOAD_WORDS * 4


def _rotl32(w: jax.Array, k: jax.Array) -> jax.Array:
    """Rotate-left each u32 by k bits (k in [0, 32), k=0 is identity)."""
    k = k.astype(jnp.uint32) % jnp.uint32(32)
    return (w << k) | (w >> ((jnp.uint32(32) - k) % jnp.uint32(32)))


def xor_checksum(words: jax.Array,
                 positions: jax.Array | None = None) -> jax.Array:
    """Position-dependent fold: XOR of rotl(word_i, pos_i); words
    (..., W) u32 -> (...,) u32.

    ``positions`` defaults to ``arange(W)`` — pass explicit payload word
    positions when the covered set is non-contiguous (``payload_valid``
    skips the stored checksum word itself). The rotation makes the fold
    sensitive to WHERE a corruption lands: equal masks on two different
    words rotate to different values and no longer cancel.
    """
    w = words.astype(jnp.uint32)
    if positions is None:
        positions = jnp.arange(words.shape[-1], dtype=jnp.uint32)
    rot = _rotl32(w, positions.astype(jnp.uint32))
    return jax.lax.reduce(rot, jnp.uint32(0), jax.lax.bitwise_xor,
                          (words.ndim - 1,))


def pack_dta_report(flow_id, reporter_id, seq, stats, five_tuple
                    ) -> jax.Array:
    """-> (..., REPORT_WORDS) u32.

    stats: (..., 7) u32; five_tuple: (..., 5) u32 (ip, ip, ports, proto, 0).
    """
    meta = ((reporter_id.astype(jnp.uint32) << 24)
            | ((seq.astype(jnp.uint32) & 0xFF) << 16))
    return jnp.concatenate([
        flow_id[..., None].astype(jnp.uint32),
        meta[..., None],
        stats.astype(jnp.uint32),
        five_tuple.astype(jnp.uint32),
    ], axis=-1)


def unpack_dta_report(r: jax.Array) -> Dict[str, jax.Array]:
    return {
        "flow_id": r[..., 0],
        "reporter_id": r[..., 1] >> 24,
        "seq": (r[..., 1] >> 16) & 0xFF,
        "stats": r[..., 2:9],
        "five_tuple": r[..., 9:14],
    }


def pack_rocev2_payload(rep: Dict[str, jax.Array], hist_idx: jax.Array
                        ) -> jax.Array:
    """Translator: DTA report fields + history index -> 64 B payload."""
    meta = ((rep["reporter_id"].astype(jnp.uint32) << 24)
            | ((rep["seq"].astype(jnp.uint32) & 0xFF) << 16)
            | (hist_idx.astype(jnp.uint32) & 0xFF))
    body = jnp.concatenate([
        rep["flow_id"][..., None].astype(jnp.uint32),
        rep["stats"].astype(jnp.uint32),
        rep["five_tuple"].astype(jnp.uint32),
        meta[..., None],
    ], axis=-1)                                            # 14 words
    # the fold also covers the pad word (position 15), which packs as zero
    # and thus contributes rotl(0, 15) = 0 — only tampering can change it
    csum = xor_checksum(body)
    pad = jnp.zeros_like(csum)
    return jnp.concatenate([body, csum[..., None], pad[..., None]], axis=-1)


def unpack_payload(p: jax.Array) -> Dict[str, jax.Array]:
    return {
        "flow_id": p[..., 0],
        "stats": p[..., STATS_SLICE],
        "five_tuple": p[..., TUPLE_SLICE],
        "reporter_id": p[..., META_WORD] >> 24,
        "seq": (p[..., META_WORD] >> 16) & 0xFF,
        "hist_idx": p[..., META_WORD] & 0xFF,
        "checksum": p[..., CSUM_WORD],
    }


CSUM_COVERED = tuple(range(CSUM_WORD)) + (PAYLOAD_WORDS - 1,)  # 0-13 + pad


def payload_valid(p: jax.Array) -> jax.Array:
    """Collector-side integrity check (Fig 4 checksum): rotate-then-xor
    fold over words 0-13 AND the pad word 15, each rotated by its payload
    position, compared against the stored word 14."""
    covered = p[..., jnp.asarray(CSUM_COVERED)]
    pos = jnp.asarray(CSUM_COVERED, jnp.uint32)
    return xor_checksum(covered, pos) == p[..., CSUM_WORD]


def pack_five_tuple(src_ip, dst_ip, sport, dport, proto) -> jax.Array:
    """-> (..., 5) u32 — 17 B of identity, word-aligned like the collector."""
    return jnp.stack([
        src_ip.astype(jnp.uint32),
        dst_ip.astype(jnp.uint32),
        ((sport.astype(jnp.uint32) & 0xFFFF) << 16)
        | (dport.astype(jnp.uint32) & 0xFFFF),
        proto.astype(jnp.uint32) & 0xFF,
        jnp.zeros_like(src_ip, jnp.uint32),
    ], axis=-1)
