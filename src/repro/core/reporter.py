"""DFA Reporter — line-rate per-flow feature extraction (paper §III-A/IV-A).

State mirrors the Tofino register layout (Fig 7): per flow-slot, eight 32-bit
stateful registers (Table I) plus the report-interval tracking register. The
Marina classification table (five-tuple -> flow id) is adapted to a
device-resident hash-slot table with stored-key collision detection: the
paper's control-plane digest path (<1k flow-mods/s, its acknowledged
bottleneck) is replaced by in-path admission — see DESIGN.md §11(3).

Packet events arrive as time-sorted arrays; IAT resolution uses the stored
last-timestamp register, with in-block predecessors resolved by a stable
sort per slot (the vectorized equivalent of sequential packet processing).
``ingest`` routes through the ingest_update kernel family: the ref backend
keeps this module's multipass shape as the bitwise oracle, the Pallas
backends take the fused sort-once / segment-reduce path
(repro.kernels.ingest_update) that forms the Table-I deltas inside the
kernel and emits one scatter-add per slot run.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DFAConfig
from repro.core import logstar as LS
from repro.core import protocol as PROTO
from repro.core import wire as WIRE

Tree = Any

# register columns (Table I order)
COL_COUNT, COL_IAT, COL_IAT2, COL_IAT3, COL_PS, COL_PS2, COL_PS3 = range(7)
N_REG = 7


class ReporterState(NamedTuple):
    regs: jax.Array        # (F, 7) u32 — Table-I stat registers
    last_ts: jax.Array     # (F,) u32 — last packet timestamp (us)
    last_report: jax.Array  # (F,) u32 — report-interval tracking register
    keys: jax.Array        # (F, 5) u32 — stored five-tuple (admission)
    active: jax.Array      # (F,) bool — slot occupied
    seq: jax.Array         # () u32 — per-reporter sequence counter (VI-B)
    collisions: jax.Array  # () u32 — hash-collision telemetry


def init_state(cfg: DFAConfig) -> ReporterState:
    F = cfg.flows_per_shard
    return ReporterState(
        regs=jnp.zeros((F, N_REG), jnp.uint32),
        last_ts=jnp.zeros((F,), jnp.uint32),
        last_report=jnp.zeros((F,), jnp.uint32),
        keys=jnp.zeros((F, 5), jnp.uint32),
        active=jnp.zeros((F,), bool),
        seq=jnp.zeros((), jnp.uint32),
        collisions=jnp.zeros((), jnp.uint32),
    )


def hash_u32(five_tuple: jax.Array) -> jax.Array:
    """Raw FNV-1a u32 hash of the 5 identity words (no table reduction).

    The full-width hash is the shared key identity both homing schemes
    derive from: ``hash_slot`` masks it into a table, the rendezvous
    scheme mixes it per-node (translator.rendezvous_flow_ids)."""
    h = jnp.full(five_tuple.shape[:-1], 0x811C9DC5, jnp.uint32)
    for i in range(5):
        h = (h ^ five_tuple[..., i].astype(jnp.uint32)) * jnp.uint32(
            0x01000193)
    return h


def hash_slot(five_tuple: jax.Array, n_slots: int) -> jax.Array:
    """FNV-1a style hash of the 5 identity words -> slot index."""
    h = hash_u32(five_tuple)
    if n_slots & (n_slots - 1) == 0:
        # power-of-two table (every shipped config): the modulo is a
        # mask — bit-identical to ``h % n_slots``, no division per event
        return (h & jnp.uint32(n_slots - 1)).astype(jnp.int32)
    return (h % jnp.uint32(n_slots)).astype(jnp.int32)


def event_deltas(iat: jax.Array, ps: jax.Array, first: jax.Array,
                 valid: jax.Array, bits: int) -> jax.Array:
    """Per-event Table-I register deltas (E, 7) u32 via the log* pipeline.

    IAT terms are zero for a flow's first packet (no predecessor)."""
    iat = jnp.where(first, jnp.uint32(0), iat.astype(jnp.uint32))
    ps = ps.astype(jnp.uint32)
    z = jnp.uint32(0)
    d = jnp.stack([
        jnp.ones_like(ps),                       # packet count
        iat,                                     # sum IAT (exact, like P4)
        LS.approx_pow(iat, 2, bits),             # sum IAT^2 (log* approx)
        LS.approx_pow(iat, 3, bits),             # sum IAT^3
        ps,                                      # sum PS
        LS.approx_pow(ps, 2, bits),              # sum PS^2
        LS.approx_pow(ps, 3, bits),              # sum PS^3
    ], axis=-1)
    return jnp.where(valid[..., None], d, z)


def resolve_iat(slots: jax.Array, ts: jax.Array, valid: jax.Array,
                last_ts: jax.Array, active: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-event (iat, first_flag, new_last_ts).

    Events are time-sorted; a stable sort by slot makes each event's
    predecessor either the previous in-block event of the same slot or the
    register value.
    """
    E = slots.shape[0]
    F = last_ts.shape[0]
    safe_slots = jnp.where(valid, slots, F)      # invalid -> sentinel bucket
    order = jnp.argsort(safe_slots, stable=True)
    s_slot = safe_slots[order]
    s_ts = ts[order]
    prev_same = jnp.concatenate(
        [jnp.array([False]), s_slot[1:] == s_slot[:-1]])
    reg_last = jnp.where(s_slot < F, last_ts[jnp.clip(s_slot, 0, F - 1)], 0)
    reg_active = jnp.where(s_slot < F,
                           active[jnp.clip(s_slot, 0, F - 1)], False)
    prev_ts = jnp.where(prev_same,
                        jnp.concatenate([jnp.zeros((1,), s_ts.dtype),
                                         s_ts[:-1]]), reg_last)
    first = jnp.where(prev_same, False, ~reg_active)
    iat_sorted = (s_ts - prev_ts).astype(jnp.uint32)
    inv = jnp.argsort(order)                      # unsort
    iat = iat_sorted[inv]
    first_flags = first[inv]
    # new last_ts per slot = the LAST event of the slot in arrival order.
    # Events are time-sorted, so that is the latest — but NOT necessarily
    # the numeric max: the u32 µs clock wraps every ~71.6 min, and a
    # ``.max(ts)`` update would pin the stale pre-wrap value forever,
    # corrupting every subsequent IAT. The stable slot-sort keeps arrival
    # order within a slot, so the tail element of each slot run is the
    # wrap-safe update (u32 subtraction in the IAT math already handles
    # the wrap itself).
    run_tail = jnp.concatenate(
        [s_slot[1:] != s_slot[:-1], jnp.array([True])])
    upd = jnp.where(run_tail & (s_slot < F), s_slot, F)
    new_last = last_ts.at[upd].set(s_ts.astype(jnp.uint32), mode="drop")
    return iat, first_flags, new_last


def admit_arrays(keys: jax.Array, active: jax.Array,
                 collisions: jax.Array, slots: jax.Array,
                 five_tuple: jax.Array, valid: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pure-array hash-slot admission with stored-key collision detection.

    A valid event either (a) matches the stored key (tracked flow),
    (b) lands in an empty slot (new flow — install key), or (c) collides —
    counted in telemetry and the event attributed to the resident flow
    (paper: no explicit mechanism for such flows either, §IV-A).

    First-come install is enforced WITHIN a block too: when several new
    flows hash to the same empty slot in one block, only the first in
    arrival order installs its key; later same-block arrivals compare
    against that installed key (same key -> tracked, different key ->
    collision). The old duplicate-index ``.at[].set`` let the last
    writer win nondeterministically.
    """
    F = keys.shape[0]
    E = slots.shape[0]
    cl = jnp.clip(slots, 0, F - 1)
    stored = keys[cl]                             # (E, 5)
    empty = ~active[cl]
    match = jnp.all(stored == five_tuple, axis=-1) & ~empty
    want_install = valid & empty
    # first arrival index per install slot (scatter-min; sentinel row F)
    cand = jnp.where(want_install, slots, F)
    idx = jnp.arange(E, dtype=jnp.int32)
    first_idx = jnp.full((F + 1,), E, jnp.int32).at[cand].min(idx)
    winner = want_install & (first_idx[cl] == idx)
    tgt = jnp.where(winner, slots, F)             # unique -> deterministic
    new_keys = keys.at[tgt].set(five_tuple, mode="drop")
    new_active = active.at[tgt].set(True, mode="drop")
    # same-block losers compare against the key the winner installed
    dup_match = jnp.all(new_keys[cl] == five_tuple, axis=-1)
    collide = valid & ((~empty & ~match)
                       | (empty & ~winner & ~dup_match))
    new_coll = collisions + jnp.sum(collide).astype(jnp.uint32)
    return new_keys, new_active, new_coll


def admit(state: ReporterState, slots: jax.Array, five_tuple: jax.Array,
          valid: jax.Array) -> Tuple[ReporterState, jax.Array]:
    """State-level wrapper over :func:`admit_arrays` (semantics there)."""
    keys, active, collisions = admit_arrays(
        state.keys, state.active, state.collisions, slots, five_tuple,
        valid)
    return state._replace(keys=keys, active=active,
                          collisions=collisions), valid


def accumulate_ref(regs: jax.Array, slots: jax.Array, deltas: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """Oracle scatter-accumulate (u32 wraparound)."""
    idx = jnp.where(valid, slots, regs.shape[0])
    return regs.at[idx].add(deltas, mode="drop")


def ingest(state: ReporterState, events: Dict[str, jax.Array],
           cfg: DFAConfig, accumulate_fn=None,
           backend=None) -> ReporterState:
    """Process one block of packet events.

    events: ts (E,) u32 µs | size (E,) u32 | five_tuple (E,5) u32 |
            valid (E,) bool

    Routes through the ``ingest_update`` kernel family
    (cfg.kernel_backend / REPRO_KERNEL_BACKEND / ``backend=``): the
    ``ref`` backend keeps the pre-fusion multipass shape (hash -> admit
    -> resolve_iat -> event_deltas -> scatter-accumulate) as the bitwise
    oracle; ``pallas``/``interpret`` take the fused sort-once,
    segment-reduce path (one argsort, deltas formed and reduced per slot
    run inside the kernel, one scatter-add per run). Passing an explicit
    ``accumulate_fn`` forces the legacy multipass path with that
    accumulator (how the flow_moments kernel is unit-tested in place).
    """
    slots = hash_slot(events["five_tuple"], cfg.flows_per_shard)
    if accumulate_fn is not None:
        return _ingest_multipass(state, slots, events, cfg, accumulate_fn)
    from repro.kernels.ingest_update.ops import ingest_update
    regs, last_ts, keys, active, collisions = ingest_update(
        state.regs, state.last_ts, state.keys, state.active,
        state.collisions, slots, events["ts"], events["size"],
        events["five_tuple"], events["valid"], cfg, backend=backend)
    return state._replace(regs=regs, last_ts=last_ts, keys=keys,
                          active=active, collisions=collisions)


def _ingest_multipass(state: ReporterState, slots: jax.Array,
                      events: Dict[str, jax.Array], cfg: DFAConfig,
                      accumulate_fn) -> ReporterState:
    """The pre-fusion multipass ingest with a caller-chosen accumulator
    (admit -> resolve_iat -> event_deltas -> accumulate)."""
    pre_active = state.active            # BEFORE this block's admissions:
    state, valid = admit(state, slots, events["five_tuple"],
                         events["valid"])
    # a flow admitted in this block must see itself as new (first packet)
    iat, first, new_last = resolve_iat(slots, events["ts"], valid,
                                       state.last_ts, pre_active)
    deltas = event_deltas(iat, events["size"], first, valid,
                          cfg.logstar_bits)
    regs = accumulate_fn(state.regs, slots, deltas, valid)
    return state._replace(regs=regs, last_ts=new_last)


def due_flows(state: ReporterState, now: jax.Array, cfg: DFAConfig,
              capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Flows whose monitoring period elapsed (paper: per-flow configurable
    interval; we use the global default with a per-flow offset hook).

    Returns (slots (capacity,) i32, mask (capacity,) bool) — fixed-size for
    SPMD; selection is by largest elapsed time (most-overdue-first).

    The elapsed compare is u32-subtraction based, so it stays correct
    across µs-clock wrap (now < last_report numerically still yields the
    true elapsed interval mod 2^32).
    """
    elapsed = (now - state.last_report).astype(jnp.uint32)
    due = state.active & (elapsed >= jnp.uint32(cfg.monitoring_period_us))
    if cfg.monitoring_period_us == 0:
        # elapsed can be 0 for a genuinely due flow; |1 keeps its score
        # above every not-due slot so top_k cannot displace it
        score = jnp.where(due, elapsed | jnp.uint32(1), jnp.uint32(0))
    else:
        score = jnp.where(due, elapsed, jnp.uint32(0))
    # top_k over k > axis size crashes; clamp to F and pad the fixed-size
    # SPMD return back up to ``capacity`` (pad rows masked out)
    F = score.shape[0]
    k = min(capacity, F)
    _, idx = jax.lax.top_k(score, k)
    # gather the due flags at the selected slots — the old ``top > 0``
    # proxy silently dropped genuinely due flows whose elapsed score is 0
    # (monitoring_period_us == 0 reports every period by contract)
    mask = due[idx]
    if k < capacity:
        idx = jnp.concatenate(
            [idx, jnp.zeros((capacity - k,), idx.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((capacity - k,), bool)])
    return idx.astype(jnp.int32), mask


def make_reports(state: ReporterState, slots: jax.Array, mask: jax.Array,
                 now: jax.Array, reporter_id: int, shard_flow_base,
                 cfg: DFAConfig, flow_ids=None
                 ) -> Tuple[ReporterState, jax.Array]:
    """Clone-and-truncate analogue: emit DTA reports for the given slots.

    Returns (state', reports (capacity, REPORT_WORDS) u32); masked-out rows
    are zero. Sequence numbers increment per report (sec VI-B).

    ``flow_ids`` (optional, (R,) u32) overrides the legacy range identity
    ``shard_flow_base + slot`` — the multi-pod mesh passes the hash-home
    global ids (translator.home_flow_ids of each slot's stored key) so a
    flow's reports name the same home ring from every ingest port.
    """
    R = slots.shape[0]
    stats = state.regs[slots]                     # (R, 7)
    tuples = state.keys[slots]
    if flow_ids is None:
        flow_ids = (shard_flow_base + slots).astype(jnp.uint32)
    else:
        flow_ids = flow_ids.astype(jnp.uint32)
    seqs = state.seq + jnp.cumsum(mask.astype(jnp.uint32)) - 1
    reports = PROTO.pack_dta_report(
        flow_ids, jnp.full((R,), reporter_id, jnp.uint32),
        seqs, stats, tuples, wire=WIRE.resolve(cfg))
    reports = jnp.where(mask[:, None], reports, jnp.uint32(0))
    F = state.last_report.shape[0]
    # wrap-aware: ``now`` is the latest time by contract even when the u32
    # clock wrapped below the stored value, so .set (slots from top_k are
    # unique) — a .max here would stall the interval tracker post-wrap
    last_report = state.last_report.at[jnp.where(mask, slots, F)].set(
        jnp.broadcast_to(now.astype(jnp.uint32), (R,)), mode="drop")
    new_seq = state.seq + jnp.sum(mask).astype(jnp.uint32)
    return state._replace(last_report=last_report, seq=new_seq), reports
