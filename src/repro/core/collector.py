"""DFA Collector — device-resident telemetry sink (§III-C/IV-C, Fig 4).

The collector exposes a (flows × history × 16-word) memory region living in
accelerator memory; payloads are placed VERBATIM at the translator-computed
coordinates (the GPUDirect analogue: producer-computed placement, no host
mediation, no copies — we even alias the buffer in-place via donation).

Integrity: per-entry checksum (Fig 4) and per-reporter sequence continuity
(the paper's §VI-B recommendation) are validated on ingest; violations are
counted, never crash the path. All layout facts — meta-word field
positions, the reporter-id space sizing ``last_seq``, the seq wrap mask
and the dup-detection window — come off the active
:class:`repro.core.wire.WireFormat`.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DFAConfig
from repro.core import protocol as PROTO
from repro.core import wire as WIRE

Tree = Any
# V1's 8-bit reporter-id space, kept as a module alias for the callers
# that predate the schema; sizing decisions should use wire.n_reporters.
N_REPORTERS = WIRE.V1.n_reporters


class CollectorState(NamedTuple):
    memory: jax.Array      # (F, H, 16) u32 — Fig 4 region
    entry_valid: jax.Array  # (F, H) bool — which ring entries hold data
    last_seq: jax.Array    # (wire.n_reporters,) u32 — seq continuity (VI-B)
    bad_checksum: jax.Array   # () u32
    seq_anomalies: jax.Array  # () u32
    received: jax.Array    # () u32 — total accepted payloads
    lost_reports: jax.Array   # () u32 — seq gaps: sent-but-never-landed


def init_state(cfg: DFAConfig) -> CollectorState:
    F, H = cfg.flows_per_shard, cfg.history
    wf = WIRE.resolve(cfg)
    return CollectorState(
        memory=jnp.zeros((F, H, PROTO.PAYLOAD_WORDS), jnp.uint32),
        entry_valid=jnp.zeros((F, H), bool),
        # stores (last seq + 1); 0 = never seen (so .max updates work)
        last_seq=jnp.zeros((wf.n_reporters,), jnp.uint32),
        bad_checksum=jnp.zeros((), jnp.uint32),
        seq_anomalies=jnp.zeros((), jnp.uint32),
        received=jnp.zeros((), jnp.uint32),
        lost_reports=jnp.zeros((), jnp.uint32),
    )


def scatter_ref(memory: jax.Array, entry_valid: jax.Array,
                payloads: jax.Array, flow: jax.Array, hist: jax.Array,
                mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Oracle ring placement: memory[flow, hist] = payload (last write wins,
    in report order — matching sequential RDMA WRITEs)."""
    F, H, W = memory.shape
    flat = memory.reshape(F * H, W)
    ev = entry_valid.reshape(F * H)
    idx = jnp.where(mask, flow * H + hist.astype(jnp.int32), F * H)
    flat = flat.at[idx].set(payloads, mode="drop")
    ev = ev.at[idx].set(True, mode="drop")
    return flat.reshape(F, H, W), ev.reshape(F, H)


def ingest(state: CollectorState, payloads: jax.Array, mask: jax.Array,
           shard_flow_base, cfg: DFAConfig,
           scatter_fn=None) -> CollectorState:
    """payloads: (R, 16) u32 RoCEv2 bodies routed to this shard.

    ``scatter_fn`` defaults to the ring_scatter kernel family resolved
    through the dispatch registry (cfg.kernel_backend / env override);
    pass ``scatter_ref`` to force the jnp oracle.
    """
    wf = WIRE.resolve(cfg)
    if scatter_fn is None:
        from repro.kernels.ring_scatter.ops import ring_scatter_collector

        def scatter_fn(memory, entry_valid, pays, flow, hist, m):
            return ring_scatter_collector(memory, entry_valid, pays, flow,
                                          hist, m, cfg=cfg)

    p = PROTO.unpack_payload(payloads, wire=wf)
    ok_csum = PROTO.payload_valid(payloads, wire=wf)
    bad = jnp.sum(mask & ~ok_csum)  # corrupted/tampered payloads (§VI-B)
    mask = mask & ok_csum
    local = (p["flow_id"].astype(jnp.int32)
             - jnp.asarray(shard_flow_base, jnp.int32))
    in_range = (local >= 0) & (local < cfg.flows_per_shard)
    mask = mask & in_range
    # sequence continuity per reporter (last_seq stores seq+1; 0 = reporter
    # never seen). The wrap mask and dup window scale with the schema's seq
    # width — V1 keeps the paper's 8-bit space / 8-deep window, V2's u16
    # space gets a 2048-deep one. Duplicates are REJECTED before placement
    # (first arrival wins), so a replayed payload with a valid checksum but
    # a stale (reporter, seq) identity can never overwrite ring state.
    n_rep = wf.n_reporters
    rep = p["reporter_id"].astype(jnp.int32)
    seq = p["seq"].astype(jnp.uint32)
    prev = state.last_seq[jnp.clip(rep, 0, n_rep - 1)]
    prev_seq = (prev - 1) & jnp.uint32(wf.seq_mask)
    dup_window = mask & (prev > 0) & (seq <= prev_seq) & (
        prev_seq - seq < jnp.uint32(wf.seq_dup_window)
    )                                 # small window => duplicate/replay
    # within-batch duplicates: two rows carrying the same (reporter, seq)
    # identity in one ingest. Sort valid rows by identity key (stable, so
    # equal keys keep arrival order — first arrival wins), mark every
    # non-first member of an equal-key run.
    ident = rep.astype(jnp.uint32) * jnp.uint32(wf.seq_mask + 1) + seq
    o1 = jnp.argsort(ident, stable=True)
    order = o1[jnp.argsort((~mask)[o1], stable=True)]  # valid rows first
    sk, sm = ident[order], mask[order]
    run = jnp.concatenate([jnp.zeros((1,), bool),
                           (sk[1:] == sk[:-1]) & sm[1:] & sm[:-1]])
    dup_batch = jnp.zeros_like(mask).at[order].set(run)
    dup = dup_window | dup_batch
    mask_ok = mask & ~dup
    memory, ev = scatter_fn(state.memory, state.entry_valid, payloads,
                            jnp.clip(local, 0, cfg.flows_per_shard - 1),
                            p["hist_idx"].astype(jnp.int32), mask_ok)
    anomalies = state.seq_anomalies + jnp.sum(dup).astype(jnp.uint32)
    new_seq = state.last_seq.at[jnp.where(mask_ok, rep, n_rep)].max(
        seq + 1, mode="drop")
    # seq-GAP loss detection (unwrapped regime): per reporter, the window
    # advanced by (new - old) seqs this batch but only `fresh` of them
    # landed — the difference is reports sent on the wire that never
    # arrived (or arrived corrupted and were discarded above).
    fresh = mask_ok & (seq + 1 >= prev)
    cnt = jnp.zeros((n_rep + 1,), jnp.uint32).at[
        jnp.where(fresh, rep, n_rep)].add(1, mode="drop")[:n_rep]
    gap = jnp.sum(new_seq - state.last_seq) - jnp.sum(cnt)
    return state._replace(
        memory=memory, entry_valid=ev, last_seq=new_seq,
        bad_checksum=state.bad_checksum + bad.astype(jnp.uint32),
        seq_anomalies=anomalies,
        received=state.received + jnp.sum(mask_ok).astype(jnp.uint32),
        lost_reports=state.lost_reports + gap.astype(jnp.uint32))


def staged_ingest(state: CollectorState, payloads: jax.Array,
                  mask: jax.Array, shard_flow_base, cfg: DFAConfig
                  ) -> CollectorState:
    """The DTA-style comparison path (Fig 3 red): payloads land in a staging
    buffer ("host memory"), then a second pass copies them into the Fig 4
    region ("cudaMemcpyHtoD"). Functionally identical, twice the memory
    traffic — used by the fig9 benchmark to quantify what GDR saves."""
    staging = jnp.array(payloads)                 # explicit extra copy
    staging = staging + jnp.uint32(0)             # defeat CSE/no-op elision
    return ingest(state, staging, mask, shard_flow_base, cfg)


def gather_flow_history(state: CollectorState, local_flow: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """(flows_q,) -> (flows_q, H, 16) entries + validity (inference input)."""
    return state.memory[local_flow], state.entry_valid[local_flow]


def enrich_flow_history(state: CollectorState, local_flow: jax.Array,
                        cfg: DFAConfig, mask=None, backend=None,
                        variant=None) -> jax.Array:
    """Fused alternative to gather_flow_history + derive: (flows_q,) ->
    (flows_q, derived_dim) f32 straight out of the ring region, routed
    through the kernel dispatch registry (backend + gather variant).
    The (flows_q, H, 16) intermediate never exists in HBM.

    ``local_flow``/``mask`` are the translator's routed coordinates
    (pipeline.RoutedBatch) — the enrich half consumes them as produced by
    the ingest half instead of re-deriving placement; masked-out rows are
    zeroed."""
    from repro.core.enrich import enrich_history
    return enrich_history(state.memory, state.entry_valid, local_flow,
                          cfg, mask=mask, backend=backend, variant=variant)
