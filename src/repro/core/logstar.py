"""log* — the paper's lookup-table logarithm (Table I).

Tofino cannot multiply 32-bit values, so Marina/DFA approximate x^n through
pre-populated match-action tables: x -> log*(x), multiply in log domain by
the small integer n (shift/add), and exp* back. We keep the same structure on
TPU: log2 in Q16 fixed point, mantissa refined through a 2^logstar_bits-entry
LUT (the match-action analogue), exp2 through the inverse LUT. All state is
uint32 with natural mod-2^32 wraparound — the P4 register semantics.

Functions are pure jnp (usable inside Pallas kernels and as the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Q = 16                      # fixed-point fractional bits for log values


@functools.lru_cache(maxsize=None)
def _luts(bits: int):
    """(log_lut, exp_lut) as numpy arrays.

    log_lut[i]  = round(2^Q * log2(1 + i/2^bits)),  i in [0, 2^bits)
    exp_lut[i]  = round(2^bits * (2^(i/2^bits) - 1)), i in [0, 2^bits)
    """
    n = 1 << bits
    i = np.arange(n, dtype=np.float64)
    log_lut = np.round((1 << Q) * np.log2(1.0 + i / n)).astype(np.uint32)
    exp_lut = np.round(n * (np.exp2(i / n) - 1.0)).astype(np.uint32)
    return log_lut, exp_lut


def log2_star_with_lut(x: jax.Array, bits: int,
                       lut: jax.Array) -> jax.Array:
    """:func:`log2_star` with the LUT passed explicitly — for Pallas
    kernel bodies, where a captured jnp constant is illegal and the LUT
    must arrive as a kernel input."""
    x = x.astype(jnp.uint32)
    # exponent = position of the leading set bit (31 - clz), on u32 so the
    # top bit (x >= 2^31) is handled correctly
    nbits = (32 - jax.lax.clz(jnp.maximum(x, jnp.uint32(1)))).astype(
        jnp.int32)
    e = (nbits - 1).astype(jnp.uint32)                     # floor(log2 x)
    # top `bits` mantissa bits below the leading bit
    shift = jnp.maximum(nbits - 1 - bits, 0).astype(jnp.uint32)
    frac_bits = ((x >> shift) & ((1 << bits) - 1)).astype(jnp.uint32)
    # if the value has fewer than `bits` mantissa bits, scale up
    upshift = jnp.maximum(bits - (nbits - 1), 0).astype(jnp.uint32)
    frac_bits = (frac_bits << upshift) & ((1 << bits) - 1)
    val = (e << Q) + lut[frac_bits]
    return jnp.where(x == 0, jnp.uint32(0), val.astype(jnp.uint32))


def log2_star(x: jax.Array, bits: int) -> jax.Array:
    """u32 -> Q16 fixed-point log2 approximation (0 for x == 0)."""
    return log2_star_with_lut(x, bits, jnp.asarray(_luts(bits)[0]))


def exp2_star_with_lut(l: jax.Array, bits: int,
                       lut: jax.Array) -> jax.Array:
    """:func:`exp2_star` with the LUT passed explicitly (Pallas-safe)."""
    l = l.astype(jnp.uint32)
    e = (l >> Q).astype(jnp.int32)                         # integer part
    frac = ((l >> (Q - bits)) & ((1 << bits) - 1)).astype(jnp.uint32)
    mant = (jnp.uint32(1) << jnp.uint32(bits)) + lut[frac]  # in [2^b, 2^{b+1})
    sat = e >= 32                       # [2^31, 2^32) is still representable
    sh = jnp.clip(e - bits, -(bits + 32), 31)
    down = jnp.clip(-sh, 1, 31).astype(jnp.uint32)
    # round (not floor) on the down-shift: matters for small values
    rounded = (mant + (jnp.uint32(1) << (down - 1))) >> down
    val = jnp.where(sh >= 0,
                    mant << jnp.clip(sh, 0, 31).astype(jnp.uint32),
                    rounded)
    val = jnp.where(sat, jnp.uint32(0xFFFFFFFF), val)
    return jnp.where(l == 0, jnp.uint32(1), val).astype(jnp.uint32)


def exp2_star(l: jax.Array, bits: int) -> jax.Array:
    """Q16 fixed-point log2 -> u32 value (saturating at 2^32-1)."""
    return exp2_star_with_lut(l, bits, jnp.asarray(_luts(bits)[1]))


def approx_pow_with_luts(x: jax.Array, n: int, bits: int,
                         log_lut: jax.Array,
                         exp_lut: jax.Array) -> jax.Array:
    """:func:`approx_pow` with both LUTs passed explicitly (Pallas-safe:
    kernel bodies feed the LUT refs they received as inputs)."""
    lx = log2_star_with_lut(x, bits, log_lut)
    ln = lx * jnp.uint32(n)
    # detect overflow of the power before exp
    sat = (ln >> Q) >= 32
    v = exp2_star_with_lut(ln, bits, exp_lut)
    v = jnp.where(sat, jnp.uint32(0xFFFFFFFF), v)
    return jnp.where(x == 0, jnp.uint32(0), v)


def approx_pow(x: jax.Array, n: int, bits: int) -> jax.Array:
    """x^n through the log*/exp* LUT pipeline (saturating u32); 0 -> 0."""
    log_lut, exp_lut = _luts(bits)
    return approx_pow_with_luts(x, n, bits, jnp.asarray(log_lut),
                                jnp.asarray(exp_lut))


def decode_log(l: jax.Array) -> jax.Array:
    """Q16 log value -> float64-ish float32 2^(l/2^Q) (for enrichment)."""
    return jnp.exp2(l.astype(jnp.float32) / float(1 << Q))
