"""End-to-end distributed DFA pipeline (Fig 1) as one SPMD step.

Every device is simultaneously one Reporter shard and one Collector shard
(+ its translator): the flow space is range-sharded over the *entire* mesh
(512 shards × 2^17 flows = 67M flows at production scale — the paper's
4-pipeline Tofino supports 524,288). One ``dfa_step``:

  local packet events ──ingest──> per-flow Table-I registers
  due flows ──clone/truncate──> DTA reports (fixed capacity)
  reports ──all_to_all over ("pod","data","model")──> owner shards
           (the ICI takes RoCEv2's place; addresses computed by the
            owner-side translator exactly as §III-B)
  payloads ──ring placement──> (F, 10, 16-word) collector memory (Fig 4)
  received flows ──enrichment──> derived feature vectors -> inference

Every hot stage (moment accumulation, ring placement, gather+enrichment)
routes through the kernel dispatch registry (repro.kernels.dispatch):
``DFAConfig.kernel_backend`` / ``REPRO_KERNEL_BACKEND`` select ref / pallas
/ interpret per run, with the Pallas kernels jitting inside ``shard_map``
(shard-local shapes are static).

The step is jit-compatible, state is donated (in-place ring updates — the
GDR analogue), and every stage has a fixed SPMD shape. ``run_periods``
streams T monitoring periods through the step under one ``lax.scan`` — the
multi-period throughput shape the fig8 / dfa_throughput / streaming
benchmarks measure.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.configs.base import DFAConfig
from repro.core import collector as COLL
from repro.core import protocol as PROTO
from repro.core import reporter as REP
from repro.core import translator as TRANS
from repro.kernels import dispatch

Tree = Any


class DFAState(NamedTuple):
    reporter: REP.ReporterState
    translator: TRANS.TranslatorState
    collector: COLL.CollectorState


class DFASystem:
    """Facade: builds sharded state + the jit-able distributed step."""

    def __init__(self, cfg: DFAConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.n_shards = int(math.prod(mesh.devices.shape))

    # -- state ------------------------------------------------------------
    def init_state(self) -> DFAState:
        """Global state arrays (leading dim = n_shards * per-shard size)."""
        n = self.n_shards

        def rep_tile(make):
            st = make(self.cfg)
            return jax.tree.map(
                lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim).reshape(
                    (n * a.shape[0],) + a.shape[1:]) if a.ndim >= 1 else
                jnp.tile(a[None], (n,)), st)

        return DFAState(rep_tile(REP.init_state),
                        rep_tile(TRANS.init_state),
                        rep_tile(COLL.init_state))

    def state_specs(self) -> DFAState:
        """PartitionSpecs: every leading dim sharded over the whole mesh."""
        ax = self.axes

        def spec(a):
            return P(ax, *([None] * (a.ndim - 1))) if a.ndim >= 1 else P()

        # build from abstract eval to avoid allocating:
        st = jax.eval_shape(self.init_state)
        return jax.tree.map(spec, st)

    def state_shardings(self) -> DFAState:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_specs())

    def init_sharded_state(self) -> DFAState:
        """``init_state`` already placed on the mesh. Use this when feeding
        a donated step/stream: plain ``init_state`` arrays are uncommitted,
        so the first donated call returns mesh-sharded state and the second
        call pays a full retrace."""
        return jax.jit(self.init_state,
                       out_shardings=self.state_shardings())()

    # -- the step ---------------------------------------------------------
    def dfa_step(self, state: DFAState, events: Dict[str, jax.Array],
                 now: jax.Array):
        """events (global): ts/size (n_shards*E,), five_tuple (…,5),
        valid (…,). Returns (state', enriched, flow_ids, emask, metrics)."""
        cfg = self.cfg
        n = self.n_shards
        cap_out = max(1, cfg.report_capacity // n)
        ax = self.axes

        def local(rep_st, tr_st, coll_st, ev_ts, ev_sz, ev_tu, ev_va, now_):
            shard = jnp.zeros((), jnp.int32)
            for a in ax:
                shard = shard * axis_size(a) + jax.lax.axis_index(a)
            flow_base = shard * cfg.flows_per_shard
            # 1. reporter ingest (flow_moments via the dispatch registry)
            rep_st = REP.ingest(rep_st, {"ts": ev_ts, "size": ev_sz,
                                         "five_tuple": ev_tu,
                                         "valid": ev_va}, cfg)
            # 2. due flows -> DTA reports
            slots, mask = REP.due_flows(rep_st, now_, cfg,
                                        cfg.report_capacity)
            rep_st, reports = REP.make_reports(
                rep_st, slots, mask, now_, 0, flow_base, cfg)
            # reporter id = shard (mod 256, the 8-bit id space)
            rid = (shard % COLL.N_REPORTERS).astype(jnp.uint32)
            reports = reports.at[:, 1].set(
                jnp.where(mask, (rid << 24) | (reports[:, 1] & 0x00FFFFFF),
                          0))
            # 3. route to owner shards (fixed-capacity buckets + all_to_all)
            buckets, bmask = TRANS.route_reports(
                reports, mask, n, cfg.flows_per_shard, cap_out)
            routed = jax.lax.all_to_all(buckets, ax, 0, 0, tiled=True)
            rmask = jax.lax.all_to_all(
                bmask.astype(jnp.uint32), ax, 0, 0,
                tiled=True).astype(bool)
            dropped = jnp.sum(mask) - jnp.sum(bmask)
            routed = routed.reshape(n * cap_out, PROTO.REPORT_WORDS)
            rmask = rmask.reshape(n * cap_out)
            # 4. owner-side translator: history addresses + RoCEv2 payloads
            tr_st, payloads, coords = TRANS.translate(
                tr_st, routed, rmask, flow_base, cfg)
            # 5. collector ring placement (ring_scatter via dispatch)
            coll_st = COLL.ingest(coll_st, payloads, rmask, flow_base, cfg)
            # 6. fused gather + enrichment of received flows (via dispatch;
            #    skips the (R, H, 16) history materialization; the op owns
            #    the [0, F) clamp of local_flow and the memory-strategy
            #    choice — full-block VMEM at reduced F, HBM-tiled at
            #    Tofino scale)
            enriched = COLL.enrich_flow_history(coll_st,
                                                coords["local_flow"], cfg)
            enriched = jnp.where(rmask[:, None], enriched, 0.0)
            flow_ids = jnp.where(rmask, routed[:, 0],
                                 jnp.uint32(0xFFFFFFFF))
            metrics = {
                "reports_sent": jax.lax.psum(jnp.sum(mask), ax),
                "reports_recv": jax.lax.psum(jnp.sum(rmask), ax),
                "bucket_drops": jax.lax.psum(jnp.sum(dropped), ax),
                "collisions": jax.lax.psum(jnp.sum(rep_st.collisions), ax),
                "bad_checksum": jax.lax.psum(jnp.sum(coll_st.bad_checksum),
                                             ax),
                "seq_anomalies": jax.lax.psum(
                    jnp.sum(coll_st.seq_anomalies), ax),
            }
            return (rep_st, tr_st, coll_st, enriched, flow_ids, rmask,
                    metrics)

        specs = self.state_specs()
        ev_specs = (P(ax), P(ax), P(ax, None), P(ax))
        out_state_specs = (specs.reporter, specs.translator, specs.collector)
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(specs.reporter, specs.translator, specs.collector)
            + ev_specs + (P(),),
            out_specs=out_state_specs
            + (P(ax, None), P(ax), P(ax),
               jax.tree.map(lambda _: P(), {
                   "reports_sent": 0, "reports_recv": 0, "bucket_drops": 0,
                   "collisions": 0, "bad_checksum": 0, "seq_anomalies": 0})),
            check=False)
        rep_st, tr_st, coll_st, enriched, flow_ids, rmask, metrics = fn(
            state.reporter, state.translator, state.collector,
            events["ts"], events["size"], events["five_tuple"],
            events["valid"], now)
        return (DFAState(rep_st, tr_st, coll_st), enriched, flow_ids,
                rmask, metrics)

    # -- multi-period streaming -------------------------------------------
    def run_periods(self, state: DFAState, events: Dict[str, jax.Array],
                    nows: jax.Array):
        """Stream T monitoring periods through ``dfa_step`` as one
        ``lax.scan`` (state is the carry, so with donation the ring memory
        is updated in place across the whole scan — the GDR analogue held
        for an entire trace window).

        events: dict of (T, n_shards*E, …) arrays; nows: (T,) u32.
        Returns (state', enriched (T, R, D), flow_ids (T, R),
        emask (T, R), metrics dict of (T,) arrays).
        """

        def body(st, xs):
            ev, now_ = xs
            st, enriched, flow_ids, emask, metrics = self.dfa_step(
                st, ev, now_)
            return st, (enriched, flow_ids, emask, metrics)

        state, (enriched, flow_ids, emask, metrics) = jax.lax.scan(
            body, state, (events, nows))
        return state, enriched, flow_ids, emask, metrics

    # -- convenience ------------------------------------------------------
    def describe(self) -> Dict[str, Any]:
        """Trace-time kernel selection for this system: backend, gather
        memory strategy, and the VMEM numbers that drove the choice."""
        cfg = self.cfg
        backend = dispatch.resolve_backend(None, cfg)
        # mirror dfa_step: each shard enriches n_shards * cap_out routed
        # rows, and ops.gather_enrich tiles that R by flow_tile
        R = self.n_shards * max(1, cfg.report_capacity // self.n_shards)
        tile = min(cfg.flow_tile, R)
        variant = ("ref" if backend == "ref" else
                   dispatch.resolve_gather_variant(
                       None, cfg, cfg.flows_per_shard, cfg.history, tile,
                       cfg.derived_dim))
        return {
            "kernel_backend": backend,
            "gather_variant": variant,
            "ring_region_bytes": cfg.ring_region_bytes(),
            "vmem_budget_bytes": cfg.vmem_budget_mb
            * dispatch.VMEM_BYTES_PER_MB,
            "gather_vmem_bytes": dispatch.gather_vmem_bytes(
                "hbm" if variant == "hbm" else "full",
                cfg.flows_per_shard, cfg.history, tile, cfg.derived_dim,
                words=cfg.payload_words),
            "n_shards": self.n_shards,
        }

    def jit_step(self, donate: bool = True):
        return jax.jit(self.dfa_step,
                       donate_argnums=(0,) if donate else ())

    def jit_stream(self, donate: bool = True):
        """jit'd ``run_periods`` with the state carry donated."""
        return jax.jit(self.run_periods,
                       donate_argnums=(0,) if donate else ())

    def event_specs(self, events_per_shard: int, periods: int = 0):
        """ShapeDtypeStructs + shardings for the global event batch; with
        ``periods`` > 0, shapes carry the leading (T,) streaming dim."""
        n = self.n_shards * events_per_shard
        lead = (periods,) if periods else ()
        sds = {
            "ts": jax.ShapeDtypeStruct(lead + (n,), jnp.uint32),
            "size": jax.ShapeDtypeStruct(lead + (n,), jnp.uint32),
            "five_tuple": jax.ShapeDtypeStruct(lead + (n, 5), jnp.uint32),
            "valid": jax.ShapeDtypeStruct(lead + (n,), jnp.bool_),
        }
        ax = self.axes
        t = (None,) if periods else ()
        specs = {"ts": P(*t, ax), "size": P(*t, ax),
                 "five_tuple": P(*t, ax, None), "valid": P(*t, ax)}
        return sds, specs
