"""End-to-end distributed DFA pipeline (Fig 1) as one SPMD step.

Every device is simultaneously one Reporter shard and one Collector shard
(+ its translator): the flow space is range-sharded over the *entire* mesh
(512 shards × 2^17 flows = 67M flows at production scale — the paper's
4-pipeline Tofino supports 524,288). One ``dfa_step``:

  local packet events ──ingest──> per-flow Table-I registers
  due flows ──clone/truncate──> DTA reports (fixed capacity)
  reports ──all_to_all over ("pod","data","model")──> owner shards
           (the ICI takes RoCEv2's place; addresses computed by the
            owner-side translator exactly as §III-B)
  payloads ──ring placement──> (F, 10, 16-word) collector memory (Fig 4)
  received flows ──enrichment──> derived feature vectors -> inference

Every hot stage (moment accumulation, ring placement, gather+enrichment)
routes through the kernel dispatch registry (repro.kernels.dispatch):
``DFAConfig.kernel_backend`` / ``REPRO_KERNEL_BACKEND`` select ref / pallas
/ interpret per run, with the Pallas kernels jitting inside ``shard_map``
(shard-local shapes are static).

The step is jit-compatible, state is donated (in-place ring updates — the
GDR analogue), and every stage has a fixed SPMD shape.

One monitoring period is two explicit half-steps:

  ``ingest_half``  — reporter ingest, due-flow reports, all_to_all
                     routing, translator addressing, ring placement;
                     returns the period's :class:`RoutedBatch` coords
  ``enrich_half``  — fused gather+enrich of those routed flows (plus the
                     optional immediate-inference hook: a model head from
                     ``models.registry.get_flow_head`` consuming the
                     (R, derived_dim) features in the same trace)

``run_periods`` chains both halves per period under one ``lax.scan``;
``run_periods_overlapped`` software-pipelines the stream — the carry holds
period t's routed coords so its enrich half runs in the same scan body as
period t+1's ingest half (one warm-up ingest, one drain enrich). The two
drivers are output-identical by construction: the deferred enrich still
reads the ring AFTER period t's placement and BEFORE period t+1's, so
enrichment latency no longer eats the next period's ingest budget without
changing a single emitted feature.

Per-period ``metrics`` are all deltas: ``collisions`` / ``bad_checksum`` /
``seq_anomalies`` report what THIS period added (the cumulative counters
stay in the state), matching ``reports_sent`` / ``reports_recv`` /
``bucket_drops`` which were always per-period.

Multi-pod (2D mesh) streaming: with ``cfg.flow_home == "hash"`` the same
drivers run on a ``(pod, shard)`` mesh (``launch.mesh.make_dfa_mesh``).
Each pod owns a disjoint set of reporter PORTS (independent per-port
Marina tables, ``cfg.ports_per_pod``), a flow's home ring is the range
shard of its hashed key in the GLOBAL keyspace (``translator
.home_flow_ids``), and delivery is two-stage: intra-pod ``all_to_all``
over the shard fabric, then a cross-pod exchange over the pod axis for
flows whose home pod differs from their ingest pod. The home translator
canonically re-orders arrivals, which makes the merged end state bitwise
independent of how the same port set factors into pods — the property
``tests/test_multipod_equiv.py`` pins scenario by scenario.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.configs.base import DFAConfig
from repro.core import collector as COLL
from repro.core import protocol as PROTO
from repro.core import reporter as REP
from repro.core import translator as TRANS
from repro.core import wire as WIRE
from repro.data import faults as FAULTS
from repro.kernels import dispatch
from repro.kernels import tuning as TUNING

Tree = Any


class DFAState(NamedTuple):
    reporter: REP.ReporterState
    translator: TRANS.TranslatorState
    collector: COLL.CollectorState


def _global_seq_gap(coll_st, lseq0, recv0, lost0, dev, ax):
    """Supersede the collector's shard-local seq-gap count with the
    global one (inside the ingest shard_map, after COLL.ingest).

    A reporter's seq stream fans out across flow-home shards, so each
    shard's local §VI-B window multi-counts advances that were simply
    routed elsewhere. Globally the accounting is exact: per reporter,
    the window advance (max over shards — seqs are minted contiguously)
    minus the accepted arrivals summed over shards is precisely the
    number of reports that never landed anywhere (dropped in flight, or
    discarded as corrupted). The global count lands on the lead shard so
    summing scalars across shards — what every merge/differential
    harness does — stays exact; ``lost0`` is the shard's pre-ingest
    value, discarding the routing-polluted local delta.
    """
    advanced = (jnp.sum(jax.lax.pmax(coll_st.last_seq, ax))
                - jnp.sum(jax.lax.pmax(lseq0, ax)))
    arrivals = jax.lax.psum(jnp.sum(coll_st.received - recv0), ax)
    lost_delta = (advanced - arrivals).astype(jnp.uint32)
    lost = lost0 + jnp.where(dev == 0, lost_delta, jnp.uint32(0))
    # counters ride the state as per-shard (1,) slices of an (n_shards,)
    # array — keep that local shape
    return coll_st._replace(
        lost_reports=lost.reshape(coll_st.lost_reports.shape)), lost_delta


class RoutedBatch(NamedTuple):
    """One period's routing products, carried from the ingest half into
    the (possibly deferred) enrich half — everything enrichment needs, so
    nothing is re-derived. All arrays are mesh-sharded over their leading
    dim exactly like the event batch (P(axes))."""
    local_flow: jax.Array   # (R,) i32 — owner-shard-local flow coords
    flow_id: jax.Array      # (R,) u32 — global flow ids (report word 0)
    mask: jax.Array         # (R,) bool — routed-report validity


class StepOutputs(NamedTuple):
    """The structured return of every driver (``dfa_step``,
    ``run_periods``, ``run_periods_overlapped``, ``stream``).

    Field arity is FIXED: ``preds`` is always present and is ``None``
    unless an inference head is armed — unlike the historical variadic
    5-or-6-tuple, whose length depended on ``cfg.inference_head`` and
    forced every continuous caller to branch on arity. Streaming drivers
    stack each per-period field under a leading (T,) dim.

    Unpack by name (``out.state``, ``out.enriched`` ...). The deprecated
    positional accessors (``as_tuple`` and the ``*_tuple`` driver shims)
    were removed after their one-release grace window.
    """
    state: DFAState                     # post-period system state
    enriched: jax.Array                 # ([T,] R, derived_dim) f32
    flow_ids: jax.Array                 # ([T,] R) u32 (0xFFFFFFFF = pad)
    mask: jax.Array                     # ([T,] R) bool validity
    metrics: Dict[str, jax.Array]       # per-period delta counters
    preds: Optional[jax.Array] = None   # ([T,] R, C) when a head is armed


class DFASystem:
    """Facade: builds sharded state + the jit-able distributed step.

    ``infer_fn`` (optional): ``feats (R, derived_dim) -> preds`` applied
    inside the enrich half — immediate inference on the just-enriched
    features. When omitted and ``cfg.inference_head != "none"`` a head is
    built from ``models.registry.get_flow_head`` (params on
    ``self.infer_params``); with the default head "none" every driver
    keeps its historical 5-tuple returns."""

    def __init__(self, cfg: DFAConfig, mesh: Mesh, infer_fn=None):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.n_shards = int(math.prod(mesh.devices.shape))
        # active wire schema (env > cfg.wire_format > "v1"), resolved
        # once — fail-loud on junk, and topology caps derive from it
        self.wire = WIRE.resolve(cfg)
        self._derive_topology()
        self.infer_params: Optional[Tree] = None
        if infer_fn is None and cfg.inference_head != "none":
            from repro.models.registry import get_flow_head  # lazy: heavy
            self.infer_params, head = get_flow_head(cfg, jax.random.key(0))
            params = self.infer_params
            infer_fn = lambda feats: head(params, feats)  # noqa: E731
        self.infer_fn = infer_fn

    def _derive_topology(self) -> None:
        """(pod, shard) mesh factorization + port placement.

        The MESH is authoritative: ``pods`` is the size of the axis named
        "pod" when present (1 otherwise) and the remaining axes form the
        intra-pod shard fabric. ``cfg.flow_home`` picks the routing
        scheme; "hash" additionally activates per-port reporter tables
        (``cfg.ports_per_pod`` ports per pod, hosted
        ``total_ports / n_devices`` per device in pod-major order, so pods
        own disjoint contiguous port ranges)."""
        cfg = self.cfg
        sizes = dict(zip(self.axes, self.mesh.devices.shape))
        self.pod_axis = "pod" if "pod" in self.axes else None
        if self.pod_axis and self.axes[0] != "pod":
            raise ValueError(
                f"the 'pod' axis must be the leading mesh axis (pod-major "
                f"device order); got axes {self.axes}")
        self.shard_axes = tuple(a for a in self.axes if a != "pod")
        self.mesh_pods = int(sizes.get("pod", 1))
        self.shards_per_pod = self.n_shards // self.mesh_pods
        self.total_flows = self.n_shards * cfg.flows_per_shard
        if cfg.flow_home not in ("ingest", "hash", "rendezvous"):
            raise ValueError(
                f"flow_home must be 'ingest', 'hash' or 'rendezvous', got "
                f"{cfg.flow_home!r}")
        self.multipod = cfg.flow_home in ("hash", "rendezvous")
        if cfg.crosspod_exchange not in ("padded", "ragged"):
            raise ValueError(
                f"crosspod_exchange must be 'padded' or 'ragged', got "
                f"{cfg.crosspod_exchange!r}")
        self.crosspod_exchange = cfg.crosspod_exchange
        if cfg.crosspod_capacity < 0:
            raise ValueError(
                f"crosspod_capacity must be >= 0 (0 = worst-case "
                f"auto-size), got {cfg.crosspod_capacity}")
        if not self.multipod:
            if cfg.crosspod_exchange != "padded":
                raise ValueError(
                    "crosspod_exchange='ragged' compresses the stage-2 "
                    "pod exchange, which only exists under "
                    "flow_home='hash'/'rendezvous'; the legacy 'ingest' "
                    "scheme has no pod stage to compress")
            if cfg.crosspod_capacity:
                raise ValueError(
                    "crosspod_capacity sizes the ragged stage-2 segments "
                    "and is meaningless under flow_home='ingest'")
        if cfg.flow_home == "rendezvous":
            nodes = tuple(cfg.home_nodes) or tuple(range(self.n_shards))
            if len(nodes) != self.n_shards:
                raise ValueError(
                    f"home_nodes has {len(nodes)} entries for a "
                    f"{self.n_shards}-device mesh: one logical node id "
                    "per device (pod-major), so the rendezvous winner "
                    "set and the mesh agree on who owns what")
            if any(b <= a for a, b in zip(nodes, nodes[1:])) or nodes[0] < 0:
                raise ValueError(
                    f"home_nodes must be strictly increasing non-negative "
                    f"ids, got {nodes}: sorted order is what keeps HRW "
                    "tie-breaking and node_position lookups mesh-invariant")
            self.home_nodes: Tuple[int, ...] = nodes
        else:
            self.home_nodes = tuple(range(self.n_shards))
        if not self.multipod:
            if self.mesh_pods > 1:
                raise ValueError(
                    "a multi-pod mesh needs flow_home='hash': the legacy "
                    "'ingest' scheme homes every flow on its ingest shard "
                    "and would never exercise the cross-pod exchange")
            if cfg.ports_per_pod and cfg.ports_per_pod != self.n_shards:
                raise ValueError(
                    "flow_home='ingest' supports exactly one port per "
                    f"shard ({self.n_shards}), got ports_per_pod="
                    f"{cfg.ports_per_pod}")
            if cfg.reporter_slots and (cfg.reporter_slots
                                       != cfg.flows_per_shard):
                raise ValueError(
                    "flow_home='ingest' mints flow ids from the shard "
                    "range, so reporter_slots must equal flows_per_shard")
            self.total_ports = self.n_shards
            self.ports_per_device = 1
            self.rep_cfg = cfg
            self.port_capacity = 0
            self.stage1_capacity = 0
            self.stage2_capacity = 0
            self.crosspod_capacity = 0
            return
        if cfg.pods != self.mesh_pods:
            raise ValueError(
                f"cfg.pods={cfg.pods} does not match the mesh's pod "
                f"axis ({self.mesh_pods}): total_ports = mesh_pods x "
                "ports_per_pod, so a silent mismatch would change the "
                "port set (and every per-port table) out from under the "
                "config")
        total_ports = (self.mesh_pods * cfg.ports_per_pod
                       if cfg.ports_per_pod else self.n_shards)
        if total_ports % self.n_shards:
            raise ValueError(
                f"total ports ({self.mesh_pods} pods x "
                f"{cfg.ports_per_pod}/pod = {total_ports}) must be a "
                f"multiple of the device count {self.n_shards}")
        if total_ports > self.wire.n_reporters:
            # with more ports than reporter ids, two ports alias one id
            # and the home-side canonical (flow, reporter, seq) order —
            # and with it the pod-count-invariance contract — stops
            # being deterministic. Fail loud instead of silently
            # degrading; the cap is the schema's, not a constant: V1's
            # 8-bit field allows 256 ports, wire_format="v2" lifts it
            # to 65,536.
            raise ValueError(
                f"total ports {total_ports} exceeds the "
                f"{self.wire.reporter_width}-bit reporter id space of "
                f"wire format {self.wire.name!r} "
                f"({self.wire.n_reporters}); canonical report ordering "
                "requires a unique (flow, reporter) pair per period — "
                "set wire_format='v2' (or REPRO_WIRE_FORMAT=v2) for "
                "u16 reporter ids")
        self.total_ports = total_ports
        self.ports_per_device = total_ports // self.n_shards
        self.rep_cfg = (dataclasses.replace(
            cfg, flows_per_shard=cfg.reporter_table_slots())
            if cfg.reporter_slots else cfg)
        self.port_capacity = cfg.port_report_capacity or max(
            1, cfg.report_capacity // total_ports)
        # stage capacities (worst case: every report to one bucket); the
        # ragged exchange replaces stage 2's padded cap with a compact
        # per-destination segment size — 0/auto keeps the worst case, so
        # compaction is structurally drop-free and bitwise ≡ padded
        self.stage1_capacity = max(
            1, self.ports_per_device * self.port_capacity)
        self.stage2_capacity = self.shards_per_pod * self.stage1_capacity
        if cfg.crosspod_capacity > self.stage2_capacity:
            raise ValueError(
                f"crosspod_capacity={cfg.crosspod_capacity} exceeds the "
                f"worst-case stage-2 capacity {self.stage2_capacity} "
                "(shards_per_pod x stage-1 bucket) — a larger segment "
                "can never fill; this is a misconfiguration")
        if cfg.crosspod_capacity and self.crosspod_exchange != "ragged":
            raise ValueError(
                "crosspod_capacity only applies to "
                "crosspod_exchange='ragged' (the padded exchange always "
                "ships the worst-case buckets)")
        self.crosspod_capacity = (
            (cfg.crosspod_capacity or self.stage2_capacity)
            if self.crosspod_exchange == "ragged" else 0)

    # -- state ------------------------------------------------------------
    def init_state(self) -> DFAState:
        """Global state arrays. Translator/collector tables have leading
        dim = n_shards * per-shard size; the reporter side tiles one
        per-PORT table per port (total_ports == n_shards with one port per
        device, i.e. always in legacy mode)."""

        def tile(st, count):
            return jax.tree.map(
                lambda a: jnp.tile(a[None], (count,) + (1,) * a.ndim
                                   ).reshape((count * a.shape[0],)
                                             + a.shape[1:])
                if a.ndim >= 1 else jnp.tile(a[None], (count,)), st)

        n = self.n_shards
        return DFAState(tile(REP.init_state(self.rep_cfg),
                             self.total_ports),
                        tile(TRANS.init_state(self.cfg), n),
                        tile(COLL.init_state(self.cfg), n))

    def state_specs(self) -> DFAState:
        """PartitionSpecs: every leading dim sharded over the whole mesh."""
        ax = self.axes

        def spec(a):
            return P(ax, *([None] * (a.ndim - 1))) if a.ndim >= 1 else P()

        # build from abstract eval to avoid allocating:
        st = jax.eval_shape(self.init_state)
        return jax.tree.map(spec, st)

    def state_shardings(self) -> DFAState:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_specs())

    def init_sharded_state(self) -> DFAState:
        """``init_state`` already placed on the mesh. Use this when feeding
        a donated step/stream: plain ``init_state`` arrays are uncommitted,
        so the first donated call returns mesh-sharded state and the second
        call pays a full retrace."""
        return jax.jit(self.init_state,
                       out_shardings=self.state_shardings())()

    # -- the step (two half-steps) ----------------------------------------
    _METRIC_KEYS = ("reports_sent", "reports_recv", "bucket_drops",
                    "misroutes", "collisions", "bad_checksum",
                    "seq_anomalies", "lost_reports")

    @property
    def fault_spec(self) -> Optional[FAULTS.FaultSpec]:
        """The armed transport-fault schedule, or None (fault path
        compiled out — zero cost when no injector is configured)."""
        fs = self.cfg.fault_spec
        return fs if fs is not None and fs.armed else None

    def _metric_specs(self, ax) -> Dict[str, P]:
        specs = {k: P() for k in self._METRIC_KEYS}
        if self.multipod and self.crosspod_exchange == "ragged":
            # exchange-volume accounting exists only on the compact
            # path: emitting (nonzero) keys on the default padded path
            # would break the pinned golden fingerprints
            specs.update({"crosspod_sent": P(), "crosspod_messages": P()})
        if self.fault_spec is not None:
            specs.update({k: P() for k in FAULTS.COUNT_KEYS})
            specs.update({k: P(ax) for k in FAULTS.LEDGER_KEYS})
        return specs

    def ingest_half(self, state: DFAState, events: Dict[str, jax.Array],
                    now: jax.Array
                    ) -> Tuple[DFAState, RoutedBatch, Dict[str, jax.Array]]:
        """First half of one monitoring period: reporter ingest, due-flow
        reports, all_to_all routing, translator addressing and ring
        placement — everything that must happen at line rate.

        events (global): ts/size (n_shards*E,), five_tuple (…,5),
        valid (…,). Returns (state', routed, metrics): ``routed`` is the
        period's :class:`RoutedBatch` (what the enrich half consumes, now
        or a period later), ``metrics`` are all PER-PERIOD deltas — the
        cumulative collision/checksum/sequence counters live in the state;
        here each period reports only what it added.

        With ``cfg.flow_home == "hash"`` the body is the 2D (pod, shard)
        mesh variant: per-port reporter tables, hash-home flow ids, and
        the two-stage intra-pod/cross-pod exchange.
        """
        if self.multipod:
            return self._ingest_half_mesh2d(state, events, now)
        cfg = self.cfg
        n = self.n_shards
        cap_out = max(1, cfg.report_capacity // n)
        ax = self.axes

        def local(rep_st, tr_st, coll_st, ev_ts, ev_sz, ev_tu, ev_va, now_):
            shard = jnp.zeros((), jnp.int32)
            for a in ax:
                shard = shard * axis_size(a) + jax.lax.axis_index(a)
            flow_base = shard * cfg.flows_per_shard
            # cumulative counters BEFORE this period (for metric deltas)
            collisions0 = jnp.sum(rep_st.collisions)
            bad_csum0 = jnp.sum(coll_st.bad_checksum)
            seq_anom0 = jnp.sum(coll_st.seq_anomalies)
            lost0 = jnp.sum(coll_st.lost_reports)
            # 1. reporter ingest (ingest_update via the dispatch
            # registry: ref = multipass oracle, pallas/interpret = fused
            # sort-once kernel; cfg.ingest_variant/event_tile select the
            # event-stream memory strategy)
            rep_st = REP.ingest(rep_st, {"ts": ev_ts, "size": ev_sz,
                                         "five_tuple": ev_tu,
                                         "valid": ev_va}, cfg)
            # 2. due flows -> DTA reports
            slots, mask = REP.due_flows(rep_st, now_, cfg,
                                        cfg.report_capacity)
            rep_st, reports = REP.make_reports(
                rep_st, slots, mask, now_, 0, flow_base, cfg)
            # reporter id = shard (mod the schema's reporter id space);
            # repack through the schema — no open-coded shifts here
            wf = self.wire
            rid = (shard % wf.n_reporters).astype(jnp.uint32)
            mw = wf.report_meta_word
            reports = reports.at[:, mw].set(
                jnp.where(mask,
                          wf.set_report_reporter(reports[:, mw], rid),
                          0))
            # 3. route to owner shards (fixed-capacity buckets + all_to_all)
            buckets, bmask, mis = TRANS.route_reports(
                reports, mask, n, cfg.flows_per_shard, cap_out)
            routed = jax.lax.all_to_all(buckets, ax, 0, 0, tiled=True)
            rmask = jax.lax.all_to_all(
                bmask.astype(jnp.uint32), ax, 0, 0,
                tiled=True).astype(bool)
            dropped = jnp.sum(mask) - jnp.sum(bmask) - mis
            routed = routed.reshape(n * cap_out, PROTO.REPORT_WORDS)
            rmask = rmask.reshape(n * cap_out)
            # 4. owner-side translator: history addresses + RoCEv2 payloads
            tr_st, payloads, coords = TRANS.translate(
                tr_st, routed, rmask, flow_base, cfg)
            # 5. collector ring placement (ring_scatter via dispatch),
            # optionally through the lossy-transport injector — faults
            # hit only what the collector sees (the RDMA segment);
            # routing coords stay faithful to what the switch emitted
            ing_pay, ing_mask = payloads, rmask
            fmetrics = {}
            if self.fault_spec is not None:
                ing_pay, ing_mask, fcounts, fledger = FAULTS.inject(
                    payloads, rmask, self.fault_spec, wf, now_, shard)
                fmetrics = {k: jax.lax.psum(v, ax)
                            for k, v in fcounts.items()}
                fmetrics.update(fledger)
            lseq0, recv0 = coll_st.last_seq, coll_st.received
            coll_st = COLL.ingest(coll_st, ing_pay, ing_mask, flow_base,
                                  cfg)
            coll_st, lost_delta = _global_seq_gap(
                coll_st, lseq0, recv0, lost0, shard, ax)
            metrics = {
                "reports_sent": jax.lax.psum(jnp.sum(mask), ax),
                "reports_recv": jax.lax.psum(jnp.sum(rmask), ax),
                "bucket_drops": jax.lax.psum(jnp.sum(dropped), ax),
                "misroutes": jax.lax.psum(mis, ax),
                # u32 new-minus-old is the period delta even across
                # counter wraparound
                "collisions": jax.lax.psum(
                    jnp.sum(rep_st.collisions) - collisions0, ax),
                "bad_checksum": jax.lax.psum(
                    jnp.sum(coll_st.bad_checksum) - bad_csum0, ax),
                "seq_anomalies": jax.lax.psum(
                    jnp.sum(coll_st.seq_anomalies) - seq_anom0, ax),
                "lost_reports": lost_delta,
                **fmetrics,
            }
            return (rep_st, tr_st, coll_st, coords["local_flow"],
                    routed[:, 0], rmask, metrics)

        specs = self.state_specs()
        ev_specs = (P(ax), P(ax), P(ax, None), P(ax))
        out_state_specs = (specs.reporter, specs.translator, specs.collector)
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(specs.reporter, specs.translator, specs.collector)
            + ev_specs + (P(),),
            out_specs=out_state_specs
            + (P(ax), P(ax), P(ax), self._metric_specs(ax)),
            check=False)
        rep_st, tr_st, coll_st, local_flow, flow_id, rmask, metrics = fn(
            state.reporter, state.translator, state.collector,
            events["ts"], events["size"], events["five_tuple"],
            events["valid"], now)
        return (DFAState(rep_st, tr_st, coll_st),
                RoutedBatch(local_flow, flow_id, rmask), metrics)

    def _ingest_half_mesh2d(self, state: DFAState,
                            events: Dict[str, jax.Array], now: jax.Array
                            ) -> Tuple[DFAState, RoutedBatch,
                                       Dict[str, jax.Array]]:
        """The 2D (pod, shard) mesh ingest half (``flow_home == "hash"``).

        Per device (pod p, shard s):

          1. each hosted reporter PORT ingests its own event slice into
             its own Marina table (ports_per_device independent tables —
             the merged reporter state depends only on the port set, not
             on the mesh factorization);
          2. due flows per port -> DTA reports whose flow id is the
             HASH-HOME global id (translator.home_flow_ids of the stored
             key), reporter id = global port index;
          3. stage 1: bucket by home SHARD, all_to_all over the intra-pod
             shard fabric (reports now sit in their home pod-column);
          4. stage 2: bucket by home POD, exchange over the pod axis —
             only flows whose home pod differs from the ingest pod
             actually cross pods;
          5. the home translator canonically re-orders the received batch
             by (flow, reporter, seq) — making history-index assignment
             and ring placement independent of the exchange interleaving
             — then computes addresses and places payloads as in the 1D
             path.

        Stage capacities are sized to the worst case (every report to one
        bucket), so ``bucket_drops`` is structurally zero here; the
        per-stage drop accounting still feeds the metric so capacity
        experiments (smaller buckets = DTA's lossy trade) surface
        immediately.
        """
        cfg = self.cfg
        ax = self.axes
        wf = self.wire
        P_l = self.ports_per_device
        Rs = self.rep_cfg.flows_per_shard       # per-port table slots
        S = self.shards_per_pod
        pods = self.mesh_pods
        R_p = self.port_capacity
        cap1 = self.stage1_capacity             # stage-1 bucket capacity
        cap2 = self.stage2_capacity             # stage-2 bucket capacity
        ragged = self.crosspod_exchange == "ragged"
        cap2c = self.crosspod_capacity          # compact segment rows
        fps = cfg.flows_per_shard               # rings per device
        G = self.total_flows
        hrw = cfg.flow_home == "rendezvous"
        # the ref backend's per-port ingest is pure jnp (sort/scatter/
        # top_k — all with batching rules), so the hosted ports can run
        # under one vmap instead of a Python-unrolled loop; essential at
        # wide port counts (V2 meshes host hundreds of ports per device,
        # and an unrolled loop would compile one ingest body per port)
        vmap_ports = dispatch.resolve_backend(None, cfg) == "ref"
        # logical node roster (pod-major positions -> stable node ids);
        # replicated constant inside the shard_map closure
        nodes_arr = jnp.asarray(self.home_nodes, jnp.uint32)

        def local(rep_st, tr_st, coll_st, ev_ts, ev_sz, ev_tu, ev_va,
                  now_):
            if self.pod_axis is not None:
                pod = jax.lax.axis_index(self.pod_axis)
            else:
                pod = jnp.zeros((), jnp.int32)
            sp = jnp.zeros((), jnp.int32)
            for a in self.shard_axes:
                sp = sp * axis_size(a) + jax.lax.axis_index(a)
            dev = pod * S + sp
            if hrw:
                # flow ids encode the stable node id, not the position
                flow_base = (nodes_arr[dev]
                             * jnp.uint32(fps)).astype(jnp.int32)
            else:
                flow_base = dev * fps
            # cumulative counters BEFORE this period (for metric deltas)
            collisions0 = jnp.sum(rep_st.collisions)
            bad_csum0 = jnp.sum(coll_st.bad_checksum)
            seq_anom0 = jnp.sum(coll_st.seq_anomalies)
            lost0 = jnp.sum(coll_st.lost_reports)
            # per-port views of this device's reporter slice
            regs = rep_st.regs.reshape(P_l, Rs, REP.N_REG)
            last_ts = rep_st.last_ts.reshape(P_l, Rs)
            last_report = rep_st.last_report.reshape(P_l, Rs)
            keys = rep_st.keys.reshape(P_l, Rs, 5)
            active = rep_st.active.reshape(P_l, Rs)
            if ev_ts.shape[0] % P_l:
                raise ValueError(
                    f"per-device event count {ev_ts.shape[0]} must "
                    f"divide across {P_l} hosted ports — a truncated "
                    "split would silently drop trailing events and "
                    "shift every port's slice off the port-major trace "
                    "layout")
            E_p = ev_ts.shape[0] // P_l

            def port_body(pst, ev, gid):
                """One hosted port: ingest its event slice, emit its due
                reports. The global port id IS the reporter identity (mod
                the schema's reporter id space) — stable across mesh
                factorizations."""
                pst = REP.ingest(pst, ev, self.rep_cfg)
                slots, mask = REP.due_flows(pst, now_, self.rep_cfg, R_p)
                rid = (gid % wf.n_reporters).astype(jnp.uint32)
                if hrw:
                    fids = TRANS.rendezvous_flow_ids(
                        pst.keys[slots], nodes_arr, fps)
                else:
                    fids = TRANS.home_flow_ids(pst.keys[slots], G)
                pst, reports = REP.make_reports(
                    pst, slots, mask, now_, rid, 0, self.rep_cfg,
                    flow_ids=fids)
                return pst, reports, mask

            gids = dev * P_l + jnp.arange(P_l, dtype=jnp.int32)
            stacked = REP.ReporterState(regs, last_ts, last_report, keys,
                                        active, rep_st.seq,
                                        rep_st.collisions)
            ev_b = {"ts": ev_ts.reshape(P_l, E_p),
                    "size": ev_sz.reshape(P_l, E_p),
                    "five_tuple": ev_tu.reshape(P_l, E_p, 5),
                    "valid": ev_va.reshape(P_l, E_p)}
            if vmap_ports:
                new_st, reports_s, masks_s = jax.vmap(port_body)(
                    stacked, ev_b, gids)
            else:
                # unrolled loop for the pallas/interpret backends: the
                # ingest path can resolve to the scalar-prefetch HBM
                # pallas variant, which has no batching rule; P_l stays
                # small there (kernel meshes host single-digit ports)
                outs = [port_body(jax.tree.map(lambda a: a[p], stacked),
                                  {k: v[p] for k, v in ev_b.items()},
                                  gids[p])
                        for p in range(P_l)]
                new_st = jax.tree.map(lambda *xs: jnp.stack(xs),
                                      *[o[0] for o in outs])
                reports_s = jnp.stack([o[1] for o in outs])
                masks_s = jnp.stack([o[2] for o in outs])
            rep_st = REP.ReporterState(
                regs=new_st.regs.reshape(P_l * Rs, REP.N_REG),
                last_ts=new_st.last_ts.reshape(P_l * Rs),
                last_report=new_st.last_report.reshape(P_l * Rs),
                keys=new_st.keys.reshape(P_l * Rs, 5),
                active=new_st.active.reshape(P_l * Rs),
                seq=new_st.seq,
                collisions=new_st.collisions)
            reports = reports_s.reshape(P_l * R_p, wf.report_words)
            mask = masks_s.reshape(P_l * R_p)
            sent = jnp.sum(mask)
            # home-pod index from the flow word — a pure function, so
            # the ragged path can recompute it after its pre-merge sort
            if hrw:
                def hpod_of(fid):
                    return TRANS.node_position(
                        fid // jnp.uint32(fps), nodes_arr) // S
            else:
                def hpod_of(fid):
                    return TRANS.home_coords(fid, fps, S,
                                             self.n_shards)[0]
            # stage 1: intra-pod all_to_all by home shard. The shard
            # coordinate of even a corrupt flow id is in range (floor
            # mod), so misroutes surface at stage 2 via the pod
            # coordinate — mis1 is structurally zero and kept only so
            # the accounting stays stage-symmetric.
            if hrw:
                pos1 = TRANS.node_position(
                    reports[:, 0] // jnp.uint32(fps), nodes_arr)
                hshard = pos1 % S
            else:
                _, hshard, _ = TRANS.home_coords(reports[:, 0], fps, S,
                                                 self.n_shards)
            b1, m1, mis1 = TRANS.route_by_dest(reports, mask, hshard, S,
                                               cap1)
            drop1 = sent - jnp.sum(m1) - mis1
            if self.shard_axes:
                b1 = jax.lax.all_to_all(b1, self.shard_axes, 0, 0,
                                        tiled=True)
                m1 = jax.lax.all_to_all(
                    m1.astype(jnp.uint32), self.shard_axes, 0, 0,
                    tiled=True).astype(bool)
            r1 = b1.reshape(S * cap1, PROTO.REPORT_WORDS)
            m1 = m1.reshape(S * cap1)
            # stage 2: cross-pod exchange by home pod
            extra = {}
            if ragged:
                # compact exchange: pod-local rows never cross, remote
                # rows are pre-merged (flow-major) and packed into
                # cap2c-row segments — only the occupied capacity moves
                # over the scarce inter-pod link
                (lrows, lmask, b2, m2, mis2,
                 nmsg) = TRANS.crosspod_compact(
                    r1, m1, pod, pods, cap2c, hpod_of, wire=wf)
                crosspod_sent = jnp.sum(m2)
                drop2 = (jnp.sum(m1) - jnp.sum(lmask) - crosspod_sent
                         - mis2)
                if self.pod_axis is not None:
                    b2 = jax.lax.all_to_all(b2, self.pod_axis, 0, 0,
                                            tiled=True)
                    m2 = jax.lax.all_to_all(
                        m2.astype(jnp.uint32), self.pod_axis, 0, 0,
                        tiled=True).astype(bool)
                routed = jnp.concatenate(
                    [lrows,
                     b2.reshape(pods * cap2c, PROTO.REPORT_WORDS)])
                rmask = jnp.concatenate(
                    [lmask, m2.reshape(pods * cap2c)])
                extra = {
                    "crosspod_sent": jax.lax.psum(crosspod_sent, ax),
                    "crosspod_messages": jax.lax.psum(nmsg, ax)}
            else:
                b2, m2, mis2 = TRANS.route_by_dest(
                    r1, m1, hpod_of(r1[:, 0]), pods, cap2)
                drop2 = jnp.sum(m1) - jnp.sum(m2) - mis2
                if self.pod_axis is not None:
                    b2 = jax.lax.all_to_all(b2, self.pod_axis, 0, 0,
                                            tiled=True)
                    m2 = jax.lax.all_to_all(
                        m2.astype(jnp.uint32), self.pod_axis, 0, 0,
                        tiled=True).astype(bool)
                routed = b2.reshape(pods * cap2, PROTO.REPORT_WORDS)
                rmask = m2.reshape(pods * cap2)
            # home-side canonical arrival order (mesh-shape independent:
            # the ragged path's local/received split and the padded
            # path's bucket interleaving both collapse to the same
            # (flow, reporter, seq) total order)
            routed, rmask = TRANS.canonical_order(routed, rmask, wire=wf)
            # owner-side translator + ring placement, as in the 1D path
            tr_st, payloads, coords = TRANS.translate(
                tr_st, routed, rmask, flow_base, cfg)
            # optional lossy-transport injector on the collector-facing
            # stream only (see the 1D path for the rationale)
            ing_pay, ing_mask = payloads, rmask
            fmetrics = {}
            if self.fault_spec is not None:
                ing_pay, ing_mask, fcounts, fledger = FAULTS.inject(
                    payloads, rmask, self.fault_spec, wf, now_, dev)
                fmetrics = {k: jax.lax.psum(v, ax)
                            for k, v in fcounts.items()}
                fmetrics.update(fledger)
            lseq0, recv0 = coll_st.last_seq, coll_st.received
            coll_st = COLL.ingest(coll_st, ing_pay, ing_mask, flow_base,
                                  cfg)
            coll_st, lost_delta = _global_seq_gap(
                coll_st, lseq0, recv0, lost0, dev, ax)
            metrics = {
                "reports_sent": jax.lax.psum(sent, ax),
                "reports_recv": jax.lax.psum(jnp.sum(rmask), ax),
                "bucket_drops": jax.lax.psum(drop1 + drop2, ax),
                "misroutes": jax.lax.psum(mis1 + mis2, ax),
                **extra,
                "collisions": jax.lax.psum(
                    jnp.sum(rep_st.collisions) - collisions0, ax),
                "bad_checksum": jax.lax.psum(
                    jnp.sum(coll_st.bad_checksum) - bad_csum0, ax),
                "seq_anomalies": jax.lax.psum(
                    jnp.sum(coll_st.seq_anomalies) - seq_anom0, ax),
                "lost_reports": lost_delta,
                **fmetrics,
            }
            return (rep_st, tr_st, coll_st, coords["local_flow"],
                    routed[:, 0], rmask, metrics)

        specs = self.state_specs()
        ev_specs = (P(ax), P(ax), P(ax, None), P(ax))
        out_state_specs = (specs.reporter, specs.translator,
                           specs.collector)
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(specs.reporter, specs.translator, specs.collector)
            + ev_specs + (P(),),
            out_specs=out_state_specs
            + (P(ax), P(ax), P(ax), self._metric_specs(ax)),
            check=False)
        rep_st, tr_st, coll_st, local_flow, flow_id, rmask, metrics = fn(
            state.reporter, state.translator, state.collector,
            events["ts"], events["size"], events["five_tuple"],
            events["valid"], now)
        return (DFAState(rep_st, tr_st, coll_st),
                RoutedBatch(local_flow, flow_id, rmask), metrics)

    def enrich_half(self, state: DFAState, routed: RoutedBatch):
        """Second half of a monitoring period: fused gather + enrichment
        of the routed flows (via dispatch; skips the (R, H, 16) history
        materialization; the op owns the [0, F) clamp of local_flow and
        the memory-strategy choice — full-block VMEM at reduced F,
        HBM-tiled at Tofino scale), plus the optional immediate-inference
        hook on the resulting features.

        Reads the collector ring, never writes it — which is what makes
        it legal to defer one period in the overlapped driver. Returns
        (enriched (R, D), flow_ids (R,), emask (R,), preds) where preds
        is None unless an inference head is armed.
        """
        cfg = self.cfg
        ax = self.axes

        def local(coll_st, lf, fid, m):
            enriched = COLL.enrich_flow_history(coll_st, lf, cfg, mask=m)
            flow_ids = jnp.where(m, fid, jnp.uint32(WIRE.PAD_FLOW_ID))
            return enriched, flow_ids, m

        specs = self.state_specs()
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(specs.collector, P(ax), P(ax), P(ax)),
            out_specs=(P(ax, None), P(ax), P(ax)), check=False)
        enriched, flow_ids, emask = fn(state.collector, routed.local_flow,
                                       routed.flow_id, routed.mask)
        preds = None
        if self.infer_fn is not None:
            # the hook consumes the features in the same trace — "features
            # land in device memory and are consumed in the same period"
            preds = self.infer_fn(enriched)
            preds = jnp.where(emask[:, None], preds, 0.0)
        return enriched, flow_ids, emask, preds

    def dfa_step(self, state: DFAState, events: Dict[str, jax.Array],
                 now: jax.Array) -> StepOutputs:
        """One full monitoring period = ingest_half ∘ enrich_half.

        events (global): ts/size (n_shards*E,), five_tuple (…,5),
        valid (…,). Returns :class:`StepOutputs` (``preds`` is ``None``
        unless an inference head is armed — the arity never changes)."""
        state, routed, metrics = self.ingest_half(state, events, now)
        enriched, flow_ids, emask, preds = self.enrich_half(state, routed)
        return StepOutputs(state, enriched, flow_ids, emask, metrics,
                           preds)

    # -- multi-period streaming -------------------------------------------
    def run_periods(self, state: DFAState, events: Dict[str, jax.Array],
                    nows: jax.Array) -> StepOutputs:
        """Stream T monitoring periods, each a full ingest+enrich chain,
        as one ``lax.scan`` (state is the carry, so with donation the ring
        memory is updated in place across the whole scan — the GDR
        analogue held for an entire trace window).

        events: dict of (T, n_shards*E, …) arrays; nows: (T,) u32.
        Returns :class:`StepOutputs` with the per-period fields stacked
        under a leading (T,) dim (metrics values are (T,) PER-PERIOD
        arrays; ``preds`` is (T, R, C) or ``None``).
        """

        def body(st, xs):
            ev, now_ = xs
            st, routed, metrics = self.ingest_half(st, ev, now_)
            enriched, flow_ids, emask, preds = self.enrich_half(st, routed)
            return st, (enriched, flow_ids, emask, metrics, preds)

        state, (enriched, flow_ids, emask, metrics, preds) = jax.lax.scan(
            body, state, (events, nows))
        return StepOutputs(state, enriched, flow_ids, emask, metrics,
                           preds)

    def run_periods_overlapped(self, state: DFAState,
                               events: Dict[str, jax.Array],
                               nows: jax.Array) -> StepOutputs:
        """Software-pipelined stream: period t's enrich(+inference) half
        runs in the same scan body as period t+1's ingest half, so
        enrichment latency overlaps the next period's line-rate work
        instead of serializing against it (ROADMAP: "overlapped
        ingest/enrich, double-buffered periods").

        The scan carry is (state, RoutedBatch of the previous period); the
        body first enriches the carried coords — reading the ring BEFORE
        this body's placement touches it — then ingests the new period.
        One warm-up ingest precedes the scan, one drain enrich follows it.
        Output-identical to ``run_periods`` (the equivalence is exact, not
        approximate: same reads of the same ring states in both drivers);
        T=1 degenerates to warm-up + drain with a zero-length scan.

        Same signature and returns as ``run_periods``.
        """
        ev0 = {k: v[0] for k, v in events.items()}
        state, routed0, metrics0 = self.ingest_half(state, ev0, nows[0])

        def body(carry, xs):
            st, prev = carry
            ev, now_ = xs
            # enrich period t from the pre-ingest ring (sequential
            # semantics) while ingesting period t+1
            enriched, flow_ids, emask, preds = self.enrich_half(st, prev)
            st, routed, metrics = self.ingest_half(st, ev, now_)
            return (st, routed), (enriched, flow_ids, emask, metrics,
                                  preds)

        rest = ({k: v[1:] for k, v in events.items()}, nows[1:])
        (state, last), (enriched, flow_ids, emask, metrics, preds) = (
            jax.lax.scan(body, (state, routed0), rest))
        # drain: the final period's enrich half
        enr_t, fid_t, em_t, preds_t = self.enrich_half(state, last)

        def tail(stacked, last_row):
            return jnp.concatenate([stacked, last_row[None]], axis=0)

        enriched = tail(enriched, enr_t)
        flow_ids = tail(flow_ids, fid_t)
        emask = tail(emask, em_t)
        preds = None if preds_t is None else tail(preds, preds_t)
        # the warm-up produced period 0's metrics; the scan periods 1..T-1
        metrics = jax.tree.map(
            lambda m0, m: jnp.concatenate([m0[None], m], axis=0),
            metrics0, metrics)
        return StepOutputs(state, enriched, flow_ids, emask, metrics,
                           preds)

    # -- convenience ------------------------------------------------------
    def describe(self) -> Dict[str, Any]:
        """Trace-time kernel selection for this system: backend, gather
        memory strategy, ingest event-stream strategy, and the VMEM
        numbers that drove the choices."""
        from repro.kernels.ingest_update.kernel import clamp_tile
        cfg = self.cfg
        backend = dispatch.resolve_backend(None, cfg)
        # mirror the ingest half: each shard enriches R routed rows, and
        # ops.gather_enrich tiles that R by flow_tile
        if self.multipod:
            R = self.total_ports * self.port_capacity
        else:
            R = self.n_shards * max(1, cfg.report_capacity
                                    // self.n_shards)
        tile = min(dispatch.resolve_report_tile(cfg, R), R)
        variant = ("ref" if backend == "ref" else
                   dispatch.resolve_gather_variant(
                       None, cfg, cfg.flows_per_shard, cfg.history, tile,
                       cfg.derived_dim))
        # ingest side: each shard sorts/reduces event_block events/period
        etile = clamp_tile(
            dispatch.resolve_event_tile(cfg, cfg.event_block),
            cfg.event_block)
        ingest_variant = ("ref" if backend == "ref" else
                          dispatch.resolve_ingest_variant(
                              None, cfg, cfg.event_block, etile))
        return {
            "kernel_backend": backend,
            "wire_format": self.wire.name,
            "gather_variant": variant,
            "ingest_variant": ingest_variant,
            "event_tile": etile,
            "ingest_vmem_bytes": dispatch.ingest_vmem_bytes(
                "hbm" if ingest_variant == "hbm" else "block",
                cfg.event_block, etile),
            "ring_region_bytes": cfg.ring_region_bytes(),
            "vmem_budget_bytes": cfg.vmem_budget_mb
            * dispatch.VMEM_BYTES_PER_MB,
            "gather_vmem_bytes": dispatch.gather_vmem_bytes(
                "hbm" if variant == "hbm" else "full",
                cfg.flows_per_shard, cfg.history, tile, cfg.derived_dim,
                words=cfg.payload_words),
            "n_shards": self.n_shards,
            "flow_home": cfg.flow_home,
            "pods": self.mesh_pods,
            "shards_per_pod": self.shards_per_pod,
            "total_ports": self.total_ports,
            "ports_per_device": self.ports_per_device,
            "reporter_slots": self.rep_cfg.flows_per_shard,
            "port_report_capacity": self.port_capacity,
            # stage-2 exchange strategy (crosspod_capacity is the
            # per-destination segment size the ragged path ships;
            # stage2_capacity is what the padded path would ship)
            "crosspod_exchange": self.crosspod_exchange,
            "crosspod_capacity": self.crosspod_capacity,
            "stage2_capacity": self.stage2_capacity,
            "tuning_registry": TUNING.resolve_path(cfg) or "none",
            # elastic knobs (launch.elastic reads the same fields)
            "home_nodes": self.home_nodes,
            "snapshot_every_periods": cfg.snapshot_every_periods,
            "overlap_periods": cfg.overlap_periods,
            "inference_head": ("custom" if (self.infer_fn is not None
                                            and self.infer_params is None)
                               else cfg.inference_head),
            # serving knobs (launch.serving reads the same fields)
            "serve_offered_eps": cfg.serve_offered_eps,
            "serve_budget_us": cfg.serve_budget_resolved_us(),
            "serve_queue_events": cfg.serve_queue_events,
            "drop_policy": cfg.drop_policy,
            # transport-fault / elastic robustness knobs
            "fault_injection": (self.fault_spec.describe()
                                if self.fault_spec is not None else "none"),
            "rehome_collision_policy": cfg.rehome_collision_policy,
        }

    def jit_step(self, donate: bool = True):
        """jit'd single-period step, cached per donate flag (the serving
        loop warms up and then serves through the SAME compiled step)."""
        cache = getattr(self, "_step_jits", None)
        if cache is None:
            cache = self._step_jits = {}
        if bool(donate) not in cache:
            cache[bool(donate)] = jax.jit(
                self.dfa_step, donate_argnums=(0,) if donate else ())
        return cache[bool(donate)]

    def jit_stream(self, donate: bool = True,
                   overlapped: Optional[bool] = None):
        """jit'd streaming driver with the state carry donated.

        ``overlapped`` defaults to ``cfg.overlap_periods``; the two
        drivers are output-identical, so callers pick purely on latency
        shape. The jitted callable is cached per (overlapped, donate), so
        repeated lookups share one trace."""
        if overlapped is None:
            overlapped = self.cfg.overlap_periods
        key = (bool(overlapped), bool(donate))
        cache = getattr(self, "_stream_jits", None)
        if cache is None:
            cache = self._stream_jits = {}
        if key not in cache:
            fn = (self.run_periods_overlapped if overlapped
                  else self.run_periods)
            cache[key] = jax.jit(fn,
                                 donate_argnums=(0,) if donate else ())
        return cache[key]

    def stream(self, state: DFAState, events: Dict[str, jax.Array],
               nows: jax.Array, overlapped: Optional[bool] = None,
               donate: bool = False,
               snapshot_dir: Optional[str] = None,
               snapshot_start: int = 0) -> StepOutputs:
        """THE streaming entry point: run T monitoring periods and return
        :class:`StepOutputs`, dispatching between the sequential and the
        software-pipelined driver (``overlapped`` defaults to
        ``cfg.overlap_periods`` — the two are output-identical, so the
        knob is purely a latency-shape choice).

        Subsumes the jit_stream/run_periods* juggling at call sites: one
        call, one structured return, jit caches shared across calls.
        ``donate=True`` donates the state carry (the caller must not
        reuse the passed-in state afterwards — streaming-loop shape).

        With ``cfg.snapshot_every_periods > 0`` and a snapshot directory
        (``snapshot_dir`` argument, else ``cfg.snapshot_dir``), the trace
        runs in chunks of that many periods with an async full-DFAState
        checkpoint at each chunk boundary AND after the final (possibly
        partial) chunk — so the on-disk replay window is at most
        ``snapshot_every_periods``. Checkpoint steps are GLOBAL period
        indices, offset by ``snapshot_start`` (pass the restored period
        when resuming after a recovery). The chunked run is bitwise
        identical to the unchunked one (pinned in tests): snapshotting is
        pure observation, ``checkpoint.save`` copies to host before the
        next chunk touches the carry."""
        every = int(self.cfg.snapshot_every_periods)
        sdir = snapshot_dir if snapshot_dir is not None \
            else (self.cfg.snapshot_dir or None)
        if every <= 0 or sdir is None:
            return self.jit_stream(donate=donate, overlapped=overlapped)(
                state, events, nows)
        return self._stream_snapshotted(state, events, nows, overlapped,
                                        donate, sdir, every,
                                        int(snapshot_start))

    def _stream_snapshotted(self, state, events, nows, overlapped,
                            donate, sdir, every, start):
        from repro.checkpoint import checkpoint as CKPT
        T = int(nows.shape[0])
        outs = []
        threads = []
        for lo in range(0, T, every):
            hi = min(lo + every, T)
            ev = {k: v[lo:hi] for k, v in events.items()}
            # chunk 0 honors the caller's donate contract; the internal
            # carry is always ours to donate
            out = self.jit_stream(donate=donate if lo == 0 else True,
                                  overlapped=overlapped)(
                state, ev, nows[lo:hi])
            state = out.state
            # async snapshot: save() device_gets synchronously (the carry
            # is safe to donate to the next chunk), only the file IO rides
            # the background thread
            t = CKPT.save(state, sdir, step=start + hi,
                          keep=self.cfg.snapshot_keep, async_=True)
            if t is not None:
                threads.append(t)
            outs.append(out)
        for t in threads:
            t.join()
        if len(outs) == 1:
            return outs[0]
        stacked = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                               *[o._replace(state=None, preds=None)
                                 for o in outs])
        preds = (None if outs[0].preds is None else
                 jnp.concatenate([o.preds for o in outs], axis=0))
        return stacked._replace(state=state, preds=preds)

    def event_specs(self, events_per_shard: int, periods: int = 0):
        """ShapeDtypeStructs + shardings for the global event batch; with
        ``periods`` > 0, shapes carry the leading (T,) streaming dim."""
        n = self.n_shards * events_per_shard
        lead = (periods,) if periods else ()
        sds = {
            "ts": jax.ShapeDtypeStruct(lead + (n,), jnp.uint32),
            "size": jax.ShapeDtypeStruct(lead + (n,), jnp.uint32),
            "five_tuple": jax.ShapeDtypeStruct(lead + (n, 5), jnp.uint32),
            "valid": jax.ShapeDtypeStruct(lead + (n,), jnp.bool_),
        }
        ax = self.axes
        t = (None,) if periods else ()
        specs = {"ts": P(*t, ax), "size": P(*t, ax),
                 "five_tuple": P(*t, ax, None), "valid": P(*t, ax)}
        return sds, specs
