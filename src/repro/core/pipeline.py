"""End-to-end distributed DFA pipeline (Fig 1) as one SPMD step.

Every device is simultaneously one Reporter shard and one Collector shard
(+ its translator): the flow space is range-sharded over the *entire* mesh
(512 shards × 2^17 flows = 67M flows at production scale — the paper's
4-pipeline Tofino supports 524,288). One ``dfa_step``:

  local packet events ──ingest──> per-flow Table-I registers
  due flows ──clone/truncate──> DTA reports (fixed capacity)
  reports ──all_to_all over ("pod","data","model")──> owner shards
           (the ICI takes RoCEv2's place; addresses computed by the
            owner-side translator exactly as §III-B)
  payloads ──ring placement──> (F, 10, 16-word) collector memory (Fig 4)
  received flows ──enrichment──> derived feature vectors -> inference

The step is jit-compatible, state is donated (in-place ring updates — the
GDR analogue), and every stage has a fixed SPMD shape.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import DFAConfig
from repro.core import collector as COLL
from repro.core import enrich as ENR
from repro.core import protocol as PROTO
from repro.core import reporter as REP
from repro.core import translator as TRANS

Tree = Any


class DFAState(NamedTuple):
    reporter: REP.ReporterState
    translator: TRANS.TranslatorState
    collector: COLL.CollectorState


class DFASystem:
    """Facade: builds sharded state + the jit-able distributed step."""

    def __init__(self, cfg: DFAConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.n_shards = int(math.prod(mesh.devices.shape))

    # -- state ------------------------------------------------------------
    def init_state(self) -> DFAState:
        """Global state arrays (leading dim = n_shards * per-shard size)."""
        n = self.n_shards

        def rep_tile(make):
            st = make(self.cfg)
            return jax.tree.map(
                lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim).reshape(
                    (n * a.shape[0],) + a.shape[1:]) if a.ndim >= 1 else
                jnp.tile(a[None], (n,)), st)

        return DFAState(rep_tile(REP.init_state),
                        rep_tile(TRANS.init_state),
                        rep_tile(COLL.init_state))

    def state_specs(self) -> DFAState:
        """PartitionSpecs: every leading dim sharded over the whole mesh."""
        ax = self.axes

        def spec(a):
            return P(ax, *([None] * (a.ndim - 1))) if a.ndim >= 1 else P()

        # build from abstract eval to avoid allocating:
        st = jax.eval_shape(self.init_state)
        return jax.tree.map(spec, st)

    def state_shardings(self) -> DFAState:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_specs())

    # -- the step ---------------------------------------------------------
    def dfa_step(self, state: DFAState, events: Dict[str, jax.Array],
                 now: jax.Array):
        """events (global): ts/size (n_shards*E,), five_tuple (…,5),
        valid (…,). Returns (state', enriched, flow_ids, emask, metrics)."""
        cfg = self.cfg
        n = self.n_shards
        cap_out = max(1, cfg.report_capacity // n)
        ax = self.axes

        def local(rep_st, tr_st, coll_st, ev_ts, ev_sz, ev_tu, ev_va, now_):
            shard = jnp.zeros((), jnp.int32)
            for a in ax:
                shard = shard * jax.lax.axis_size(a) + jax.lax.axis_index(a)
            flow_base = shard * cfg.flows_per_shard
            # 1. reporter ingest
            rep_st = REP.ingest(rep_st, {"ts": ev_ts, "size": ev_sz,
                                         "five_tuple": ev_tu,
                                         "valid": ev_va}, cfg)
            # 2. due flows -> DTA reports
            slots, mask = REP.due_flows(rep_st, now_, cfg,
                                        cfg.report_capacity)
            rep_st, reports = REP.make_reports(
                rep_st, slots, mask, now_, 0, flow_base, cfg)
            # reporter id = shard (mod 256, the 8-bit id space)
            rid = (shard % COLL.N_REPORTERS).astype(jnp.uint32)
            reports = reports.at[:, 1].set(
                jnp.where(mask, (rid << 24) | (reports[:, 1] & 0x00FFFFFF),
                          0))
            # 3. route to owner shards (fixed-capacity buckets + all_to_all)
            buckets, bmask = TRANS.route_reports(
                reports, mask, n, cfg.flows_per_shard, cap_out)
            routed = jax.lax.all_to_all(buckets, ax, 0, 0, tiled=True)
            rmask = jax.lax.all_to_all(
                bmask.astype(jnp.uint32), ax, 0, 0,
                tiled=True).astype(bool)
            dropped = jnp.sum(mask) - jnp.sum(bmask)
            routed = routed.reshape(n * cap_out, PROTO.REPORT_WORDS)
            rmask = rmask.reshape(n * cap_out)
            # 4. owner-side translator: history addresses + RoCEv2 payloads
            tr_st, payloads, coords = TRANS.translate(
                tr_st, routed, rmask, flow_base, cfg)
            # 5. collector ring placement + integrity checks
            coll_st = COLL.ingest(coll_st, payloads, rmask, flow_base, cfg)
            # 6. enrichment of received flows
            lf = jnp.clip(coords["local_flow"], 0, cfg.flows_per_shard - 1)
            entries, ev_valid = COLL.gather_flow_history(coll_st, lf)
            enriched = ENR.derive_ref(entries, ev_valid, cfg)
            enriched = jnp.where(rmask[:, None], enriched, 0.0)
            flow_ids = jnp.where(rmask, routed[:, 0],
                                 jnp.uint32(0xFFFFFFFF))
            metrics = {
                "reports_sent": jax.lax.psum(jnp.sum(mask), ax),
                "reports_recv": jax.lax.psum(jnp.sum(rmask), ax),
                "bucket_drops": jax.lax.psum(jnp.sum(dropped), ax),
                "collisions": jax.lax.psum(jnp.sum(rep_st.collisions), ax),
                "bad_checksum": jax.lax.psum(jnp.sum(coll_st.bad_checksum),
                                             ax),
                "seq_anomalies": jax.lax.psum(
                    jnp.sum(coll_st.seq_anomalies), ax),
            }
            return (rep_st, tr_st, coll_st, enriched, flow_ids, rmask,
                    metrics)

        specs = self.state_specs()
        ev_specs = (P(ax), P(ax), P(ax, None), P(ax))
        out_state_specs = (specs.reporter, specs.translator, specs.collector)
        fn = jax.shard_map(
            local, mesh=self.mesh,
            in_specs=(specs.reporter, specs.translator, specs.collector)
            + ev_specs + (P(),),
            out_specs=out_state_specs
            + (P(ax, None), P(ax), P(ax),
               jax.tree.map(lambda _: P(), {
                   "reports_sent": 0, "reports_recv": 0, "bucket_drops": 0,
                   "collisions": 0, "bad_checksum": 0, "seq_anomalies": 0})),
            check_vma=False)
        rep_st, tr_st, coll_st, enriched, flow_ids, rmask, metrics = fn(
            state.reporter, state.translator, state.collector,
            events["ts"], events["size"], events["five_tuple"],
            events["valid"], now)
        return (DFAState(rep_st, tr_st, coll_st), enriched, flow_ids,
                rmask, metrics)

    # -- convenience ------------------------------------------------------
    def jit_step(self, donate: bool = True):
        return jax.jit(self.dfa_step,
                       donate_argnums=(0,) if donate else ())

    def event_specs(self, events_per_shard: int):
        """ShapeDtypeStructs + shardings for the global event batch."""
        n = self.n_shards * events_per_shard
        sds = {
            "ts": jax.ShapeDtypeStruct((n,), jnp.uint32),
            "size": jax.ShapeDtypeStruct((n,), jnp.uint32),
            "five_tuple": jax.ShapeDtypeStruct((n, 5), jnp.uint32),
            "valid": jax.ShapeDtypeStruct((n,), jnp.bool_),
        }
        ax = self.axes
        specs = {"ts": P(ax), "size": P(ax), "five_tuple": P(ax, None),
                 "valid": P(ax)}
        return sds, specs
