"""AdamW with decoupled weight decay, global-norm clipping, and a
configurable optimizer-state dtype (bf16 moments let 671B-class models fit
the 16 GB/chip HBM budget — see configs/deepseek_v3_671b.py).

Pure pytree functions; state shardings mirror the parameter shardings so
FSDP semantics fall out of GSPMD for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Tree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Tree
    nu: Tree


def init(params: Tree, cfg: TrainConfig, state_dtype: str = "float32"
         ) -> OptState:
    dt = jnp.dtype(state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(z, params),
                    nu=jax.tree.map(z, params))


def abstract_state(params: Tree, cfg: TrainConfig,
                   state_dtype: str = "float32") -> OptState:
    dt = jnp.dtype(state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    mu=jax.tree.map(z, params), nu=jax.tree.map(z, params))


def clip_by_global_norm(grads: Tree, max_norm: float) -> Tuple[Tree,
                                                               jax.Array]:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply(params: Tree, grads: Tree, opt: OptState, cfg: TrainConfig,
          lr: jax.Array) -> Tuple[Tree, OptState, jax.Array]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt.mu, opt.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_mu, new_nu), gnorm
