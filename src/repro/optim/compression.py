"""Gradient compression for cross-pod data parallelism.

int8 error-feedback compression: gradients are quantized per-leaf to int8
with a per-leaf fp32 scale before the cross-pod all-reduce; the quantization
residual is carried in an error-feedback buffer so the compression bias
vanishes over steps (Karimireddy et al. style). At 512-chip scale the DP
all-reduce is the dominant collective for small models — int8 cuts its
bytes 4x (quantified in EXPERIMENTS.md §Perf).

Used inside shard_map over the DP axes: psum happens on the quantized
values; dequantization follows.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def init_error(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """-> (int8 q, fp32 scale, new residual)."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    resid = x - q.astype(jnp.float32) * scale
    return q, scale, resid


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Tree, err: Tree, axis_names) -> Tuple[Tree,
                                                                 Tree]:
    """All-reduce int8-quantized grads over ``axis_names`` (inside
    shard_map); returns (mean grads fp32, new error feedback)."""
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        n = n * jax.lax.axis_size(a)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        # SHARED scale across ranks (one scalar pmax) so the int32 sum
        # dequantizes exactly: sum_r q_r * s == sum_r x_r up to rounding
        local_max = jnp.max(jnp.abs(x))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_names),
                            1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        resid = x - q.astype(jnp.float32) * scale
        tot = jax.lax.psum(q.astype(jnp.int32), axis_names)
        g_hat = tot.astype(jnp.float32) * scale / n
        return g_hat, resid

    out = jax.tree.map(one, grads, err)
    g2 = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    e2 = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return g2, e2
