"""LR schedules: linear warmup + cosine decay (the framework default)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_at(step, cfg: TrainConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.learning_rate * jnp.minimum(step / max(cfg.warmup_steps, 1),
                                           1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm,
                     cfg.learning_rate * (0.1 + 0.9 * cos))
