"""Fault-tolerance runtime pieces: step watchdog, heartbeats, retry loop.

* StepMonitor — EMA step-time tracker; flags stragglers (step > k× EMA) and
  raises after ``max_consecutive_slow`` (a hung collective on real fleets).
* Heartbeat — per-process liveness file (multi-host: the coordinator scans
  peers' mtimes; single-process here but the protocol is complete).
* run_with_restart — wraps a step function with checkpoint-restore retry:
  on exception, restore latest checkpoint and replay (the step index comes
  from the checkpoint, and the data pipeline is step-keyed, so replay is
  exact).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional


@dataclass
class StepMonitor:
    ema_decay: float = 0.9
    slow_factor: float = 3.0
    max_consecutive_slow: int = 5
    ema: Optional[float] = None
    consecutive_slow: int = 0
    slow_steps: int = 0
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> Dict[str, float]:
        dt = time.monotonic() - self._t0
        slow = self.ema is not None and dt > self.slow_factor * self.ema
        if slow:
            self.consecutive_slow += 1
            self.slow_steps += 1
        else:
            self.consecutive_slow = 0
        self.ema = dt if self.ema is None else (
            self.ema_decay * self.ema + (1 - self.ema_decay) * dt)
        if self.consecutive_slow >= self.max_consecutive_slow:
            raise RuntimeError(
                f"straggler watchdog: {self.consecutive_slow} consecutive "
                f"slow steps (last {dt:.3f}s vs EMA {self.ema:.3f}s)")
        return {"step_time": dt, "ema": self.ema, "slow": float(slow)}


@dataclass
class Heartbeat:
    """Per-process liveness file; ``pod`` records which pod of the 2D
    (pod, shard) mesh the process serves, so the coordinator can tell a
    single straggler from a whole pod losing its ICI/power domain (the
    multi-pod stream can drain and re-home a pod's port set; a lone dead
    process is a restart)."""
    directory: str
    process_index: int = 0
    stale_after_s: float = 60.0
    pod: int = 0

    def beat(self, step: int):
        os.makedirs(self.directory, exist_ok=True)
        path = os.path.join(self.directory, f"hb_{self.process_index}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "t": time.time(), "pod": self.pod}, f)
        os.replace(tmp, path)

    def dead_peers(self) -> Dict[int, float]:
        """-> {process_index: seconds_since_last_beat} for stale peers."""
        return {idx: age for idx, (age, _pod)
                in self._stale().items()}

    def dead_peers_by_pod(self) -> Dict[int, Dict[int, float]]:
        """-> {pod: {process_index: seconds_since_last_beat}} for stale
        peers, grouped by the pod each peer recorded in its last beat
        (heartbeat files from before the pod field default to pod 0)."""
        out: Dict[int, Dict[int, float]] = {}
        for idx, (age, pod) in self._stale().items():
            out.setdefault(pod, {})[idx] = age
        return out

    def _stale(self) -> Dict[int, tuple]:
        now = time.time()
        out: Dict[int, tuple] = {}
        if not os.path.isdir(self.directory):
            return out
        for name in os.listdir(self.directory):
            if not name.startswith("hb_") or not name.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.directory, name)) as f:
                    d = json.load(f)
                age = now - d["t"]
                if age > self.stale_after_s:
                    out[int(name[3:-5])] = (age, int(d.get("pod", 0)))
            except (json.JSONDecodeError, OSError, ValueError):
                continue
        return out


def run_with_restart(step_fn: Callable[[Any, int], Any], state: Any,
                     start_step: int, num_steps: int,
                     save_fn: Callable[[Any, int], None],
                     restore_fn: Callable[[], Any],
                     checkpoint_every: int = 50,
                     max_restarts: int = 3,
                     monitor: Optional[StepMonitor] = None,
                     on_metrics: Optional[Callable] = None):
    """Crash-tolerant training loop driver."""
    restarts = 0
    step = start_step
    while step < num_steps:
        try:
            if monitor:
                monitor.start()
            state, metrics = step_fn(state, step)
            if monitor:
                metrics = {**metrics, **monitor.stop()}
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(state, step)
        except (RuntimeError, ValueError, FloatingPointError) as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            state, step = restore_fn()
            if monitor:
                monitor.consecutive_slow = 0
    return state, step
