"""Fault-tolerance runtime pieces: step watchdog, heartbeats, retry loop.

* StepMonitor — EMA step-time tracker; flags stragglers (step > k× EMA) and
  raises after ``max_consecutive_slow`` (a hung collective on real fleets).
* Heartbeat — per-process liveness file (multi-host: the coordinator scans
  peers' mtimes; single-process here but the protocol is complete).
* run_with_restart — wraps a step function with checkpoint-restore retry:
  on exception, restore latest checkpoint and replay (the step index comes
  from the checkpoint, and the data pipeline is step-keyed, so replay is
  exact).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Optional, Union


@dataclass
class StepMonitor:
    ema_decay: float = 0.9
    slow_factor: float = 3.0
    max_consecutive_slow: int = 5
    ema: Optional[float] = None
    consecutive_slow: int = 0
    slow_steps: int = 0
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> Dict[str, float]:
        dt = time.monotonic() - self._t0
        slow = self.ema is not None and dt > self.slow_factor * self.ema
        if slow:
            self.consecutive_slow += 1
            self.slow_steps += 1
        else:
            self.consecutive_slow = 0
        self.ema = dt if self.ema is None else (
            self.ema_decay * self.ema + (1 - self.ema_decay) * dt)
        if self.consecutive_slow >= self.max_consecutive_slow:
            raise RuntimeError(
                f"straggler watchdog: {self.consecutive_slow} consecutive "
                f"slow steps (last {dt:.3f}s vs EMA {self.ema:.3f}s)")
        return {"step_time": dt, "ema": self.ema, "slow": float(slow)}


@dataclass
class Heartbeat:
    """Per-process liveness file; ``pod`` records which pod of the 2D
    (pod, shard) mesh the process serves, so the coordinator can tell a
    single straggler from a whole pod losing its ICI/power domain (the
    multi-pod stream can drain and re-home a pod's port set; a lone dead
    process is a restart).

    ``expected_peers`` registers the roster up front — either a mapping
    {process_index: pod} or an iterable of process indices (pod 0). A
    registered peer that has *never* written a beat file (died before its
    first beat, or its file is unreadable) is reported dead with
    ``age=inf``; without a roster such a process is invisible, which is
    fatal for the elastic pod-loss trigger."""
    directory: str
    process_index: int = 0
    stale_after_s: float = 60.0
    pod: int = 0
    expected_peers: Optional[Union[Dict[int, int], Iterable[int]]] = None
    # processes deliberately removed from the roster (a recovered-from
    # pod): they never beat again, and reporting them dead forever would
    # re-trip the pod-loss trigger on every scan
    retired: set = field(default_factory=set)

    def retire_peers(self, indices: Iterable[int]) -> None:
        """Stop reporting these processes as dead (post-recovery)."""
        self.retired.update(int(i) for i in indices)

    def retire_pod(self, pod: int) -> None:
        """Retire every registered process of ``pod`` (the elastic
        recovery path calls this after the survivor mesh is live)."""
        self.retire_peers(i for i, p in self._expected().items()
                          if p == pod)

    def beat(self, step: int):
        os.makedirs(self.directory, exist_ok=True)
        path = os.path.join(self.directory, f"hb_{self.process_index}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "t": time.time(), "pod": self.pod}, f)
        os.replace(tmp, path)

    def dead_peers(self) -> Dict[int, float]:
        """-> {process_index: seconds_since_last_beat} for stale peers."""
        return {idx: age for idx, (age, _pod)
                in self._stale().items()}

    def dead_peers_by_pod(self) -> Dict[int, Dict[int, float]]:
        """-> {pod: {process_index: seconds_since_last_beat}} for stale
        peers, grouped by the pod each peer recorded in its last beat
        (heartbeat files from before the pod field default to pod 0)."""
        out: Dict[int, Dict[int, float]] = {}
        for idx, (age, pod) in self._stale().items():
            out.setdefault(pod, {})[idx] = age
        return out

    def _expected(self) -> Dict[int, int]:
        if self.expected_peers is None:
            return {}
        if isinstance(self.expected_peers, dict):
            return {int(k): int(v) for k, v in self.expected_peers.items()}
        return {int(i): 0 for i in self.expected_peers}

    def _stale(self) -> Dict[int, tuple]:
        now = time.time()
        out: Dict[int, tuple] = {}
        seen: set = set()
        if os.path.isdir(self.directory):
            for name in os.listdir(self.directory):
                if not name.startswith("hb_") or not name.endswith(".json"):
                    continue
                try:
                    idx = int(name[3:-5])
                    with open(os.path.join(self.directory, name)) as f:
                        d = json.load(f)
                    age = now - d["t"]
                except (json.JSONDecodeError, OSError, ValueError,
                        KeyError, TypeError):
                    # unparsable beat counts as never-beaten, not healthy
                    continue
                seen.add(idx)
                if age > self.stale_after_s and idx not in self.retired:
                    out[idx] = (age, int(d.get("pod", 0)))
        for idx, pod in self._expected().items():
            if idx not in seen and idx not in self.retired:
                out[idx] = (float("inf"), pod)
        return out


def run_with_restart(step_fn: Callable[[Any, int], Any], state: Any,
                     start_step: int, num_steps: int,
                     save_fn: Callable[[Any, int], None],
                     restore_fn: Callable[[], Any],
                     checkpoint_every: int = 50,
                     max_restarts: int = 3,
                     monitor: Optional[StepMonitor] = None,
                     on_metrics: Optional[Callable] = None):
    """Crash-tolerant training loop driver.

    Restore falls back to the caller's ``(state, start_step)`` when no
    checkpoint exists yet (a crash before the first save must count
    against ``max_restarts``, not escape as FileNotFoundError), and the
    final state is always saved on loop exit, so the tail
    ``num_steps % checkpoint_every`` steps survive a later process death.
    """
    restarts = 0
    step = start_step
    initial = (state, start_step)
    while step < num_steps:
        try:
            if monitor:
                monitor.start()
            state, metrics = step_fn(state, step)
            if monitor:
                metrics = {**metrics, **monitor.stop()}
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(state, step)
        except (RuntimeError, ValueError, FloatingPointError):
            restarts += 1
            if restarts > max_restarts:
                raise
            try:
                state, step = restore_fn()
            except FileNotFoundError:
                state, step = initial
            if monitor:
                monitor.consecutive_slow = 0
    save_fn(state, step)
    return state, step
