"""Pipeline parallelism over the "pod" axis (GPipe-style, collective_permute).

At 2+ pods the inter-pod links are the scarcest bandwidth; PP sends only
(microbatch, seq, d_model) activations across pods once per microbatch
instead of all-reducing every gradient. Stages are layer ranges; the
schedule is the classic (num_micro + num_stages - 1)-tick loop with
bubble fraction (S-1)/(M+S-1). This module is mesh-agnostic: it works for
any stage axis, and composes with the FSDP/TP shardings inside each stage.

Used by launch/train.py when --pp is set; equivalence against the plain
path is tested in tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map

Tree = Any


def pipeline_apply(stage_fn: Callable[[Tree, jax.Array, jax.Array],
                                      jax.Array],
                   stage_params: Tree, x: jax.Array, mesh: Mesh,
                   axis: str = "pod", num_micro: int = 4) -> jax.Array:
    """Run ``x`` (B, S, d) through num_stages = |axis| pipeline stages.

    stage_params: per-stage params ALREADY sharded over ``axis`` (leading
    dim == num_stages, removed inside the shard_map).
    stage_fn(params, x, stage_idx) -> x.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis not in sizes:
        raise ValueError(
            f"pipeline stage axis {axis!r} is not in mesh axes "
            f"{tuple(mesh.axis_names)}; the 2D DFA meshes name their pod "
            "axis 'pod' (launch.mesh.make_dfa_mesh / "
            "make_production_mesh(multi_pod=True))")
    S = sizes[axis]
    B = x.shape[0]
    assert B % num_micro == 0, (B, num_micro)
    mb = B // num_micro

    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def local(params, xl):
        """params: (1, ...) stage slice; xl: (B_l, S, d) — replicated over
        the stage axis, sharded over the data axes."""
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        assert xl.shape[0] % num_micro == 0 and xl.shape[0] >= num_micro, (
            f"local batch {xl.shape[0]} not divisible into {num_micro} "
            "microbatches")
        micro = xl.reshape(num_micro, xl.shape[0] // num_micro,
                           *xl.shape[1:])
        n_t = num_micro + S - 1
        buf = jnp.zeros_like(micro[0])
        outs = jnp.zeros_like(micro)
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range)
            inject = jnp.clip(t, 0, num_micro - 1)
            x_in = jnp.where(sid == 0, micro[inject], buf)
            y = stage_fn(params, x_in, sid)
            # stage s processes microbatch (t - s) when in [0, M)
            m_idx = t - sid
            active = (m_idx >= 0) & (m_idx < num_micro)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            out_idx = jnp.clip(m_idx, 0, num_micro - 1)
            record = active & (sid == S - 1)
            outs = jnp.where(
                record,
                jax.lax.dynamic_update_index_in_dim(outs, y, out_idx, 0),
                outs)
            # shift activations down the pipe
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), ()

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_t))
        # replicate final outputs from the last stage to every stage
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(xl.shape)

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params),
                  P(other_axes or None)),
        out_specs=P(other_axes or None),
        check=False)
    return fn(stage_params, x)
