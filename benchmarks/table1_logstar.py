"""Paper Table I: accuracy of the log* approximation for the moment sums.

Marina/DFA store Σ approx(x^n) through log/exp LUTs. We quantify the
relative error of the approximated squares/cubes over realistic IAT (µs,
lognormal) and packet-size (bimodal 40..1514 B) distributions, and the
error induced on the DERIVED features (variance / skewness) — the quantity
the ML models actually consume.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv
from repro.core import logstar as LS

BITS = 7


def rel_err(x, n):
    approx = np.asarray(LS.approx_pow(jnp.asarray(x, jnp.uint32), n, BITS),
                        np.float64)
    true = x.astype(np.float64) ** n
    ok = true < 2**32
    return np.abs(approx[ok] - true[ok]) / np.maximum(true[ok], 1)


def run():
    rng = np.random.default_rng(0)
    iat = np.clip(rng.lognormal(5.5, 1.5, 50_000), 1, 10**6).astype(
        np.uint32)
    small = rng.random(50_000) < 0.45
    ps = np.where(small, rng.integers(40, 120, 50_000),
                  rng.integers(900, 1514, 50_000)).astype(np.uint32)
    for name, x in (("iat", iat), ("ps", ps)):
        for n in (2, 3):
            e = rel_err(x, n)
            csv(f"table1_logstar_{name}_pow{n}", 0.0,
                f"mean_rel_err={e.mean():.4f};p99={np.quantile(e, .99):.4f}"
                f";max={e.max():.4f}")
    # error on derived variance: var = S2/n - mean^2
    xs = iat[:1000].astype(np.float64)
    s2_true = (xs ** 2).sum()
    s2_approx = np.asarray(LS.approx_pow(jnp.asarray(
        xs.astype(np.uint32)), 2, BITS), np.float64).sum()
    var_true = s2_true / len(xs) - xs.mean() ** 2
    var_approx = s2_approx / len(xs) - xs.mean() ** 2
    csv("table1_derived_variance_err", 0.0,
        f"rel_err={abs(var_approx - var_true) / var_true:.4f}")


if __name__ == "__main__":
    run()
