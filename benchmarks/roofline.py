"""§Roofline table: aggregates the dry-run artifacts into the per-cell
three-term roofline report (reads experiments/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    cells = load_cells()
    if not cells:
        csv("roofline", 0.0, "no dry-run artifacts; run repro.launch.dryrun")
        return
    for c in cells:
        tag = f"{c['arch']}|{c['shape']}|{'pod2' if c['multi_pod'] else 'pod1'}"
        if c.get("skipped"):
            csv(f"roofline_{tag}", 0.0, "SKIP=quadratic_500k")
            continue
        r = c["roofline"]
        m = c["memory"]
        csv(f"roofline_{tag}", r["step_time_lower_bound_s"] * 1e6,
            f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};dom={r['dominant']};"
            f"useful_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f};"
            f"hbm_GiB={m['hbm_used_bytes']/2**30:.2f};"
            f"fits={m['fits_hbm']}")


if __name__ == "__main__":
    run()
