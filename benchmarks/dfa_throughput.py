"""Headline reproduction: feature-vector delivery rate (paper: 31 M/s on
one 100 Gb/s port; 524,288 flows within <= 20 ms monitoring periods).

Measures the full dfa_step (extract + route + place + enrich) and projects
the per-chip TPU rate from the bytes each stage moves; then derives the
supported flow count at the paper's 20 ms period.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, ICI_BW, PEAK_FLOPS, csv, time_loop
from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.core import protocol as P
from repro.data import packets as PK


def run():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh)
    flows = PK.gen_flows(64, seed=0)
    ev = PK.events_for_shards(flows, 0, 1, cfg.event_block)
    evj = {k: jnp.asarray(v) for k, v in ev.items()}
    state = system.init_sharded_state()
    step = jax.jit(system.dfa_step, donate_argnums=(0,))
    t = time_loop(step, state, evj, jnp.uint32(100_000))
    E = cfg.event_block
    csv("dfa_step_cpu", t * 1e6,
        f"events_per_s_cpu={E / t:.3e}")
    # TPU projection per stage (bytes/flops moved per report/event):
    # extraction: one-hot matmul E x F_tile x 8 halves (MXU)
    F = (1 << 17)
    extract_flops_per_event = F * 16 * 2          # one-hot MACs (split u16)
    extract_rate = PEAK_FLOPS / extract_flops_per_event
    # delivery: 64 B payload over ICI + ring rw in HBM
    deliver_rate_ici = ICI_BW / P.PAYLOAD_BYTES
    deliver_rate_hbm = HBM_BW / (P.PAYLOAD_BYTES * 3 + 8)
    enrich_rate = HBM_BW / (10 * P.PAYLOAD_BYTES + 96 * 4)
    vec_rate = min(deliver_rate_ici, deliver_rate_hbm, enrich_rate)
    csv("dfa_tpu_projection", 0.0,
        f"extract_events_per_s={extract_rate:.3e};"
        f"deliver_vecs_per_s_ici={deliver_rate_ici:.3e};"
        f"deliver_vecs_per_s_hbm={deliver_rate_hbm:.3e};"
        f"enrich_vecs_per_s={enrich_rate:.3e};"
        f"bottleneck_vecs_per_s={vec_rate:.3e};paper_port=3.1e7")
    flows_20ms = vec_rate * 0.020
    csv("dfa_flows_at_20ms_per_chip", 0.0,
        f"flows={flows_20ms:.3e};paper=5.24e5;"
        f"x512_chips={flows_20ms * 512:.3e}")


if __name__ == "__main__":
    run()
