"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines; ``--json PATH`` additionally
writes the rows as a machine-readable artifact (the CI bench-smoke job
uploads it so the perf trajectory accumulates per commit). ``--tiny``
shrinks problem sizes / iteration counts for shared runners.

CPU wall numbers are relative only; every benchmark derives the TPU v5e
roofline projection used by EXPERIMENTS.md (this container has no TPU).
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="bench-smoke mode: tiny configs, 2 timed iters")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--only", default=None, metavar="NAMES",
                    help="comma-separated module suffixes to run")
    args = ap.parse_args()
    if args.tiny:
        os.environ["REPRO_BENCH_TINY"] = "1"   # before benchmarks import

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)       # `import benchmarks` as a namespace pkg
    from benchmarks import (common, dfa_throughput, elastic_recovery,
                            fig6_resources, fig8_message_rate,
                            fig9_gdr_vs_staged, gather_scaling,
                            ingest_scaling, roofline, serving_latency,
                            streaming_periods, table1_logstar)
    mods = [fig6_resources, table1_logstar, fig8_message_rate,
            fig9_gdr_vs_staged, dfa_throughput, streaming_periods,
            serving_latency, elastic_recovery, gather_scaling,
            ingest_scaling, roofline]
    if args.only:
        keep = {m.strip() for m in args.only.split(",")}
        known = {m.__name__.split(".")[-1] for m in mods}
        unknown = keep - known
        if unknown:
            sys.exit(f"--only: unknown module(s) {sorted(unknown)}; "
                     f"known: {sorted(known)}")
        mods = [m for m in mods if m.__name__.split(".")[-1] in keep]

    print("name,us_per_call,derived")
    failures = []
    for mod in mods:
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append(mod.__name__)
            print(f"{mod.__name__},nan,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()

    if args.json:
        common.write_artifact(args.json, failures=failures, tag="run")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
