"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. CPU wall numbers are relative
only; every benchmark derives the TPU v5e roofline projection used by
EXPERIMENTS.md (this container has no TPU).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (dfa_throughput, fig6_resources,
                            fig8_message_rate, fig9_gdr_vs_staged,
                            roofline, table1_logstar)
    print("name,us_per_call,derived")
    for mod in (fig6_resources, table1_logstar, fig8_message_rate,
                fig9_gdr_vs_staged, dfa_throughput, roofline):
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{mod.__name__},nan,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
