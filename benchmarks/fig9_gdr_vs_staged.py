"""Paper Fig 9: GPUDirect (direct placement) vs RDMA + memcopy (staged).

The paper: 31 M msg/s direct-to-GPU vs 25 M msg/s when payloads land in
host memory first and are memcopied to the GPU. Our analogue: fused
in-place ring placement vs a staged double-buffer copy then placement.
The structural ratio (bytes moved) is 1 : (1 + payload/ring traffic) — the
TPU projection reproduces the paper's ~20% direct-placement advantage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, csv, time_loop
from repro.configs import get_dfa_config
from repro.core import collector as C
from benchmarks.fig8_message_rate import FLOWS, R, payload_batch
from repro.core import protocol as P


def run():
    cfg = get_dfa_config(reduced=False).__class__(flows_per_shard=FLOWS)
    rng = np.random.default_rng(0)
    pays = payload_batch(rng, cfg, P.PAYLOAD_WORDS)
    mask = jnp.ones(R, bool)

    direct = jax.jit(lambda st, p: C.ingest(st, p, mask, 0, cfg),
                     donate_argnums=(0,))
    staged = jax.jit(lambda st, p: C.staged_ingest(st, p, mask, 0, cfg),
                     donate_argnums=(0,))
    td = time_loop(direct, C.init_state(cfg), pays)
    ts = time_loop(staged, C.init_state(cfg), pays)
    payload, row = 64, 64
    direct_moved = payload + 2 * row + 8
    staged_moved = direct_moved + 2 * payload        # extra staging rw
    r_direct = HBM_BW / direct_moved
    r_staged = HBM_BW / staged_moved
    csv("fig9_direct_gdr_64B", td / R * 1e6,
        f"cpu_msgs_per_s={R/td:.3e};tpu_roofline={r_direct:.3e};paper=3.1e7")
    csv("fig9_staged_memcopy_64B", ts / R * 1e6,
        f"cpu_msgs_per_s={R/ts:.3e};tpu_roofline={r_staged:.3e};paper=2.5e7")
    csv("fig9_direct_advantage", 0.0,
        f"tpu_ratio={r_direct/r_staged:.2f};paper_ratio={31/25:.2f}")


if __name__ == "__main__":
    run()
