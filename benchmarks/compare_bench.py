"""Nightly bench-smoke regression gate: diff two benchmark JSON artifacts
(schema repro-bench-v1, as written by ``benchmarks/run.py --json``) and
fail when any matching row regressed by more than the threshold.

    python benchmarks/compare_bench.py baseline.json current.json \
        [--threshold 0.15] [--allow-missing] [--allow-missing-baseline]

Rows are matched by name on ``us_per_call`` (lower is better). New rows
(no baseline) never fail the gate; rows whose time is 0 or NaN on either
side are informational-only (speedup/crossover rows encode their payload
in the derived column). A baseline row that VANISHED from the current
artifact fails the gate: a renamed or silently-dropped benchmark would
otherwise never gate again, which is exactly how a perf regression hides.
Pass ``--allow-missing`` to downgrade vanished rows to a warning when the
removal is intentional. Exit 1 iff a matched row slowed down by more than
``threshold`` (default 15%, mirroring CI runner noise bounds) or a
baseline row vanished without ``--allow-missing``.
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != "repro-bench-v1":
        sys.exit(f"{path}: unexpected schema {payload.get('schema')!r}")
    rows = {}
    for row in payload.get("rows", []):
        rows[row["name"]] = row
    return rows


def compare(base: dict, cur: dict, threshold: float):
    """-> (regressions, improvements, skipped, unmatched) row reports."""
    regressions, improvements, skipped = [], [], []
    for name, row in sorted(cur.items()):
        if name not in base:
            skipped.append((name, "new row (no baseline)"))
            continue
        b = base[name].get("us_per_call")
        c = row.get("us_per_call")
        if not _timed(b) or not _timed(c):
            skipped.append((name, "untimed row (derived-only)"))
            continue
        ratio = c / b
        line = (name, b, c, ratio)
        if ratio > 1.0 + threshold:
            regressions.append(line)
        elif ratio < 1.0 - threshold:
            improvements.append(line)
    unmatched = [n for n in sorted(base) if n not in cur]
    return regressions, improvements, skipped, unmatched


def _timed(v) -> bool:
    return isinstance(v, (int, float)) and math.isfinite(v) and v > 0


def gate_verdict(regressions, unmatched, allow_missing: bool):
    """The exit-1 reasons (empty list = gate passes). Pure so the test
    suite can pin the policy without spawning a process."""
    reasons = []
    if regressions:
        reasons.append(f"{len(regressions)} matched row(s) regressed "
                       "past the threshold")
    if unmatched and not allow_missing:
        reasons.append(
            f"{len(unmatched)} baseline row(s) vanished from the current "
            "artifact — a renamed or dropped benchmark never gates "
            "again; pass --allow-missing if the removal is intentional")
    return reasons


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated slowdown fraction (default 0.15)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="downgrade vanished baseline rows (present in "
                         "the baseline, absent from the current artifact)"
                         " from a gate failure to a warning")
    ap.add_argument("--allow-missing-baseline", action="store_true",
                    help="exit 0 when the baseline file doesn't exist "
                         "(first nightly run has nothing to diff)")
    args = ap.parse_args()
    # validate the current artifact FIRST: a corrupt/schema-drifted
    # artifact must fail tonight, not next night when it becomes the
    # baseline of a run that can't fix it
    cur = load_rows(args.current)
    try:
        base = load_rows(args.baseline)
    except FileNotFoundError:
        if args.allow_missing_baseline:
            print(f"[gate] no baseline at {args.baseline}; current "
                  f"artifact parses ({len(cur)} rows) — nothing to diff, "
                  "passing")
            return
        raise
    regressions, improvements, skipped, unmatched = compare(
        base, cur, args.threshold)

    for name, reason in skipped:
        print(f"[gate] skip {name}: {reason}")
    tag = "warn" if args.allow_missing else "MISSING"
    for name in unmatched:
        print(f"[gate] {tag}: baseline row {name!r} vanished from the "
              "current artifact")
    for name, b, c, r in improvements:
        print(f"[gate] IMPROVED {name}: {b:.1f} -> {c:.1f} us "
              f"({(1 - r) * 100:.0f}% faster)")
    for name, b, c, r in regressions:
        print(f"[gate] REGRESSION {name}: {b:.1f} -> {c:.1f} us "
              f"(+{(r - 1) * 100:.0f}%, threshold "
              f"{args.threshold * 100:.0f}%)")
    reasons = gate_verdict(regressions, unmatched, args.allow_missing)
    if reasons:
        for reason in reasons:
            print(f"[gate] FAIL: {reason}")
        sys.exit(1)
    print(f"[gate] OK: {len(cur) - len(skipped)} matched rows within "
          f"{args.threshold * 100:.0f}% of baseline")


if __name__ == "__main__":
    main()
