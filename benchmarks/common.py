"""Shared benchmark helpers: timing + TPU roofline projection.

This container has no TPU: wall-clock numbers are CPU-measured (relative
comparisons only); every benchmark also derives the TPU v5e roofline
projection from the bytes/flops it moves, which is the number EXPERIMENTS.md
reports against the paper's NIC-bound measurements.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import env as ENV

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# bench-smoke mode (CI): shrink problem sizes and iteration counts so the
# whole sweep finishes in minutes on a shared runner. Set by run.py --tiny.
TINY = ENV.read_flag(ENV.BENCH_TINY.name)

# every csv() row, for run.py --json artifact emission
ROWS: list = []


def _counts(warmup, iters):
    return (1, 2) if TINY else (warmup, iters)


def time_it(fn, *args, warmup=2, iters=5):
    """Median wall seconds for jit'd fn(*args)."""
    warmup, iters = _counts(warmup, iters)
    for _ in range(warmup):
        out = fn(*args)
        jax.tree.map(lambda a: a.block_until_ready()
                     if hasattr(a, "block_until_ready") else a, out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda a: a.block_until_ready()
                     if hasattr(a, "block_until_ready") else a, out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_loop(fn, state, *args, warmup=2, iters=6):
    """Median wall seconds for state-carrying fn(state, *args) -> (state, ...)
    chains (donation-safe: the carry threads through)."""
    def next_state(out):
        # StepOutputs-style records carry the state under .state; a
        # NamedTuple without one (e.g. CollectorState) IS the state; a
        # plain tuple means (state, ...extras)
        if hasattr(out, "state"):
            return out.state
        if isinstance(out, tuple) and not hasattr(out, "_fields"):
            return out[0]
        return out

    warmup, iters = _counts(warmup, iters)
    for _ in range(warmup):
        out = fn(state, *args)
        state = next_state(out)
        jax.tree.map(lambda a: a.block_until_ready()
                     if hasattr(a, "block_until_ready") else a, out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(state, *args)
        state = next_state(out)
        jax.tree.map(lambda a: a.block_until_ready()
                     if hasattr(a, "block_until_ready") else a, out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv(name: str, us: float, derived: str):
    ROWS.append({"name": name, "us_per_call": float(us),
                 "derived": derived})
    print(f"{name},{us:.2f},{derived}")


def write_artifact(path: str, failures=(), tag: str = "bench"):
    """Write the accumulated ROWS as the repro-bench-v1 JSON artifact —
    the ONE place the schema lives (run.py and every standalone benchmark
    entry point call this, so the nightly regression gate always sees
    identically-shaped payloads)."""
    import json
    import platform

    payload = {
        "schema": "repro-bench-v1",
        "tiny": TINY,
        "unix_time": time.time(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "failures": list(failures),
        "rows": ROWS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    import sys
    print(f"[{tag}] wrote {len(ROWS)} rows -> {path}", file=sys.stderr)
