"""Continuous serving under the SLO: per-period wall latency percentiles.

Runs the real serving loop (launch.serving) — trace-replay source,
double-buffered host ingest ring, donated per-period ``dfa_step`` — for
>= 100 periods and reports the wall-clock period latency distribution as
p50/p99/p999 rows. These are the rows the nightly ``compare_bench.py``
gate matches night over night: the paper's claim is an SLO (verdicts
inside the 20 ms monitoring period), so the regression signal must be a
latency percentile, not a throughput mean.

Two operating points:

* ``serving_latency_p50/p99/p999`` — offered rate == batch capacity
  (every period full, no queueing): the steady-state SLO numbers.
* ``serving_overrun_*`` derived rows — offered 2x capacity with a small
  host queue: exercises backpressure and checks the drop-accounting
  identity (``offered == processed + dropped`` after drain) inside the
  bench itself, so the nightly artifact records that the serving path
  sheds load exactly, never silently.

CPU wall numbers are relative only (no TPU in this container); the SLO
verdict column reports violations of the paper's 20 ms budget for
context, and the derived fields carry sustained events/s.

Standalone: ``python benchmarks/serving_latency.py --tiny --json out.json``
(also wired into benchmarks/run.py for the CI bench-smoke artifact).
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):           # executed as a script: mirror
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))   # run.py's sys.path
    sys.path.insert(0, _root)
    if "--tiny" in sys.argv:            # before benchmarks.common binds TINY
        os.environ["REPRO_BENCH_TINY"] = "1"

import dataclasses

from benchmarks.common import TINY, csv
from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.launch.serving import ServingLoop, build_source

PERIODS = 100 if TINY else 256


def run():
    mesh = make_mesh((1, 1), ("data", "model"))
    base = get_dfa_config(reduced=True)
    E = base.event_block
    budget_us = base.monitoring_period_us
    capacity_eps = E / (budget_us / 1e6)    # one full batch per period
    trace_T = 4
    events, nows = PK.period_batches(1, trace_T, E, n_flows=32,
                                     flow_seed=0)

    # -- steady state: offered == capacity, no queue, no drops ----------
    cfg = dataclasses.replace(base, serve_offered_eps=capacity_eps)
    system = DFASystem(cfg, mesh)
    # warm-up loop on its own source: the measured run then serves every
    # period through the already-compiled step (jit_step is cached on the
    # system), so p999 reflects serving jitter, not the one-off compile
    ServingLoop(system, build_source(system, events, nows)).run(3)
    report = ServingLoop(system, build_source(system, events, nows)).run(
        PERIODS)
    assert report.balanced, "serving accounting must close"
    assert report.dropped == 0, "steady state must not shed load"
    lat = report.latency
    ctx = (f"periods={PERIODS};budget_us={budget_us};"
           f"offered_eps={capacity_eps:.3e};"
           f"sustained_eps={report.sustained_eps:.3e};"
           f"violations={report.violations}")
    csv("serving_latency_p50", lat["p50"], ctx)
    csv("serving_latency_p99", lat["p99"], ctx)
    csv("serving_latency_p999", lat["p999"], ctx)

    # -- forced overrun: 2x capacity, bounded queue, exact shedding -----
    cfg_o = dataclasses.replace(base,
                                serve_offered_eps=2.0 * capacity_eps,
                                serve_queue_events=2 * E,
                                drop_policy="newest")
    sys_o = DFASystem(cfg_o, mesh)
    rep_o = ServingLoop(sys_o, build_source(sys_o, events, nows)).run(
        PERIODS)
    assert rep_o.balanced, \
        (rep_o.offered, rep_o.processed, rep_o.dropped)
    assert rep_o.dropped > 0, "2x offered must force drops"
    lat_o = rep_o.latency
    csv("serving_overrun_p99", lat_o["p99"],
        f"periods={PERIODS};drained={rep_o.drained_periods};"
        f"offered_eps={2.0 * capacity_eps:.3e};"
        f"sustained_eps={rep_o.sustained_eps:.3e}")
    csv("serving_overrun_accounting", 0.0,
        f"offered={rep_o.offered};processed={rep_o.processed};"
        f"dropped={rep_o.dropped};exact="
        f"{rep_o.offered == rep_o.processed + rep_o.dropped};"
        f"drop_policy=newest;queue_events={2 * E}")


def _main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="bench-smoke mode (already applied pre-import)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    from benchmarks import common
    print("name,us_per_call,derived")
    run()
    if args.json:
        common.write_artifact(args.json, tag="serving_latency")


if __name__ == "__main__":
    _main()
