"""ingest_update scaling sweep — events/shard E up to 2^20.

The paper's headline number is line-rate *ingest* (31M feature vectors/s
of extraction), so this sweep measures events/s the way gather_scaling
measures flows/s: per E it times, on one reporter shard,

* multipass  — the pre-fusion ingest (backend="ref": two argsorts,
               a materialized (E, 7) delta array, three scatters)
* fused      — the sort-once jnp engine (one argsort, deltas formed on
               the sorted stream and segment-reduced per slot run by
               cumsum differences, one scatter-add per run)
* interpret/block, interpret/hbm — the Pallas kernels in interpreter
               mode, smallest E only (interpreter walls are orders of
               magnitude off compiled-kernel performance and would
               drown the sweep; they pin the kernels' plumbing cost)

plus the analytic block->hbm VMEM crossover E from the budget formula —
the bench-smoke artifact trends the measured rows and the fused-vs-
multipass ratio per commit (the PR 3 nightly regression-gate diffs
matched rows). CPU walls are relative; the derived column carries a TPU
v5e HBM projection of the per-event stream traffic.

Standalone: ``python benchmarks/ingest_scaling.py --tiny --json out.json``
(also wired into benchmarks/run.py, so the CI bench-smoke artifact
includes the per-E records).
"""
from __future__ import annotations

import dataclasses
import os
import sys

if __package__ in (None, ""):           # executed as a script: mirror
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))   # run.py's sys.path
    sys.path.insert(0, _root)
    if "--tiny" in sys.argv:            # before benchmarks.common binds TINY
        os.environ["REPRO_BENCH_TINY"] = "1"

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, TINY, csv
from repro.configs import get_dfa_config
from repro.core import reporter as R
from repro.kernels import dispatch
from repro.kernels.ingest_update.kernel import clamp_tile
from repro.kernels.ingest_update.ops import (ingest_update,
                                             ingest_update_fused)

F = 1 << 12 if TINY else 1 << 17         # flows/shard (paper: 2^17)
E_SWEEP = ([1 << 10, 1 << 12, 1 << 14] if TINY else
           [1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20])
INTERPRET_E = E_SWEEP[0]                 # interpreter rows: smallest E only


def _events(rng, E):
    n_keys = max(8, E // 16)             # ~16-packet flows per block
    keys = rng.integers(1, 2**31, size=(n_keys, 5)).astype(np.uint32)
    ts = np.sort(rng.integers(0, 10_000_000, size=E)) + np.arange(E)
    return {"ts": jnp.asarray(ts.astype(np.uint32)),
            "size": jnp.asarray(rng.integers(40, 1500, size=E)
                                .astype(np.uint32)),
            "five_tuple": jnp.asarray(keys[rng.integers(0, n_keys,
                                                        size=E)]),
            "valid": jnp.ones(E, bool)}


def _fused_fn(cfg):
    def fn(st, ev):
        slots = R.hash_slot(ev["five_tuple"], cfg.flows_per_shard)
        return ingest_update_fused(
            st.regs, st.last_ts, st.keys, st.active, st.collisions,
            slots, ev["ts"], ev["size"], ev["five_tuple"], ev["valid"],
            cfg)
    return fn


def _interpret_fn(cfg, variant):
    def fn(st, ev):
        slots = R.hash_slot(ev["five_tuple"], cfg.flows_per_shard)
        return ingest_update(
            st.regs, st.last_ts, st.keys, st.active, st.collisions,
            slots, ev["ts"], ev["size"], ev["five_tuple"], ev["valid"],
            cfg, backend="interpret", variant=variant)
    return fn


def _timed(fn, *args):
    """min-of-6 wall seconds. time_it's tiny-mode median-of-2 is too
    noisy for the fused-vs-multipass ratio the regression gate watches,
    and these row sizes are cheap enough that 6 iterations still fit the
    bench-smoke budget; min is the stable statistic for a ratio."""
    import time

    import numpy as _np
    out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, out)
    ts = []
    for _ in range(6):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda a: a.block_until_ready()
                     if hasattr(a, "block_until_ready") else a, out)
        ts.append(time.perf_counter() - t0)
    return float(_np.min(ts))


def run(tune=None):
    cfg = dataclasses.replace(get_dfa_config(), flows_per_shard=F)
    rng = np.random.default_rng(0)
    st = R.init_state(cfg)
    reg = _open_registry(tune)
    # per-event stream traffic the fused kernel moves: five sorted u32
    # words in, one 8-word run-sum row out — the v5e HBM-bound floor
    bytes_per_event = dispatch.EVENT_WORDS * 4 + 8 * 4
    for E in E_SWEEP:
        ev = _events(rng, E)
        tile = clamp_tile(cfg.event_tile, E)
        auto = dispatch.resolve_ingest_variant(None, cfg, E, tile)
        tpu_us = E * bytes_per_event / HBM_BW * 1e6
        t_multi = _timed(jax.jit(
            lambda s, e: R.ingest(s, e, cfg, backend="ref")), st, ev)
        csv(f"ingest_scaling_E{E}_multipass", t_multi * 1e6,
            f"events_per_s={E / t_multi:.3e};F={F};auto={auto}")
        t_fused = _timed(jax.jit(_fused_fn(cfg)), st, ev)
        csv(f"ingest_scaling_E{E}_fused", t_fused * 1e6,
            f"events_per_s={E / t_fused:.3e};F={F};"
            f"fused_vs_multipass={t_multi / t_fused:.2f};auto={auto};"
            f"tpu_v5e_us={tpu_us:.2f}")
        if E <= INTERPRET_E:
            walls = {}
            for variant in ("block", "hbm"):
                t = _timed(jax.jit(_interpret_fn(cfg, variant)), st, ev)
                walls[variant] = t
                csv(f"ingest_scaling_E{E}_interpret_{variant}", t * 1e6,
                    f"events_per_s={E / t:.3e};F={F}")
            if reg is not None:
                win = min(walls, key=walls.get)
                reg.record("ingest_update.variant", "interpret", (E,),
                           win, walls[win] * 1e6,
                           source="ingest_scaling")
                # event_tile mini-sweep on the winning variant: the
                # measured tile beats the static DFAConfig default when
                # this registry is armed via REPRO_TUNING_REGISTRY
                for et in (64, 128, 256):
                    cfgt = dataclasses.replace(cfg, event_tile=et)
                    tt = _timed(jax.jit(_interpret_fn(cfgt, win)), st, ev)
                    reg.record("ingest_update.event_tile", "interpret",
                               (E,), clamp_tile(et, E), tt * 1e6,
                               source="ingest_scaling")
    # analytic crossover: largest power-of-two E whose sorted stream
    # still fits the VMEM budget as blocks — auto flips to hbm above
    budget = cfg.vmem_budget_mb * dispatch.VMEM_BYTES_PER_MB
    Ex = 1
    while dispatch.ingest_vmem_bytes("block", Ex * 2, 256) <= budget:
        Ex *= 2
    csv("ingest_scaling_vmem_crossover", 0.0,
        f"max_block_E={Ex};budget_mb={cfg.vmem_budget_mb};"
        f"event_tile=256;target_E={1 << 20};target_variant="
        f"{dispatch.resolve_ingest_variant(None, cfg, 1 << 20, 256)}")
    if reg is not None:
        reg.save(tune)


def _open_registry(tune):
    """Load-merge semantics: an existing registry keeps entries this
    sweep doesn't re-measure, and re-measured keys keep the faster of
    the two (TuningRegistry.record is fastest-wins)."""
    if tune is None:
        return None
    from repro.kernels import tuning
    if os.path.exists(tune):
        return tuning.TuningRegistry.load(tune)
    return tuning.TuningRegistry()


def main():
    """Standalone entry: python benchmarks/ingest_scaling.py [--tiny]
    [--json PATH]. The --tiny env contract matches run.py (the flag is
    consumed before benchmarks.common binds TINY, via the script
    bootstrap above)."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--tune", default=None, metavar="PATH",
                    help="record the measured winners (variant + "
                         "event_tile per E) into a tuned-config "
                         "registry consulted by dispatch.resolve_*")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tune=args.tune)
    if args.json:
        from benchmarks import common
        common.write_artifact(args.json, tag="ingest_scaling")


if __name__ == "__main__":
    main()
