"""Elastic operations cost: snapshot overhead per period + recovery time.

Two rows the nightly ``compare_bench.py`` gate watches:

* ``elastic_snapshot_overhead`` — extra wall µs per streamed period when
  the snapshot-chunked ``DFASystem.stream()`` path checkpoints the full
  DFAState every ``snapshot_every_periods`` (vs the plain stream on the
  same trace). This is the continuous price of survivability; the chunked
  path is bitwise-identical in outputs (tests/test_elastic_equiv.py), so
  the only thing allowed to change night-over-night is this number.
* ``elastic_recovery_us`` — wall time of one full
  ``recover_from_snapshot`` cycle: restore the newest snapshot, build the
  survivor (pods-1, shard) system, HRW-re-home the dead pod's flows,
  device_put onto the survivor mesh. Needs >= 4 devices for the (2,2)
  mesh; on smaller runners (the 1-device CI bench-smoke) the row is
  skipped with a note so the artifact stays honest about coverage.

Two more rows from the fault-injection/live-recovery PR:

* ``fault_unarmed_overhead`` — per-period wall delta between
  ``fault_spec=None`` and an all-zero ``FaultSpec``: the unconfigured
  fault path is compiled out, so this must stay within noise (the
  zero-cost-when-unconfigured contract).
* ``fault_injection_overhead`` — per-period cost of an ARMED mixed fault
  schedule (the price of running chaos in the loop, informational).
* ``serving_journal_recovery_us`` — the live in-loop recovery wall: a
  chaos-killed pod absorbed MID-SERVE by ``ServingLoop`` (snapshot
  restore + survivor rebuild + journal replay + pending re-stage),
  i.e. the ``recovery_stall_us`` SLO bucket. Same 4-device guard as
  ``elastic_recovery_us``.

CPU wall numbers are relative only (no TPU in this container).

Standalone: ``python benchmarks/elastic_recovery.py --tiny --json out.json``
(also wired into benchmarks/run.py for the CI bench-smoke artifact).
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):           # executed as a script: mirror
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))   # run.py's sys.path
    sys.path.insert(0, _root)
    if "--tiny" in sys.argv:            # before benchmarks.common binds TINY
        os.environ["REPRO_BENCH_TINY"] = "1"

import dataclasses
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TINY, csv
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import scenarios as SC
from repro.launch import elastic as EL
from repro.launch.mesh import make_dfa_mesh

TOTAL_PORTS = 4
EVENTS_PER_PORT = 32 if TINY else 128
T = 8
SNAP_EVERY = 2
ITERS = 2 if TINY else 5


def _cfg(pods, shards):
    return dataclasses.replace(
        get_dfa_config(reduced=True),
        flow_home="rendezvous", pods=pods,
        ports_per_pod=TOTAL_PORTS // pods,
        reporter_slots=64, flows_per_shard=256 if TINY else 512,
        port_report_capacity=16,
        snapshot_every_periods=SNAP_EVERY)


def _stream_wall(system, events, nows, snapshot_dir=None):
    t0 = time.perf_counter()
    out = system.stream(system.init_state(), events, nows,
                        snapshot_dir=snapshot_dir)
    jax.block_until_ready(out.state)
    return time.perf_counter() - t0


def run():
    devs = jax.devices()
    ev, nows_np = SC.build("cross_pod_mix", TOTAL_PORTS,
                           EVENTS_PER_PORT, T)
    events = {k: jnp.asarray(v) for k, v in ev.items()}
    nows = jnp.asarray(nows_np)

    # -- snapshot overhead per period (single device: always runs) ------
    system = DFASystem(_cfg(1, 1), make_dfa_mesh(1, 1, devs[:1]))
    snap_dir = tempfile.mkdtemp(prefix="dfa_snap_bench_")
    try:
        with system.mesh:
            _stream_wall(system, events, nows)               # compile
            _stream_wall(system, events, nows,
                         snapshot_dir=snap_dir)              # compile
            plain = min(_stream_wall(system, events, nows)
                        for _ in range(ITERS))
            snap = min(_stream_wall(system, events, nows,
                                    snapshot_dir=snap_dir)
                       for _ in range(ITERS))
        over_us = max(0.0, (snap - plain) / T * 1e6)
        csv("elastic_snapshot_overhead", over_us,
            f"per_period;T={T};every={SNAP_EVERY};"
            f"plain_us={plain * 1e6:.0f};snap_us={snap * 1e6:.0f};"
            f"snapshots={T // SNAP_EVERY + (T % SNAP_EVERY > 0)}")
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)

    # -- fault path cost (single device: always runs) -------------------
    from repro.data.faults import FaultSpec
    unarmed_sys = DFASystem(
        dataclasses.replace(_cfg(1, 1), fault_spec=FaultSpec()),
        make_dfa_mesh(1, 1, devs[:1]))
    armed_sys = DFASystem(
        dataclasses.replace(_cfg(1, 1), fault_spec=FaultSpec(
            seed=3, drop_rate=0.05, dup_rate=0.05, flip_rate=0.05,
            replay_rate=0.02, reorder_rate=0.1)),
        make_dfa_mesh(1, 1, devs[:1]))
    with system.mesh:
        plain = min(_stream_wall(system, events, nows)
                    for _ in range(ITERS))
    for name, sysm in (("fault_unarmed_overhead", unarmed_sys),
                       ("fault_injection_overhead", armed_sys)):
        with sysm.mesh:
            _stream_wall(sysm, events, nows)                 # compile
            wall = min(_stream_wall(sysm, events, nows)
                       for _ in range(ITERS))
        csv(name, (wall - plain) / T * 1e6,
            f"per_period;T={T};plain_us={plain * 1e6:.0f};"
            f"with_us={wall * 1e6:.0f};"
            f"spec={sysm.cfg.fault_spec.describe()}")

    # -- recovery time: (2,2) -> kill pod 0 -> (1,2) --------------------
    if len(devs) < 4:
        csv("elastic_recovery_us", float("nan"),
            f"skipped;need=4_devices;have={len(devs)}")
        csv("serving_journal_recovery_us", float("nan"),
            f"skipped;need=4_devices;have={len(devs)}")
        return
    full = DFASystem(_cfg(2, 2), make_dfa_mesh(2, 2, devs[:4]))
    snap_dir = tempfile.mkdtemp(prefix="dfa_snap_bench_")
    try:
        with full.mesh:
            full.stream(full.init_state(), events, nows,
                        snapshot_dir=snap_dir)
        t0 = time.perf_counter()
        new_sys, new_state, period = EL.recover_from_snapshot(
            full, snap_dir, 0, devices=devs[:2])
        jax.block_until_ready(new_state)
        rec_us = (time.perf_counter() - t0) * 1e6
        moved = int(np.asarray(new_state.collector.entry_valid)
                    .any(axis=1).sum())
        csv("elastic_recovery_us", rec_us,
            f"mesh=(2,2)->(1,2);period={period};replay_window<="
            f"{SNAP_EVERY};occupied_rows={moved}")
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)

    # -- live in-loop recovery wall (ServingLoop journal path) ----------
    from repro.launch.serving import ServingLoop, build_source
    kill_at = 2 * SNAP_EVERY + 1          # mid-window: 1 journal replay
    snap_dir = tempfile.mkdtemp(prefix="dfa_snap_bench_")
    try:
        loop = ServingLoop(
            full, build_source(full, ev, nows_np),
            snapshot_dir=snap_dir,
            chaos=lambda t: [0] if t == kill_at else [],
            recovery_devices=devs[:2])
        report = loop.run(T)
        assert report.recoveries == 1
        csv("serving_journal_recovery_us", report.recovery_stall_us[0],
            f"mesh=(2,2)->(1,2);kill_at={kill_at};"
            f"journal_replayed={report.journal_replayed};"
            f"periods={T};violations={report.violations}")
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)


def _main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="bench-smoke mode (already applied pre-import)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    from benchmarks import common
    print("name,us_per_call,derived")
    run()
    if args.json:
        common.write_artifact(args.json, tag="elastic_recovery")


if __name__ == "__main__":
    _main()
