"""Paper Fig 6/7: data-plane resource footprint, DTA vs DFA.

On Tofino the costs are SRAM + stateful ALUs (DFA fills 9 of 12 stages with
2^17 x 32-bit registers). The TPU analogue is HBM state per flow and VMEM
tile footprint per kernel invocation. We report both absolute and
relative-to-DTA (DTA keeps only an 8 B value per key — no Table-I
registers, no history ring).
"""
from __future__ import annotations

from benchmarks.common import csv
from repro.configs import get_dfa_config
from repro.core import protocol as P
from repro.kernels.flow_moments.kernel import EVENT_BLOCK, REG_PAD


def run():
    cfg = get_dfa_config()          # full Tofino-scale config
    F = cfg.flows_per_shard
    # per-flow state (bytes)
    dfa_regs = 7 * 4 + 4 + 4        # Table-I stats + last_ts + last_report
    dfa_keys = 5 * 4 + 1            # stored five-tuple + active bit
    dfa_ring = cfg.history * P.PAYLOAD_BYTES
    dta_like = 8                    # DTA key-write: one 8 B slot
    csv("fig6_per_flow_state_dfa_reporter", 0.0,
        f"bytes={dfa_regs + dfa_keys};paper=9x32b_registers")
    csv("fig6_per_flow_state_dfa_collector", 0.0,
        f"bytes={dfa_ring};ring_entries={cfg.history}x{P.PAYLOAD_BYTES}B")
    csv("fig6_per_flow_state_dta", 0.0, f"bytes={dta_like}")
    csv("fig6_shard_totals", 0.0,
        f"reporter_MB={(dfa_regs + dfa_keys) * F / 2**20:.1f};"
        f"collector_MB={dfa_ring * F / 2**20:.1f};"
        f"dta_MB={dta_like * F / 2**20:.1f};"
        f"ratio_vs_dta={(dfa_regs + dfa_keys + dfa_ring) / dta_like:.1f}")
    # kernel VMEM tiles (the "stage SRAM" analogue)
    fm_tile = (cfg.flow_tile * REG_PAD * 4 + EVENT_BLOCK * (4 + 2 * 8 * 4))
    rs_tile = cfg.flow_tile * cfg.history * P.PAYLOAD_BYTES
    df_tile = cfg.flow_tile * (cfg.history * P.PAYLOAD_BYTES
                               + cfg.derived_dim * 4)
    csv("fig6_vmem_tile_flow_moments", 0.0, f"bytes={fm_tile}")
    csv("fig6_vmem_tile_ring_scatter", 0.0, f"bytes={rs_tile}")
    csv("fig6_vmem_tile_derived_features", 0.0, f"bytes={df_tile}")
    csv("fig6_flows_per_pipeline", 0.0,
        f"ours_per_shard={F};paper_per_pipeline={1 << 17};"
        f"ours_512_shards={F * 512}")


if __name__ == "__main__":
    run()
