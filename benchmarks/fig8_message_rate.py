"""Paper Fig 8: achievable message rate / payload bandwidth vs payload size.

The paper measures the NIC path (T-Rex -> Translator -> GDR): 32 M msg/s at
8 B, ~31 M at 64 B, ~28 M at 128 B on one 100 Gb/s port. Our transport is
the collector ingest path (validate + ring placement); the TPU projection is
HBM-bound: rate = HBM_BW / bytes_moved_per_message (each message reads the
payload, reads+writes one ring row + bookkeeping).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, TINY, csv, time_loop
from repro.configs import get_dfa_config
from repro.core import collector as C
from repro.core import protocol as P

R = 1024 if TINY else 8192        # messages per batch
FLOWS = (1 << 10) if TINY else (1 << 14)   # fit CPU memory; same structure


def payload_batch(rng, cfg, words):
    """Build valid payloads, truncated/padded to `words` u32 words."""
    flows = rng.integers(0, cfg.flows_per_shard, R)
    hists = rng.integers(0, cfg.history, R)
    reps = {"flow_id": jnp.asarray(flows, jnp.uint32),
            "reporter_id": jnp.zeros(R, jnp.uint32),
            "seq": jnp.asarray(np.arange(R) & 0xFF, jnp.uint32),
            "stats": jnp.asarray(
                rng.integers(0, 2**20, (R, 7)), jnp.uint32),
            "five_tuple": jnp.asarray(
                rng.integers(0, 2**31, (R, 5)), jnp.uint32)}
    full = P.pack_rocev2_payload(reps, jnp.asarray(hists, jnp.uint32))
    return full


def run():
    cfg = get_dfa_config(reduced=False).__class__(flows_per_shard=FLOWS)
    rng = np.random.default_rng(0)
    state = C.init_state(cfg)
    pays = payload_batch(rng, cfg, P.PAYLOAD_WORDS)
    mask = jnp.ones(R, bool)

    step = jax.jit(lambda st, p: C.ingest(st, p, mask, 0, cfg),
                   donate_argnums=(0,))
    t = time_loop(step, C.init_state(cfg), pays)
    for payload_bytes in (8, 16, 45, 64, 128):
        # bytes moved per message on the collector: payload read + ring row
        # read-modify-write + valid bit + seq table touch
        cpu_rate = R / t
        ring_row = 64                          # the pow-2 ring entry (Fig 4)
        moved = payload_bytes + 2 * ring_row + 8
        tpu_rate = HBM_BW / moved
        csv(f"fig8_message_rate_{payload_bytes}B", t / R * 1e6,
            f"cpu_msgs_per_s={cpu_rate:.3e};tpu_roofline_msgs_per_s="
            f"{tpu_rate:.3e};paper_64B=3.1e7;payload_gbps="
            f"{tpu_rate * payload_bytes * 8 / 1e9:.1f}")


if __name__ == "__main__":
    run()
