"""Multi-period streaming throughput: ``run_periods`` (one lax.scan over T
monitoring periods, donated state) vs T sequential jit'd ``dfa_step``
calls, and — the headline row pair — the sequential scan vs
``run_periods_overlapped`` (period t's enrich half software-pipelined into
period t+1's scan body). The two drivers are output-identical (see
tests/test_overlap_equiv.py), so their ratio isolates what overlapping
ingest with enrich+inference buys: on TPU the enrich DMA/compute hides
behind the next period's line-rate work instead of eating its budget.

Also streams the same periods through both gather_enrich memory
strategies (interpret backend, full-block VMEM vs HBM-tiled DMA) so the
bench-smoke artifact records what the Tofino-scale memory strategy costs
inside the full pipeline, not just at kernel level (gather_scaling.py).

TPU projection: the per-period byte budget is identical to dfa_throughput;
streaming changes the *dispatch* overhead, so the derived column reports
host-side us/period for both drivers plus the scan and overlap speedups.

Standalone: ``python benchmarks/streaming_periods.py --tiny --json out.json``
(also wired into benchmarks/run.py, so the CI bench-smoke artifact
includes the sequential-vs-overlapped rows).
"""
from __future__ import annotations

import dataclasses
import os
import sys

if __package__ in (None, ""):           # executed as a script: mirror
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))   # run.py's sys.path
    sys.path.insert(0, _root)
    if "--tiny" in sys.argv:            # before benchmarks.common binds TINY
        os.environ["REPRO_BENCH_TINY"] = "1"

from benchmarks.common import TINY, csv, time_loop
from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK

T = 4 if TINY else 16


def run():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh)
    E = cfg.event_block
    events, nows = PK.period_batches(system.n_shards, T, E, n_flows=32,
                                     flow_seed=0)

    stream = system.jit_stream(donate=True, overlapped=False)
    t_stream = time_loop(stream, system.init_sharded_state(), events, nows)

    # the software-pipelined driver on the SAME config/events: identical
    # outputs, different latency shape (enrich overlaps the next ingest)
    overlapped = system.jit_stream(donate=True, overlapped=True)
    t_ovl = time_loop(overlapped, system.init_sharded_state(), events,
                      nows)

    # donate the baseline too: both paths then elide the state copy and the
    # speedup row isolates per-period host dispatch overhead (time_loop
    # threads the carry, so donation is safe here)
    step = system.jit_step(donate=True)

    def sequential(state, events_, nows_):
        out = None
        for t in range(T):
            ev_t = {k: v[t] for k, v in events_.items()}
            state, *rest = step(state, ev_t, nows_[t])
            out = rest
        return (state, *out)

    t_seq = time_loop(sequential, system.init_sharded_state(), events, nows)

    csv("streaming_run_periods", t_stream / T * 1e6,
        f"periods={T};events_per_s={T * E / t_stream:.3e};"
        f"us_per_period={t_stream / T * 1e6:.1f}")
    csv("streaming_run_periods_overlapped", t_ovl / T * 1e6,
        f"periods={T};events_per_s={T * E / t_ovl:.3e};"
        f"us_per_period={t_ovl / T * 1e6:.1f}")
    csv("streaming_sequential_steps", t_seq / T * 1e6,
        f"periods={T};events_per_s={T * E / t_seq:.3e};"
        f"us_per_period={t_seq / T * 1e6:.1f}")
    csv("streaming_scan_speedup", 0.0,
        f"x={t_seq / t_stream:.2f};paper_period_ms=20")
    csv("streaming_overlap_speedup", 0.0,
        f"x={t_stream / t_ovl:.2f};vs=run_periods;"
        f"outputs_identical=true;paper_period_ms=20")

    # the overlapped driver with the immediate-inference hook armed: the
    # full paper headline (features -> verdicts in the same scan body)
    cfg_i = dataclasses.replace(cfg, overlap_periods=True,
                                inference_head="linear")
    sys_i = DFASystem(cfg_i, mesh)
    t_inf = time_loop(sys_i.jit_stream(donate=True),
                      sys_i.init_sharded_state(), events, nows)
    csv("streaming_overlapped_inference", t_inf / T * 1e6,
        f"periods={T};events_per_s={T * E / t_inf:.3e};"
        f"head=linear;classes={cfg_i.inference_classes}")

    # gather memory strategy inside the stream: full-block vs HBM-tiled
    # (interpret backend — CPU-relative numbers; the variant knob is what
    # is being exercised, selection happens at trace time)
    for variant in ("full", "hbm"):
        cfg_v = dataclasses.replace(cfg, kernel_backend="interpret",
                                    gather_variant=variant)
        sys_v = DFASystem(cfg_v, mesh)
        t_v = time_loop(sys_v.jit_stream(donate=True),
                        sys_v.init_sharded_state(), events, nows)
        csv(f"streaming_gather_{variant}", t_v / T * 1e6,
            f"periods={T};events_per_s={T * E / t_v:.3e};"
            f"backend=interpret;variant={variant}")


def _main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="bench-smoke mode (already applied pre-import)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    from benchmarks import common
    print("name,us_per_call,derived")
    run()
    if args.json:
        common.write_artifact(args.json, tag="streaming_periods")


if __name__ == "__main__":
    _main()
