"""Multi-period streaming throughput: ``run_periods`` (one lax.scan over T
monitoring periods, donated state) vs T sequential jit'd ``dfa_step``
calls, and — the headline row pair — the sequential scan vs
``run_periods_overlapped`` (period t's enrich half software-pipelined into
period t+1's scan body). The two drivers are output-identical (see
tests/test_overlap_equiv.py), so their ratio isolates what overlapping
ingest with enrich+inference buys: on TPU the enrich DMA/compute hides
behind the next period's line-rate work instead of eating its budget.

Also streams the same periods through both gather_enrich memory
strategies (interpret backend, full-block VMEM vs HBM-tiled DMA) so the
bench-smoke artifact records what the Tofino-scale memory strategy costs
inside the full pipeline, not just at kernel level (gather_scaling.py).

Multi-pod rows: one fixed 4-port trace streams through the 2D
(pod, shard) mesh fabric (flow_home="hash": per-port tables, hash homes,
two-stage exchange). ``streaming_multipod_ports4`` (a (1,1)-pod mesh
hosting all 4 ports) always runs and is the row the nightly
regression-gate matches; ``streaming_multipod_pods{2,4}`` join when the
host exposes enough devices — standalone, ``--pods N`` forces N host
devices before jax initializes:
``python benchmarks/streaming_periods.py --tiny --pods 4``.

TPU projection: the per-period byte budget is identical to dfa_throughput;
streaming changes the *dispatch* overhead, so the derived column reports
host-side us/period for both drivers plus the scan and overlap speedups.

Standalone: ``python benchmarks/streaming_periods.py --tiny --json out.json``
(also wired into benchmarks/run.py, so the CI bench-smoke artifact
includes the sequential-vs-overlapped rows).
"""
from __future__ import annotations

import dataclasses
import os
import sys

if __package__ in (None, ""):           # executed as a script: mirror
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))   # run.py's sys.path
    sys.path.insert(0, _root)
    if "--tiny" in sys.argv:            # before benchmarks.common binds TINY
        os.environ["REPRO_BENCH_TINY"] = "1"
    _n = 0                              # before jax initializes: force
    for _i, _a in enumerate(sys.argv):  # both --pods N and --pods=N
        try:
            if _a == "--pods":
                _n = int(sys.argv[_i + 1])
            elif _a.startswith("--pods="):
                _n = int(_a.split("=", 1)[1])
        except (IndexError, ValueError):
            _n = 0                      # argparse reports the usage error
    _flags = os.environ.get("XLA_FLAGS", "")
    if _n > 1 and "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + _flags).strip()

import jax
import jax.numpy as jnp

from benchmarks.common import TINY, csv, time_loop
from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.configs.dfa import REDUCED_MULTIPOD
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.data import scenarios as SC
from repro.launch.mesh import make_dfa_mesh

T = 4 if TINY else 16


def run():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh)
    E = cfg.event_block
    events, nows = PK.period_batches(system.n_shards, T, E, n_flows=32,
                                     flow_seed=0)

    stream = system.jit_stream(donate=True, overlapped=False)
    t_stream = time_loop(stream, system.init_sharded_state(), events, nows)

    # the software-pipelined driver on the SAME config/events: identical
    # outputs, different latency shape (enrich overlaps the next ingest)
    overlapped = system.jit_stream(donate=True, overlapped=True)
    t_ovl = time_loop(overlapped, system.init_sharded_state(), events,
                      nows)

    # donate the baseline too: both paths then elide the state copy and the
    # speedup row isolates per-period host dispatch overhead (time_loop
    # threads the carry, so donation is safe here)
    step = system.jit_step(donate=True)

    def sequential(state, events_, nows_):
        out = None
        for t in range(T):
            ev_t = {k: v[t] for k, v in events_.items()}
            out = step(state, ev_t, nows_[t])
            state = out.state
        return out

    t_seq = time_loop(sequential, system.init_sharded_state(), events, nows)

    csv("streaming_run_periods", t_stream / T * 1e6,
        f"periods={T};events_per_s={T * E / t_stream:.3e};"
        f"us_per_period={t_stream / T * 1e6:.1f}")
    csv("streaming_run_periods_overlapped", t_ovl / T * 1e6,
        f"periods={T};events_per_s={T * E / t_ovl:.3e};"
        f"us_per_period={t_ovl / T * 1e6:.1f}")
    csv("streaming_sequential_steps", t_seq / T * 1e6,
        f"periods={T};events_per_s={T * E / t_seq:.3e};"
        f"us_per_period={t_seq / T * 1e6:.1f}")
    csv("streaming_scan_speedup", 0.0,
        f"x={t_seq / t_stream:.2f};paper_period_ms=20")
    csv("streaming_overlap_speedup", 0.0,
        f"x={t_stream / t_ovl:.2f};vs=run_periods;"
        f"outputs_identical=true;paper_period_ms=20")

    # the overlapped driver with the immediate-inference hook armed: the
    # full paper headline (features -> verdicts in the same scan body)
    cfg_i = dataclasses.replace(cfg, overlap_periods=True,
                                inference_head="linear")
    sys_i = DFASystem(cfg_i, mesh)
    t_inf = time_loop(sys_i.jit_stream(donate=True),
                      sys_i.init_sharded_state(), events, nows)
    csv("streaming_overlapped_inference", t_inf / T * 1e6,
        f"periods={T};events_per_s={T * E / t_inf:.3e};"
        f"head=linear;classes={cfg_i.inference_classes}")

    # gather memory strategy inside the stream: full-block vs HBM-tiled
    # (interpret backend — CPU-relative numbers; the variant knob is what
    # is being exercised, selection happens at trace time)
    for variant in ("full", "hbm"):
        cfg_v = dataclasses.replace(cfg, kernel_backend="interpret",
                                    gather_variant=variant)
        sys_v = DFASystem(cfg_v, mesh)
        t_v = time_loop(sys_v.jit_stream(donate=True),
                        sys_v.init_sharded_state(), events, nows)
        csv(f"streaming_gather_{variant}", t_v / T * 1e6,
            f"periods={T};events_per_s={T * E / t_v:.3e};"
            f"backend=interpret;variant={variant}")

    run_pod_sweep()


def _pod_system(pods, shards, total_ports, events_per_port,
                exchange="padded"):
    ndev = pods * shards
    mesh = make_dfa_mesh(pods, shards, devices=jax.devices()[:ndev])
    cfg = dataclasses.replace(
        REDUCED_MULTIPOD, pods=pods,
        ports_per_pod=total_ports // pods,
        reporter_slots=128,
        flows_per_shard=512 // ndev,
        port_report_capacity=32,
        crosspod_exchange=exchange)
    system = DFASystem(cfg, mesh)
    ev, nows = SC.build("cross_pod_mix", total_ports, events_per_port, T)
    events = {k: jnp.asarray(v) for k, v in ev.items()}
    return system, events, jnp.asarray(nows)


def _pod_row(name, pods, shards, total_ports, events_per_port,
             exchange="padded"):
    """One (pods, shards) mesh streaming row over the same fixed port
    set: the us/period delta against the single-pod row IS the cross-pod
    routing overhead the nightly regression gate watches."""
    system, events, nows = _pod_system(pods, shards, total_ports,
                                       events_per_port, exchange)
    t = time_loop(system.jit_stream(donate=True),
                  system.init_sharded_state(), events, nows)
    E_tot = total_ports * events_per_port
    csv(name, t / T * 1e6,
        f"periods={T};pods={pods};shards={shards};ports={total_ports};"
        f"events_per_s={T * E_tot / t:.3e};flow_home=hash;"
        f"exchange={exchange}")
    return t


def _exchange_volume_rows(pods, shards, total_ports, events_per_port):
    """The ragged-exchange accounting rows the nightly artifact trends:

    * ``streaming_exchange_occupancy`` — fraction of the padded stage-2
      slot budget the compact exchange actually shipped; 1 - occupancy
      is the wire volume the ragged exchange saves.
    * ``streaming_crosspod_compact_ratio`` — cross-pod rows / delivered
      rows; on ``cross_pod_mix`` this is strictly between 0 and 1 (half
      the ports are pod-local), proving the compaction bites.

    Derived-only rows (us=0.0) with FIXED names, computed on the widest
    pod mesh the host exposes — pods=1 on the 1-device CI runner (both
    metrics 0: nothing crosses a 1-pod mesh) so the row set is
    device-count invariant and the vanished-row gate stays quiet."""
    import numpy as np
    system, events, nows = _pod_system(pods, shards, total_ports,
                                       events_per_port, "ragged")
    out = system.jit_stream(donate=False)(system.init_sharded_state(),
                                          events, nows)
    met = {k: np.asarray(v) for k, v in out.metrics.items()}
    sent = int(met["crosspod_sent"].sum())
    msgs = int(met["crosspod_messages"].sum())
    recv = int(met["reports_recv"].sum())
    slots = T * system.n_shards * pods * system.crosspod_capacity
    csv("streaming_exchange_occupancy", 0.0,
        f"frac={sent / slots:.4f};pods={pods};shards={shards};"
        f"crosspod_sent={sent};padded_slots={slots};"
        f"segment_capacity={system.crosspod_capacity}")
    csv("streaming_crosspod_compact_ratio", 0.0,
        f"x={sent / max(1, recv):.4f};pods={pods};crosspod_sent={sent};"
        f"reports_recv={recv};crosspod_messages={msgs}")


def run_pod_sweep():
    """Multi-pod (pod, shard) mesh rows over one fixed 4-port traffic
    trace. The 1-device (1,1)-pod mesh rows always run (they are the
    rows CI bench-smoke emits and the regression gate matches night over
    night); wider meshes join the sweep when the host exposes enough
    devices (standalone: ``--pods N`` forces N host devices before jax
    init). Each mesh is timed under both stage-2 exchange strategies —
    the padded/ragged pair is output-identical
    (tests/test_ragged_exchange.py), so the ratio isolates what segment
    compaction costs (host) or saves (wire volume, see the occupancy
    rows)."""
    total_ports, events_per_port = 4, 64 if TINY else 256
    t1 = _pod_row("streaming_multipod_ports4", 1, 1, total_ports,
                  events_per_port)
    tr1 = _pod_row("streaming_multipod_ragged_ports4", 1, 1, total_ports,
                   events_per_port, exchange="ragged")
    csv("streaming_ragged_overhead_ports4", 0.0,
        f"x={tr1 / t1:.2f};vs=streaming_multipod_ports4;"
        "outputs_identical=true")
    widest = 1
    for pods in (2, 4):
        if jax.device_count() < pods:
            continue
        widest = pods
        tp = _pod_row(f"streaming_multipod_pods{pods}", pods, 1,
                      total_ports, events_per_port)
        csv(f"streaming_crosspod_overhead_pods{pods}", 0.0,
            f"x={tp / t1:.2f};vs=streaming_multipod_ports4;"
            "same_port_set=true")
        trp = _pod_row(f"streaming_multipod_ragged_pods{pods}", pods, 1,
                       total_ports, events_per_port, exchange="ragged")
        csv(f"streaming_ragged_overhead_pods{pods}", 0.0,
            f"x={trp / tp:.2f};vs=streaming_multipod_pods{pods};"
            "outputs_identical=true")
    _exchange_volume_rows(widest, 1, total_ports, events_per_port)


def _main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="bench-smoke mode (already applied pre-import)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--pods", type=int, default=None, metavar="N",
                    help="force N host devices (applied pre-import) so "
                         "the pod sweep includes real (N, 1) meshes")
    args = ap.parse_args()
    from benchmarks import common
    print("name,us_per_call,derived")
    run()
    if args.json:
        common.write_artifact(args.json, tag="streaming_periods")


if __name__ == "__main__":
    _main()
