"""Multi-period streaming throughput: ``run_periods`` (one lax.scan over T
monitoring periods, donated state) vs T sequential jit'd ``dfa_step``
calls. This is the shape the paper's headline numbers imply — the feature
path running continuously, period after period, with the ring memory
updated in place — and the scan removes the per-period host dispatch the
sequential loop pays.

Also streams the same periods through both gather_enrich memory
strategies (interpret backend, full-block VMEM vs HBM-tiled DMA) so the
bench-smoke artifact records what the Tofino-scale memory strategy costs
inside the full pipeline, not just at kernel level (gather_scaling.py).

TPU projection: the per-period byte budget is identical to dfa_throughput;
streaming changes the *dispatch* overhead, so the derived column reports
host-side us/period for both drivers plus the scan speedup.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import TINY, csv, time_loop
from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK

T = 4 if TINY else 16


def _period_events(system, T_, events_per_shard):
    flows = PK.gen_flows(32, seed=0)
    evs = [PK.events_for_shards(flows, t, system.n_shards, events_per_shard)
           for t in range(T_)]
    events = {k: jnp.stack([jnp.asarray(e[k]) for e in evs])
              for k in evs[0]}
    nows = jnp.asarray([(t + 1) * 100_000 for t in range(T_)], jnp.uint32)
    return events, nows


def run():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh)
    E = cfg.event_block
    events, nows = _period_events(system, T, E)

    stream = system.jit_stream(donate=True)
    t_stream = time_loop(stream, system.init_sharded_state(), events, nows)

    # donate the baseline too: both paths then elide the state copy and the
    # speedup row isolates per-period host dispatch overhead (time_loop
    # threads the carry, so donation is safe here)
    step = system.jit_step(donate=True)

    def sequential(state, events_, nows_):
        out = None
        for t in range(T):
            ev_t = {k: v[t] for k, v in events_.items()}
            state, *rest = step(state, ev_t, nows_[t])
            out = rest
        return (state, *out)

    t_seq = time_loop(sequential, system.init_sharded_state(), events, nows)

    csv("streaming_run_periods", t_stream / T * 1e6,
        f"periods={T};events_per_s={T * E / t_stream:.3e};"
        f"us_per_period={t_stream / T * 1e6:.1f}")
    csv("streaming_sequential_steps", t_seq / T * 1e6,
        f"periods={T};events_per_s={T * E / t_seq:.3e};"
        f"us_per_period={t_seq / T * 1e6:.1f}")
    csv("streaming_scan_speedup", 0.0,
        f"x={t_seq / t_stream:.2f};paper_period_ms=20")

    # gather memory strategy inside the stream: full-block vs HBM-tiled
    # (interpret backend — CPU-relative numbers; the variant knob is what
    # is being exercised, selection happens at trace time)
    for variant in ("full", "hbm"):
        cfg_v = dataclasses.replace(cfg, kernel_backend="interpret",
                                    gather_variant=variant)
        sys_v = DFASystem(cfg_v, mesh)
        t_v = time_loop(sys_v.jit_stream(donate=True),
                        sys_v.init_sharded_state(), events, nows)
        csv(f"streaming_gather_{variant}", t_v / T * 1e6,
            f"periods={T};events_per_s={T * E / t_v:.3e};"
            f"backend=interpret;variant={variant}")


if __name__ == "__main__":
    run()
