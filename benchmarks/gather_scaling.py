"""gather_enrich scaling sweep — flows/shard F up to the paper's 2^17.

The question the tentpole answers: at what F does the full-block kernel
(whole ring region as one VMEM block) stop being viable, and what does the
HBM-resident tiled kernel cost at scale? This sweep times, per F:

* ref                 — jnp oracle (gather + derive, (R,H,16) in HBM)
* interpret/full      — full-block kernel, only while its working set
                        fits the VMEM budget (beyond that the real TPU
                        compile would fail — the sweep records the wall)
* interpret/hbm       — HBM-tiled kernel, every F (its VMEM footprint is
                        O(report_tile * H * 16), independent of F)

plus the analytic VMEM crossover F from the budget formula — the bench-
smoke artifact trends both the measured rows and the crossover per commit.
CPU wall numbers are relative; the derived column carries a TPU v5e HBM
projection of the per-report gather traffic (H * 68 B enriched straight
out of the ring, no (R, H, 16) round trip).

Standalone: ``python benchmarks/gather_scaling.py --tiny --json out.json``
(also wired into benchmarks/run.py, so the CI bench-smoke artifact
includes the per-F records).
"""
from __future__ import annotations

import dataclasses
import os
import sys

if __package__ in (None, ""):           # executed as a script: mirror
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))   # run.py's sys.path
    sys.path.insert(0, _root)
    if "--tiny" in sys.argv:            # before benchmarks.common binds TINY
        os.environ["REPRO_BENCH_TINY"] = "1"

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, TINY, csv, time_it
from repro.configs import get_dfa_config
from repro.kernels import dispatch
from repro.kernels.gather_enrich.ops import gather_enrich

H = 8                                    # acceptance shape: 2^17 x 8
R = 256 if TINY else 1024
REPORT_TILE = 128
F_SWEEP = ([1 << 12, 1 << 14, 1 << 17] if TINY else
           [1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 17])


def _case(F, rng):
    mem = jnp.asarray(rng.integers(0, 1 << 20, size=(F, H, 16),
                                   dtype=np.uint64).astype(np.uint32))
    ev = jnp.asarray(rng.random((F, H)) > 0.3)
    lf = jnp.asarray(rng.integers(0, F, size=R).astype(np.int32))
    return mem, ev, lf


def _timed(mem, ev, lf, cfg, backend, variant=None):
    fn = jax.jit(lambda m, e, l: gather_enrich(m, e, l, cfg,
                                               backend=backend,
                                               variant=variant))
    return time_it(fn, mem, ev, lf)


def run(tune=None):
    cfg = dataclasses.replace(get_dfa_config(), history=H,
                              flow_tile=REPORT_TILE)
    budget = cfg.vmem_budget_mb * dispatch.VMEM_BYTES_PER_MB
    rng = np.random.default_rng(0)
    reg = _open_registry(tune)
    # per-report ring traffic the fused path moves: H x (64 B entry + 4 B
    # validity) in, derived_dim x 4 B out — the v5e HBM-bound floor
    bytes_per_report = H * (16 * 4 + 4) + cfg.derived_dim * 4
    for F in F_SWEEP:
        mem, ev, lf = _case(F, rng)
        full_fits = dispatch.gather_vmem_bytes(
            "full", F, H, REPORT_TILE, cfg.derived_dim) <= budget
        auto = dispatch.resolve_gather_variant(None, cfg, F, H,
                                               REPORT_TILE,
                                               cfg.derived_dim)
        variants = [("ref", "ref", None), ("interpret", "hbm", "hbm")]
        if full_fits:
            variants.append(("interpret", "full", "full"))
        walls = {}
        for backend, label, variant in variants:
            t = _timed(mem, ev, lf, cfg, backend, variant)
            walls[label] = t
            tpu_us = R * bytes_per_report / HBM_BW * 1e6
            csv(f"gather_scaling_F{F}_{label}", t * 1e6,
                f"flows_per_s={R / t:.3e};R={R};H={H};auto={auto};"
                f"tpu_v5e_us={tpu_us:.2f}")
        if reg is not None and full_fits:
            win = min(("full", "hbm"), key=walls.get)
            reg.record("gather_enrich.variant", "interpret",
                       (F, H, REPORT_TILE, cfg.derived_dim), win,
                       walls[win] * 1e6, source="gather_scaling")
        if not full_fits:
            # 0.0, not NaN: NaN rows would make the bench-smoke JSON
            # artifact unparseable by strict consumers (jq, JSON.parse)
            csv(f"gather_scaling_F{F}_full", 0.0,
                f"skipped=ring_region_exceeds_vmem;"
                f"ring_mb={dispatch.ring_vmem_bytes(F, H) / 2**20:.1f};"
                f"budget_mb={cfg.vmem_budget_mb}")
    # analytic crossover: largest power-of-two F whose full-block working
    # set still fits the budget — auto flips to hbm one step above
    Fx = 1
    while dispatch.gather_vmem_bytes("full", Fx * 2, H, REPORT_TILE,
                                     cfg.derived_dim) <= budget:
        Fx *= 2
    csv("gather_scaling_vmem_crossover", 0.0,
        f"max_full_F={Fx};budget_mb={cfg.vmem_budget_mb};H={H};"
        f"paper_F={1 << 17};paper_variant="
        f"{dispatch.resolve_gather_variant(None, cfg, 1 << 17, H, REPORT_TILE, cfg.derived_dim)}")
    if reg is not None:
        # report_tile mini-sweep at the smallest F on the F-independent
        # hbm kernel: the winner is keyed by report count R, matching
        # dispatch.resolve_report_tile's (reports,) lookup
        mem, ev, lf = _case(F_SWEEP[0], rng)
        for rt in (64, 128, 256):
            cfgt = dataclasses.replace(cfg, flow_tile=rt)
            t = _timed(mem, ev, lf, cfgt, "interpret", "hbm")
            reg.record("gather_enrich.report_tile", "interpret", (R,),
                       min(rt, R), t * 1e6, source="gather_scaling")
        reg.save(tune)


def _open_registry(tune):
    """Load-merge semantics: an existing registry keeps entries this
    sweep doesn't re-measure, and re-measured keys keep the faster of
    the two (TuningRegistry.record is fastest-wins)."""
    if tune is None:
        return None
    from repro.kernels import tuning
    if os.path.exists(tune):
        return tuning.TuningRegistry.load(tune)
    return tuning.TuningRegistry()


def main():
    """Standalone entry: python benchmarks/gather_scaling.py [--tiny]
    [--json PATH]. The --tiny env contract matches run.py (the flag is
    consumed before benchmarks.common binds TINY, via the script
    bootstrap above)."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--tune", default=None, metavar="PATH",
                    help="record the measured winners (full-vs-hbm "
                         "variant per F, report_tile at the smallest F) "
                         "into a tuned-config registry consulted by "
                         "dispatch.resolve_*")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tune=args.tune)
    if args.json:
        from benchmarks import common
        common.write_artifact(args.json, tag="gather_scaling")


if __name__ == "__main__":
    main()
