"""Train a flow classifier on DFA-enriched features (the paper's 'training
new models on smaller intervals' future-work direction, §VI).

    PYTHONPATH=src python examples/train_flow_classifier.py

Generates two synthetic traffic classes, runs them through the full DFA
pipeline, and trains a small MLP on the enriched feature vectors with the
framework's own optimizer. Reports accuracy on held-out periods.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.configs.base import TrainConfig
from repro.core.pipeline import DFASystem
from repro.core.reporter import hash_slot
from repro.optim import adamw
from repro.optim.schedule import lr_at


def collect_features(system, periods=6, n_flows=32, seed=0):
    rng = np.random.default_rng(seed)
    state = system.init_state()
    cfg = system.cfg
    step = jax.jit(system.dfa_step, donate_argnums=(0,))
    X, y = [], []
    keys = rng.integers(1, 2**31, (n_flows, 5)).astype(np.uint32)
    lab = rng.integers(0, 2, n_flows)
    slot2lab = {int(np.asarray(hash_slot(jnp.asarray(keys[i]),
                                         cfg.flows_per_shard))): lab[i]
                for i in range(n_flows)}
    for period in range(periods):
        evs = []
        for i in range(n_flows):
            cnt = 24 if lab[i] else 6
            ts = np.sort(rng.integers(0, 20_000, cnt)) + period * 100_000
            size = (rng.integers(1000, 1514, cnt) if lab[i]
                    else rng.integers(40, 200, cnt))
            evs.append((ts, size, np.tile(keys[i], (cnt, 1))))
        ts = np.concatenate([e[0] for e in evs]).astype(np.uint32)
        order = np.argsort(ts, kind="stable")
        ev = {"ts": jnp.asarray(ts[order]),
              "size": jnp.asarray(np.concatenate(
                  [e[1] for e in evs]).astype(np.uint32)[order]),
              "five_tuple": jnp.asarray(np.concatenate(
                  [e[2] for e in evs]).astype(np.uint32)[order]),
              "valid": jnp.ones(len(ts), bool)}
        out = step(state, ev, jnp.uint32((period + 1) * 100_000))
        state = out.state
        em = np.asarray(out.mask)
        en = np.asarray(out.enriched)[em]
        fid = np.asarray(out.flow_ids)[em]
        for j in range(len(fid)):
            sl = int(fid[j]) % cfg.flows_per_shard
            if sl in slot2lab:
                X.append(en[j])
                y.append(slot2lab[sl])
    return np.asarray(X, np.float32), np.asarray(y, np.int32)


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh)
    with mesh:
        X, y = collect_features(system)
    X = np.log1p(np.abs(X))
    X = (X - X.mean(0)) / (X.std(0) + 1e-6)
    n = len(X)
    tr = slice(0, int(n * 0.7))
    te = slice(int(n * 0.7), n)
    print(f"collected {n} enriched feature vectors "
          f"({cfg.derived_dim}-dim) through the DFA pipeline")

    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=200,
                      weight_decay=0.01)
    key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    params = {"w1": 0.1 * jax.random.normal(k1, (cfg.derived_dim, 64)),
              "b1": jnp.zeros(64),
              "w2": 0.1 * jax.random.normal(k2, (64, 2)),
              "b2": jnp.zeros(2)}
    opt = adamw.init(params, tcfg)

    def loss_fn(p, xb, yb):
        h = jax.nn.relu(xb @ p["w1"] + p["b1"])
        lg = h @ p["w2"] + p["b2"]
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(len(yb)), yb])

    @jax.jit
    def train_step(p, o, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        p, o, _ = adamw.apply(p, g, o, tcfg, lr_at(o.step, tcfg))
        return p, o, l

    Xtr, ytr = jnp.asarray(X[tr]), jnp.asarray(y[tr])
    for step in range(200):
        params, opt, l = train_step(params, opt, Xtr, ytr)
        if step % 50 == 0:
            print(f"step {step:3d} loss {float(l):.4f}")

    def acc(p, xb, yb):
        h = jax.nn.relu(xb @ p["w1"] + p["b1"])
        pred = jnp.argmax(h @ p["w2"] + p["b2"], -1)
        return float((pred == yb).mean())

    a = acc(params, jnp.asarray(X[te]), jnp.asarray(y[te]))
    print(f"held-out accuracy: {a:.3f} (mice vs elephants from Table-I "
          f"moment features)")
    assert a > 0.85


if __name__ == "__main__":
    main()
