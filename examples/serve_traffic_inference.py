"""End-to-end driver (the paper's headline use case): DFA telemetry feeding
IMMEDIATE ML inference on the accelerator — the enrich half's inference
hook consumes the (R, derived_dim) features in the same scan body that
ingests the NEXT monitoring period (run_periods_overlapped), so verdicts
never serialize against collection. A small LM backbone then consumes the
most suspicious flows as a second, heavier stage.

    PYTHONPATH=src python examples/serve_traffic_inference.py

Pipeline: packets -> overlapped period stream
            -> enriched (T, R, 96) features
            -> per-flow verdict logits from the models.registry flow head
               (the hook, inside the stream)
            -> the top flows' verdict classes become the prompt tokens
               for the granite-3-2b (reduced) backbone
               -> batched prefill+decode.
"""
import sys

sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_config, get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.launch.serve import serve
from repro.models.registry import get_model


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    # arm the streaming hook: overlapped periods + linear verdict head
    dfa_cfg = dataclasses.replace(get_dfa_config(reduced=True),
                                  overlap_periods=True,
                                  inference_head="linear",
                                  inference_classes=8)
    system = DFASystem(dfa_cfg, mesh)
    T = 4
    events, nows = PK.period_batches(system.n_shards, T, 512, n_flows=24,
                                     flow_seed=3)

    cfg = get_config("granite-3-2b", reduced=True)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(0))

    t0 = time.time()
    with mesh:
        # one jit'd call streams all T periods, each period's verdicts
        # computed while the next period's packets ingest
        stream = system.jit_stream(donate=True)
        state, enriched, flow_ids, emask, metrics, preds = stream(
            system.init_sharded_state(), events, nows)
        em = np.asarray(emask)
        verdicts = np.asarray(jnp.argmax(preds, axis=-1))
        scores = np.asarray(jax.nn.logsumexp(preds, axis=-1))
        # stage 2: the 4 highest-scoring flows of the last period go to
        # the LM backbone; each flow's prompt is its verdict class id
        # (offset past token 0) — a flow-dependent prefix, so different
        # telemetry produces different stage-2 inputs
        last = T - 1
        rows = np.nonzero(em[last])[0]
        rows = rows[np.argsort(-scores[last][rows])][:4]
        B = max(1, len(rows))
        vcls = (verdicts[last][rows] if len(rows)
                else np.zeros(1, np.int64))
        vtok = jnp.asarray(vcls.reshape(B, 1) + 1, jnp.int32)
        prompt = {"tokens": jnp.concatenate(
            [jnp.zeros((B, 4), jnp.int32),
             jnp.tile(vtok, (1, 4))], axis=1)}
        toks, tps = serve(model, params, prompt, 8, 8, 32)
    dt = time.time() - t0

    sent = np.asarray(metrics["reports_sent"])
    print(f"{T} overlapped periods: reports/period {sent.tolist()} "
          f"(metrics are per-period deltas)")
    for t in range(T):
        v, c = np.unique(verdicts[t][em[t]], return_counts=True)
        print(f"  period {t}: {int(em[t].sum()):3d} flows enriched, "
              f"verdict histogram {dict(zip(v.tolist(), c.tolist()))}")
    print(f"stage-2 batch: {B} flows {np.asarray(flow_ids[last])[rows]}")
    print(f"verdict tokens per flow: {np.asarray(toks)[:, :6]}")
    print(f"end-to-end (telemetry->verdicts->tokens) {dt*1000:.0f} ms; "
          f"decode {tps:.1f} tok/s; paper target: sub-20 ms periods "
          f"(on TPU, not this CPU container)")


if __name__ == "__main__":
    main()
