"""End-to-end ONLINE serving (the paper's headline use case): a continuous
period loop under a latency SLO — packets replayed at a configured offered
rate, host-staged through the double-buffered ingest ring (period t+1's
events upload while period t computes), per-flow verdicts from the
streaming inference hook every period, per-period wall latency measured
against the 20 ms budget with exact drop accounting. A small LM backbone
then consumes the most suspicious flows of the final period as a second,
heavier stage.

    PYTHONPATH=src python examples/serve_traffic_inference.py

Pipeline: trace-replay source (paced events/s)
            -> HostIngestRing (double-buffered jax.device_put)
            -> donated dfa_step per period: ingest -> enrich
               -> per-flow verdict logits (models.registry flow head)
            -> ServingReport: p50/p99/p999 period latency, SLO
               violations, offered == processed + dropped
            -> the top flows' verdict classes become the prompt tokens
               for the granite-3-2b (reduced) backbone
               -> batched prefill+decode.
"""
import sys

sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_config, get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.launch.serve import serve
from repro.launch.serving import ServingLoop, build_source
from repro.models.registry import get_model


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    # arm the streaming inference hook + the serving knobs: offer events
    # 25% above the batch-capacity rate so backpressure (queueing + tail
    # drop) is actually exercised, not just configured
    dfa_cfg = dataclasses.replace(get_dfa_config(reduced=True),
                                  inference_head="linear",
                                  inference_classes=8)
    capacity_eps = (dfa_cfg.event_block
                    / (dfa_cfg.monitoring_period_us / 1e6))
    dfa_cfg = dataclasses.replace(dfa_cfg,
                                  serve_offered_eps=1.25 * capacity_eps,
                                  serve_queue_events=2 * dfa_cfg.event_block,
                                  drop_policy="newest")
    system = DFASystem(dfa_cfg, mesh)
    periods = 16
    events, nows = PK.period_batches(system.n_shards, 4,
                                     dfa_cfg.event_block, n_flows=24,
                                     flow_seed=3)

    cfg = get_config("granite-3-2b", reduced=True)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(0))

    t0 = time.time()
    with mesh:
        loop = ServingLoop(system, build_source(system, events, nows))
        report = loop.run(periods)          # drains the queue on shutdown
        out = report.last                    # StepOutputs, final period
        em = np.asarray(out.mask)
        verdicts = np.asarray(jnp.argmax(out.preds, axis=-1))
        scores = np.asarray(jax.nn.logsumexp(out.preds, axis=-1))
        # stage 2: the 4 highest-scoring flows of the final period go to
        # the LM backbone; each flow's prompt is its verdict class id
        # (offset past token 0) — a flow-dependent prefix, so different
        # telemetry produces different stage-2 inputs
        rows = np.nonzero(em)[0]
        rows = rows[np.argsort(-scores[rows])][:4]
        B = max(1, len(rows))
        vcls = (verdicts[rows] if len(rows) else np.zeros(1, np.int64))
        vtok = jnp.asarray(vcls.reshape(B, 1) + 1, jnp.int32)
        prompt = {"tokens": jnp.concatenate(
            [jnp.zeros((B, 4), jnp.int32),
             jnp.tile(vtok, (1, 4))], axis=1)}
        toks, tps = serve(model, params, prompt, 8, 8, 32)
    dt = time.time() - t0

    lat = report.latency
    assert report.balanced, "accounting must close after drain"
    print(f"{report.periods} serving periods (+{report.drained_periods} "
          f"drain), SLO budget {report.budget_us / 1000:.0f} ms")
    print(f"offered {report.offered} == processed {report.processed} "
          f"+ dropped {report.dropped} (exact, drop_policy="
          f"{system.cfg.drop_policy})")
    print(f"period latency: p50 {lat['p50'] / 1000:.1f} ms, "
          f"p99 {lat['p99'] / 1000:.1f} ms, "
          f"p999 {lat['p999'] / 1000:.1f} ms; "
          f"{report.violations} budget violations "
          f"(CPU container — TPU is the SLO target)")
    print(f"sustained {report.sustained_eps:.3e} events/s of "
          f"{system.cfg.serve_offered_eps:.3e} offered")
    v, c = np.unique(verdicts[em], return_counts=True)
    print(f"final period: {int(em.sum())} flows enriched, verdict "
          f"histogram {dict(zip(v.tolist(), c.tolist()))}")
    print(f"stage-2 batch: {B} flows {np.asarray(out.flow_ids)[rows]}")
    print(f"verdict tokens per flow: {np.asarray(toks)[:, :6]}")
    print(f"end-to-end (serve loop + verdicts -> tokens) {dt*1000:.0f} ms; "
          f"decode {tps:.1f} tok/s; paper target: sub-20 ms periods")


if __name__ == "__main__":
    main()
