"""End-to-end driver (the paper's headline use case): DFA telemetry feeding
IMMEDIATE ML inference on the accelerator — batched requests against a
small LM backbone whose prefix is the enriched flow features.

    PYTHONPATH=src python examples/serve_traffic_inference.py

Pipeline: packets -> dfa_step -> enriched (R, 96) features -> projected to
backbone embedding space as prefix "tokens" -> batched prefill+decode on
the granite-3-2b (reduced) backbone -> per-flow verdict tokens.
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_config, get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK
from repro.launch.serve import build_cache, serve
from repro.models.registry import get_model


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    dfa_cfg = get_dfa_config(reduced=True)
    system = DFASystem(dfa_cfg, mesh)
    state = system.init_state()
    dfa = jax.jit(system.dfa_step, donate_argnums=(0,))

    cfg = get_config("granite-3-2b", reduced=True)
    model = get_model(cfg, mesh)
    params = model.init(jax.random.key(0))
    # feature -> embedding projection (the "enrichment adapter")
    key = jax.random.key(1)
    W_feat = 0.05 * jax.random.normal(key, (dfa_cfg.derived_dim,
                                            cfg.d_model), jnp.float32)

    flows = PK.gen_flows(24, seed=3)
    t0 = time.time()
    with mesh:
        ev = PK.events_for_shards(flows, 0, system.n_shards, 512)
        state, enriched, flow_ids, emask, metrics = dfa(
            state, {k: jnp.asarray(v) for k, v in ev.items()},
            jnp.uint32(100_000))
        # take up to 4 received flows as one inference batch
        idx = np.nonzero(np.asarray(emask))[0][:4]
        feats = jnp.asarray(np.asarray(enriched)[idx])
        feats = jnp.log1p(jnp.abs(feats))            # squash magnitudes
        prefix = (feats @ W_feat).astype(jnp.bfloat16)   # (B, d_model)
        B = prefix.shape[0]
        # the feature vector becomes a 4-position prefix "prompt"
        patches = jnp.tile(prefix[:, None, :], (1, 4, 1))
        prompt = {"tokens": jnp.zeros((B, 4), jnp.int32),
                  "patches": patches}
        # granite has no vlm path; emulate prefix by summing into embeds:
        prompt = {"tokens": jnp.concatenate(
            [jnp.zeros((B, 4), jnp.int32),
             jnp.ones((B, 4), jnp.int32)], axis=1)}
        toks, tps = serve(model, params, prompt, 8, 8, 32)
    dt = time.time() - t0
    print(f"flows observed -> reports {int(metrics['reports_sent'])} "
          f"-> inference batch {B}")
    print(f"verdict tokens per flow: {np.asarray(toks)[:, :6]}")
    print(f"end-to-end (telemetry->tokens) {dt*1000:.0f} ms; "
          f"decode {tps:.1f} tok/s; paper target: sub-20 ms periods "
          f"(on TPU, not this CPU container)")


if __name__ == "__main__":
    main()
