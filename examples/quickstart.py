"""Quickstart: the paper's loop in 60 lines — packets in, per-flow Table-I
features extracted at the reporter, DTA-routed to collector shards, placed
in the Fig-4 ring buffer, enriched, ready for inference.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.configs import get_dfa_config
from repro.core.pipeline import DFASystem
from repro.data import packets as PK


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_dfa_config(reduced=True)
    system = DFASystem(cfg, mesh)
    state = system.init_state()
    step = jax.jit(system.dfa_step, donate_argnums=(0,))

    flows = PK.gen_flows(32, seed=0)
    print(f"monitoring {len(flows['rate'])} flows, "
          f"period={cfg.monitoring_period_us/1000:.0f} ms, "
          f"history={cfg.history} entries/flow")
    with mesh:
        for period in range(3):
            ev = PK.events_for_shards(flows, period, system.n_shards, 512,
                                      window_us=cfg.monitoring_period_us)
            now = jnp.uint32((period + 1) * cfg.monitoring_period_us * 2)
            out = step(
                state, {k: jnp.asarray(v) for k, v in ev.items()}, now)
            state, metrics = out.state, out.metrics
            got = int(np.asarray(out.mask).sum())
            en = np.asarray(out.enriched)[np.asarray(out.mask)]
            print(f"period {period}: {int(metrics['reports_sent'])} reports"
                  f" -> {got} feature vectors "
                  f"(mean pkts/flow {en[:, 0].mean():.1f}, "
                  f"mean rate {en[:, 12].mean()/1e6:.2f} Mb/s, "
                  f"checksum errors {int(metrics['bad_checksum'])})")
    ring = np.asarray(state.collector.entry_valid).sum()
    print(f"collector ring entries written: {ring} "
          f"(64 B each, verbatim RoCEv2 payloads)")


if __name__ == "__main__":
    main()
