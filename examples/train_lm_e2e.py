"""End-to-end LM training driver: a few hundred steps of a reduced
architecture with the full production substrate — fault-tolerant loop,
async checkpointing, step-keyed data, straggler watchdog.

    PYTHONPATH=src python examples/train_lm_e2e.py [--arch granite-3-2b]
                                                   [--steps 200]

(On a real TPU pod the same driver runs the full configs: swap
make_local_mesh for make_production_mesh and drop --reduced.)
"""
import sys

sys.path.insert(0, "src")

import argparse

from repro.launch import train as TR


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    losses = TR.main(["--arch", args.arch, "--reduced",
                      "--steps", str(args.steps),
                      "--batch", "8", "--seq", "128", "--lr", "3e-3",
                      "--ckpt-dir", "/tmp/repro_example_ckpt",
                      "--ckpt-every", "50", "--log-every", "20"])
    drop = losses[0] - sum(losses[-10:]) / 10
    print(f"loss dropped {drop:.3f} over {args.steps} steps "
          f"(checkpoints in /tmp/repro_example_ckpt)")


if __name__ == "__main__":
    main()
